"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.configs.base import MoESpec
from repro.models import Model, train_batch_specs
from repro.models.params import param_count

KEY = jax.random.PRNGKey(0)
B, S, EXTRA = 2, 24, 3


def _batches(cfg, Sfull, Spre, tok):
    full = {"tokens": tok}
    pre = {"tokens": tok[:, :Spre]}
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 16, cfg.d_model), jnp.float32)
        full["enc_embeds"] = enc
        pre["enc_embeds"] = enc
    if cfg.family == "vlm":
        P = 4
        patch = jax.random.normal(jax.random.fold_in(KEY, 3), (B, P, cfg.d_model))

        def mpos(L):
            p = jnp.broadcast_to(jnp.arange(L)[None, :, None], (B, L, 1))
            return jnp.broadcast_to(p, (B, L, 3)).astype(jnp.int32)

        full = {"tokens": tok[:, : Sfull - P], "patch_embeds": patch, "positions": mpos(Sfull)}
        pre = {"tokens": tok[:, : Spre - P], "patch_embeds": patch, "positions": mpos(Spre)}
    return full, pre


@pytest.fixture(scope="module", params=list_archs())
def arch_setup(request):
    cfg = get_arch(request.param).reduced()
    if cfg.family == "moe":
        # no-drop capacity so prefill/decode agree exactly
        cfg = dataclasses.replace(
            cfg, moe=MoESpec(cfg.moe.n_experts, cfg.moe.top_k, capacity=float(cfg.moe.n_experts))
        )
    model = Model(cfg)
    params = model.init(KEY)
    return request.param, cfg, model, params


def test_smoke_train_step(arch_setup):
    """One forward/loss step on CPU: output shapes + finite values."""
    name, cfg, model, params = arch_setup
    tok = jax.random.randint(jax.random.fold_in(KEY, 7), (B, S), 0, cfg.vocab)
    batch, _ = _batches(cfg, S, S, tok)
    batch["labels"] = jnp.zeros((B, S), jnp.int32)
    logits = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


def test_grads_flow_and_finite(arch_setup):
    name, cfg, model, params = arch_setup
    tok = jax.random.randint(jax.random.fold_in(KEY, 8), (B, S), 0, cfg.vocab)
    batch, _ = _batches(cfg, S, S, tok)
    batch["labels"] = tok
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # at least 90% of parameter tensors receive nonzero gradient
    nz = sum(float(jnp.abs(g).max()) > 0 for g in flat)
    assert nz / len(flat) > 0.9


def test_prefill_decode_matches_forward(arch_setup):
    """Teacher-forced forward == prefill + step-by-step decode."""
    name, cfg, model, params = arch_setup
    Sfull = S + EXTRA
    tok = jax.random.randint(jax.random.fold_in(KEY, 1), (B, Sfull), 0, cfg.vocab)
    full, pre = _batches(cfg, Sfull, S, tok)
    logits_full = model.forward(params, full)
    last, state = model.prefill(params, pre)
    np.testing.assert_allclose(
        last.astype(jnp.float32), logits_full[:, S - 1].astype(jnp.float32),
        atol=1e-4, rtol=1e-4,
    )

    def pad_kv(arr, to):
        padw = [(0, 0)] * arr.ndim
        padw[2] = (0, to - arr.shape[2])
        return jnp.pad(arr, padw)

    if cfg.family in ("dense", "moe", "vlm"):
        state = (pad_kv(state[0], Sfull), pad_kv(state[1], Sfull))
    elif cfg.family == "encdec":
        state = {
            "self": (pad_kv(state["self"][0], Sfull), pad_kv(state["self"][1], Sfull)),
            "cross": state["cross"],
        }
    for t in range(EXTRA):
        pos = S + t
        nxt = tok[:, pos - 4] if cfg.family == "vlm" else tok[:, pos]
        logits, state = model.decode_step(params, state, nxt, jnp.int32(pos))
        np.testing.assert_allclose(
            logits.astype(jnp.float32), logits_full[:, pos].astype(jnp.float32),
            atol=1e-4, rtol=1e-4,
        )


def test_full_config_registered_param_counts():
    """Full configs expose the published hyper-parameters."""
    expect = {
        "qwen1.5-110b": (80, 8192, 64, 8),
        "deepseek-67b": (95, 8192, 64, 8),
        "yi-34b": (60, 7168, 56, 8),
        "smollm-135m": (30, 576, 9, 3),
        "qwen2-vl-2b": (28, 1536, 12, 2),
        "recurrentgemma-2b": (26, 2560, 10, 1),
        "mamba2-130m": (24, 768, 0, 0),
        "dbrx-132b": (40, 6144, 48, 8),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16),
        "seamless-m4t-large-v2": (24, 1024, 16, 16),
    }
    for name, (L, d, H, K) in expect.items():
        cfg = get_arch(name)
        assert cfg.n_layers == L, name
        assert cfg.d_model == d, name
        if H:
            assert cfg.n_heads == H and cfg.n_kv_heads == K, name


@pytest.mark.parametrize(
    "name,lo,hi",
    [
        ("smollm-135m", 0.10e9, 0.20e9),
        ("mamba2-130m", 0.10e9, 0.21e9),
        ("yi-34b", 30e9, 39e9),
        ("deepseek-67b", 60e9, 72e9),
        ("qwen1.5-110b", 100e9, 120e9),
        ("dbrx-132b", 120e9, 145e9),
        ("qwen2-vl-2b", 1.2e9, 2.4e9),
        ("recurrentgemma-2b", 2.0e9, 3.4e9),
    ],
)
def test_spec_param_counts_match_published_scale(name, lo, hi):
    """Materialisable spec tree is the size the model card says."""
    model = Model(get_arch(name))
    n = param_count(model.specs())
    assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"


@pytest.mark.parametrize("arch", ["dbrx-132b", "moonshot-v1-16b-a3b"])
def test_moe_dispatch_impls_identical(arch):
    """vmap and batched MoE dispatch are numerically identical (§Perf)."""
    from repro.models import ExecConfig

    cfg = get_arch(arch).reduced()
    tok = jax.random.randint(jax.random.fold_in(KEY, 9), (2, 24), 0, cfg.vocab)
    m1 = Model(cfg, ExecConfig(moe_impl="vmap", remat="none"))
    m2 = Model(cfg, ExecConfig(moe_impl="batched", remat="none"))
    params = m1.init(KEY)
    l1 = m1.forward(params, {"tokens": tok})
    l2 = m2.forward(params, {"tokens": tok})
    np.testing.assert_allclose(
        l1.astype(jnp.float32), l2.astype(jnp.float32), atol=1e-5
    )


def test_train_batch_specs_cover_all_cells():
    from repro.configs.shapes import SHAPES

    for arch in list_archs():
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            specs = train_batch_specs(cfg, shape)
            assert "tokens" in specs and "labels" in specs
