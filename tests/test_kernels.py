"""Pallas kernels vs jnp oracles — shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.models.layers import chunked_attention

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # B, S, T, H, K, hd, causal, window, bq, bk
    (2, 128, 128, 4, 2, 64, True, 0, 64, 64),
    (1, 256, 256, 8, 8, 64, True, 0, 128, 128),
    (2, 128, 128, 4, 1, 32, False, 0, 64, 64),
    (1, 256, 256, 4, 2, 64, True, 64, 64, 64),
    (2, 96, 200, 4, 4, 128, False, 0, 64, 128),  # uneven, cross
    (1, 64, 64, 2, 2, 256, True, 0, 64, 64),  # big head dim
]


@pytest.mark.parametrize("case", ATTN_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    B, S, T, H, K, hd, causal, window, bq, bk = case
    q = jax.random.normal(KEY, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, K, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, K, hd), dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=bq, block_kv=bk, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


def test_chunked_xla_attention_matches_oracle_with_kvlen_and_offset():
    B, S, T, H, K, hd = 2, 24, 64, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, K, hd))
    out = chunked_attention(
        q, k, v, q_offset=8, kv_len=jnp.int32(30), causal=True, kv_chunk=16
    )
    want = ref.attention_ref(q, k, v, q_offset=8, kv_len=jnp.int32(30), causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_unrolled_causal_attention_matches_scan():
    B, S, H, K, hd = 1, 128, 4, 2, 32
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, K, hd))
    a = chunked_attention(q, k, v, causal=True, kv_chunk=32, unroll_causal=True)
    b = chunked_attention(q, k, v, causal=True, kv_chunk=32, unroll_causal=False)
    np.testing.assert_allclose(a, b, atol=1e-6)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, nh, hp, ng, ds, chunk
    (2, 128, 4, 16, 1, 32, 32),
    (1, 256, 8, 64, 2, 64, 64),
    (2, 64, 4, 32, 4, 16, 16),
    (1, 128, 2, 8, 1, 8, 128),  # single chunk
]


def _ssd_inputs(B, S, nh, hp, ng, ds, dtype=jnp.float32):
    ks = [jax.random.fold_in(KEY, i) for i in range(6)]
    x = jax.random.normal(ks[0], (B, S, nh, hp), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, ng, ds)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, ng, ds)) * 0.3).astype(dtype)
    D = jax.random.normal(ks[5], (nh,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("case", SSD_CASES, ids=str)
def test_ssd_chunked_ref_matches_naive(case):
    B, S, nh, hp, ng, ds, chunk = case
    x, dt, A, Bm, Cm, D = _ssd_inputs(B, S, nh, hp, ng, ds)
    want = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    got = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("case", SSD_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_matches_naive(case, dtype):
    B, S, nh, hp, ng, ds, chunk = case
    x, dt, A, Bm, Cm, D = _ssd_inputs(B, S, nh, hp, ng, ds, dtype)
    want = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    got, st = ssd_scan_pallas(
        x, dt, A, Bm, Cm, D, chunk=chunk, return_state=True, interpret=True
    )
    # naive oracle accumulates differently (O(S^2) sum order): 2e-4 at f32
    tol = _tol(dtype) if dtype == jnp.bfloat16 else dict(atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol
    )
    # final state matches the chunked reference's
    _, st_ref = ref.ssd_chunked_ref(
        x, dt, A, Bm, Cm, D, chunk=chunk, return_state=True
    )
    np.testing.assert_allclose(st, st_ref, atol=2e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_ssd_decode_steps_match_full_scan():
    B, S, nh, hp, ng, ds = 1, 16, 2, 8, 1, 8
    x, dt, A, Bm, Cm, D = _ssd_inputs(B, S, nh, hp, ng, ds)
    y_full = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    st = jnp.zeros((B, nh, ds, hp))
    for t in range(S):
        y_t, st = ref.ssd_decode_step(st, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        np.testing.assert_allclose(y_t, y_full[:, t], atol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

RGLRU_CASES = [
    # B, S, W, bt, bc
    (2, 128, 64, 32, 64),
    (1, 100, 200, 64, 128),  # uneven both dims
    (2, 64, 256, 64, 128),
    (1, 32, 16, 32, 16),
]


def _rglru_inputs(B, S, W, dtype=jnp.float32):
    ks = [jax.random.fold_in(KEY, 20 + i) for i in range(4)]
    return (
        jax.random.normal(ks[0], (B, S, W), dtype),
        jax.random.normal(ks[1], (B, S, W), dtype),
        jax.random.normal(ks[2], (B, S, W), dtype),
        jax.random.normal(ks[3], (W,)),
    )


@pytest.mark.parametrize("case", RGLRU_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_pallas_matches_ref(case, dtype):
    B, S, W, bt, bc = case
    x, r, i, lam = _rglru_inputs(B, S, W, dtype)
    want, st_want = ref.rglru_ref(x, r, i, lam, return_state=True)
    got, st = rglru_scan_pallas(
        x, r, i, lam, block_t=bt, block_c=bc, return_state=True, interpret=True
    )
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        st, st_want, atol=2e-2 if dtype == jnp.bfloat16 else 2e-5
    )


def test_rglru_decode_steps_match_full_scan():
    B, S, W = 1, 12, 16
    x, r, i, lam = _rglru_inputs(B, S, W)
    y_full = ref.rglru_ref(x, r, i, lam)
    st = jnp.zeros((B, W))
    for t in range(S):
        y_t, st = ref.rglru_decode_step(st, x[:, t], r[:, t], i[:, t], lam)
        np.testing.assert_allclose(y_t, y_full[:, t], atol=1e-5)


def test_rglru_stability_long_sequence():
    """Decay in (0,1): the state never blows up over 4k steps."""
    B, S, W = 1, 4096, 8
    x, r, i, lam = _rglru_inputs(B, S, W)
    y = ref.rglru_ref(x, r, i, lam)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(y).max()) < 1e3


# ---------------------------------------------------------------------------
# Alg-2 placement sweep (scheduler hot path)
# ---------------------------------------------------------------------------


def _placement_block(B=257, n_t=6, n_f=5, seed=0):
    rng = np.random.default_rng(seed)
    t_slr = rng.uniform(30.0, 120.0, n_f)
    t_cfg = rng.uniform(0.0, 8.0, n_f)
    iis = rng.uniform(0.0, 6.0, n_t)
    # Rows spread around the fleet capacity: mixed feasible/infeasible.
    shares = rng.uniform(0.5, 1.5, (B, n_t)) * (
        rng.uniform(0.3, 1.3, (B, 1)) * t_slr.sum() / n_t
    )
    return shares, iis, t_slr, t_cfg


@pytest.mark.parametrize("block_rows", [64, 1024], ids=["tiled", "one-tile"])
@pytest.mark.parametrize("repay_init", [True, False], ids=["padpsfr", "preemptive"])
def test_placement_sweep_pallas_matches_ref(block_rows, repay_init):
    from jax.experimental import enable_x64

    from repro.kernels.placement_step import placement_sweep_pallas

    shares, iis, t_slr, t_cfg = _placement_block()
    resume = 0.0 if repay_init else 9.5
    with enable_x64():
        want = ref.placement_sweep_ref(
            jnp.asarray(shares), jnp.asarray(iis), jnp.asarray(t_slr),
            jnp.asarray(t_cfg), jnp.float64(resume), repay_init=repay_init,
        )
        got = placement_sweep_pallas(
            jnp.asarray(shares), jnp.asarray(iis), jnp.asarray(t_slr),
            jnp.asarray(t_cfg), resume_cost=resume, repay_init=repay_init,
            block_rows=block_rows, interpret=True,
        )
    assert int(np.asarray(want[0]).sum()) > 0  # the block exercises both verdicts
    assert int((~np.asarray(want[0])).sum()) > 0
    for g, w, name in zip(got, want, ("feasible", "placed", "n_splits", "devices"), strict=True):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_placement_sweep_ref_matches_numpy_backend():
    """The jnp reference is pinned to the core numpy engine bit-for-bit."""
    from jax.experimental import enable_x64

    from repro.core.placement_backends import get_backend

    shares, iis, t_slr, t_cfg = _placement_block(B=123, seed=3)
    bn = get_backend("numpy").place_block(shares, iis, t_slr, t_cfg)
    with enable_x64():
        feas, placed, n_splits, dev = ref.placement_sweep_ref(
            jnp.asarray(shares), jnp.asarray(iis), jnp.asarray(t_slr),
            jnp.asarray(t_cfg), jnp.float64(0.0),
        )
    np.testing.assert_array_equal(np.asarray(feas), bn.feasible)
    np.testing.assert_array_equal(np.asarray(placed), bn.placed_tasks)
    np.testing.assert_array_equal(np.asarray(n_splits), bn.n_splits)
    np.testing.assert_array_equal(np.asarray(dev), bn.devices_used)
