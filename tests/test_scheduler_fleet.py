"""Fleet-level scheduling (paper's algorithm on TPU job variants) +
baseline comparisons (EDF/LLF/ER-fair, preemptive DP-Fair of refs [9]/[10])."""

import pytest

from repro.configs import get_arch
from repro.configs.shapes import get_shape
from repro.configs.paper_examples import example1_fleet, example1_tasks
from repro.core import (
    FleetSpec,
    PADPSFRScheduler,
    count_placeable,
    edf_schedule,
    erfair_context_switches,
    llf_schedule,
    preemptive_dpfair_schedule,
    sweep_fleet,
)
from repro.core.variants import JobSpec, make_task
from repro.launch.schedule import plan_fleet


def _jobs():
    return [
        JobSpec(cfg=get_arch("yi-34b"), shape=get_shape("train_4k"),
                period_s=3600, steps_per_period=600),
        JobSpec(cfg=get_arch("smollm-135m"), shape=get_shape("decode_32k"),
                period_s=600, steps_per_period=3000),
        JobSpec(cfg=get_arch("mamba2-130m"), shape=get_shape("train_4k"),
                period_s=1800, steps_per_period=2000),
    ]


def test_fleet_plan_feasible_and_power_minimal():
    fleet = FleetSpec(n_f=4, t_slr=3600.0, t_cfg=45.0)
    tasks, result = plan_fleet(_jobs(), fleet, chip_options=(16, 32, 64))
    assert result.feasible
    # chosen = minimum power among placeable (property asserted exhaustively
    # in test_core_properties; here sanity-check the integration)
    assert result.total_power > 0
    assert result.plan is not None
    placed = {seg.task for s in result.plan.scripts for seg in s.segments if seg.kind == "run"}
    assert placed == set(range(len(tasks)))


def test_fleet_infeasible_when_period_too_tight():
    jobs = [
        JobSpec(cfg=get_arch("yi-34b"), shape=get_shape("train_4k"),
                period_s=10.0, steps_per_period=100000)
    ]
    fleet = FleetSpec(n_f=2, t_slr=10.0, t_cfg=1.0)
    _tasks, result = plan_fleet(jobs, fleet, chip_options=(64, 128))
    assert not result.feasible


# ---------------------------------------------------------------------------
# baselines (paper §IV-C / Table III)
# ---------------------------------------------------------------------------


def test_preemptive_dpfair_accepts_fewer_or_equal_sets():
    """Fig 8: with honest capture/store overhead, refs [9]/[10] place
    fewer task sets than PADPS-FR at every fleet size."""
    tasks, fleet = example1_tasks(), example1_fleet()
    for n_f in (4, 5, 6):
        f = fleet.with_devices(n_f)
        _, _, ours = count_placeable(tasks, f)
        _, _, theirs = count_placeable(
            tasks, f, t_capture=12.0, t_store=12.0, repay_init=False
        )
        assert theirs <= ours


def test_preemptive_dpfair_schedule_runs():
    res = preemptive_dpfair_schedule(
        example1_tasks(), example1_fleet(), t_capture=12.0, t_store=12.0
    )
    assert res.n_tss == 1024
    if res.feasible:
        assert res.total_power >= 31.5 - 1e-9  # never better than PADPS-FR


def test_greedy_baselines_ignore_power():
    tasks, fleet = example1_tasks(), example1_fleet()
    edf = edf_schedule(tasks, fleet)
    llf = llf_schedule(tasks, fleet)
    ours = PADPSFRScheduler(fleet).schedule(tasks)
    # greedy always burns the fastest variants: strictly more power
    assert edf.total_power > ours.total_power
    assert llf.total_power > ours.total_power


def test_erfair_context_switches_uncontrolled():
    """ER-fair reconfigurations grow with quantum resolution; DP-wrap's
    are bounded by n_t + n_f - 1."""
    tasks, fleet = example1_tasks(), example1_fleet()
    coarse = erfair_context_switches(tasks, fleet, quantum=10.0)
    fine = erfair_context_switches(tasks, fleet, quantum=1.0)
    assert fine > coarse
    ours = PADPSFRScheduler(fleet).schedule(tasks)
    n_cfgs = sum(
        sum(1 for seg in s.segments if seg.kind == "cfg")
        for s in ours.plan.scripts
    )
    assert n_cfgs <= len(tasks) + fleet.n_f - 1
    assert fine > n_cfgs


def test_sweep_matches_fig5_trend():
    """TRR falls with more FPGAs and rises with t_cfg (Figs 5-7)."""
    tasks = example1_tasks()
    base = example1_fleet()
    pts = sweep_fleet(tasks, base, n_f_values=[3, 4, 5, 6], t_cfg_values=[6.0],
                      with_placement=False)
    trrs = [p.trr_eq7 for p in pts]
    assert trrs == sorted(trrs, reverse=True)  # monotone non-increasing
    assert trrs[0] > 90  # n_f=3: paper says ~100%
    assert trrs[-1] < 10  # n_f=6: paper says ~0%

    pts_cfg = sweep_fleet(tasks, base, n_f_values=[4], t_cfg_values=[2.0, 6.0, 10.0],
                          with_placement=False)
    trr_by_cfg = [p.trr_eq7 for p in pts_cfg]
    assert trr_by_cfg == sorted(trr_by_cfg)  # rises with t_cfg

    # Fig 6/7: the *theoretical* workload threshold 1 - (n_t+1)·t_cfg /
    # (n_f·t_slr) rises with n_f; the empirical max over the DISCRETE set
    # of accepted combos tracks it within ~1.5 percentage points.
    wl = [p.workload_threshold for p in pts]
    for a, b in zip(wl, wl[1:], strict=False):
        assert b >= a - 1.5
    assert wl[-1] > wl[0]
    aw = [p.avg_weight_threshold for p in pts]
    for a, b in zip(aw, aw[1:], strict=False):
        assert b >= a - 0.02
    assert aw[-1] > aw[0]
