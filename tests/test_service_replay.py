"""Event-replay equivalence: the service's live plan is always
bit-identical to a cold ``schedule()`` of whatever task set survived.

This is the warm-start soundness property from ``repro.core.replan``
exercised end-to-end: random traces of arrivals / exits / device
failures flow through :class:`repro.service.SchedulerService` (plan
cache on and off, exhaustive recording on and off), and after every
trace the final plan — winner variants, power, rank, reject count, and
the scalar placement plan itself — must equal a from-scratch solve of
the final task tuple on the final fleet, across placement engines.
"""

import random

import pytest

from repro.core import FleetSpec, PADPSFRScheduler, Task, TaskVariant, WalkStats
from repro.core.placement_backends import available_backends
from repro.service import (
    DeviceFailure,
    SchedulerService,
    TaskArrival,
    TaskExit,
)

ENGINES = [e for e in ("scalar", "numpy", "jax") if e in available_backends()]


def _rand_task(rng, name, *, int_powers=False):
    variants = tuple(
        TaskVariant(
            cu=1,
            throughput=rng.uniform(1.0, 8.0),
            power=float(rng.randint(1, 8)) if int_powers else rng.uniform(1, 10),
        )
        for _ in range(rng.randint(1, 3))
    )
    return Task(
        name=name,
        period=rng.uniform(5, 20),
        data=rng.uniform(10, 60),
        init_interval=rng.uniform(0.0, 1.0),
        variants=variants,
    )


def _assert_matches_cold(svc):
    if not svc.tasks:
        assert svc.plan is None
        return
    cold = PADPSFRScheduler(svc.fleet, engine=svc.engine).schedule(
        svc.tasks, **svc.placement_kw
    )
    live = svc.plan
    assert live is not None
    assert live.feasible == cold.feasible
    assert live.chosen_rank == cold.chosen_rank
    assert live.n_placement_rejects == cold.n_placement_rejects
    assert live.total_power == cold.total_power
    if cold.feasible:
        assert live.combo.variant_idx == cold.combo.variant_idx
        assert str(live.plan) == str(cold.plan)


@pytest.mark.parametrize("engine", ENGINES)
def test_random_event_traces_bit_identical(engine):
    n_trials = 6 if engine == "scalar" else 10
    for seed in range(n_trials):
        rng = random.Random(1000 * ENGINES.index(engine) + seed)
        fleet = FleetSpec(
            n_f=rng.randint(2, 3),
            t_slr=rng.uniform(15, 40),
            t_cfg=rng.uniform(0.0, 1.5),
        )
        svc = SchedulerService(
            fleet,
            engine=engine,
            record_exhaustive=bool(seed % 2),
            cache_plans=bool(seed % 3),
        )
        counter = 0
        events = []
        for _ in range(rng.randint(3, 6)):
            roll = rng.random()
            if roll < 0.55 or not svc.tasks:
                counter += 1
                events.append(
                    TaskArrival(
                        _rand_task(rng, f"t{counter}", int_powers=seed % 2 == 0)
                    )
                )
            elif roll < 0.9:
                events.append(TaskExit(rng.choice(svc.tasks).name))
            elif svc.fleet.n_f > 1:
                events.append(DeviceFailure())
            svc.replay(events[-1:])
            _assert_matches_cold(svc)
        assert len(svc.telemetry) == len(events)


def test_warm_arrival_levels_match_cold():
    """Direct replan-level check, hammering the tie-break path with
    integer powers and both recording modes."""
    for seed in range(14):
        rng = random.Random(77 + seed)
        fleet = FleetSpec(
            n_f=rng.randint(1, 3),
            t_slr=rng.uniform(15, 40),
            t_cfg=rng.uniform(0.0, 1.5),
        )
        tasks = [
            _rand_task(rng, f"t{i}", int_powers=True)
            for i in range(rng.randint(2, 4))
        ]
        sch = PADPSFRScheduler(fleet, engine="numpy")
        rec = sch.schedule(
            tasks, record_state=True, record_exhaustive=seed % 2 == 0
        )
        extended = tasks + [_rand_task(rng, "new", int_powers=True)]
        warm = sch.replan(rec.plan_state, extended)
        cold = sch.schedule(extended)
        assert warm.feasible == cold.feasible
        assert warm.chosen_rank == cold.chosen_rank
        assert warm.n_placement_rejects == cold.n_placement_rejects
        assert warm.total_power == cold.total_power
        if cold.feasible:
            assert warm.combo.variant_idx == cold.combo.variant_idx
            assert str(warm.plan) == str(cold.plan)


def test_warm_exit_transfers_reject_depths_zero_dispatch():
    """Death-depth transfer, pinned directly: every recorded reject dies
    among the surviving tasks, so the warm exit re-finds the winner
    without dispatching a single placement row.

    Construction: 2 devices x 30 slots, t_cfg=0 (the eq-7 budget is
    then task-count independent, so the gap walk is empty).  The
    all-cheap combo's shares sum to 59 — inside the eq-7 budget of 60,
    but placing it needs two splits and each split re-pays II=2, so the
    primary sweep dies on the third task (depth 2).  A near-zero eps
    task appended *last* is exhaustively recorded; dropping it leaves
    the reject's death depth (2) strictly below the dropped position
    (3), and the winner's PLACEABLE verdict survives verbatim — the
    warm walk should consume only transferred verdicts.
    """
    fleet = FleetSpec(n_f=2, t_slr=30.0, t_cfg=0.0)
    # share = data * t_slr / (period * th) = 3 / th
    def task(name, shr_cheap, p_cheap, p_exp):
        return Task(name, period=10.0, data=1.0, init_interval=2.0,
                    variants=(TaskVariant(cu=1, throughput=3.0 / shr_cheap,
                                          power=p_cheap),
                              TaskVariant(cu=1, throughput=3.0 / 13.0,
                                          power=p_exp)))

    tasks = [task("a", 21.0, 1.0, 5.0), task("b", 21.0, 2.0, 6.0),
             task("c", 17.0, 3.0, 7.0)]
    eps = Task("eps", period=50.0, data=1.0, init_interval=1.0,
               variants=(TaskVariant(cu=1, throughput=30.0 / (50.0 * 1e-6),
                                     power=1e-6),))
    sched = PADPSFRScheduler(fleet, engine="numpy")

    rec = sched.schedule([*tasks, eps], record_state=True,
                         record_exhaustive=True)
    assert rec.feasible
    # the recording saw real placement rejects, all dying at depth 2
    depths = rec.plan_state.rec_depth
    n = len(tasks) + 1
    died = depths[(depths >= 0) & (depths < n)]
    assert died.size > 0 and died.max() == 2

    stats = WalkStats()
    warm = sched.replan(rec.plan_state, tasks, walk_stats=stats)
    cold = sched.schedule(tasks)
    assert cold.chosen_rank > 0  # the transferred rejects are load-bearing
    assert warm.feasible and cold.feasible
    assert warm.chosen_rank == cold.chosen_rank
    assert warm.n_placement_rejects == cold.n_placement_rejects
    assert warm.total_power == cold.total_power
    assert warm.combo.variant_idx == cold.combo.variant_idx
    assert str(warm.plan) == str(cold.plan)
    # the whole point: no placement row was probed or dispatched
    assert stats.rows == 0


def _v(th, pw):
    return TaskVariant(cu=1, throughput=th, power=pw)


def _abc():
    a = Task("a", period=10.0, data=20.0, init_interval=1.0,
             variants=(_v(2.0, 5.0), _v(4.0, 8.0)))
    b = Task("b", period=10.0, data=40.0, init_interval=1.0,
             variants=(_v(4.0, 4.0), _v(8.0, 6.0)))
    c = Task("c", period=10.0, data=30.0, init_interval=1.0,
             variants=(_v(6.0, 3.0), _v(12.0, 9.0)))
    return a, b, c


def test_admission_filter_and_rollback():
    a, b, c = _abc()
    svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
    assert svc.submit(a).admitted and svc.submit(b).admitted
    before = svc.plan

    dup = svc.submit(Task("a", period=9.0, data=5.0, init_interval=0.0,
                          variants=(_v(5.0, 1.0),)))
    assert not dup.admitted and dup.path == "admission"
    assert "duplicate" in dup.reason

    hopeless = svc.submit(Task("big", period=10.0, data=10000.0,
                               init_interval=1.0, variants=(_v(2.0, 1.0),)))
    assert not hopeless.admitted and hopeless.path == "admission"
    assert "eq-7" in hopeless.reason

    # passes the eq-7 filter (modest share) but can never place: its II
    # exceeds every device's usable window — rolled back after replan
    tight = svc.submit(Task("tight", period=10.0, data=48.0,
                            init_interval=29.0, variants=(_v(6.0, 1.0),)))
    assert not tight.admitted and tight.path in ("warm", "general")
    assert svc.tasks == (a, b)
    assert svc.plan is before  # untouched plan object

    _assert_matches_cold(svc)


def test_plan_cache_steady_state_churn():
    a, b, _ = _abc()
    svc = SchedulerService(FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0))
    svc.submit(a)
    svc.submit(b)
    svc.remove(b.name)
    back = svc.submit(b)  # same tuple (a, b) on the same fleet as before
    assert back.path == "cache"
    assert back.latency_s < 0.05
    _assert_matches_cold(svc)

    uncached = SchedulerService(
        FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0), cache_plans=False
    )
    uncached.submit(a)
    uncached.submit(b)
    uncached.remove(b.name)
    assert uncached.submit(b).path != "cache"
    _assert_matches_cold(uncached)


def test_device_failure_degrades_and_replans():
    a, b, c = _abc()
    svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
    svc.submit(a)
    svc.submit(b)
    tel = svc.fail_device()
    assert tel.admitted and svc.fleet.n_f == 1
    _assert_matches_cold(svc)

    last = svc.fail_device()
    assert not last.admitted and "last device" in last.reason
    assert svc.fleet.n_f == 1

    # heterogeneous failure drops the indexed profile
    from repro.core import DeviceProfile

    hsvc = SchedulerService(FleetSpec.heterogeneous(
        [DeviceProfile(t_slr=30.0, t_cfg=1.0),
         DeviceProfile(t_slr=20.0, t_cfg=0.1, klass="gpu")]))
    hsvc.submit(a)
    hsvc.fail_device(1)
    assert hsvc.fleet.n_f == 1 and hsvc.fleet.devices[0].klass == "fpga"
    _assert_matches_cold(hsvc)


def test_telemetry_trace_is_complete():
    a, b, _ = _abc()
    svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
    svc.replay([TaskArrival(a), TaskArrival(b), TaskExit("a")])
    assert [t.event for t in svc.telemetry] == [
        "arrival(a)", "arrival(b)", "exit(a)",
    ]
    assert all(t.latency_s >= 0 for t in svc.telemetry)
    assert svc.telemetry[-1].n_tasks == 1
    assert svc.telemetry[-1].feasible


def test_solve_path_telemetry_classifies_warm_and_general():
    """The telemetry label keys off :attr:`PlanState.origin`: the first
    arrival cold-solves (general) and every later arrival chains warm
    through the recorded root.  Regression for the
    ``record_exhaustive=True`` carry-over bug: the warm path used to emit
    a thin state that forced the *third* arrival cold — now two (and
    three) consecutive arrivals all take the warm path.  The live plan
    stays bit-identical to cold throughout."""
    fleet = FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0)

    def mk(name, power):
        return Task(
            name=name,
            period=10.0,
            data=20.0,
            init_interval=1.0,
            variants=(TaskVariant(cu=1, throughput=6.0, power=power),),
        )

    svc = SchedulerService(fleet, engine="numpy")
    rows = [svc.submit(mk("a", 2.0)), svc.submit(mk("b", 3.0)),
            svc.submit(mk("c", 1.0)), svc.submit(mk("d", 2.5))]
    assert all(r.admitted for r in rows)
    assert [r.path for r in rows] == ["general", "warm", "warm", "warm"]
    _assert_matches_cold(svc)


def test_warm_exit_and_failure_telemetry_paths():
    """Exits of root tasks classify as ``warm_exit`` and device failures
    as ``warm_failure``; both stay bit-identical to cold.  (An exit of a
    task the state *appended* legitimately rides the arrival projection
    and reports plain ``warm``.)  ``max_stale=1`` keeps the root fresh so
    every removal replans against a full exhaustive recording."""
    a, b, c = _abc()
    svc = SchedulerService(FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0), max_stale=1)
    svc.submit(a)
    svc.submit(b)
    svc.submit(c)
    assert svc.rerecord_count >= 1
    _assert_matches_cold(svc)

    tel = svc.remove("a")  # root task: projection path
    assert tel.path == "warm_exit"
    _assert_matches_cold(svc)

    tel = svc.fail_device()
    assert tel.path == "warm_failure"
    _assert_matches_cold(svc)


def _mixed_trace(rng, svc, n_events):
    """Drive ``svc`` through ``n_events`` mixed events, checking the live
    plan against a cold solve after every prefix."""
    counter = 0
    paths = []
    for _ in range(n_events):
        roll = rng.random()
        n_alive = len(svc.tasks)
        if (roll < 0.45 and n_alive < 4) or n_alive == 0:
            counter += 1
            tel = svc.submit(_rand_task(rng, f"t{counter}", int_powers=True))
        elif roll < 0.80 and n_alive:
            tel = svc.remove(rng.choice(svc.tasks).name)
        elif roll < 0.90 and svc.fleet.n_f > svc.resilience + 1:
            tel = svc.fail_device()
        else:
            tel = svc.recover_device()
        paths.append(tel.path)
        _assert_matches_cold(svc)
    return paths


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("resilience", [0, 1])
def test_churn_trace_prefix_equivalence(engine, resilience):
    """Every prefix of a 100+-event mixed arrival/exit/failure/recovery
    trace yields plans bit-identical to cold ``schedule()`` — per engine
    and for resilience k=0 and k=1.  The staleness-bounded re-record
    policy runs live inside the trace (it raises on any warm/cold
    divergence, so it doubles as an equivalence oracle)."""
    rng = random.Random(4242 + 17 * ENGINES.index(engine) + resilience)
    svc = SchedulerService(
        FleetSpec(n_f=3, t_slr=35.0, t_cfg=1.0),
        engine=engine,
        resilience=resilience,
        max_stale=5,
    )
    n_events = 60 if engine == "scalar" else 110
    paths = _mixed_trace(rng, svc, n_events)
    assert len(svc.telemetry) == n_events
    # the trace must actually exercise the warm machinery
    solved = [p for p in paths if p not in ("admission", "noop")]
    assert any(p in ("warm", "warm_exit", "warm_failure", "cache")
               for p in solved)


def test_rerecord_policy_fires_and_preserves_plan():
    """With a tight ``max_stale`` the re-record policy swaps in a fresh
    exhaustive root mid-trace; the plan is unchanged (the policy raises
    on any mismatch) and later arrivals keep hitting the warm path."""
    rng = random.Random(99)
    svc = SchedulerService(
        FleetSpec(n_f=3, t_slr=35.0, t_cfg=1.0), max_stale=2
    )
    _mixed_trace(rng, svc, 40)
    assert svc.rerecord_count >= 1
    _assert_matches_cold(svc)
