"""Event-replay equivalence: the service's live plan is always
bit-identical to a cold ``schedule()`` of whatever task set survived.

This is the warm-start soundness property from ``repro.core.replan``
exercised end-to-end: random traces of arrivals / exits / device
failures flow through :class:`repro.service.SchedulerService` (plan
cache on and off, exhaustive recording on and off), and after every
trace the final plan — winner variants, power, rank, reject count, and
the scalar placement plan itself — must equal a from-scratch solve of
the final task tuple on the final fleet, across placement engines.
"""

import random

import pytest

from repro.core import FleetSpec, PADPSFRScheduler, Task, TaskVariant
from repro.core.placement_backends import available_backends
from repro.service import (
    DeviceFailure,
    SchedulerService,
    TaskArrival,
    TaskExit,
)

ENGINES = [e for e in ("scalar", "numpy", "jax") if e in available_backends()]


def _rand_task(rng, name, *, int_powers=False):
    variants = tuple(
        TaskVariant(
            cu=1,
            throughput=rng.uniform(1.0, 8.0),
            power=float(rng.randint(1, 8)) if int_powers else rng.uniform(1, 10),
        )
        for _ in range(rng.randint(1, 3))
    )
    return Task(
        name=name,
        period=rng.uniform(5, 20),
        data=rng.uniform(10, 60),
        init_interval=rng.uniform(0.0, 1.0),
        variants=variants,
    )


def _assert_matches_cold(svc):
    if not svc.tasks:
        assert svc.plan is None
        return
    cold = PADPSFRScheduler(svc.fleet, engine=svc.engine).schedule(svc.tasks)
    live = svc.plan
    assert live is not None
    assert live.feasible == cold.feasible
    assert live.chosen_rank == cold.chosen_rank
    assert live.n_placement_rejects == cold.n_placement_rejects
    assert live.total_power == cold.total_power
    if cold.feasible:
        assert live.combo.variant_idx == cold.combo.variant_idx
        assert str(live.plan) == str(cold.plan)


@pytest.mark.parametrize("engine", ENGINES)
def test_random_event_traces_bit_identical(engine):
    n_trials = 6 if engine == "scalar" else 10
    for seed in range(n_trials):
        rng = random.Random(1000 * ENGINES.index(engine) + seed)
        fleet = FleetSpec(
            n_f=rng.randint(2, 3),
            t_slr=rng.uniform(15, 40),
            t_cfg=rng.uniform(0.0, 1.5),
        )
        svc = SchedulerService(
            fleet,
            engine=engine,
            record_exhaustive=bool(seed % 2),
            cache_plans=bool(seed % 3),
        )
        counter = 0
        events = []
        for _ in range(rng.randint(3, 6)):
            roll = rng.random()
            if roll < 0.55 or not svc.tasks:
                counter += 1
                events.append(
                    TaskArrival(
                        _rand_task(rng, f"t{counter}", int_powers=seed % 2 == 0)
                    )
                )
            elif roll < 0.9:
                events.append(TaskExit(rng.choice(svc.tasks).name))
            elif svc.fleet.n_f > 1:
                events.append(DeviceFailure())
            svc.replay(events[-1:])
            _assert_matches_cold(svc)
        assert len(svc.telemetry) == len(events)


def test_warm_arrival_levels_match_cold():
    """Direct replan-level check, hammering the tie-break path with
    integer powers and both recording modes."""
    for seed in range(14):
        rng = random.Random(77 + seed)
        fleet = FleetSpec(
            n_f=rng.randint(1, 3),
            t_slr=rng.uniform(15, 40),
            t_cfg=rng.uniform(0.0, 1.5),
        )
        tasks = [
            _rand_task(rng, f"t{i}", int_powers=True)
            for i in range(rng.randint(2, 4))
        ]
        sch = PADPSFRScheduler(fleet, engine="numpy")
        rec = sch.schedule(
            tasks, record_state=True, record_exhaustive=seed % 2 == 0
        )
        extended = tasks + [_rand_task(rng, "new", int_powers=True)]
        warm = sch.replan(rec.plan_state, extended)
        cold = sch.schedule(extended)
        assert warm.feasible == cold.feasible
        assert warm.chosen_rank == cold.chosen_rank
        assert warm.n_placement_rejects == cold.n_placement_rejects
        assert warm.total_power == cold.total_power
        if cold.feasible:
            assert warm.combo.variant_idx == cold.combo.variant_idx
            assert str(warm.plan) == str(cold.plan)


def _v(th, pw):
    return TaskVariant(cu=1, throughput=th, power=pw)


def _abc():
    a = Task("a", period=10.0, data=20.0, init_interval=1.0,
             variants=(_v(2.0, 5.0), _v(4.0, 8.0)))
    b = Task("b", period=10.0, data=40.0, init_interval=1.0,
             variants=(_v(4.0, 4.0), _v(8.0, 6.0)))
    c = Task("c", period=10.0, data=30.0, init_interval=1.0,
             variants=(_v(6.0, 3.0), _v(12.0, 9.0)))
    return a, b, c


def test_admission_filter_and_rollback():
    a, b, c = _abc()
    svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
    assert svc.submit(a).admitted and svc.submit(b).admitted
    before = svc.plan

    dup = svc.submit(Task("a", period=9.0, data=5.0, init_interval=0.0,
                          variants=(_v(5.0, 1.0),)))
    assert not dup.admitted and dup.path == "admission"
    assert "duplicate" in dup.reason

    hopeless = svc.submit(Task("big", period=10.0, data=10000.0,
                               init_interval=1.0, variants=(_v(2.0, 1.0),)))
    assert not hopeless.admitted and hopeless.path == "admission"
    assert "eq-7" in hopeless.reason

    # passes the eq-7 filter (modest share) but can never place: its II
    # exceeds every device's usable window — rolled back after replan
    tight = svc.submit(Task("tight", period=10.0, data=48.0,
                            init_interval=29.0, variants=(_v(6.0, 1.0),)))
    assert not tight.admitted and tight.path in ("warm", "general")
    assert svc.tasks == (a, b)
    assert svc.plan is before  # untouched plan object

    _assert_matches_cold(svc)


def test_plan_cache_steady_state_churn():
    a, b, _ = _abc()
    svc = SchedulerService(FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0))
    svc.submit(a)
    svc.submit(b)
    svc.remove(b.name)
    back = svc.submit(b)  # same tuple (a, b) on the same fleet as before
    assert back.path == "cache"
    assert back.latency_s < 0.05
    _assert_matches_cold(svc)

    uncached = SchedulerService(
        FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0), cache_plans=False
    )
    uncached.submit(a)
    uncached.submit(b)
    uncached.remove(b.name)
    assert uncached.submit(b).path != "cache"
    _assert_matches_cold(uncached)


def test_device_failure_degrades_and_replans():
    a, b, c = _abc()
    svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
    svc.submit(a)
    svc.submit(b)
    tel = svc.fail_device()
    assert tel.admitted and svc.fleet.n_f == 1
    _assert_matches_cold(svc)

    last = svc.fail_device()
    assert not last.admitted and "last device" in last.reason
    assert svc.fleet.n_f == 1

    # heterogeneous failure drops the indexed profile
    from repro.core import DeviceProfile

    hsvc = SchedulerService(FleetSpec.heterogeneous(
        [DeviceProfile(t_slr=30.0, t_cfg=1.0),
         DeviceProfile(t_slr=20.0, t_cfg=0.1, klass="gpu")]))
    hsvc.submit(a)
    hsvc.fail_device(1)
    assert hsvc.fleet.n_f == 1 and hsvc.fleet.devices[0].klass == "fpga"
    _assert_matches_cold(hsvc)


def test_telemetry_trace_is_complete():
    a, b, _ = _abc()
    svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
    svc.replay([TaskArrival(a), TaskArrival(b), TaskExit("a")])
    assert [t.event for t in svc.telemetry] == [
        "arrival(a)", "arrival(b)", "exit(a)",
    ]
    assert all(t.latency_s >= 0 for t in svc.telemetry)
    assert svc.telemetry[-1].n_tasks == 1
    assert svc.telemetry[-1].feasible


def test_solve_path_telemetry_classifies_warm_and_general():
    """The warm/general telemetry label keys off replan's thin-state
    sentinel (``complete_below == -inf``).  Regression for the sentinel
    check in ``SchedulerService._solve``: the first arrival cold-solves
    (general), the second replans warm from the recorded state, and the
    third — replanning from the warm path's *thin* state — falls back to
    the general fresh walk.  The live plan stays bit-identical to cold
    throughout."""
    fleet = FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0)

    def mk(name, power):
        return Task(
            name=name,
            period=10.0,
            data=20.0,
            init_interval=1.0,
            variants=(TaskVariant(cu=1, throughput=6.0, power=power),),
        )

    svc = SchedulerService(fleet, engine="numpy")
    rows = [svc.submit(mk("a", 2.0)), svc.submit(mk("b", 3.0)),
            svc.submit(mk("c", 1.0))]
    assert all(r.admitted for r in rows)
    assert [r.path for r in rows] == ["general", "warm", "general"]
    _assert_matches_cold(svc)
