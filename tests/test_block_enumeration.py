"""Block-native streaming TFS enumeration: order, determinism, pipelining.

The block enumerator (``repro.core.feasibility.iter_feasible_pruned_blocks``)
must emit the TFS in *exactly* the order of the materialised
``tfs_indices_by_power()`` — ascending total power, exact-power ties broken
by TSS flat index — and so must the Python-heap streamer
(``iter_feasible_pruned``).  This file covers:

* combo-for-combo order parity of all three enumeration engines, on the
  paper's examples and randomized heterogeneous fleets;
* power-tie determinism across 100+ randomized fleets with discrete
  (tie-heavy) power tables;
* the tightened heterogeneous eq-7 prefix bound: streamed == exhaustive
  row sets (the bound prunes nothing the exhaustive filter keeps);
* block-size/ramp invariance of the streaming scheduler path and parity
  against both the exhaustive path and the scalar oracle engine;
* asynchronous ``dispatch_block`` parity (jax/pallas double buffering);
* the ``outer_sum`` in-place accumulation regression (bitwise equality +
  peak-memory cap on large products).
"""

import tracemalloc

import numpy as np
import pytest

from repro.configs.paper_examples import (
    example1_fleet,
    example1_tasks,
    example2_fleet,
    example2_tasks,
    example3_fleet,
    example3_tasks,
)
from repro.core import (
    FleetSpec,
    PADPSFRScheduler,
    Task,
    TaskVariant,
    WalkStats,
    block_ramp,
    get_backend,
    iter_feasible_pruned,
    iter_feasible_pruned_blocks,
    outer_sum,
    search_feasible,
)
from repro.core.feasibility import _scalar_overhead_lb, config_overhead_lower_bound

from test_placement_batched import (
    _assert_results_identical,
    _random_fleet,
    _random_tasks,
)

try:
    import jax  # noqa: F401

    HAS_JAX = True
except ImportError:  # pragma: no cover - exercised by the no-jax CI leg
    HAS_JAX = False

needs_jax = pytest.mark.skipif(not HAS_JAX, reason="jax not installed")

PAPER_CASES = [
    (example1_tasks, example1_fleet),
    (example2_tasks, example2_fleet),
    (example3_tasks, example3_fleet),
]
PAPER_IDS = ["example1", "example2", "example3"]


def _materialized_order(tasks, fleet):
    feas = search_feasible(tasks, fleet)
    return [feas.combo_at(int(i)) for i in feas.tfs_indices_by_power()]


def _block_order(tasks, fleet, block_sizes):
    out = []
    for blk in iter_feasible_pruned_blocks(tasks, fleet, block_sizes):
        assert blk.shares.shape == blk.variant_idx.shape
        assert blk.total_power.shape == (len(blk),)
        out.extend(blk.materialize(r) for r in range(len(blk)))
    return out


# ---------------------------------------------------------------------------
# order parity: heap == blocks == materialized, combo for combo
# ---------------------------------------------------------------------------


class TestEnumerationOrderParity:
    @pytest.mark.parametrize("tasks_fn,fleet_fn", PAPER_CASES, ids=PAPER_IDS)
    def test_paper_examples_exact_order(self, tasks_fn, fleet_fn):
        tasks, fleet = tasks_fn(), fleet_fn()
        mat = _materialized_order(tasks, fleet)
        assert list(iter_feasible_pruned(tasks, fleet)) == mat
        assert _block_order(tasks, fleet, 64) == mat

    @pytest.mark.parametrize("block_sizes", [1, 3, 4096, None], ids=["b1", "b3", "b4096", "ramp"])
    def test_randomized_exact_order_any_blocking(self, block_sizes):
        rng = np.random.default_rng(101)
        sizes = block_ramp() if block_sizes is None else block_sizes
        rows = 0
        for _ in range(40):
            tasks = _random_tasks(rng)
            fleet = _random_fleet(rng)
            mat = _materialized_order(tasks, fleet)
            sizes_i = block_ramp() if block_sizes is None else sizes
            assert _block_order(tasks, fleet, sizes_i) == mat
            rows += len(mat)
        assert rows > 200

    def test_heap_streamer_exact_order_randomized(self):
        rng = np.random.default_rng(55)
        for _ in range(40):
            tasks = _random_tasks(rng)
            fleet = _random_fleet(rng)
            assert list(iter_feasible_pruned(tasks, fleet)) == _materialized_order(
                tasks, fleet
            )

    def test_block_shares_match_shares_matrix_bitwise(self):
        tasks, fleet = example1_tasks(), example1_fleet()
        feas = search_feasible(tasks, fleet)
        order = feas.tfs_indices_by_power()
        want = feas.shares_matrix(order)
        got = np.concatenate(
            [b.shares for b in iter_feasible_pruned_blocks(tasks, fleet, 100)]
        )
        assert got.shape == want.shape
        assert (got == want).all()  # bitwise, not approx

    def test_total_power_matches_outer_sum_bitwise(self):
        tasks, fleet = example1_tasks(), example1_fleet()
        feas = search_feasible(tasks, fleet)
        want = feas.total_power[feas.tfs_indices_by_power()]
        got = np.concatenate(
            [b.total_power for b in iter_feasible_pruned_blocks(tasks, fleet, 128)]
        )
        assert (got == want).all()

    def test_empty_task_set_single_empty_combo(self):
        fleet = FleetSpec(n_f=2, t_slr=50.0, t_cfg=1.0)
        blocks = list(iter_feasible_pruned_blocks((), fleet, 8))
        assert len(blocks) == 1 and len(blocks[0]) == 1
        combo = blocks[0].materialize(0)
        assert combo.variant_idx == () and combo.total_power == 0.0

    def test_block_sizes_validation(self):
        tasks, fleet = example1_tasks(), example1_fleet()
        with pytest.raises(ValueError, match="block_size must be >= 1"):
            list(iter_feasible_pruned_blocks(tasks, fleet, 0))


# ---------------------------------------------------------------------------
# power-tie determinism (satellite): discrete powers force exact ties
# ---------------------------------------------------------------------------


def _tie_tasks(rng, max_tasks=5, powers=(1.0, 2.0, 3.0)):
    n_t = int(rng.integers(2, max_tasks + 1))
    out = []
    for i in range(n_t):
        nv = int(rng.integers(2, 4))
        ths = np.sort(rng.uniform(0.3, 4.0, nv))
        pws = rng.choice(powers, nv)
        out.append(
            Task(
                name=f"T{i}",
                period=50.0,
                data=float(rng.uniform(5.0, 60.0)),
                init_interval=float(rng.uniform(0.0, 5.0)),
                variants=tuple(
                    TaskVariant(cu=j + 1, throughput=float(t), power=float(p))
                    for j, (t, p) in enumerate(zip(ths, pws, strict=True))
                ),
            )
        )
    return out


class TestPowerTieDeterminism:
    def test_streamed_and_materialized_agree_under_exact_ties(self):
        """Satellite: across 100+ randomized fleets with tie-heavy power
        tables, the streamed orders (heap and block) must equal the
        materialized stable-argsort order combo for combo."""
        rng = np.random.default_rng(42)
        ties = 0
        for _ in range(120):
            tasks = _tie_tasks(rng)
            fleet = _random_fleet(rng)
            feas = search_feasible(tasks, fleet)
            order = feas.tfs_indices_by_power()
            ties += int((np.diff(feas.total_power[order]) == 0).sum())
            mat = [feas.combo_at(int(i)) for i in order]
            assert list(iter_feasible_pruned(tasks, fleet)) == mat
            assert _block_order(tasks, fleet, 7) == mat
        assert ties > 500  # the instances actually exercised exact ties

    def test_tie_order_is_flat_index_order(self):
        """Within an exact-power tie run, combos come out in ascending TSS
        flat (C-order variant-index) order."""
        rng = np.random.default_rng(3)
        checked = 0
        for _ in range(40):
            tasks = _tie_tasks(rng)
            fleet = _random_fleet(rng)
            combos = list(iter_feasible_pruned(tasks, fleet))
            for a, b in zip(combos, combos[1:], strict=False):
                if a.total_power == b.total_power:
                    assert a.variant_idx < b.variant_idx
                    checked += 1
        assert checked > 100


# ---------------------------------------------------------------------------
# tightened heterogeneous eq-7 prefix bound
# ---------------------------------------------------------------------------


class TestHeteroPrefixBound:
    def test_scalar_overhead_twin_matches_vectorized(self):
        rng = np.random.default_rng(8)
        for _ in range(50):
            fleet = _random_fleet(rng)
            n_t = int(rng.integers(1, 7))
            w = rng.uniform(0.0, fleet.capacity * 1.5, 32)
            want = config_overhead_lower_bound(fleet, n_t, w)
            fn = _scalar_overhead_lb(fleet, n_t)
            got = np.asarray([fn(float(x)) for x in w])
            assert (got == want).all()  # bitwise twin

    def test_streamed_tfs_equals_exhaustive_on_hetero(self):
        """The prefix bound prunes nothing the exhaustive hetero filter
        keeps (and vice versa): identical row sets in identical order."""
        rng = np.random.default_rng(5)
        rows = 0
        for _ in range(60):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng)
            if not fleet.is_heterogeneous:
                continue
            mat = _materialized_order(tasks, fleet)
            assert _block_order(tasks, fleet, 16) == mat
            assert list(iter_feasible_pruned(tasks, fleet)) == mat
            rows += len(mat)
        assert rows > 200


# ---------------------------------------------------------------------------
# scheduler streaming path: ramp invariance + cross-path parity
# ---------------------------------------------------------------------------


class TestStreamingSchedulerParity:
    def test_ramp_vs_fixed_block_sizes_identical(self):
        rng = np.random.default_rng(77)
        checked = 0
        for _ in range(20):
            tasks = _random_tasks(rng)
            fleet = _random_fleet(rng)
            results = []
            for bs in (None, 1, 3, 4096):
                for exhaustive in (True, False):
                    sched = PADPSFRScheduler(
                        fleet, exhaustive=exhaustive, block_size=bs
                    )
                    results.append(
                        sched.schedule(tasks, count_all_rejects=True)
                    )
            first = results[0]
            for other in results[1:]:
                _assert_results_identical(other, first)
                assert other.n_placement_rejects == first.n_placement_rejects
            if first.feasible:
                checked += 1
        assert checked > 5

    def test_streaming_matches_scalar_oracle_engine(self):
        rng = np.random.default_rng(13)
        for _ in range(25):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng)
            rs = PADPSFRScheduler(
                fleet, engine="scalar", exhaustive=False
            ).schedule(tasks, count_all_rejects=True)
            rb = PADPSFRScheduler(fleet, exhaustive=False).schedule(
                tasks, count_all_rejects=True
            )
            _assert_results_identical(rb, rs)

    def test_walk_stats_record_ramp_and_phases(self):
        tasks, fleet = example1_tasks(), example1_fleet()
        ws = WalkStats()
        res = PADPSFRScheduler(fleet, exhaustive=False).schedule(
            tasks, count_all_rejects=True, walk_stats=ws
        )
        assert res.feasible
        assert ws.rows == 620  # full TFS walked under count_all_rejects
        assert ws.block_sizes[0] == 64  # the ramp starts small
        assert sum(ws.block_sizes) == ws.rows
        assert ws.total_us > 0
        d = ws.as_dict()
        assert d["n_blocks"] == len(ws.block_sizes)

    def test_early_winner_stops_enumeration(self):
        """A shallow winner must not walk (or even enumerate) the deep TFS:
        the adaptive ramp caps the scanned rows at the first block, and
        eager backends (numpy) resolve each block before pulling the next
        — no speculative second block."""
        tasks, fleet = example1_tasks(), example1_fleet()
        ws = WalkStats()
        res = PADPSFRScheduler(fleet, exhaustive=False).schedule(
            tasks, walk_stats=ws
        )
        assert res.feasible and res.chosen_rank == 4
        assert ws.rows == 64  # exactly the first ramp block


# ---------------------------------------------------------------------------
# asynchronous dispatch (double buffering)
# ---------------------------------------------------------------------------


@needs_jax
class TestAsyncDispatchParity:
    @pytest.mark.parametrize("engine", ["jax", "pallas"])
    def test_dispatch_block_equals_place_block(self, engine):
        rng = np.random.default_rng(21)
        backend = get_backend(engine)
        for _ in range(5):
            B, n_t, n_f = int(rng.integers(1, 40)), 4, 5
            shares = rng.uniform(1.0, 40.0, (B, n_t))
            iis = rng.uniform(0.0, 5.0, n_t)
            t_slr = rng.uniform(40.0, 90.0, n_f)
            t_cfg = rng.uniform(0.0, 6.0, n_f)
            resolve = backend.dispatch_block(shares, iis, t_slr, t_cfg, None)
            a = resolve()
            b = backend.place_block(shares, iis, t_slr, t_cfg, None)
            assert (a.feasible == b.feasible).all()
            assert (a.placed_tasks == b.placed_tasks).all()
            assert (a.n_splits == b.n_splits).all()
            assert (a.devices_used == b.devices_used).all()

    def test_pipelined_streaming_schedule_matches_scalar(self):
        rng = np.random.default_rng(31)
        for _ in range(8):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng)
            rs = PADPSFRScheduler(
                fleet, engine="scalar", exhaustive=False
            ).schedule(tasks, count_all_rejects=True)
            rj = PADPSFRScheduler(fleet, engine="jax", exhaustive=False).schedule(
                tasks, count_all_rejects=True
            )
            _assert_results_identical(rj, rs)

    def test_dispatch_block_degenerate_blocks(self):
        backend = get_backend("jax")
        bp = backend.dispatch_block(
            np.zeros((3, 0)), [], np.ones(2), np.zeros(2), None
        )()
        assert bp.feasible.all()  # n_t == 0: vacuously feasible
        bp = backend.dispatch_block(
            np.ones((2, 2)), [1.0, 1.0], np.empty(0), np.empty(0), None
        )()
        assert not bp.feasible.any()  # n_f == 0: nothing places


# ---------------------------------------------------------------------------
# outer_sum in-place accumulation (satellite regression)
# ---------------------------------------------------------------------------


class TestOuterSumRegression:
    def test_bitwise_equal_to_left_fold(self):
        rng = np.random.default_rng(2)
        for _ in range(20):
            vecs = [
                rng.uniform(0.0, 50.0, int(rng.integers(1, 5)))
                for _ in range(int(rng.integers(1, 5)))
            ]
            got = outer_sum(vecs)
            acc = np.zeros((1,))
            for v in vecs:
                acc = (acc[:, None] + v[None, :]).reshape(-1)
            assert (got == acc).all()  # bitwise: same fold order

    def test_empty_input(self):
        assert (outer_sum([]) == np.zeros(1)).all()

    def test_zero_length_factor_gives_empty_product(self):
        out = outer_sum([np.asarray([]), np.asarray([1.0, 2.0])])
        assert out.shape == (0,)
        out = outer_sum([np.asarray([1.0]), np.asarray([])])
        assert out.shape == (0,)

    def test_large_product_values(self):
        vecs = [np.arange(1.0, 11.0)] * 6 + [np.asarray([0.25, 0.5])]
        out = outer_sum(vecs)  # 2e6 rows
        assert out.shape == (2_000_000,)
        assert out[0] == 6 * 1.0 + 0.25
        assert out[-1] == 6 * 10.0 + 0.5
        idx = [3, 1, 4, 1, 5, 9, 1]
        flat = 0
        for i, v in zip(idx, vecs, strict=True):
            flat = flat * v.shape[0] + i
        assert out[flat] == sum(v[i] for i, v in zip(idx, vecs, strict=True))

    def test_peak_memory_capped_at_output_size(self):
        """The old fold held the previous level alive while materialising
        the next (1.5x output at a final 2-wide level); the in-place
        accumulate allocates the output once."""
        vecs = [np.arange(1.0, 11.0)] * 6 + [np.asarray([0.25, 0.5])]
        out_bytes = 2_000_000 * 8
        outer_sum(vecs)  # warm any numpy internals
        tracemalloc.start()
        outer_sum(vecs)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert peak < out_bytes * 1.25, f"peak {peak} vs output {out_bytes}"


# ---------------------------------------------------------------------------
# deep-rank smoke: the streaming pipeline end to end
# ---------------------------------------------------------------------------


def test_deep_band_instance_streams_to_the_winner():
    """A small version of the benchmark's deep-band instance: thousands of
    eq-7-passing rows fail placement before the winner; streamed and
    PR-2-style walks agree on winner, rank, and combo."""
    from benchmarks.scheduler_scale import _band_tasks
    from repro.core.scheduler import select_lowest_power_batched

    tasks = _band_tasks(7, 4, base=101.0)
    fleet = FleetSpec(n_f=5, t_slr=100.0, t_cfg=0.0)
    ws = WalkStats()
    res = PADPSFRScheduler(fleet, exhaustive=False).schedule(
        tasks, walk_stats=ws
    )
    assert res.feasible and res.chosen_rank > 100
    combo, _, rank, _ = select_lowest_power_batched(
        iter_feasible_pruned(tasks, fleet), tasks, fleet, block_size=512
    )
    assert rank == res.chosen_rank and combo == res.combo
    # the ramp actually ramped
    assert ws.block_sizes[0] == 64
    assert any(b > 64 for b in ws.block_sizes)
