"""Public-API doctests, wired into tier-1.

Runs :func:`doctest.testmod` over the modules whose docstrings carry
worked examples, so the examples in ``PADPSFRScheduler.schedule`` /
``replan``, ``iter_feasible_pruned_blocks``, ``place_batch`` and
``make_hetero_fleet`` are executed on every test run (the plain pytest
invocation — no ``--doctest-modules`` flag needed).
"""

import doctest

import pytest

import repro.core.feasibility
import repro.core.placement_batched
import repro.core.scheduler
import repro.core.variants

_MODULES = [
    repro.core.feasibility,
    repro.core.placement_batched,
    repro.core.scheduler,
    repro.core.variants,
]


@pytest.mark.parametrize("mod", _MODULES, ids=lambda m: m.__name__)
def test_module_doctests(mod):
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0, f"{mod.__name__} lost its doctest examples"
    assert result.failed == 0
