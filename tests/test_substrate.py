"""Substrate unit tests: optimizers, schedules, compression, data, sharding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.pipeline import SyntheticLM
from repro.optim import (
    AdamW,
    Adafactor,
    ErrorFeedback,
    SGD,
    clip_by_global_norm,
    compress_int8,
    cosine_lr,
    decompress_int8,
    global_norm,
    linear_warmup_cosine,
)
from repro.sharding import PRESETS, resolve_spec
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt_name", ["adamw", "sgd", "adafactor"])
def test_optimizer_minimises_quadratic(opt_name):
    opt = {
        "adamw": AdamW(0.1, weight_decay=0.0),
        "sgd": SGD(0.05),
        "adafactor": Adafactor(0.3),
    }[opt_name]
    target = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for step in range(1000 if opt_name == "adafactor" else 200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(step))
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_shrinks_params():
    opt = AdamW(0.1, weight_decay=0.5, grad_clip=0.0)
    params = {"w": jnp.ones((4,)) * 10.0}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros((4,))}
    p1, _ = opt.update(zero_g, state, params, jnp.int32(0))
    assert float(p1["w"][0]) < 10.0


def test_global_norm_and_clip():
    tree = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    n = float(global_norm(tree))
    assert n == pytest.approx(np.sqrt(9 * 3 + 16 * 4))
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(n)


def test_schedules_shapes():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(0.0)
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)
    c = cosine_lr(2.0, 50)
    assert float(c(jnp.int32(0))) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from([(7,), (16,), (3, 5), (128,), (300,)]),
    scale=st.floats(1e-3, 1e3),
)
def test_int8_roundtrip_error_bounded(shape, scale):
    x = np.random.default_rng(0).standard_normal(shape).astype(np.float32) * scale
    q, s = compress_int8(jnp.asarray(x))
    assert q.dtype == jnp.int8
    back = decompress_int8(q, s, shape, jnp.float32)
    # per-block max error <= scale/127 within each 256-block
    err = np.abs(np.asarray(back) - x)
    assert err.max() <= np.abs(x).max() / 127.0 + 1e-6


def test_error_feedback_converges_in_mean():
    """With EF, quantisation error doesn't accumulate: the running sum of
    compressed grads tracks the true sum."""
    rng = np.random.default_rng(1)
    g_true = [rng.standard_normal(64).astype(np.float32) for _ in range(50)]
    residual = ErrorFeedback.init({"g": jnp.zeros(64)})
    acc_c, acc_t = np.zeros(64), np.zeros(64)
    for g in g_true:
        out, residual = ErrorFeedback.apply({"g": jnp.asarray(g)}, residual)
        acc_c += np.asarray(out["g"])
        acc_t += g
    # EF keeps cumulative drift to the size of one step's error
    assert np.abs(acc_c - acc_t).max() < np.abs(g_true[-1]).max()


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_host_sharded():
    ds = SyntheticLM(vocab=1000, seq_len=32, global_batch=8, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the global batch
    h0 = ds.batch(5, host_id=0, host_count=2)
    h1 = ds.batch(5, host_id=1, host_count=2)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_has_learnable_structure():
    ds = SyntheticLM(vocab=257, seq_len=128, global_batch=4, seed=0, structure=1.0)
    t = ds.batch(0)["tokens"]
    a = 6364136223846793005 % 257
    b = 1442695040888963407 % 257
    np.testing.assert_array_equal(t[:, 1:], (t[:, :-1] * a + b) % 257)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh22():
    from repro.launch.mesh import make_mesh

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    return make_mesh((1, 1), ("data", "model"))


def test_resolve_spec_drops_nondividing_axes():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    rules = PRESETS["fsdp_tp"]
    # vocab 7 not divisible by the 1-sized axis is fine (1 divides);
    # use shape-math directly on the resolve function
    spec = resolve_spec(("vocab", "embed"), (7, 16), mesh, rules)
    assert isinstance(spec, P)


def test_resolve_spec_no_duplicate_mesh_axes():
    """A mesh axis never shards two dims of one tensor."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    rules = PRESETS["fsdp_tp_sp"]
    spec = resolve_spec(("batch", "act_seq", "mlp"), (16, 64, 64), mesh, rules)
    flat = [a for part in spec for a in ((part,) if isinstance(part, str) else (part or ()))]
    assert len(flat) == len(set(flat))


def test_preset_tables_cover_all_logical_axes():
    needed = {
        "batch", "heads", "kv", "mlp", "vocab", "expert", "state",
        "embed", "layers", "conv", "seq", "act_seq",
    }
    for name, rules in PRESETS.items():
        assert needed <= set(rules.table), name
