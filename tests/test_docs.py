"""Docs stay true: links resolve and architecture snippets execute.

Runs ``tools/check_docs.py`` in a subprocess (same invocation as the CI
docs leg) so documented APIs can't drift from the real ones.
"""

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(extra):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "tools/check_docs.py"] + extra,
        capture_output=True, text=True, timeout=300, env=env, cwd=_ROOT,
    )


def test_docs_links_and_snippets():
    proc = _run([])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
    # the architecture tour must actually exercise code, not just prose
    assert "snippet(s) executed" in proc.stdout
    assert "0 snippet(s)" not in proc.stdout


def test_docs_checker_catches_broken_link(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does/not/exist.py)\n")
    proc = _run(["--no-snippets", str(bad)])
    assert proc.returncode == 1
    assert "broken link" in proc.stdout
