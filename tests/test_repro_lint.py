"""Tests for ``tools/repro_lint`` — the repo-native invariant analyzer.

Every rule id gets a positive fixture (a minimal snippet that must fire)
and a negative one (the idiomatic-clean twin that must stay silent), so a
rule regression shows up as a named fixture failure rather than as noise
in CI.  The suite also pins the suppression round-trip, the ``--json``
schema, the CLI exit codes, and — the meta-invariant — that the analyzer
is clean on its own source.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.repro_lint import all_rules, lint_source, run_paths  # noqa: E402
from tools.repro_lint.engine import to_json  # noqa: E402
from tools.repro_lint.rules import backend_contract  # noqa: E402

CORE = "src/repro/core/snippet.py"  # path inside the precision/sched scope
PLAIN = "snippet.py"  # path outside every path-scoped rule


def rules_of(source, path=PLAIN, select=None):
    return [f.rule for f in lint_source(textwrap.dedent(source), path, select)]


def assert_fires(rule, source, path=PLAIN):
    got = rules_of(source, path, select=[rule])
    assert got, f"{rule} did not fire on:\n{textwrap.dedent(source)}"


def assert_clean(rule, source, path=PLAIN):
    got = rules_of(source, path, select=[rule])
    assert not got, f"{rule} false positive ({got}) on:\n{textwrap.dedent(source)}"


class TestCatalog:
    def test_all_rule_ids_present(self):
        catalog = all_rules()
        expected = {
            "E001", "S001",
            "B101", "B102", "B103",
            "P201", "P202", "P203",
            "T301", "T302", "T303",
            "D401", "D402", "D403", "D404",
        }
        assert expected == set(catalog)
        assert all(isinstance(v, str) and v for v in catalog.values())


class TestE001:
    def test_syntax_error_is_a_finding_not_a_crash(self):
        findings = lint_source("def f(:\n", "bad.py")
        assert [f.rule for f in findings] == ["E001"]
        assert findings[0].line == 1


class TestP201:
    def test_float_literal_equality(self):
        assert_fires("P201", "ok = x == 1.0\n")

    def test_division_result_equality(self):
        assert_fires("P201", "ok = (a / b) != c\n")

    def test_float_call_equality(self):
        assert_fires("P201", "ok = float(x) == y\n")

    def test_integer_equality_clean(self):
        assert_clean("P201", "ok = x == 1\n")

    def test_float_ordering_clean(self):
        # Ordering comparisons are the eq-7 idiom; only ==/!= are suspect.
        assert_clean("P201", "ok = x >= 1.0\n")

    def test_identity_clean(self):
        assert_clean("P201", "ok = x is None\n")


class TestP202:
    def test_f32_cast_reaches_comparison(self):
        assert_fires(
            "P202",
            """
            def f(x, thr):
                y = x.astype(np.float32)
                return y > thr
            """,
            path=CORE,
        )

    def test_f32_cast_reaches_selection(self):
        assert_fires(
            "P202",
            """
            def f(p):
                q = jnp.float32(p)
                return np.argsort(q)
            """,
            path=CORE,
        )

    def test_select_then_cast_clean(self):
        # The required order: survivor selection at float64, cast after.
        assert_clean(
            "P202",
            """
            def f(p):
                idx = np.argsort(p)
                q = p.astype(np.float32)
                return idx, q
            """,
            path=CORE,
        )

    def test_identity_test_on_cast_value_clean(self):
        assert_clean(
            "P202",
            """
            def f(x):
                y = x.astype(np.float32)
                return y is not None
            """,
            path=CORE,
        )

    def test_out_of_scope_module_clean(self):
        # ML model code routes at f32 by design; the rule is scoped.
        assert_clean(
            "P202",
            """
            def route(logits):
                w = logits.astype(jnp.float32)
                return jnp.argsort(w)
            """,
            path="src/repro/models/layers.py",
        )

    def test_pragma_opts_module_in(self):
        assert_fires(
            "P202",
            """
            # repro-lint: precision-critical
            def f(x, thr):
                y = x.astype(np.float32)
                return y > thr
            """,
            path=PLAIN,
        )


class TestP203:
    def test_asarray_without_dtype(self):
        assert_fires("P203", "y = jnp.asarray(x)\n", path=CORE)

    def test_asarray_with_dtype_clean(self):
        assert_clean("P203", "y = jnp.asarray(x, dtype=jnp.float64)\n", path=CORE)

    def test_explicit_f32_allocation(self):
        assert_fires("P203", "y = np.zeros(n, dtype=np.float32)\n", path=CORE)

    def test_f64_allocation_clean(self):
        assert_clean("P203", "y = np.zeros(n, dtype=np.float64)\n", path=CORE)

    def test_out_of_scope_clean(self):
        assert_clean("P203", "y = jnp.asarray(x)\n", path="src/repro/models/x.py")


class TestT301:
    def test_if_on_traced_value(self):
        assert_fires(
            "T301",
            """
            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
            """,
        )

    def test_shape_check_clean(self):
        assert_clean(
            "T301",
            """
            @jax.jit
            def f(x):
                if x.shape[0] > 0:
                    return x
                return -x
            """,
        )

    def test_static_argnames_clean(self):
        assert_clean(
            "T301",
            """
            @partial(jax.jit, static_argnames=("n",))
            def f(x, n):
                if n > 2:
                    return x * n
                return x
            """,
        )

    def test_bool_call(self):
        assert_fires(
            "T301",
            """
            @jax.jit
            def f(x):
                return bool(x > 0)
            """,
        )

    def test_function_passed_to_while_loop(self):
        assert_fires(
            "T301",
            """
            def cond(s):
                if s > 0:
                    return True
                return False

            out = lax.while_loop(cond, body, x0)
            """,
        )

    def test_undecorated_function_clean(self):
        assert_clean(
            "T301",
            """
            def f(x):
                if x > 0:
                    return x
                return -x
            """,
        )


class TestT302:
    def test_item_call(self):
        assert_fires(
            "T302",
            """
            @jax.jit
            def f(x):
                return x.sum().item()
            """,
        )

    def test_float_call(self):
        assert_fires(
            "T302",
            """
            @jax.jit
            def f(x):
                return float(x[0])
            """,
        )

    def test_np_asarray(self):
        assert_fires(
            "T302",
            """
            @jax.jit
            def f(x):
                return np.asarray(x)
            """,
        )

    def test_len_clean(self):
        assert_clean(
            "T302",
            """
            @jax.jit
            def f(x):
                return x * len(x.shape)
            """,
        )


class TestT303:
    def test_mutable_global_read(self):
        assert_fires(
            "T303",
            """
            CACHE = {}

            @jax.jit
            def f(x):
                return x + CACHE["bias"]
            """,
        )

    def test_global_statement(self):
        assert_fires(
            "T303",
            """
            @jax.jit
            def f(x):
                global COUNT
                COUNT = COUNT + 1
                return x
            """,
        )

    def test_immutable_module_constant_clean(self):
        assert_clean(
            "T303",
            """
            SCALE = 2.5

            @jax.jit
            def f(x):
                return x * SCALE
            """,
        )


class TestD401:
    def test_for_over_set_literal(self):
        assert_fires("D401", "for x in {1, 2, 3}:\n    print(x)\n")

    def test_for_over_set_bound_name(self):
        assert_fires(
            "D401",
            """
            names = set(items)
            for n in names:
                emit(n)
            """,
        )

    def test_list_materialisation(self):
        assert_fires("D401", "xs = list({1, 2})\n")

    def test_star_unpack(self):
        assert_fires("D401", "f(*{1, 2})\n")

    def test_sorted_set_clean(self):
        assert_clean("D401", "for x in sorted({1, 2, 3}):\n    print(x)\n")

    def test_order_free_consumer_clean(self):
        assert_clean("D401", "n = len({1, 2}); m = max({1, 2})\n")


class TestD402:
    def test_unsorted_listdir(self):
        assert_fires("D402", "names = os.listdir(path)\n")

    def test_sorted_listdir_clean(self):
        assert_clean("D402", "names = sorted(os.listdir(path))\n")

    def test_path_iterdir(self):
        assert_fires("D402", "for p in base.iterdir():\n    load(p)\n")

    def test_glob_glob(self):
        assert_fires("D402", "hits = glob.glob(pattern)\n")

    def test_ast_walk_clean(self):
        # `walk` alone must not match: ast.walk is not filesystem enumeration.
        assert_clean("D402", "for node in ast.walk(tree):\n    visit(node)\n")


class TestD403:
    def test_legacy_np_random(self):
        assert_fires("D403", "x = np.random.rand(3)\n")

    def test_default_rng_clean(self):
        assert_clean(
            "D403", "rng = np.random.default_rng(0)\nx = rng.standard_normal(3)\n"
        )

    def test_stdlib_random_module_call(self):
        assert_fires("D403", "x = random.random()\n")

    def test_random_instance_clean(self):
        assert_clean("D403", "rng = random.Random(0)\nx = rng.random()\n")

    def test_from_import_sampler(self):
        assert_fires("D403", "from random import shuffle\nshuffle(xs)\n")


class TestD404:
    def test_wall_clock_in_core(self):
        assert_fires("D404", "t = time.time()\n", path=CORE)

    def test_wall_clock_in_service(self):
        assert_fires(
            "D404", "now = datetime.now()\n", path="src/repro/service/x.py"
        )

    def test_perf_counter_clean(self):
        assert_clean("D404", "t = time.perf_counter()\n", path=CORE)

    def test_out_of_scope_clean(self):
        assert_clean("D404", "t = time.time()\n", path="benchmarks/x.py")


# --- B1xx: backend-contract conformance (needs files next to a base.py) ----

MINI_BASE = textwrap.dedent(
    """
    class PlacementBackend:
        def place_block(self, shares, iis, t_slr, t_cfg, opts=None):
            ...

    def dispatch_instance_blocks(backend, batch, opts=None, *, shard=None):
        ...
    """
)

GOOD_BACKEND = textwrap.dedent(
    """
    @register_backend("good")
    class GoodBackend:
        name = "good"

        def place_block(self, shares, iis, t_slr, t_cfg, opts=None): ...
        def dispatch_block(self, shares, iis, t_slr, t_cfg, opts=None): ...
        def place_blocks(self, batch, opts=None, *, shard=None): ...
        def dispatch_blocks(self, batch, opts=None, *, shard=None): ...
        def dispatch_blocks_raw(self, batch, opts=None, *, shard=None): ...
    """
)


def lint_backend_dir(tmp_path, module_source, base_source=MINI_BASE):
    backend_contract._reset_cache()
    d = tmp_path / "placement_backends"
    d.mkdir()
    (d / "base.py").write_text(base_source)
    (d / "candidate.py").write_text(textwrap.dedent(module_source))
    result = run_paths([str(d / "candidate.py")], root=str(tmp_path))
    return [f for f in result.findings if f.rule.startswith("B")]


class TestBackendContract:
    def test_conforming_backend_clean(self, tmp_path):
        assert lint_backend_dir(tmp_path, GOOD_BACKEND) == []

    def test_missing_method_b101(self, tmp_path):
        source = GOOD_BACKEND.replace(
            "    def dispatch_blocks_raw(self, batch, opts=None, *, shard=None): ...\n",
            "",
        )
        findings = lint_backend_dir(tmp_path, source)
        assert [f.rule for f in findings] == ["B101"]
        assert "dispatch_blocks_raw" in findings[0].message

    def test_signature_mismatch_b102(self, tmp_path):
        # `shard` demoted from keyword-only to positional: structural drift.
        source = GOOD_BACKEND.replace(
            "def place_blocks(self, batch, opts=None, *, shard=None)",
            "def place_blocks(self, batch, opts=None, shard=None)",
        )
        findings = lint_backend_dir(tmp_path, source)
        assert [f.rule for f in findings] == ["B102"]
        assert "place_blocks" in findings[0].message

    def test_registry_name_mismatch_b103(self, tmp_path):
        source = GOOD_BACKEND.replace('name = "good"', 'name = "g00d"')
        findings = lint_backend_dir(tmp_path, source)
        assert [f.rule for f in findings] == ["B103"]

    def test_unregistered_backend_b103(self, tmp_path):
        source = textwrap.dedent(
            """
            class ShadowBackend:
                name = "shadow"

                def place_block(self, shares, iis, t_slr, t_cfg, opts=None): ...
            """
        )
        findings = lint_backend_dir(tmp_path, source)
        assert "B103" in [f.rule for f in findings]

    def test_specs_derive_from_base_not_fallback(self, tmp_path):
        # Widen base.py's protocol; the same backend must now be out of date.
        widened = MINI_BASE.replace(
            "t_slr, t_cfg, opts=None", "t_slr, t_cfg, budgets, opts=None"
        )
        findings = lint_backend_dir(tmp_path, GOOD_BACKEND, base_source=widened)
        assert {f.rule for f in findings} == {"B102"}
        assert any("budgets" in f.message for f in findings)

    def test_outside_backend_dir_not_checked(self, tmp_path):
        backend_contract._reset_cache()
        (tmp_path / "candidate.py").write_text(textwrap.dedent(GOOD_BACKEND))
        result = run_paths([str(tmp_path / "candidate.py")], root=str(tmp_path))
        assert [f for f in result.findings if f.rule.startswith("B")] == []


class TestSuppression:
    def test_suppression_round_trip(self, tmp_path):
        src = "x = np.random.rand(3)  # repro-lint: ignore[D403]  # fixture demo\n"
        p = tmp_path / "mod.py"
        p.write_text(src)
        result = run_paths([str(p)], root=str(tmp_path))
        assert result.ok
        assert len(result.suppressed) == 1
        finding, reason = result.suppressed[0]
        assert finding.rule == "D403"
        assert reason == "fixture demo"

    def test_suppression_only_covers_listed_rules(self):
        src = "t = time.time()  # repro-lint: ignore[D403]  # wrong rule id\n"
        assert rules_of(src, path=CORE) == ["D404"]

    def test_multiple_ids_one_comment(self):
        src = (
            "for x in {1, 2}:  # repro-lint: ignore[D401,D402]  # demo\n"
            "    pass\n"
        )
        assert rules_of(src) == []

    def test_reasonless_suppression_is_s001(self):
        src = "x = np.random.rand(3)  # repro-lint: ignore[D403]\n"
        got = rules_of(src)
        # A reasonless ignore is not a suppression at all: the original
        # finding stays AND the comment itself is flagged.
        assert "S001" in got
        assert "D403" in got

    def test_s001_is_unsuppressable(self):
        src = "x = 1  # repro-lint: ignore[S001]\n"
        assert "S001" in rules_of(src)

    def test_multiline_statement_suppressed_at_first_line(self):
        src = (
            "ok = (x ==  # repro-lint: ignore[P201]  # bit-exact by contract\n"
            "      1.0)\n"
        )
        assert rules_of(src) == []


class TestJsonSchema:
    def test_schema_version_1(self, tmp_path):
        p = tmp_path / "mod.py"
        p.write_text(
            "x = np.random.rand(2)\n"
            "y = 3  # repro-lint: ignore[D401]  # no-op demo suppression\n"
        )
        payload = json.loads(to_json(run_paths([str(p)], root=str(tmp_path))))
        assert set(payload) == {
            "version", "rules", "files", "findings", "suppressed", "counts"
        }
        assert payload["version"] == 1
        assert payload["rules"] == all_rules()
        assert payload["files"] == ["mod.py"]
        (finding,) = payload["findings"]
        assert set(finding) == {"rule", "path", "line", "col", "message"}
        assert finding["rule"] == "D403"
        assert payload["counts"] == {"findings": 1, "suppressed": 0, "files": 1}

    def test_suppressed_entries_carry_reason(self, tmp_path):
        p = tmp_path / "mod.py"
        p.write_text("x = random.random()  # repro-lint: ignore[D403]  # why\n")
        payload = json.loads(to_json(run_paths([str(p)], root=str(tmp_path))))
        assert payload["findings"] == []
        (sup,) = payload["suppressed"]
        assert sup["rule"] == "D403" and sup["reason"] == "why"


def run_cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", *argv],
        cwd=cwd,
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": str(REPO)},
    )


class TestCli:
    def test_injected_violation_fails_with_rule_id(self, tmp_path):
        p = tmp_path / "dirty.py"
        p.write_text("import numpy as np\nx = np.random.rand(4)\n")
        proc = run_cli(str(p), "--root", str(tmp_path))
        assert proc.returncode == 1
        assert "D403" in proc.stdout
        assert "dirty.py:2" in proc.stdout

    def test_clean_file_exits_zero(self, tmp_path):
        p = tmp_path / "clean.py"
        p.write_text("rng = np.random.default_rng(7)\n")
        proc = run_cli(str(p), "--root", str(tmp_path))
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_json_flag_emits_parseable_report(self, tmp_path):
        p = tmp_path / "dirty.py"
        p.write_text("t = time.time()\n")
        proc = run_cli(
            str(p), "--root", str(tmp_path), "--json", cwd=REPO
        )
        payload = json.loads(proc.stdout)
        assert payload["version"] == 1

    def test_no_paths_is_usage_error(self):
        assert run_cli().returncode == 2

    def test_list_rules(self):
        proc = run_cli("--list-rules")
        assert proc.returncode == 0
        for rid in ("B101", "P202", "T301", "D404", "S001"):
            assert rid in proc.stdout


class TestSelfClean:
    def test_analyzer_is_clean_on_itself(self):
        result = run_paths(["tools/repro_lint"], root=str(REPO))
        assert result.ok, [f.render() for f in result.findings]

    def test_backend_modules_conform(self):
        # The real placement backends are the contract's raison d'etre.
        result = run_paths(
            ["src/repro/core/placement_backends"], root=str(REPO)
        )
        bad = [f for f in result.findings if f.rule.startswith("B")]
        assert bad == [], [f.render() for f in bad]


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
