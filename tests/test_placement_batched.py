"""Batched placement engine vs the scalar oracle: exact-parity tests.

The batched engine (``repro.core.placement_batched``) must agree with the
scalar Alg-2/Alg-3 simulation bit-for-bit: on the paper's worked examples
(Figs 2-4), on >= 200 randomized task-set x heterogeneous-fleet instances,
and (where hypothesis is installed) on property-generated instances.
"""

import numpy as np
import pytest

from repro.configs.paper_examples import (
    example1_fleet,
    example1_tasks,
    example2_fleet,
    example2_tasks,
    example3_fleet,
    example3_tasks,
)
from repro.core import (
    DeviceProfile,
    FleetSpec,
    PADPSFRScheduler,
    Task,
    TaskVariant,
    config_overhead_lower_bound,
    place_batch,
    place_combo,
    place_shares,
    render_gantt,
    search_feasible,
)
from repro.core.variants import make_hetero_fleet


def _assert_results_identical(rb, rs):
    """Batched and scalar ScheduleResults must match field-for-field."""
    assert rb.feasible == rs.feasible
    assert rb.chosen_rank == rs.chosen_rank
    assert rb.n_placement_rejects == rs.n_placement_rejects
    assert rb.total_power == rs.total_power
    if not rb.feasible:
        return
    assert rb.combo == rs.combo  # variant indices, shares, powers — exact
    # Same per-device splits: the winner's plan comes from the same oracle,
    # but assert anyway — this is the contract the issue pins.
    assert len(rb.plan.splits) == len(rs.plan.splits)
    for a, b in zip(rb.plan.splits, rs.plan.splits, strict=True):
        assert a.task == b.task
        assert a.devices == b.devices
        assert a.share_parts == b.share_parts


def _mask_parity(tasks, fleet):
    """Per-row feasibility/split parity over the full power-sorted TFS."""
    feas = search_feasible(tasks, fleet)
    order = feas.tfs_indices_by_power()
    if order.size == 0:
        return 0
    iis = [t.init_interval for t in tasks]
    bp = place_batch(feas.shares_matrix(order), iis, fleet)
    for i, fi in enumerate(order):
        plan = place_combo(feas.combo_at(int(fi)), tasks, fleet)
        assert plan.feasible == bool(bp.feasible[i]), f"row {i}"
        if plan.feasible:
            assert plan.n_splits == int(bp.n_splits[i]), f"row {i}"
    return int(order.size)


# ---------------------------------------------------------------------------
# fixed regressions: the paper's worked examples (Figs 2-4)
# ---------------------------------------------------------------------------


class TestPaperExamples:
    @pytest.mark.parametrize(
        "tasks_fn,fleet_fn",
        [
            (example1_tasks, example1_fleet),
            (example2_tasks, example2_fleet),
            (example3_tasks, example3_fleet),
        ],
        ids=["example1", "example2", "example3"],
    )
    def test_schedule_identical_to_scalar(self, tasks_fn, fleet_fn):
        tasks, fleet = tasks_fn(), fleet_fn()
        rb = PADPSFRScheduler(fleet, engine="batched").schedule(
            tasks, count_all_rejects=True
        )
        rs = PADPSFRScheduler(fleet, engine="scalar").schedule(
            tasks, count_all_rejects=True
        )
        _assert_results_identical(rb, rs)

    def test_example1_full_tfs_mask_parity(self):
        n = _mask_parity(example1_tasks(), example1_fleet())
        assert n == 620  # the paper's |TFS|

    def test_example1_winner_fig2_splits(self):
        # Fig 2 pinning through the batched path: T3 splits 12:12 on F2/F3.
        res = PADPSFRScheduler(example1_fleet()).schedule(example1_tasks())
        assert res.chosen_rank == 4
        assert len(res.plan.splits) == 1
        sp = res.plan.splits[0]
        assert sp.task == 2 and sp.devices == (1, 2)
        assert [round(p) for p in sp.share_parts] == [12, 12]

    def test_example2_rejected_row_rejected_by_batch(self):
        # Fig 3: II(T3)=12 makes the Example-1 winner un-placeable; the
        # batched engine must reject the same row.
        fleet = example2_fleet()
        shares = np.asarray([[48, 36, 24, 32, 24, 24]], dtype=np.float64)
        bp = place_batch(shares, [2, 4, 12, 4, 6, 6], fleet)
        assert not bp.feasible[0]
        assert not place_shares([48, 36, 24, 32, 24, 24], [2, 4, 12, 4, 6, 6], fleet).feasible

    def test_example3_full_tfs_mask_parity(self):
        _mask_parity(example3_tasks(), example3_fleet())


# ---------------------------------------------------------------------------
# randomized parity: >= 200 task-set x heterogeneous-fleet instances
# ---------------------------------------------------------------------------


def _random_tasks(rng, max_tasks=5, max_variants=3):
    n_t = int(rng.integers(1, max_tasks + 1))
    out = []
    for i in range(n_t):
        nv = int(rng.integers(1, max_variants + 1))
        ths = np.sort(rng.uniform(0.3, 4.0, nv))
        pws = np.sort(rng.uniform(1.0, 9.0, nv))
        out.append(
            Task(
                name=f"T{i}",
                period=float(rng.uniform(20.0, 100.0)),
                data=float(rng.uniform(5.0, 80.0)),
                init_interval=float(rng.uniform(0.0, 8.0)),
                variants=tuple(
                    TaskVariant(cu=j + 1, throughput=float(th), power=float(pw))
                    for j, (th, pw) in enumerate(zip(ths, pws, strict=True))
                ),
            )
        )
    return out


def _random_fleet(rng, max_devices=6):
    n_f = int(rng.integers(1, max_devices + 1))
    klasses = ["fpga", "gpu", "cpu"]
    profiles = tuple(
        DeviceProfile(
            t_slr=float(rng.uniform(30.0, 120.0)),
            # GPUs/CPUs get t_cfg ~ 0; FPGAs pay a real reconfiguration.
            t_cfg=0.0 if (k := klasses[int(rng.integers(3))]) in ("gpu", "cpu")
            else float(rng.uniform(0.5, 10.0)),
            klass=k,
        )
        for _ in range(n_f)
    )
    return FleetSpec.heterogeneous(profiles)


def test_randomized_hetero_parity_200_instances():
    rng = np.random.default_rng(42)
    rows_checked = 0
    schedules_checked = 0
    for _ in range(200):
        tasks = _random_tasks(rng)
        fleet = _random_fleet(rng)
        rows_checked += _mask_parity(tasks, fleet)
        rb = PADPSFRScheduler(fleet, engine="batched").schedule(
            tasks, count_all_rejects=True
        )
        rs = PADPSFRScheduler(fleet, engine="scalar").schedule(
            tasks, count_all_rejects=True
        )
        _assert_results_identical(rb, rs)
        schedules_checked += 1
    assert schedules_checked == 200
    assert rows_checked > 1000  # the masks actually exercised real TFS rows


def test_randomized_homogeneous_parity_with_preemption_model():
    """Parity holds under the refs-[9]/[10] capture/store placement knobs."""
    rng = np.random.default_rng(7)
    for _ in range(50):
        tasks = _random_tasks(rng, max_tasks=4)
        fleet = FleetSpec(
            n_f=int(rng.integers(1, 5)),
            t_slr=float(rng.uniform(30.0, 120.0)),
            t_cfg=float(rng.uniform(0.0, 8.0)),
        )
        kw = dict(t_capture=12.0, t_store=12.0, repay_init=False)
        feas = search_feasible(tasks, fleet)
        order = feas.tfs_indices_by_power()
        if order.size == 0:
            continue
        iis = [t.init_interval for t in tasks]
        bp = place_batch(feas.shares_matrix(order), iis, fleet, **kw)
        for i, fi in enumerate(order):
            plan = place_combo(feas.combo_at(int(fi)), tasks, fleet, **kw)
            assert plan.feasible == bool(bp.feasible[i])


# ---------------------------------------------------------------------------
# heterogeneity semantics
# ---------------------------------------------------------------------------


class TestHeterogeneousFleet:
    def test_make_hetero_fleet_classes(self):
        fleet = make_hetero_fleet({"fpga": 2, "gpu": 1, "cpu": 1}, t_slr=100.0)
        assert fleet.n_f == 4
        assert fleet.is_heterogeneous
        assert [d.klass for d in fleet.devices] == ["fpga", "fpga", "gpu", "cpu"]
        # GPUs/CPUs reconfigure for ~free, FPGAs don't
        assert fleet.devices[2].t_cfg < fleet.devices[0].t_cfg
        assert fleet.devices[3].t_cfg == 0.0
        # CPU capacity derates
        assert fleet.devices[3].t_slr < fleet.devices[0].t_slr

    def test_homogeneous_reduction(self):
        """A heterogeneous fleet of identical profiles == the scalar fleet."""
        base = FleetSpec(n_f=4, t_slr=60.0, t_cfg=6.0)
        hetero = FleetSpec.heterogeneous(
            tuple(DeviceProfile(t_slr=60.0, t_cfg=6.0) for _ in range(4))
        )
        tasks = example1_tasks()
        rh = PADPSFRScheduler(hetero).schedule(tasks, count_all_rejects=True)
        rb = PADPSFRScheduler(base).schedule(tasks, count_all_rejects=True)
        _assert_results_identical(rh, rb)
        assert hetero.workable_budget(6) == base.workable_budget(6)

    def test_eq7_refinement_sound_at_zero_extra_cfgs(self):
        """With ``extra_cfgs=0`` the per-class overhead bound is a strict
        necessary condition: every combo it rejects is truly unplaceable.

        (The default ``extra_cfgs=1`` deliberately inherits the paper's
        one-split allowance, which — exactly like the homogeneous eq. 7 —
        may reject a combo that happens to place with no split; that is
        the documented Example-1 accounting, not a refinement bug.)
        """
        rng = np.random.default_rng(3)
        checked = 0
        for _ in range(60):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng)
            feas = search_feasible(tasks, fleet)
            iis = [t.init_interval for t in tasks]
            overhead = config_overhead_lower_bound(
                fleet, len(tasks), feas.sum_shr, extra_cfgs=0
            )
            rejected = np.flatnonzero(
                feas.sum_shr > fleet.capacity - overhead + 1e-9
            )
            if rejected.size == 0:
                continue
            bp = place_batch(feas.shares_matrix(rejected), iis, fleet)
            assert not bp.feasible.any(), "strict bound rejected a placeable combo"
            checked += int(rejected.size)
        assert checked > 100

    def test_eq7_refinement_sound_across_seeds(self):
        """The seeds the strict bound must survive include those that break
        the (false) extra_cfgs=1 'soundness' reading."""
        for seed in (3, 6, 7, 8, 18):
            rng = np.random.default_rng(seed)
            for _ in range(20):
                tasks = _random_tasks(rng, max_tasks=4)
                fleet = _random_fleet(rng)
                feas = search_feasible(tasks, fleet)
                iis = [t.init_interval for t in tasks]
                overhead = config_overhead_lower_bound(
                    fleet, len(tasks), feas.sum_shr, extra_cfgs=0
                )
                rejected = np.flatnonzero(
                    feas.sum_shr > fleet.capacity - overhead + 1e-9
                )
                if rejected.size == 0:
                    continue
                bp = place_batch(feas.shares_matrix(rejected), iis, fleet)
                assert not bp.feasible.any()

    def test_streaming_engine_matches_exhaustive_on_hetero(self):
        """iter_feasible_pruned applies the same hetero eq-7 refinement as
        search_feasible: identical TFS stream, rejects, and chosen rank."""
        from repro.core import iter_feasible_pruned

        rng = np.random.default_rng(5)
        for _ in range(40):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng)
            feas = search_feasible(tasks, fleet)
            exhaustive = [c.variant_idx for c in feas.iter_tfs_by_power()]
            streamed = [c.variant_idx for c in iter_feasible_pruned(tasks, fleet)]
            assert sorted(exhaustive) == sorted(streamed)
            re = PADPSFRScheduler(fleet, exhaustive=True).schedule(
                tasks, count_all_rejects=True
            )
            rs = PADPSFRScheduler(fleet, exhaustive=False).schedule(
                tasks, count_all_rejects=True
            )
            assert re.feasible == rs.feasible
            assert re.chosen_rank == rs.chosen_rank
            assert re.n_placement_rejects == rs.n_placement_rejects
            if re.feasible:
                assert re.combo == rs.combo

    def test_refinement_reduces_to_paper_charge_homogeneous(self):
        fleet = example1_fleet()  # n_f=4, t_slr=60, t_cfg=6
        w = np.asarray([100.0, 150.0, 178.0])
        overhead = config_overhead_lower_bound(fleet, n_t=6, sum_shr=w)
        np.testing.assert_allclose(overhead, 7 * 6.0)  # (n_t+1) * t_cfg

    def test_gpu_device_hosts_more_tasks_than_fpga(self):
        """With t_cfg=0 a GPU packs tasks an FPGA of equal capacity cannot."""
        shares = [30.0, 30.0, 30.0]
        iis = [0.0, 0.0, 0.0]
        fpga_only = FleetSpec(n_f=1, t_slr=100.0, t_cfg=8.0)
        gpu_only = FleetSpec.heterogeneous(
            (DeviceProfile(t_slr=100.0, t_cfg=0.0, klass="gpu"),)
        )
        assert not place_shares(shares, iis, fpga_only).feasible
        assert place_shares(shares, iis, gpu_only).feasible
        bp = place_batch(np.asarray([shares]), iis, gpu_only)
        assert bp.feasible[0]

    def test_hetero_gantt_renders_device_classes(self):
        fleet = make_hetero_fleet({"fpga": 2, "gpu": 1}, t_slr=80.0)
        tasks = _random_tasks(np.random.default_rng(11), max_tasks=3)
        res = PADPSFRScheduler(fleet).schedule(tasks)
        if not res.feasible:
            pytest.skip("random instance infeasible on this fleet")
        txt = render_gantt(res.plan, tasks, fleet)
        assert "heterogeneous fleet" in txt
        assert "F1[f]" in txt and "F3[g]" in txt

    def test_with_devices_cycles_profile_pattern(self):
        fleet = make_hetero_fleet({"fpga": 1, "gpu": 1}, t_slr=50.0)
        grown = fleet.with_devices(5)
        assert [d.klass for d in grown.devices] == ["fpga", "gpu", "fpga", "gpu", "fpga"]

    def test_with_t_cfg_scales_proportionally(self):
        fleet = make_hetero_fleet({"fpga": 1, "gpu": 1}, t_slr=50.0)
        doubled = fleet.with_t_cfg(fleet.t_cfg * 2)
        assert doubled.devices[0].t_cfg == pytest.approx(fleet.devices[0].t_cfg * 2)
        assert doubled.devices[1].t_cfg == pytest.approx(fleet.devices[1].t_cfg * 2)


# The hypothesis-based parity property test lives in
# tests/test_core_properties.py (module-gated on hypothesis availability)
# so this file's 200-instance randomized parity always runs.
