"""Fleet-parallel batch scheduling: ``schedule_many`` parity and semantics.

The batched lockstep walk (``PADPSFRScheduler.schedule_many``) must be an
exact drop-in for a Python loop of solo ``schedule()`` calls, per engine:

* edge semantics — ``schedule_many([])`` is ``[]``, a singleton batch
  equals the solo call field-for-field, and an infeasible instance in a
  mixed batch yields its own ``feasible=False`` result without touching
  its batchmates;
* >= 50 randomized heterogeneous instances (ragged task counts, variant
  counts and fleets mixed in one batch) bit-identical to the solo loop on
  every engine, including exact total-power ties;
* ``InstanceBatch.pack`` shape/padding contract (uniform fast path and
  ragged fallback) and the raw untrimmed ``dispatch_blocks_raw`` surface;
* ``shard=`` layout: graceful single-device degrade, clamping, and — on
  multi-device hosts (CI forces 4 via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``) — shard_map
  parity with the unsharded walk.

The randomized-instance harness is shared with
``tests/test_placement_batched.py``.
"""

import numpy as np
import pytest

from repro.core import (
    FleetSpec,
    PADPSFRScheduler,
    ScheduleInstance,
    Task,
    TaskVariant,
)
from repro.core.placement_backends import InstanceBatch, PlacementOptions, get_backend

from test_placement_batched import (
    _assert_results_identical,
    _random_fleet,
    _random_tasks,
)

try:
    import jax  # noqa: F401

    HAS_JAX = True
except ImportError:  # pragma: no cover - exercised by the no-jax CI leg
    HAS_JAX = False

needs_jax = pytest.mark.skipif(not HAS_JAX, reason="jax not installed")

ENGINES = [
    "scalar",
    "numpy",
    pytest.param("jax", marks=needs_jax),
    pytest.param("pallas", marks=needs_jax),
]
# Engines with a true batched surface (scalar loops solo schedules by
# definition, so batching it against itself proves nothing).
BATCHED_ENGINES = [
    "numpy",
    pytest.param("jax", marks=needs_jax),
    pytest.param("pallas", marks=needs_jax),
]


def _solo_loop(insts, base_fleet, engine="numpy", **kw):
    """The reference semantics: one solo ``schedule()`` per instance."""
    out = []
    for inst in insts:
        fleet = inst.fleet if inst.fleet is not None else base_fleet
        out.append(PADPSFRScheduler(fleet, engine=engine).schedule(inst.tasks, **kw))
    return out


def _random_instances(rng, n, *, max_tasks=4, max_variants=3):
    return [
        ScheduleInstance(
            tasks=tuple(_random_tasks(rng, max_tasks, max_variants)),
            fleet=_random_fleet(rng, max_devices=4),
        )
        for _ in range(n)
    ]


def _infeasible_tasks():
    """Every variant's share alone exceeds any single-device capacity."""
    return (
        Task(
            name="hog",
            period=10.0,
            data=1000.0,
            init_interval=1.0,
            variants=(TaskVariant(cu=1, throughput=1.0, power=5.0),),
        ),
    )


# ---------------------------------------------------------------------------
# edge semantics, per engine
# ---------------------------------------------------------------------------


class TestEdgeSemantics:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_empty_batch_returns_empty_list(self, engine):
        sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0), engine=engine)
        assert sched.schedule_many([]) == []

    @pytest.mark.parametrize("engine", ENGINES)
    def test_singleton_batch_equals_solo_schedule(self, engine):
        rng = np.random.default_rng(11)
        n = 3 if engine == "pallas" else 8
        for _ in range(n):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng, max_devices=4)
            sched = PADPSFRScheduler(fleet, engine=engine)
            solo = sched.schedule(tasks, count_all_rejects=True)
            many = sched.schedule_many(
                [ScheduleInstance(tasks=tuple(tasks))], count_all_rejects=True
            )
            assert len(many) == 1
            _assert_results_identical(many[0], solo)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_infeasible_instance_in_mixed_batch(self, engine):
        fleet = FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0)

        def v(th, pw):
            return TaskVariant(cu=1, throughput=th, power=pw)

        ok = Task("a", period=10.0, data=20.0, init_interval=1.0,
                  variants=(v(2.0, 5.0), v(4.0, 8.0)))
        insts = [
            ScheduleInstance(tasks=(ok,)),
            ScheduleInstance(tasks=_infeasible_tasks()),
            ScheduleInstance(tasks=(ok,)),
        ]
        sched = PADPSFRScheduler(fleet, engine=engine)
        res = sched.schedule_many(insts)
        assert [r.feasible for r in res] == [True, False, True]
        bad = res[1]
        assert bad.chosen_rank == -1
        assert bad.combo is None and bad.plan is None
        assert bad.total_power == float("inf")
        # The feasible batchmates are untouched by the infeasible one.
        solo = sched.schedule(insts[0].tasks)
        _assert_results_identical(res[0], solo)
        _assert_results_identical(res[2], solo)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_all_infeasible_batch(self, engine):
        fleet = FleetSpec(n_f=1, t_slr=30.0, t_cfg=1.0)
        sched = PADPSFRScheduler(fleet, engine=engine)
        res = sched.schedule_many(
            [ScheduleInstance(tasks=_infeasible_tasks()) for _ in range(3)]
        )
        assert len(res) == 3 and not any(r.feasible for r in res)

    def test_bare_task_sequences_inherit_scheduler_fleet(self):
        fleet = FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0)

        def v(th, pw):
            return TaskVariant(cu=1, throughput=th, power=pw)

        a = Task("a", period=10.0, data=20.0, init_interval=1.0,
                 variants=(v(2.0, 5.0), v(4.0, 8.0)))
        sched = PADPSFRScheduler(fleet)
        res = sched.schedule_many([[a]])
        _assert_results_identical(res[0], sched.schedule([a]))


# ---------------------------------------------------------------------------
# randomized heterogeneous parity: >= 50 instances per batched engine
# ---------------------------------------------------------------------------


class TestRandomizedParity:
    @pytest.mark.parametrize("engine", ["numpy", pytest.param("jax", marks=needs_jax)])
    def test_heterogeneous_batches_match_solo_loop(self, engine):
        """Ragged batches (mixed n_t, nv, fleets) vs the solo loop."""
        rng = np.random.default_rng(2026)
        checked = 0
        while checked < 56:
            insts = _random_instances(rng, int(rng.integers(2, 9)))
            sched = PADPSFRScheduler(
                FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0), engine=engine
            )
            many = sched.schedule_many(insts, count_all_rejects=True)
            ref = _solo_loop(
                insts, sched.fleet, engine=engine, count_all_rejects=True
            )
            for got, want in zip(many, ref, strict=True):
                _assert_results_identical(got, want)
            checked += len(insts)
        assert checked >= 50

    @needs_jax
    def test_pallas_interpret_batches_match_solo_loop(self):
        """Interpret-mode pallas stays bit-identical (smaller sample: slow)."""
        rng = np.random.default_rng(77)
        insts = _random_instances(rng, 6, max_tasks=3, max_variants=2)
        sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0), engine="pallas")
        many = sched.schedule_many(insts, count_all_rejects=True)
        ref = _solo_loop(insts, sched.fleet, engine="numpy", count_all_rejects=True)
        for got, want in zip(many, ref, strict=True):
            _assert_results_identical(got, want)

    @pytest.mark.parametrize("engine", BATCHED_ENGINES)
    def test_exact_power_ties_resolve_identically(self, engine):
        """Combos with exactly equal total power: rank choice must match the
        solo walk bit-for-bit (ties are where ordering bugs hide)."""

        def v(cu, th, pw):
            return TaskVariant(cu=cu, throughput=th, power=pw)

        # Both tasks offer two variants at the SAME power but different
        # shares, so the power-sorted TFS holds runs of exactly-tied rows.
        tied = (
            Task("x", period=10.0, data=20.0, init_interval=1.0,
                 variants=(v(1, 2.0, 5.0), v(2, 4.0, 5.0))),
            Task("y", period=10.0, data=40.0, init_interval=1.0,
                 variants=(v(1, 4.0, 4.0), v(2, 8.0, 4.0))),
        )
        fleet = FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0)
        sched = PADPSFRScheduler(fleet, engine=engine)
        insts = [ScheduleInstance(tasks=tied), ScheduleInstance(tasks=tied[::-1])]
        many = sched.schedule_many(insts, count_all_rejects=True)
        for got, inst in zip(many, insts, strict=True):
            _assert_results_identical(
                got, sched.schedule(inst.tasks, count_all_rejects=True)
            )

    @pytest.mark.parametrize("engine", BATCHED_ENGINES)
    def test_block_size_invariance_in_batch(self, engine):
        """The batched walk coalesces rounds internally; results must not
        depend on the configured block size either way."""
        rng = np.random.default_rng(5)
        insts = _random_instances(rng, 4, max_tasks=3)
        base = None
        for bs in (1, 7, 64, None):
            sched = PADPSFRScheduler(
                FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0),
                engine=engine,
                block_size=bs,
            )
            res = sched.schedule_many(insts, count_all_rejects=True)
            if base is None:
                base = res
            else:
                for got, want in zip(res, base, strict=True):
                    _assert_results_identical(got, want)


# ---------------------------------------------------------------------------
# InstanceBatch packing and the raw dispatch surface
# ---------------------------------------------------------------------------


class TestInstanceBatch:
    def test_pack_empty(self):
        batch = InstanceBatch.pack([])
        assert len(batch) == 0
        assert batch.shares.shape == (0, 0, 0)

    def test_pack_uniform_fast_path_shapes(self):
        rng = np.random.default_rng(0)
        blocks = [
            (rng.uniform(size=(5, 3)), np.ones(3), np.full(2, 30.0), np.zeros(2))
            for _ in range(4)
        ]
        batch = InstanceBatch.pack(blocks)
        assert batch.shares.shape == (4, 5, 3)
        assert batch.iis.shape == (4, 3)
        assert batch.t_slr.shape == (4, 2) and batch.t_cfg.shape == (4, 2)
        assert (batch.n_t_eff == 3).all()
        assert (batch.n_f_eff == 2).all()
        assert (batch.n_rows == 5).all()
        for i in range(4):
            s, iis, slr, cfg = batch.instance_view(i)
            np.testing.assert_array_equal(s, blocks[i][0])
            np.testing.assert_array_equal(slr, blocks[i][2])

    def test_pack_ragged_pads_to_maxima(self):
        rng = np.random.default_rng(1)
        blocks = [
            (rng.uniform(size=(2, 1)), np.ones(1), np.full(3, 30.0), np.zeros(3)),
            (rng.uniform(size=(7, 4)), np.ones(4), np.full(1, 50.0), np.ones(1)),
        ]
        batch = InstanceBatch.pack(blocks)
        assert batch.shares.shape == (2, 7, 4)
        assert list(batch.n_rows) == [2, 7]
        assert list(batch.n_t_eff) == [1, 4]
        assert list(batch.n_f_eff) == [3, 1]
        # Padded regions are zero; live views round-trip exactly.
        assert batch.shares[0, 2:, :].sum() == 0.0
        assert batch.shares[0, :, 1:].sum() == 0.0
        for i in range(2):
            s, iis, slr, cfg = batch.instance_view(i)
            np.testing.assert_array_equal(s, blocks[i][0])
            np.testing.assert_array_equal(iis, blocks[i][1])
            np.testing.assert_array_equal(slr, blocks[i][2])
            np.testing.assert_array_equal(cfg, blocks[i][3])

    def test_pack_rejects_mismatched_ii_length(self):
        with pytest.raises(ValueError):
            InstanceBatch.pack(
                [(np.ones((2, 3)), np.ones(2), np.full(2, 30.0), np.zeros(2))]
            )

    @pytest.mark.parametrize(
        "engine", [pytest.param("jax", marks=needs_jax), pytest.param("pallas", marks=needs_jax)]
    )
    def test_dispatch_blocks_raw_matches_trimmed_surface(self, engine):
        """Raw untrimmed (B', Rp) verdicts agree with ``dispatch_blocks`` on
        every live row, and degenerate batches return ``None``."""
        rng = np.random.default_rng(9)
        backend = get_backend(engine)
        blocks = [
            (
                rng.uniform(5.0, 25.0, size=(int(rng.integers(1, 6)), nt)),
                rng.uniform(0.5, 3.0, nt),
                np.full(nf, 30.0),
                np.full(nf, 1.0),
            )
            for nt, nf in [(2, 2), (3, 1), (1, 3)]
        ]
        batch = InstanceBatch.pack(blocks)
        opts = PlacementOptions()
        raw = backend.dispatch_blocks_raw(batch, opts)
        assert raw is not None
        feas, placed, n_splits, devices_used = raw()
        trimmed = backend.dispatch_blocks(batch, opts)()
        assert len(trimmed) == len(batch)
        for i, bp in enumerate(trimmed):
            r = int(batch.n_rows[i])
            np.testing.assert_array_equal(feas[i, :r].astype(bool), bp.feasible)
            np.testing.assert_array_equal(placed[i, :r], bp.placed_tasks)
            np.testing.assert_array_equal(n_splits[i, :r], bp.n_splits)
            np.testing.assert_array_equal(devices_used[i, :r], bp.devices_used)
        assert backend.dispatch_blocks_raw(InstanceBatch.pack([]), opts) is None


# ---------------------------------------------------------------------------
# shard= device layout
# ---------------------------------------------------------------------------


@needs_jax
class TestSharding:
    def test_resolve_shard_clamps(self):
        from repro.core.placement_backends.jax_backend import resolve_shard

        n_dev = len(jax.devices())
        assert resolve_shard(None, 8) == 1
        assert resolve_shard("auto", 0) == 1
        # Largest power of two <= min(request, devices, batch).
        assert resolve_shard(64, 2) <= 2
        want = resolve_shard("auto", 64)
        assert want & (want - 1) == 0  # power of two
        assert want <= n_dev
        with pytest.raises(ValueError):
            resolve_shard(0, 8)

    def test_shard_auto_single_or_multi_device_parity(self):
        """shard='auto' must be a pure layout knob: identical results on
        one device (plain-vmap degrade) and on many."""
        rng = np.random.default_rng(21)
        insts = _random_instances(rng, 5, max_tasks=3)
        sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0), engine="jax")
        plain = sched.schedule_many(insts, count_all_rejects=True)
        sharded = sched.schedule_many(insts, shard="auto", count_all_rejects=True)
        for got, want in zip(sharded, plain, strict=True):
            _assert_results_identical(got, want)

    @pytest.mark.skipif(
        not HAS_JAX or len(__import__("jax").devices()) < 2,
        reason="needs >= 2 jax devices (CI forces 4 via XLA_FLAGS)",
    )
    def test_shard_map_multi_device_matches_solo_loop(self):
        from repro.core.placement_backends.jax_backend import resolve_shard

        assert resolve_shard("auto", 8) >= 2  # the mesh is really in play
        rng = np.random.default_rng(33)
        insts = _random_instances(rng, 8, max_tasks=3)
        sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0), engine="jax")
        for shard in ("auto", 2):
            res = sched.schedule_many(insts, shard=shard, count_all_rejects=True)
            ref = _solo_loop(insts, sched.fleet, engine="numpy", count_all_rejects=True)
            for got, want in zip(res, ref, strict=True):
                _assert_results_identical(got, want)


# ---------------------------------------------------------------------------
# service-side entry point
# ---------------------------------------------------------------------------


class TestWhatIfMany:
    def test_what_if_many_matches_solo_what_ifs(self):
        from repro.service import SchedulerService

        def v(th, pw):
            return TaskVariant(cu=1, throughput=th, power=pw)

        svc = SchedulerService(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
        svc.submit(Task("base", period=10.0, data=20.0, init_interval=1.0,
                        variants=(v(2.0, 5.0), v(4.0, 8.0))))
        arrivals = [
            Task("c1", period=10.0, data=40.0, init_interval=1.0,
                 variants=(v(4.0, 4.0), v(8.0, 6.0))),
            Task("hog", period=10.0, data=1000.0, init_interval=1.0,
                 variants=(v(1.0, 5.0),)),
        ]
        res = svc.what_if_many(arrivals)
        assert len(res) == 2
        assert res[0].feasible and not res[1].feasible
        # Speculative: the service itself is untouched.
        assert [t.name for t in svc.tasks] == ["base"]
        for got, a in zip(res, arrivals, strict=True):
            want = PADPSFRScheduler(svc.fleet, engine=svc.engine).schedule(
                tuple(svc.tasks) + (a,)
            )
            _assert_results_identical(got, want)
