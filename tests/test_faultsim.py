"""k-fault tolerance, end to end: the resilience guarantee under injection.

The contract under test (ISSUE 8):

* ``schedule(..., resilience=k)`` plans are bit-identical across every
  placement engine, and carry a feasible backup placement on the
  worst-case survivor fleet;
* a k-resilient plan replayed through the fault-injection simulator
  survives **any** k seeded device failures with zero replan-window
  deadline misses — while the k=0 plan of the same instance demonstrably
  does not;
* the worst-case-survivor adversary (``worst_case_survivor_indices`` /
  ``FleetSpec.survivors``) drops the k most capable devices
  deterministically and preserves the reference share scale;
* ``SchedulerService`` validates failure injection inputs
  (``fail_device`` index range, ``resilience`` type/sign) and recovers
  failed devices LIFO (``DeviceRecovery``).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FleetSpec, PADPSFRScheduler, Task, TaskVariant
from repro.core.placement_backends import available_backends
from repro.core.task import DeviceProfile, worst_case_survivor_indices
from repro.service import (
    DeviceFailure,
    DeviceRecovery,
    SchedulerService,
    make_failure_trace,
    power_premium,
    run_fault_injection,
)

ENGINES = [
    e for e in ("scalar", "numpy", "jax", "pallas") if e in available_backends()
]


def _crafted(n_f=4):
    """Premium-ladder instance: n_f share-25 tasks fill n_f devices, so
    every resilience level forces upgrades to the hot share-10 variant."""
    fleet = FleetSpec(n_f=n_f, t_slr=30.0, t_cfg=1.0)
    tasks = [
        Task(
            name=f"R{i}",
            period=10.0,
            data=20.0,
            init_interval=1.0,
            variants=(
                TaskVariant(cu=1, throughput=2.4, power=2.0),
                TaskVariant(cu=2, throughput=6.0, power=8.0),
            ),
        )
        for i in range(n_f)
    ]
    return fleet, tasks


# ---------------------------------------------------------------------------
# worst-case survivor adversary


def test_survivor_indices_k0_is_identity():
    idx = worst_case_survivor_indices(
        np.array([10.0, 20.0, 30.0]), np.array([1.0, 1.0, 1.0]), 0
    )
    assert idx.tolist() == [0, 1, 2]


def test_survivor_indices_drops_largest_slice_first():
    # Adversary kills the most capable device: largest t_slr goes first.
    idx = worst_case_survivor_indices(
        np.array([10.0, 30.0, 20.0]), np.array([1.0, 1.0, 1.0]), 1
    )
    assert idx.tolist() == [0, 2]


def test_survivor_indices_tiebreaks_on_t_cfg_then_index():
    # Equal t_slr: the device with the *smaller* t_cfg is more capable
    # (cheaper reconfiguration), so the adversary kills it first ...
    idx = worst_case_survivor_indices(
        np.array([30.0, 30.0]), np.array([5.0, 1.0]), 1
    )
    assert idx.tolist() == [0]
    # ... and a full tie falls to the lowest index, deterministically.
    idx = worst_case_survivor_indices(
        np.array([30.0, 30.0]), np.array([1.0, 1.0]), 1
    )
    assert idx.tolist() == [1]


def test_survivor_indices_validates_k():
    t = np.array([10.0, 20.0])
    with pytest.raises(ValueError):
        worst_case_survivor_indices(t, t, -1)
    with pytest.raises(ValueError):
        worst_case_survivor_indices(t, t, 2)


def test_fleet_survivors_homogeneous():
    fleet = FleetSpec(n_f=5, t_slr=30.0, t_cfg=2.0)
    surv = fleet.survivors(2)
    assert surv.n_f == 3
    assert surv.t_slr == fleet.t_slr and surv.t_cfg == fleet.t_cfg
    assert fleet.survivors(0) is fleet


def test_fleet_survivors_hetero_preserves_reference_scale():
    fleet = FleetSpec.heterogeneous(
        [
            DeviceProfile(t_slr=40.0, t_cfg=4.0),
            DeviceProfile(t_slr=80.0, t_cfg=0.0, klass="gpu"),
            DeviceProfile(t_slr=60.0, t_cfg=2.0),
        ]
    )
    surv = fleet.survivors(1)
    # The 80-unit GPU dies, but shares stay defined against the original
    # reference slice — otherwise the backup pass would re-scale eq. 5.
    assert surv.n_f == 2
    assert [d.t_slr for d in surv.devices] == [40.0, 60.0]
    assert surv.t_slr == fleet.t_slr == 80.0


# ---------------------------------------------------------------------------
# cross-engine bit-identity of resilient plans


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("k", [0, 1, 2])
def test_resilient_schedule_engine_parity(engine, k):
    fleet, tasks = _crafted()
    ref = PADPSFRScheduler(fleet, engine="scalar").schedule(tasks, resilience=k)
    got = PADPSFRScheduler(fleet, engine=engine).schedule(tasks, resilience=k)
    assert got.feasible == ref.feasible
    assert got.chosen_rank == ref.chosen_rank
    assert got.n_placement_rejects == ref.n_placement_rejects
    assert got.total_power == ref.total_power
    if ref.feasible:
        assert got.combo.variant_idx == ref.combo.variant_idx
        assert str(got.plan) == str(ref.plan)


@pytest.mark.parametrize("k", [1, 2])
def test_resilient_plan_carries_feasible_backup(k):
    fleet, tasks = _crafted()
    res = PADPSFRScheduler(fleet).schedule(tasks, resilience=k)
    assert res.feasible
    backup = res.plan.backup
    assert backup is not None and backup.feasible
    assert len(backup.scripts) <= fleet.n_f - k


def test_resilience_exceeding_fleet_is_infeasible_not_an_error():
    fleet, tasks = _crafted(n_f=3)
    res = PADPSFRScheduler(fleet).schedule(tasks, resilience=3)
    assert not res.feasible and res.chosen_rank == -1
    assert res.n_tfs == 0 and res.n_tnfs == res.n_tss


def test_resilience_validation_rejects_bad_values():
    fleet, tasks = _crafted(n_f=3)
    sched = PADPSFRScheduler(fleet)
    for bad in (-1, 1.5, True, "1"):
        with pytest.raises(ValueError):
            sched.schedule(tasks, resilience=bad)
    with pytest.raises(ValueError):
        SchedulerService(fleet, resilience=-2)


# ---------------------------------------------------------------------------
# the property: k-resilient plans survive any k failures; k=0 does not


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("k", [1, 2])
def test_resilient_plan_survives_any_k_failures(seed, k):
    fleet, tasks = _crafted()
    r = run_fault_injection(
        fleet, tasks, resilience=k, n_failures=k, seed=seed
    )
    assert r.survived and r.total_misses == 0
    for rec in r.records:
        # The guarantee is about the *serving* plan: zero replan-window
        # misses at every step.  A replan at the original k may itself be
        # infeasible on the shrunken fleet (k=2 on 3 survivors) — that is
        # allowed; the old plan keeps serving and keeps meeting deadlines.
        assert rec.plan_survived


def test_unprotected_plan_misses_on_the_same_trace():
    fleet, tasks = _crafted()
    r = run_fault_injection(fleet, tasks, resilience=0, n_failures=1, seed=0)
    assert not r.survived
    assert r.total_misses == len(tasks)


def test_resilience_power_premium_ladder():
    fleet, tasks = _crafted()
    pp = power_premium(fleet, tasks, ks=(0, 1, 2))
    assert pp[0]["power"] == 8.0 and pp[0]["premium_pct"] == 0.0
    assert pp[1]["power"] == 20.0 and pp[1]["premium_pct"] == pytest.approx(150.0)
    assert pp[2]["power"] == 32.0 and pp[2]["premium_pct"] == pytest.approx(300.0)


def test_fault_injection_rejects_inadmissible_instance():
    # Three share-25 tasks on three devices: k=2 leaves one survivor that
    # cannot host all three even at the hot variant — submit refuses, and
    # the simulator surfaces that instead of "verifying" nothing.
    fleet, tasks = _crafted(n_f=3)
    with pytest.raises(ValueError, match="rejected at resilience=2"):
        run_fault_injection(fleet, tasks, resilience=2, n_failures=2)


def test_recovery_trace_returns_to_initial_fleet():
    fleet, tasks = _crafted()
    r = run_fault_injection(
        fleet, tasks, resilience=1, n_failures=1, seed=4, recover=True
    )
    assert r.survived
    assert [rec.n_f_after for rec in r.records] == [3, 4]
    # Back on the full fleet, the replanned plan is the k=1 optimum again.
    assert r.records[-1].total_power == r.initial_power


def test_make_failure_trace_deterministic_and_validated():
    a = make_failure_trace(6, 3, seed=11, recover=True)
    b = make_failure_trace(6, 3, seed=11, recover=True)
    assert [e.describe() for e in a] == [e.describe() for e in b]
    assert sum(isinstance(e, DeviceRecovery) for e in a) == 3
    with pytest.raises(ValueError):
        make_failure_trace(3, 3)


# ---------------------------------------------------------------------------
# service: injection input validation + LIFO recovery


def test_fail_device_rejects_out_of_range_index():
    fleet, tasks = _crafted(n_f=3)
    svc = SchedulerService(fleet)
    for t in tasks:
        svc.submit(t)
    for bad in (3, 7, -2):
        with pytest.raises(ValueError, match="out of range"):
            svc.fail_device(bad)
    assert svc.fleet.n_f == 3  # nothing was mutated by the rejects


def test_service_rejects_when_resilience_exceeds_fleet():
    fleet, tasks = _crafted(n_f=3)
    svc = SchedulerService(fleet, resilience=3)
    row = svc.submit(tasks[0])
    assert not row.admitted
    assert "resilience" in row.reason


def test_recover_device_restores_hetero_profile_lifo():
    fleet = FleetSpec.heterogeneous(
        [
            DeviceProfile(t_slr=40.0, t_cfg=4.0),
            DeviceProfile(t_slr=80.0, t_cfg=0.0, klass="gpu"),
            DeviceProfile(t_slr=60.0, t_cfg=2.0),
        ]
    )
    svc = SchedulerService(fleet)
    svc.fail_device(0)
    svc.fail_device(0)  # the former index-1 GPU, now at 0
    assert [d.t_slr for d in svc.fleet.devices] == [60.0]
    svc.recover_device()
    assert [d.t_slr for d in svc.fleet.devices] == [80.0, 60.0]
    svc.recover_device()
    assert svc.fleet.devices == fleet.devices  # full LIFO restoration
    row = svc.recover_device()
    assert not row.admitted and "no failed device" in row.reason


def test_replay_handles_recovery_events():
    fleet, tasks = _crafted()
    svc = SchedulerService(fleet, resilience=1)
    for t in tasks:
        assert svc.submit(t).admitted
    svc.replay([DeviceFailure(device=2), DeviceRecovery()])
    assert svc.fleet == dataclasses.replace(fleet)
    # Live plan equals a cold resilient solve of the same instance.
    cold = PADPSFRScheduler(fleet).schedule(tasks, resilience=1)
    assert svc.plan is not None and svc.plan.total_power == cold.total_power


def test_power_premium_zero_power_baseline():
    """A zero-power k=0 winner must report premium 0.0 at every feasible
    level — not None, and never a ZeroDivisionError (regression: the
    ratio branch is guarded on base > 0, pinned by repro-lint P201)."""
    fleet = FleetSpec(n_f=4, t_slr=30.0, t_cfg=1.0)
    tasks = [
        Task(
            name=f"Z{i}",
            period=10.0,
            data=20.0,
            init_interval=1.0,
            variants=(TaskVariant(cu=1, throughput=2.4, power=0.0),),
        )
        for i in range(2)
    ]
    pp = power_premium(fleet, tasks, ks=(0, 1))
    assert pp[0]["feasible"] and pp[0]["power"] == 0.0
    assert pp[0]["premium_pct"] == 0.0
    assert pp[1]["feasible"] and pp[1]["power"] == 0.0
    assert pp[1]["premium_pct"] == 0.0
