"""Hypothesis property tests on the scheduler's invariants."""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    DeviceProfile,
    FleetSpec,
    PADPSFRScheduler,
    Task,
    TaskVariant,
    combo_count,
    config_overhead_lower_bound,
    iter_feasible_pruned,
    iter_feasible_pruned_blocks,
    outer_sum,
    place_batch,
    place_combo,
    place_shares,
    search_feasible,
)

# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

variants = st.lists(
    st.tuples(
        st.floats(0.1, 10.0, allow_nan=False),  # throughput
        st.floats(0.0, 20.0, allow_nan=False),  # power
    ),
    min_size=1,
    max_size=4,
)


@st.composite
def tasks_strategy(draw, max_tasks=5):
    n = draw(st.integers(1, max_tasks))
    out = []
    for i in range(n):
        vs = draw(variants)
        out.append(
            Task(
                name=f"T{i}",
                period=draw(st.floats(10.0, 200.0)),
                data=draw(st.floats(1.0, 100.0)),
                init_interval=draw(st.floats(0.0, 10.0)),
                variants=tuple(
                    TaskVariant(cu=j + 1, throughput=th, power=pw)
                    for j, (th, pw) in enumerate(vs)
                ),
            )
        )
    return out


fleets = st.builds(
    FleetSpec,
    n_f=st.integers(1, 6),
    t_slr=st.floats(20.0, 200.0),
    t_cfg=st.floats(0.0, 10.0),
)


# ---------------------------------------------------------------------------
# Algorithm 1 invariants
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(tasks=tasks_strategy(), fleet=fleets)
def test_tfs_tnfs_partition_tss(tasks, fleet):
    feas = search_feasible(tasks, fleet)
    assert feas.n_tfs + feas.n_tnfs == feas.n_combos == combo_count(tasks)
    # every TFS row satisfies eq. 7, every TNFS row violates it
    fit = feas.sum_shr <= feas.budget + 1e-9
    assert (fit == feas.fit_mask).all()


@settings(max_examples=30, deadline=None)
@given(tasks=tasks_strategy(max_tasks=4), fleet=fleets)
def test_pruned_iterator_matches_exhaustive(tasks, fleet):
    """Branch-and-bound stream == power-sorted TFS of the exhaustive
    engine, combo for combo — including exact-power tie order."""
    feas = search_feasible(tasks, fleet)
    exhaustive = list(feas.iter_tfs_by_power())
    pruned = list(iter_feasible_pruned(tasks, fleet))
    assert pruned == exhaustive
    # ascending by power
    powers = [c.total_power for c in pruned]
    assert all(a <= b + 1e-9 for a, b in zip(powers, powers[1:], strict=False))


@settings(max_examples=30, deadline=None)
@given(
    tasks=tasks_strategy(max_tasks=4),
    fleet=fleets,
    block_size=st.sampled_from([1, 3, 64, 4096]),
)
def test_block_enumerator_matches_exhaustive(tasks, fleet, block_size):
    """The vectorized block enumerator emits the exhaustive power-sorted
    TFS exactly, for any block size."""
    feas = search_feasible(tasks, fleet)
    exhaustive = list(feas.iter_tfs_by_power())
    streamed = []
    for blk in iter_feasible_pruned_blocks(tasks, fleet, block_size):
        streamed.extend(blk.materialize(r) for r in range(len(blk)))
    assert streamed == exhaustive


@settings(max_examples=30, deadline=None)
@given(
    vecs=st.lists(
        st.lists(st.floats(0, 50, allow_nan=False), min_size=1, max_size=4),
        min_size=1,
        max_size=4,
    )
)
def test_outer_sum_equals_cartesian(vecs):
    arrs = [np.asarray(v) for v in vecs]
    got = outer_sum(arrs)
    import itertools

    want = np.asarray([sum(t) for t in itertools.product(*arrs)])
    np.testing.assert_allclose(got, want, rtol=1e-12)


# ---------------------------------------------------------------------------
# Placement invariants (Algs 2/3)
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(
    shares=st.lists(st.floats(1.0, 80.0), min_size=1, max_size=6),
    iis=st.data(),
    fleet=fleets,
)
def test_placement_invariants(shares, iis, fleet):
    ii = [iis.draw(st.floats(0.0, 10.0)) for _ in shares]
    plan = place_shares(shares, ii, fleet)

    # (1) device timelines never exceed t_slr and segments are contiguous
    for script in plan.scripts:
        t = 0.0
        for seg in script.segments:
            assert seg.start == pytest.approx(t, abs=1e-6)
            assert seg.end >= seg.start - 1e-9
            t = seg.end
        assert t <= fleet.t_slr + 1e-6

    # (2) share conservation: executed share per task never exceeds its
    # share; feasible => fully executed
    for k, shr in enumerate(shares):
        assert plan.executed_share[k] <= shr + 1e-6
        if plan.feasible:
            assert plan.executed_share[k] == pytest.approx(shr, abs=1e-6)

    # (3) split ratios are positive and sum to 1
    for sp in plan.splits:
        assert all(p > -1e-9 for p in sp.share_parts)
        assert sum(sp.ratio) == pytest.approx(1.0)
        # split devices are consecutive (DP-wrap wraps to the next device)
        ds = list(sp.devices)
        assert ds == sorted(ds)

    # (4) every run segment is preceded by its configuration segment
    for script in plan.scripts:
        segs = script.segments
        for i, seg in enumerate(segs):
            if seg.kind == "run":
                prior = [s for s in segs[:i] if s.task == seg.task and s.kind == "cfg"]
                assert prior, "run without configuration"

    # (5) infeasible plans name the unplaced tasks
    if not plan.feasible:
        assert plan.unplaced


@settings(max_examples=40, deadline=None)
@given(tasks=tasks_strategy(max_tasks=4), fleet=fleets)
def test_scheduler_returns_minimum_power_placeable(tasks, fleet):
    """The chosen combo has minimal power among ALL placeable TFS rows."""
    res = PADPSFRScheduler(fleet).schedule(tasks, count_all_rejects=True)
    if not res.feasible:
        return
    feas = search_feasible(tasks, fleet)
    placeable_powers = []
    for idx in np.flatnonzero(feas.fit_mask):
        combo = feas.combo_at(int(idx))
        from repro.core import place_combo

        if place_combo(combo, tasks, fleet).feasible:
            placeable_powers.append(combo.total_power)
    assert placeable_powers
    assert res.total_power == pytest.approx(min(placeable_powers))


hetero_fleets = st.builds(
    lambda profiles: FleetSpec.heterogeneous(tuple(profiles)),
    st.lists(
        st.builds(
            DeviceProfile,
            t_slr=st.floats(20.0, 200.0),
            t_cfg=st.floats(0.0, 10.0),
            klass=st.sampled_from(["fpga", "gpu", "cpu"]),
        ),
        min_size=1,
        max_size=5,
    ),
)


@settings(max_examples=50, deadline=None)
@given(tasks=tasks_strategy(max_tasks=4), fleet=st.one_of(fleets, hetero_fleets))
def test_batched_engine_matches_scalar_oracle(tasks, fleet):
    """Batched block placement == per-row scalar oracle: feasibility,
    split count, chosen rank and winner — on homogeneous AND
    heterogeneous fleets."""
    feas = search_feasible(tasks, fleet)
    order = feas.tfs_indices_by_power()
    if order.size:
        iis = [t.init_interval for t in tasks]
        bp = place_batch(feas.shares_matrix(order), iis, fleet)
        for i, fi in enumerate(order):
            plan = place_combo(feas.combo_at(int(fi)), tasks, fleet)
            assert plan.feasible == bool(bp.feasible[i])
            if plan.feasible:
                assert plan.n_splits == int(bp.n_splits[i])
    rb = PADPSFRScheduler(fleet, engine="batched").schedule(tasks)
    rs = PADPSFRScheduler(fleet, engine="scalar").schedule(tasks)
    assert rb.feasible == rs.feasible
    assert rb.chosen_rank == rs.chosen_rank
    assert rb.total_power == rs.total_power
    if rb.feasible:
        assert rb.combo == rs.combo


@settings(max_examples=60, deadline=None)
@given(tasks=tasks_strategy(max_tasks=4), fleet=hetero_fleets)
def test_tightened_eq7_bound_never_prunes_placeable_combo(tasks, fleet):
    """Soundness of the capacity-aware min-cost device-cover refinement:
    with ``extra_cfgs=0`` (the strict necessary condition) every combo the
    bound rejects is truly unplaceable by the scalar Alg-2/3 oracle.

    (The enumerators apply the default ``extra_cfgs=1`` charge — the
    paper's own one-split allowance, identical to the exhaustive
    ``search_feasible`` filter; exactness of that equivalence is covered
    by ``test_block_enumerator_matches_exhaustive`` above.)
    """
    feas = search_feasible(tasks, fleet)
    overhead = config_overhead_lower_bound(
        fleet, len(tasks), feas.sum_shr, extra_cfgs=0
    )
    rejected = np.flatnonzero(feas.sum_shr > fleet.capacity - overhead + 1e-9)
    for idx in rejected[:64]:
        combo = feas.combo_at(int(idx))
        plan = place_combo(combo, tasks, fleet)
        assert not plan.feasible, (
            f"strict eq-7 refinement pruned placeable combo {combo.variant_idx}"
        )


@settings(max_examples=40, deadline=None)
@given(tasks=tasks_strategy(max_tasks=3), fleet=fleets)
def test_more_devices_never_hurt(tasks, fleet):
    """Monotonicity: adding devices keeps feasibility and can't raise the
    minimum power."""
    res_small = PADPSFRScheduler(fleet).schedule(tasks)
    res_big = PADPSFRScheduler(fleet.with_devices(fleet.n_f + 2)).schedule(tasks)
    if res_small.feasible:
        assert res_big.feasible
        assert res_big.total_power <= res_small.total_power + 1e-9
