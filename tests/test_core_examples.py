"""Paper-exact reproduction tests: Examples 1-3 (§IV-A, Tables I/II, Figs 2-4)."""

import pytest

from repro.configs.paper_examples import (
    example1_fleet,
    example1_tasks,
    example2_fleet,
    example2_tasks,
    example3_fleet,
    example3_tasks,
)
from repro.core import (
    PADPSFRScheduler,
    place_shares,
    render_gantt,
    search_feasible,
)


class TestExample1:
    """Table I: 6 tasks, nv=[2,4,4,4,4,2], t_slr=60, n_f=4, t_cfg=6."""

    @pytest.fixture(scope="class")
    def result(self):
        sched = PADPSFRScheduler(example1_fleet())
        return sched.schedule(example1_tasks(), count_all_rejects=True)

    def test_tss_size_is_1024(self, result):
        assert result.n_tss == 1024

    def test_eq7_split_620_404(self, result):
        # paper: 620 task sets satisfy eq. 7, 404 do not
        assert result.n_tfs == 620
        assert result.n_tnfs == 404

    def test_chosen_combo_is_paper_5th(self, result):
        # paper: the 5th power-sorted combination [48,36,24,32,24,24] wins
        assert result.feasible
        assert result.chosen_rank == 4  # 0-based rank 4 == 5th
        assert [round(s) for s in result.combo.shares] == [48, 36, 24, 32, 24, 24]

    def test_chosen_variants(self, result):
        # 1CU-T1, 1CU-T2, 2CU-T3, 3CU-T4, 2CU-T5, 2CU-T6
        cus = [
            example1_tasks()[i].variants[j].cu
            for i, j in enumerate(result.combo.variant_idx)
        ]
        assert cus == [1, 1, 2, 3, 2, 2]

    def test_t3_splits_12_12_across_F2_F3(self, result):
        # Fig 2: T3 (share 24) splits 12:12 across devices F2, F3 ->
        # input data divided 1:1 (24 GB -> 12 GB + 12 GB)
        splits = result.plan.splits
        assert len(splits) == 1
        sp = splits[0]
        assert sp.task == 2  # T3
        assert sp.devices == (1, 2)  # F2, F3 (0-based)
        assert [round(p) for p in sp.share_parts] == [12, 12]
        assert sp.ratio == (0.5, 0.5)

    def test_t2_finishes_at_42ms_on_F2(self, result):
        # §IV-A1: "The 1CU-T2 task is finished at 42 ms"
        f2 = result.plan.scripts[1]
        t2_runs = [s for s in f2.segments if s.task == 1 and s.kind == "run"]
        assert t2_runs and abs(t2_runs[-1].end - 42.0) < 1e-9

    def test_alg2_reject_count_documented_deviation(self, result):
        # Paper says 156 placement rejects (-> 464 accepted); the pinned
        # Fig-2/3 semantics give 146 (474 accepted). No boundary reading
        # of the pseudocode yields 156 (see EXPERIMENTS.md) — we assert
        # our reproducible number and the paper's qualitative claim that
        # Alg 2 rejects SOME eq-7-feasible sets.
        assert result.n_placement_rejects == 146
        assert 0 < result.n_placement_rejects < result.n_tfs

    def test_gantt_renders(self, result):
        txt = render_gantt(result.plan, example1_tasks(), example1_fleet())
        assert "split T3" in txt and "F4" in txt


class TestExample2:
    """II(T3): 2 -> 12 ms makes the Example-1 winner un-placeable (Fig 3)."""

    def test_paper_combo_rejected(self):
        fleet = example2_fleet()
        plan = place_shares([48, 36, 24, 32, 24, 24], [2, 4, 12, 4, 6, 6], fleet)
        assert not plan.feasible

    def test_f2_cannot_host_t3(self):
        # §IV-A2: remaining capacity 18 ms == t_cfg + II = 6 + 12 -> no
        # data production time, T3 must move
        fleet = example2_fleet()
        plan = place_shares([48, 36, 24, 32, 24, 24], [2, 4, 12, 4, 6, 6], fleet)
        f2_tasks = {s.task for s in plan.scripts[1].segments if s.kind == "run"}
        assert 2 not in f2_tasks

    def test_scheduler_falls_back_to_other_combo(self):
        res = PADPSFRScheduler(example2_fleet()).schedule(example2_tasks())
        assert res.feasible
        assert [round(s) for s in res.combo.shares] != [48, 36, 24, 32, 24, 24]
        # equal-power alternative found (total power unchanged at 31.5)
        assert res.total_power == pytest.approx(31.5)


class TestExample3:
    """Table II: LZ-4/ZSTD/VAdd on 2 Alveo-50s, t_slr=600, t_cfg=21."""

    @pytest.fixture(scope="class")
    def result(self):
        sched = PADPSFRScheduler(example3_fleet())
        return sched.schedule(example3_tasks(), count_all_rejects=True)

    def test_tss_24(self, result):
        assert result.n_tss == 24  # 3 x 2 x 4

    def test_six_accepted(self, result):
        # paper: 6 combinations accepted, 18 rejected
        assert result.n_tfs - result.n_placement_rejects == 6

    def test_chosen_shares_540_440_119(self, result):
        assert result.feasible
        assert [round(s) for s in result.combo.shares] == [540, 440, 119]

    def test_chosen_power(self, result):
        # 6.64 + 6.89 + 6.21 = 19.74 mW
        assert result.total_power == pytest.approx(19.74, abs=0.01)

    def test_chosen_variants(self, result):
        tasks = example3_tasks()
        cus = [tasks[i].variants[j].cu for i, j in enumerate(result.combo.variant_idx)]
        assert cus == [3, 1, 2]  # 3CU-LZ4, 1CU-ZSTD, 2CU-VAdd


def test_feasibility_budget_matches_paper_arithmetic():
    # Example 1: (60*4) - (6+1)*6 = 198 budget; paper quotes the sample
    # combo [24,18,16,24,48,48] (sum 178) as eq-7-feasible
    fleet = example1_fleet()
    feas = search_feasible(example1_tasks(), fleet)
    assert 178 <= feas.budget + 1e-9
