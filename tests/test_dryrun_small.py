"""Small-mesh dry-run smoke: lower + compile reduced cells on forced host
devices, in a subprocess (device count must be set before jax init)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.configs.shapes import InputShape
from repro.models import Model, ExecConfig
from repro.models.model import train_batch_specs
from repro.optim import AdamW
from repro.sharding import PRESETS, activation_sharding, batch_axes_tree, tree_shardings
from repro.train.step import make_train_step, train_state_axes
from repro.launch.dryrun import _abstract_train_state
from repro.launch.mesh import make_mesh
from repro.roofline import analyze_compiled

arch = sys_argv_arch
mesh = make_mesh((4, 2), ("data", "model"))
rules = PRESETS["fsdp_tp_sp"]
cfg = get_arch(arch).reduced()
shape = InputShape("t", 32, 8, "train")
model = Model(cfg, ExecConfig(remat="full"))
state = _abstract_train_state(model)
batch = train_batch_specs(cfg, shape)
state_sh = tree_shardings(state, train_state_axes(model), mesh, rules)
batch_sh = tree_shardings(batch, batch_axes_tree(batch), mesh, rules)
step = make_train_step(model, AdamW(1e-4))
with activation_sharding(mesh, rules):
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, NamedSharding(mesh, P())))
    compiled = jitted.lower(state, batch).compile()
res = analyze_compiled(compiled, arch=arch, shape="t", mesh_name="m", n_chips=8,
                       model_flops=1.0)
print("RESULT " + json.dumps({
    "flops": res.flops_per_device,
    "coll": res.coll_bytes_per_device,
    "mem": float(compiled.memory_analysis().argument_size_in_bytes),
}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-130m", "moonshot-v1-16b-a3b"])
def test_reduced_cell_compiles_on_small_mesh(arch):
    code = _SCRIPT.replace("sys_argv_arch", repr(arch))
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["flops"] > 0
    assert res["coll"] > 0  # sharded training must communicate
    assert res["mem"] > 0
