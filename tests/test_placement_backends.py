"""Pluggable placement-backend architecture: registry, regressions, parity.

The backend contract (``repro.core.placement_backends``) pins every engine
to the scalar Alg-2/Alg-3 oracle bit-for-bit.  This file covers:

* registry semantics (names, aliases, ``auto``, custom registration);
* the empty-fleet and ``block_size`` regressions;
* ``_walk_tfs_blocks`` bookkeeping invariants across block sizes and
  ``count_all_rejects`` — backend-independent by construction;
* jax-gated cross-backend parity (jit'd ``lax.while_loop`` sweep and the
  fused Pallas kernel) on the paper's Figs 2-4 examples and >= 100
  randomized heterogeneous fleets under scoped ``enable_x64``.

The randomized-instance harness is shared with
``tests/test_placement_batched.py``.
"""

from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs.paper_examples import (
    example1_fleet,
    example1_tasks,
    example2_fleet,
    example2_tasks,
    example3_fleet,
    example3_tasks,
)
from repro.core import (
    PADPSFRScheduler,
    available_backends,
    backend_names,
    get_backend,
    place_batch,
    place_combo,
    resolve_engine,
    search_feasible,
)
from repro.core.placement_backends import (
    BatchPlacement,
    PlacementOptions,
    prepare_block,
    register_backend,
)

from test_placement_batched import (
    _assert_results_identical,
    _random_fleet,
    _random_tasks,
)

try:
    import jax  # noqa: F401

    HAS_JAX = True
except ImportError:  # pragma: no cover - exercised by the no-jax CI leg
    HAS_JAX = False

needs_jax = pytest.mark.skipif(not HAS_JAX, reason="jax not installed")

PAPER_CASES = [
    (example1_tasks, example1_fleet),
    (example2_tasks, example2_fleet),
    (example3_tasks, example3_fleet),
]
PAPER_IDS = ["example1", "example2", "example3"]


def _full_tfs_block(tasks, fleet):
    feas = search_feasible(tasks, fleet)
    order = feas.tfs_indices_by_power()
    iis = [t.init_interval for t in tasks]
    return feas, order, feas.shares_matrix(order) if order.size else None, iis


def _assert_blocks_identical(a: BatchPlacement, b: BatchPlacement, ctx: str = ""):
    assert (a.feasible == b.feasible).all(), f"{ctx}: feasible mask"
    assert (a.placed_tasks == b.placed_tasks).all(), f"{ctx}: placed_tasks"
    assert (a.n_splits == b.n_splits).all(), f"{ctx}: n_splits"
    assert (a.devices_used == b.devices_used).all(), f"{ctx}: devices_used"


def _backend_vs_oracle(tasks, fleet, backend_name, **kw) -> int:
    """Backend verdicts vs the scalar oracle per row, over the full TFS."""
    feas, order, shares, iis = _full_tfs_block(tasks, fleet)
    if shares is None:
        return 0
    opts = PlacementOptions(**kw)
    bp = get_backend(backend_name).place_block(
        shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr, opts
    )
    for i, fi in enumerate(order):
        plan = place_combo(feas.combo_at(int(fi)), tasks, fleet, **kw)
        assert plan.feasible == bool(bp.feasible[i]), f"{backend_name} row {i}"
        if plan.feasible:
            assert plan.n_splits == int(bp.n_splits[i]), f"{backend_name} row {i}"
    return int(order.size)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_core_engines_registered(self):
        names = backend_names()
        for name in ("scalar", "numpy", "jax", "pallas"):
            assert name in names
        # zero-dependency engines are always available
        avail = available_backends()
        assert "numpy" in avail and "scalar" in avail

    def test_aliases_and_auto(self):
        assert resolve_engine("batched") == "numpy"
        assert resolve_engine("auto") in available_backends()
        if not HAS_JAX:
            assert resolve_engine("auto") == "numpy"

    def test_unknown_engine_raises(self):
        with pytest.raises(ValueError, match="unknown placement engine"):
            resolve_engine("fpga-magic")
        with pytest.raises(ValueError, match="unknown placement engine"):
            PADPSFRScheduler(example1_fleet(), engine="fpga-magic")

    def test_jax_engines_report_unavailable_without_jax(self):
        if HAS_JAX:
            assert "jax" in available_backends()
        else:
            assert "jax" not in available_backends()
            with pytest.raises(RuntimeError, match=r"install the \[jax\] extra"):
                get_backend("jax")

    def test_register_custom_backend(self):
        """The documented extension point: a registered class resolves by
        name and drives the scheduler end to end.  The fake engine is
        removed from the process-global registry afterwards."""
        from repro.core.placement_backends import base as backends_base

        try:

            @register_backend("numpy-echo-test")
            class EchoBackend:
                name = "numpy-echo-test"
                calls = 0

                @classmethod
                def available(cls):
                    return True

                def place_block(self, shares, iis, t_slr, t_cfg, opts=None):
                    type(self).calls += 1
                    return get_backend("numpy").place_block(
                        shares, iis, t_slr, t_cfg, opts
                    )

            tasks, fleet = example1_tasks(), example1_fleet()
            re = PADPSFRScheduler(fleet, engine="numpy-echo-test").schedule(tasks)
            rn = PADPSFRScheduler(fleet, engine="numpy").schedule(tasks)
            assert EchoBackend.calls > 0
            assert re.chosen_rank == rn.chosen_rank == 4
            assert re.combo == rn.combo
        finally:
            backends_base._REGISTRY.pop("numpy-echo-test", None)
            backends_base._INSTANCES.pop("numpy-echo-test", None)
        assert "numpy-echo-test" not in backend_names()

    def test_reregistering_name_replaces_cached_instance(self):
        """Overriding a name drops the previously cached instance."""
        from repro.core.placement_backends import base as backends_base
        from repro.core.placement_backends.numpy_backend import (
            NumpyPlacementBackend,
        )

        try:

            @register_backend("override-test")
            class FirstBackend(NumpyPlacementBackend):
                name = "override-test"

            first = get_backend("override-test")
            assert isinstance(first, FirstBackend)

            @register_backend("override-test")
            class SecondBackend(NumpyPlacementBackend):
                name = "override-test"

            second = get_backend("override-test")
            assert isinstance(second, SecondBackend)
            assert second is not first
        finally:
            backends_base._REGISTRY.pop("override-test", None)
            backends_base._INSTANCES.pop("override-test", None)


# ---------------------------------------------------------------------------
# regressions: empty fleet, block_size validation
# ---------------------------------------------------------------------------


class TestEmptyFleetRegression:
    """place_batch with n_f == 0 and n_t > 0 used to IndexError on the
    ``t_cfg_arr[jj]`` gather; it must return an all-infeasible verdict."""

    def _stub_fleet(self):
        return SimpleNamespace(
            n_f=0,
            t_slr_arr=np.empty(0, dtype=np.float64),
            t_cfg_arr=np.empty(0, dtype=np.float64),
        )

    def test_place_batch_empty_fleet_all_infeasible(self):
        shares = np.asarray([[10.0, 20.0], [5.0, 5.0]])
        bp = place_batch(shares, [1.0, 2.0], self._stub_fleet())
        assert not bp.feasible.any()
        assert (bp.placed_tasks == 0).all()
        assert (bp.devices_used == 0).all()

    @pytest.mark.parametrize("backend", ["numpy", "scalar"])
    def test_backends_empty_fleet(self, backend):
        shares = np.asarray([[10.0, 20.0]])
        bp = get_backend(backend).place_block(
            shares, [1.0, 2.0], np.empty(0), np.empty(0)
        )
        assert not bp.feasible.any()

    def test_empty_fleet_empty_tasks_vacuously_feasible(self):
        bp = place_batch(np.zeros((3, 0)), [], self._stub_fleet())
        assert bp.feasible.all()

    def test_prepare_block_shape_validation(self):
        with pytest.raises(ValueError, match=r"shares must be \(B, n_t\)"):
            prepare_block(np.zeros(4), [], np.ones(1), np.zeros(1), None)
        with pytest.raises(ValueError, match="init_intervals"):
            prepare_block(np.zeros((2, 3)), [1.0], np.ones(1), np.zeros(1), None)


class TestBlockSizeValidation:
    @pytest.mark.parametrize("bad", [0, -1, -4096])
    def test_rejects_nonpositive(self, bad):
        with pytest.raises(ValueError, match="block_size must be >= 1"):
            PADPSFRScheduler(example1_fleet(), block_size=bad)

    def test_block_size_one_still_schedules(self):
        res = PADPSFRScheduler(example1_fleet(), block_size=1).schedule(
            example1_tasks()
        )
        assert res.feasible and res.chosen_rank == 4

    def test_batched_selectors_reject_nonpositive(self):
        """The guard sits where block_size is consumed, not only in the
        facade: block_size=0 used to silently return no winner on the
        streaming path and raise an opaque range() error exhaustively."""
        from repro.core.scheduler import (
            _select_from_feasibility,
            select_lowest_power_batched,
        )

        tasks, fleet = example1_tasks(), example1_fleet()
        feas = search_feasible(tasks, fleet)
        with pytest.raises(ValueError, match="block_size must be >= 1"):
            select_lowest_power_batched(
                feas.iter_tfs_by_power(), tasks, fleet, block_size=0
            )
        with pytest.raises(ValueError, match="block_size must be >= 1"):
            _select_from_feasibility(feas, tasks, fleet, block_size=0)


# ---------------------------------------------------------------------------
# _walk_tfs_blocks bookkeeping invariants (backend-independent)
# ---------------------------------------------------------------------------


class TestWalkInvariants:
    """Chosen rank, reject count and plan must not depend on how the TFS
    stream is chopped into blocks, nor on the reject-counting mode."""

    @pytest.mark.parametrize("exhaustive", [True, False], ids=["exhaustive", "streaming"])
    def test_block_size_and_reject_mode_invariance(self, exhaustive):
        rng = np.random.default_rng(123)
        checked = 0
        for _ in range(25):
            tasks = _random_tasks(rng)
            fleet = _random_fleet(rng)
            results = {}
            for count_all in (False, True):
                per_block = []
                for bs in (1, 3, 4096):
                    sched = PADPSFRScheduler(
                        fleet, exhaustive=exhaustive, block_size=bs
                    )
                    per_block.append(
                        sched.schedule(tasks, count_all_rejects=count_all)
                    )
                first = per_block[0]
                for other in per_block[1:]:
                    _assert_results_identical(other, first)
                    assert other.n_placement_rejects == first.n_placement_rejects
                results[count_all] = first
            # Across reject modes the winner is invariant...
            assert results[False].feasible == results[True].feasible
            assert results[False].chosen_rank == results[True].chosen_rank
            assert results[False].combo == results[True].combo
            if results[False].feasible:
                # ...and without count_all the rejects are exactly the rows
                # ranked before the winner (all of which failed placement).
                assert (
                    results[False].n_placement_rejects
                    == results[False].chosen_rank
                )
                assert (
                    results[True].n_placement_rejects
                    >= results[False].n_placement_rejects
                )
                checked += 1
        assert checked > 5  # enough feasible instances actually exercised


# ---------------------------------------------------------------------------
# cross-backend parity: jax (jit'd while_loop) and pallas (fused kernel)
# ---------------------------------------------------------------------------


@needs_jax
class TestJaxBackendParity:
    @pytest.mark.parametrize("tasks_fn,fleet_fn", PAPER_CASES, ids=PAPER_IDS)
    def test_paper_examples_schedule_identical_to_scalar(self, tasks_fn, fleet_fn):
        tasks, fleet = tasks_fn(), fleet_fn()
        rj = PADPSFRScheduler(fleet, engine="jax").schedule(
            tasks, count_all_rejects=True
        )
        rs = PADPSFRScheduler(fleet, engine="scalar").schedule(
            tasks, count_all_rejects=True
        )
        _assert_results_identical(rj, rs)

    @pytest.mark.parametrize("tasks_fn,fleet_fn", PAPER_CASES, ids=PAPER_IDS)
    def test_paper_examples_full_tfs_bitwise_vs_numpy(self, tasks_fn, fleet_fn):
        tasks, fleet = tasks_fn(), fleet_fn()
        _, order, shares, iis = _full_tfs_block(tasks, fleet)
        if shares is None:
            pytest.skip("empty TFS")
        bn = get_backend("numpy").place_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )
        bj = get_backend("jax").place_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )
        _assert_blocks_identical(bj, bn, "jax-vs-numpy")

    def test_randomized_hetero_parity_100_instances(self):
        """engine="jax" agrees with the scalar oracle on >= 100 randomized
        heterogeneous fleets (acceptance criterion)."""
        rng = np.random.default_rng(42)
        rows_checked = 0
        instances = 0
        for _ in range(100):
            tasks = _random_tasks(rng)
            fleet = _random_fleet(rng)
            _, order, shares, iis = _full_tfs_block(tasks, fleet)
            if shares is not None:
                bn = get_backend("numpy").place_block(
                    shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
                )
                bj = get_backend("jax").place_block(
                    shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
                )
                _assert_blocks_identical(bj, bn, "jax-vs-numpy")
                rows_checked += int(order.size)
            rj = PADPSFRScheduler(fleet, engine="jax").schedule(
                tasks, count_all_rejects=True
            )
            rs = PADPSFRScheduler(fleet, engine="scalar").schedule(
                tasks, count_all_rejects=True
            )
            _assert_results_identical(rj, rs)
            instances += 1
        assert instances == 100
        assert rows_checked > 500

    def test_preemption_model_parity(self):
        """Parity holds under the refs-[9]/[10] capture/store knobs."""
        rng = np.random.default_rng(7)
        kw = dict(t_capture=12.0, t_store=12.0, repay_init=False)
        checked = 0
        for _ in range(20):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng)
            checked += _backend_vs_oracle(tasks, fleet, "jax", **kw)
        assert checked > 50

    def test_block_handoff_matches_oracle_rows(self):
        """Spot-check the jax verdicts directly against the oracle (not
        just against numpy) on the paper's Example 1."""
        n = _backend_vs_oracle(example1_tasks(), example1_fleet(), "jax")
        assert n == 620  # the paper's |TFS|

    def test_scheduler_engine_auto_resolves_and_schedules(self):
        sched = PADPSFRScheduler(example1_fleet(), engine="auto")
        assert sched.engine in available_backends()
        res = sched.schedule(example1_tasks())
        assert res.feasible and res.chosen_rank == 4


@needs_jax
class TestPallasBackendParity:
    """The fused kernel runs in Pallas interpret mode off-TPU; verdicts
    must stay bit-identical to the numpy engine (and thus the oracle)."""

    @pytest.mark.parametrize("tasks_fn,fleet_fn", PAPER_CASES, ids=PAPER_IDS)
    def test_paper_examples_full_tfs_bitwise_vs_numpy(self, tasks_fn, fleet_fn):
        tasks, fleet = tasks_fn(), fleet_fn()
        _, order, shares, iis = _full_tfs_block(tasks, fleet)
        if shares is None:
            pytest.skip("empty TFS")
        bn = get_backend("numpy").place_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )
        bp = get_backend("pallas").place_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )
        _assert_blocks_identical(bp, bn, "pallas-vs-numpy")

    def test_example1_schedule_identical_to_scalar(self):
        tasks, fleet = example1_tasks(), example1_fleet()
        rp = PADPSFRScheduler(fleet, engine="pallas").schedule(
            tasks, count_all_rejects=True
        )
        rs = PADPSFRScheduler(fleet, engine="scalar").schedule(
            tasks, count_all_rejects=True
        )
        _assert_results_identical(rp, rs)

    def test_randomized_parity_10_instances(self):
        rng = np.random.default_rng(11)
        done = 0
        for _ in range(10):
            tasks = _random_tasks(rng, max_tasks=4)
            fleet = _random_fleet(rng, max_devices=4)
            _, order, shares, iis = _full_tfs_block(tasks, fleet)
            if shares is None:
                continue
            bn = get_backend("numpy").place_block(
                shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
            )
            bp = get_backend("pallas").place_block(
                shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
            )
            _assert_blocks_identical(bp, bn, "pallas-vs-numpy")
            done += 1
        assert done > 3


# ---------------------------------------------------------------------------
# scalar backend through the unified walk
# ---------------------------------------------------------------------------


def test_scalar_engine_matches_numpy_engine():
    rng = np.random.default_rng(9)
    for _ in range(15):
        tasks = _random_tasks(rng, max_tasks=4)
        fleet = _random_fleet(rng)
        rs = PADPSFRScheduler(fleet, engine="scalar").schedule(
            tasks, count_all_rejects=True
        )
        rn = PADPSFRScheduler(fleet, engine="numpy").schedule(
            tasks, count_all_rejects=True
        )
        _assert_results_identical(rs, rn)


def test_scalar_backend_block_verdicts_match_numpy():
    rng = np.random.default_rng(17)
    for _ in range(10):
        tasks = _random_tasks(rng, max_tasks=4)
        fleet = _random_fleet(rng)
        _, order, shares, iis = _full_tfs_block(tasks, fleet)
        if shares is None:
            continue
        bs = get_backend("scalar").place_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )
        bn = get_backend("numpy").place_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )
        _assert_blocks_identical(bs, bn, "scalar-vs-numpy")


def test_every_backend_exposes_the_full_dispatch_surface():
    """Runtime twin of repro-lint rule B101: every registered backend
    spells out the five surface methods and declares its pipelining via
    ``async_dispatch`` (the walk chooses depth from the flag, not from
    method presence — see ``_streaming_block_walk``)."""
    surface = (
        "place_block",
        "dispatch_block",
        "place_blocks",
        "dispatch_blocks",
        "dispatch_blocks_raw",
    )
    for name in available_backends():
        backend = get_backend(name)
        for meth in surface:
            assert callable(getattr(backend, meth, None)), (name, meth)
        assert isinstance(backend.async_dispatch, bool), name


def test_eager_backend_dispatch_matches_place():
    """The eager dispatch hooks added for contract completeness must be
    behaviorally invisible: resolver output equals the eager call."""
    rng = np.random.default_rng(20260808)
    fleet = example1_fleet()
    shares = rng.uniform(1.0, 30.0, size=(32, 4))
    iis = rng.uniform(0.0, 1.0, size=4)
    for name in ("scalar", "numpy"):
        backend = get_backend(name)
        assert backend.async_dispatch is False
        eager = backend.place_block(shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr)
        resolved = backend.dispatch_block(
            shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
        )()
        _assert_blocks_identical(eager, resolved, f"{name} dispatch parity")
