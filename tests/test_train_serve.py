"""End-to-end training loop + serving engine tests (reduced configs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.train import build_loop
from repro.models import ExecConfig, Model
from repro.serve import ServeConfig, ServeEngine


def test_train_loss_decreases(tmp_path):
    loop, _ = build_loop(
        "smollm-135m", steps=80, seq_len=64, batch=4, lr=3e-3,
        ckpt_dir=str(tmp_path / "ck"), log_every=0,
    )
    loop.run(jax.random.PRNGKey(0))
    first = np.mean([h["loss"] for h in loop.history[:5]])
    last = np.mean([h["loss"] for h in loop.history[-5:]])
    assert last < first * 0.9, f"loss did not fall: {first:.3f} -> {last:.3f}"


def test_train_resume_is_bitwise_deterministic(tmp_path):
    # run A: 20 steps straight through
    loop_a, _ = build_loop("smollm-135m", steps=20, seq_len=32, batch=4, log_every=0)
    state_a = loop_a.run(jax.random.PRNGKey(1))

    # run B: 10 steps, "crash", resume to 20 from checkpoint.  Build with
    # the same 20-step horizon (same LR schedule), stop early via config.
    ck = str(tmp_path / "ck")
    loop_b1, _ = build_loop("smollm-135m", steps=20, seq_len=32, batch=4,
                            ckpt_dir=ck, log_every=0)
    loop_b1.config.total_steps = 10
    loop_b1.config.ckpt_every = 10
    loop_b1.run(jax.random.PRNGKey(1))
    loop_b2, _ = build_loop("smollm-135m", steps=20, seq_len=32, batch=4,
                            ckpt_dir=ck, log_every=0)
    state_b = loop_b2.run(jax.random.PRNGKey(1))
    assert int(loop_b2.history[0]["step"]) == 10  # actually resumed

    for a, b in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_b.params), strict=True):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


def test_microbatch_matches_full_batch():
    loop_full, _ = build_loop("smollm-135m", steps=1, seq_len=32, batch=8, log_every=0)
    loop_mb, _ = build_loop("smollm-135m", steps=1, seq_len=32, batch=8,
                            microbatch=4, log_every=0)
    sa = loop_full.run(jax.random.PRNGKey(2))
    sb = loop_mb.run(jax.random.PRNGKey(2))
    la = loop_full.history[0]["loss"]
    lb = loop_mb.history[0]["loss"]
    assert la == pytest.approx(lb, rel=1e-4)
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_compressed_grads_still_learn():
    loop, _ = build_loop("smollm-135m", steps=25, seq_len=64, batch=4,
                         lr=1e-3, compress_grads=True, log_every=0)
    loop.run(jax.random.PRNGKey(3))
    first = np.mean([h["loss"] for h in loop.history[:5]])
    last = np.mean([h["loss"] for h in loop.history[-5:]])
    assert last < first


def test_serve_engine_greedy_matches_manual_decode():
    cfg = get_arch("smollm-135m").reduced()
    model = Model(cfg, ExecConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    B, S, NEW = 2, 16, 6
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    engine = ServeEngine(model, params, ServeConfig(max_len=S + NEW), jit=False)
    out = engine.generate({"tokens": tok}, NEW)
    assert out.shape == (B, NEW)

    # manual: prefill + greedy loop
    last, state = model.prefill(params, {"tokens": tok})
    state = (
        jnp.pad(state[0], ((0, 0), (0, 0), (0, NEW), (0, 0), (0, 0))),
        jnp.pad(state[1], ((0, 0), (0, 0), (0, NEW), (0, 0), (0, 0))),
    )
    want = [jnp.argmax(last, -1).astype(jnp.int32)]
    for t in range(1, NEW):
        logits, state = model.decode_step(params, state, want[-1], jnp.int32(S + t - 1))
        want.append(jnp.argmax(logits, -1).astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), np.stack([np.asarray(w) for w in want], 1))


def test_serve_engine_ssm_family():
    cfg = get_arch("mamba2-130m").reduced()
    model = Model(cfg, ExecConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, ServeConfig(max_len=32), jit=False)
    out = engine.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, 5)
    assert out.shape == (2, 5)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())
