"""Checkpointing + fault-tolerance tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_pytree, save_pytree
from repro.configs.paper_examples import example1_fleet, example1_tasks
from repro.core.task import FleetSpec, Task, TaskVariant
from repro.ft import ElasticController, FleetHealth, SliceState, StragglerDetector


# ---------------------------------------------------------------------------
# checkpoint primitives
# ---------------------------------------------------------------------------


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.int32), "c": jnp.zeros((5,), jnp.bfloat16)},
    }


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t, meta={"step": 7})
    loaded, meta = load_pytree(str(tmp_path / "ck"), t)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded), strict=True):
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                      np.asarray(b, dtype=np.float32))


def test_atomic_publication(tmp_path):
    """A directory missing its manifest is never considered LATEST."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), sync=True)
    # simulate a torn write of step 2
    os.makedirs(tmp_path / "step_00000002")
    with open(tmp_path / "LATEST", "w") as f:
        f.write("2")
    assert mgr.latest_step() == 1  # falls back past the torn step


def test_manager_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree())
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_manager_keep_every(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, keep_every=2)
    for step in (1, 2, 3, 4, 5):
        mgr.save(step, _tree(), sync=True)
    steps = mgr.all_steps()
    assert 5 in steps and 2 in steps and 4 in steps


def test_restore_into_like(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t, sync=True)
    like = jax.tree.map(jnp.zeros_like, t)
    restored, meta = mgr.restore(like)
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_restore_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore(_tree()) is None


# ---------------------------------------------------------------------------
# health / elastic / straggler
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_health_state_machine():
    clock = FakeClock()
    h = FleetHealth(3, timeout=30, suspect=10, clock=clock)
    assert h.n_up == 3
    clock.t = 15.0
    h.heartbeat(0)
    states = h.poll()
    assert states[0] == SliceState.UP
    assert states[1] == SliceState.SUSPECT
    clock.t = 45.0
    h.heartbeat(0)
    states = h.poll()
    assert states[0] == SliceState.UP
    assert states[1] == SliceState.DOWN
    assert h.n_up == 1
    h.revive(1)
    assert h.poll()[1] == SliceState.UP


def test_elastic_replan_on_failure_and_recovery():
    tasks, fleet = example1_tasks(), example1_fleet()
    ctl = ElasticController(fleet, tasks)
    assert ctl.current.feasible
    p0 = ctl.current.total_power

    ev = ctl.on_slice_down(3)  # 4 -> 3 slices
    assert ev.n_slices == 3
    # fewer devices: either still feasible at >= power, or tasks shed
    if ev.result.feasible and not ev.dropped_tasks:
        assert ev.result.total_power >= p0 - 1e-9

    ev2 = ctl.on_slice_up(3)
    assert ev2.n_slices == 4
    assert ev2.result.feasible
    assert ev2.result.total_power == pytest.approx(p0)


def test_elastic_sheds_tasks_when_overloaded():
    # tiny fleet that cannot host all tasks -> shed lowest priority
    tasks = example1_tasks()
    fleet = FleetSpec(n_f=2, t_slr=60.0, t_cfg=6.0)
    ctl = ElasticController(fleet, tasks)
    assert ctl.current.feasible
    assert ctl.events[0].dropped_tasks  # had to shed something
    kept = {t.name for t in ctl.active_tasks}
    assert "T1" in kept  # highest priority survives


def test_elastic_poll_triggers_on_heartbeat_loss():
    clock = FakeClock()
    health = FleetHealth(4, timeout=30, suspect=10, clock=clock)
    ctl = ElasticController(example1_fleet(), example1_tasks(), health=health)
    n_events = len(ctl.events)
    clock.t = 31.0
    for j in (0, 1, 2):
        health.heartbeat(j)  # slice 3 silent
    ev = ctl.poll()
    assert ev is not None and ev.n_slices == 3
    assert len(ctl.events) == n_events + 1
    assert ctl.poll() is None  # no further change, no replan


def test_straggler_detection_and_reset():
    det = StragglerDetector(threshold=1.5, patience=3)
    for _ in range(10):
        flagged = det.observe(0, step_time=1.0, predicted=1.0)
    assert not flagged
    for _ in range(10):
        flagged = det.observe(1, step_time=5.0, predicted=1.0)
    assert flagged
    assert det.stragglers() == [1]
    det.reset(1)
    assert det.stragglers() == []


def test_latest_step_scan_is_order_independent(tmp_path):
    """The torn-pointer fallback scans the directory; creation order must
    not leak into the answer (regression: the listdir is sorted, pinned
    by repro-lint D402)."""
    mgr = CheckpointManager(str(tmp_path))
    for step in (7, 2, 31, 16):  # deliberately non-monotone creation order
        save_pytree(mgr.step_dir(step), {"w": np.arange(3) + step})
    # No LATEST pointer was ever written: force the scan path.
    assert not os.path.exists(os.path.join(str(tmp_path), "LATEST"))
    assert mgr.all_steps() == [2, 7, 16, 31]
    assert mgr.latest_step() == 31
