"""Roofline machinery: HLO cost walker, collective parsing, power model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.power import V5E, PowerModel, step_time_roofline
from repro.roofline import analyze_compiled, collective_bytes
from repro.roofline.hlo_costs import parse_hlo_costs


def test_walker_matches_cost_analysis_loop_free():
    def f(a, b):
        return (a @ b).sum()

    a = jnp.ones((64, 128))
    b = jnp.ones((128, 32))
    comp = jax.jit(f).lower(a, b).compile()
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    w = parse_hlo_costs(comp.as_text())
    assert w.dot_flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)
    assert w.flops == pytest.approx(float(ca["flops"]), rel=0.2)


def test_walker_scales_scan_by_trip_count():
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), ()

        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()

    x = jnp.ones((32, 64))
    flops = {}
    for L in (2, 8):
        ws = jnp.ones((L, 64, 64))
        comp = jax.jit(f).lower(x, ws).compile()
        flops[L] = parse_hlo_costs(comp.as_text()).dot_flops
    assert flops[8] == pytest.approx(4 * flops[2], rel=0.01)
    assert flops[2] == pytest.approx(2 * 2 * 32 * 64 * 64, rel=0.01)


def test_walker_nested_loops_multiply():
    def f(x, ws):
        def outer(h, w):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), ()

            h2, _ = jax.lax.scan(inner, h, jnp.arange(3))
            return h2, ()

        h, _ = jax.lax.scan(outer, x, ws)
        return h.sum()

    x = jnp.ones((16, 32))
    ws = jnp.ones((5, 32, 32))
    comp = jax.jit(f).lower(x, ws).compile()
    w = parse_hlo_costs(comp.as_text())
    assert w.dot_flops == pytest.approx(5 * 3 * 2 * 16 * 32 * 32, rel=0.01)


def test_collective_parse_synthetic_hlo():
    txt = """
HloModule m

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%p), replica_groups={}
  ROOT %ag = f32[8,32]{1,0} all-gather(%ar), dimensions={1}
}
"""
    stats = collective_bytes(txt)
    assert stats.per_op_count["all-reduce"] == 1
    assert stats.per_op_count["all-gather"] == 1
    assert stats.per_op["all-reduce"] == 8 * 16 * 4


def test_walker_counts_collectives_with_defs():
    txt = """
HloModule m

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  %c = f32[8,16]{1,0} copy(%p)
  %ar = f32[8,16]{1,0} all-reduce(%c), replica_groups={}
  ROOT %r = f32[8,16]{1,0} copy(%ar)
}
"""
    w = parse_hlo_costs(txt)
    assert w.coll_counts["all-reduce"] == 1
    assert w.coll_bytes["all-reduce"] == 8 * 16 * 4


def test_roofline_terms_and_power_model():
    t, terms = step_time_roofline(
        flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, n_chips=1
    )
    assert terms["compute"] == pytest.approx(1.0)
    assert terms["memory"] == pytest.approx(1.0)
    assert t == pytest.approx(1.0)

    pm = PowerModel()
    idle = pm.chip_power(0, 0, 0)
    busy = pm.chip_power(V5E.peak_flops, V5E.hbm_bw, 0)
    assert idle == pytest.approx(75.0)
    assert 180 <= busy <= 230  # calibrated ~200 W at full tilt


def test_analyze_compiled_end_to_end():
    def f(a, b):
        return a @ b

    a = jnp.ones((64, 64))
    comp = jax.jit(f).lower(a, a).compile()
    res = analyze_compiled(
        comp, arch="t", shape="s", mesh_name="m", n_chips=1,
        model_flops=2 * 64 * 64 * 64,
    )
    assert res.flops_per_device > 0
    assert res.bottleneck() in ("compute", "memory", "collective")
    row = res.to_row()
    assert row["useful_flops_frac"] == pytest.approx(1.0, rel=0.05)


def test_variant_generation_monotone():
    """More chips -> shorter step (roofline) and different power point."""
    from repro.configs import get_arch
    from repro.configs.shapes import get_shape
    from repro.core.variants import JobSpec, make_task

    job = JobSpec(cfg=get_arch("yi-34b"), shape=get_shape("train_4k"), period_s=3600)
    task = make_task(job, chip_options=(64, 128, 256))
    assert task.nv >= 2
    ths = [v.throughput for v in task.variants]
    assert ths == sorted(ths)  # more chips, more steps/s
    pws = [v.power for v in task.variants]
    assert all(p > 0 for p in pws)


def test_variant_generation_respects_memory_floor():
    """Slices too small to hold the weights are not offered."""
    from repro.configs import get_arch
    from repro.configs.shapes import get_shape
    from repro.core.variants import JobSpec, variant_table

    job = JobSpec(cfg=get_arch("qwen1.5-110b"), shape=get_shape("train_4k"), period_s=3600)
    vs = variant_table(job, chip_options=(8, 256))
    assert all(v.cu != 8 for v in vs)  # 110B f32 train state >> 8 chips
