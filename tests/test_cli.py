"""CLI entry-point smoke tests (subprocess)."""

import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=300):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=_ROOT,
    )


def test_schedule_cli():
    proc = _run([
        "repro.launch.schedule",
        "--slices", "4", "--slice-chips", "64",
        "--t-slr", "3600", "--t-cfg", "45",
        "--job", "yi-34b:train_4k:1800:250",
        "--job", "smollm-135m:decode_32k:600:5000",
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "chosen-rank" in proc.stdout
    assert "time slice" in proc.stdout  # Gantt rendered


def test_train_cli(tmp_path):
    proc = _run([
        "repro.launch.train", "--arch", "mamba2-130m",
        "--steps", "3", "--seq-len", "32", "--batch", "2",
        "--ckpt-dir", str(tmp_path / "ck"),
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "done: step=3" in proc.stdout


def test_serve_cli():
    proc = _run([
        "repro.launch.serve", "--arch", "recurrentgemma-2b",
        "--batch", "2", "--prompt-len", "24", "--new-tokens", "4",
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "generated" in proc.stdout


def _bench_artifact(
    us_by_name, rows_per_s=None, crossover=None, replan=None, resilience=None,
    churn=None,
):
    doc = {
        "benchmark": "scheduler_scale",
        "rows": [{"name": n, "us": v, "derived": ""} for n, v in us_by_name.items()],
    }
    if rows_per_s is not None:
        doc["backend_sweep"] = {
            "sizes": [1000],
            "us": {},
            "rows_per_s": rows_per_s,
            "numpy_jax_crossover_rows": crossover,
        }
    if replan is not None:
        doc["replan"] = replan
    if resilience is not None:
        doc["resilience"] = resilience
    if churn is not None:
        doc["churn"] = churn
    return doc


def test_trend_report_cli(tmp_path):
    a = tmp_path / "BENCH_old.json"
    b = tmp_path / "BENCH_new.json"
    a.write_text(json.dumps(_bench_artifact(
        {"alg2_batched_tfs4096": 1000.0},
        rows_per_s={"numpy": {"1000": 5e5}, "jax": {"1000": 4e5}},
    )))
    b.write_text(json.dumps(_bench_artifact(
        {"alg2_batched_tfs4096": 800.0, "only_in_new": 5.0},
        rows_per_s={"numpy": {"1000": 5e5}, "jax": {"1000": 8e5}},
        crossover=1000,
    )))
    out = tmp_path / "trend.json"
    proc = _run(["benchmarks.trend_report", str(a), str(b), "--json", str(out)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "alg2_batched_tfs4096" in proc.stdout
    assert "-20.0%" in proc.stdout  # 1000us -> 800us
    assert "jax @ 1000 rows" in proc.stdout
    trend = json.loads(out.read_text())
    assert trend["rows"]["alg2_batched_tfs4096"]["delta_pct"] == pytest.approx(-20.0)
    assert trend["rows"]["only_in_new"]["us"] == [None, 5.0]
    assert trend["numpy_jax_crossover_rows"] == [None, 1000]

    # fewer than two artifacts is a usage error
    proc = _run(["benchmarks.trend_report", str(a)])
    assert proc.returncode != 0


def test_trend_report_replan_rows_graceful(tmp_path):
    """Artifacts predating the delta-replan benchmark must not crash the
    trend report — clear note, exit 0 (the CI bench-smoke contract)."""
    old = tmp_path / "BENCH_old.json"
    new = tmp_path / "BENCH_new.json"
    old.write_text(json.dumps(_bench_artifact({"alg2_batched_tfs4096": 1000.0})))
    new.write_text(json.dumps(_bench_artifact(
        {"alg2_batched_tfs4096": 900.0, "replan_warm_11t": 150.0},
        replan={"cold_us": 2.0e6, "warm_us": 1.6e5, "speedup": 12.5,
                "bit_identical": True},
    )))

    # old + new: replan trend renders, with a note about the older file
    proc = _run(["benchmarks.trend_report", str(old), str(new)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "delta replan" in proc.stdout
    assert "12.5x" in proc.stdout
    assert "predates the delta-replan benchmark" in proc.stdout

    # two pre-replan artifacts: skipped with a message, still exit 0
    old2 = tmp_path / "BENCH_old2.json"
    old2.write_text(json.dumps(_bench_artifact({"alg2_batched_tfs4096": 950.0})))
    proc = _run(["benchmarks.trend_report", str(old), str(old2)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "no artifact carries replan rows" in proc.stdout


def test_trend_report_resilience_rows_graceful(tmp_path):
    """Artifacts predating the resilience benchmark must not crash the
    trend report — same contract as the replan/fleet_parallel sections."""
    old = tmp_path / "BENCH_old.json"
    new = tmp_path / "BENCH_new.json"
    old.write_text(json.dumps(_bench_artifact({"alg2_batched_tfs4096": 1000.0})))
    new.write_text(json.dumps(_bench_artifact(
        {"alg2_batched_tfs4096": 900.0, "resilience_k1_4t4f": 650.0},
        resilience={
            "instance": "4t4f",
            "points": {
                "k0": {"power": 8.0, "premium_pct": 0.0, "us": 400.0},
                "k1": {"power": 20.0, "premium_pct": 150.0, "us": 650.0},
                "k2": {"power": 32.0, "premium_pct": 300.0, "us": 550.0},
            },
            "faultsim": {"k1_survives_all_seeds": True},
        },
    )))

    # old + new: resilience trend renders, with a note about the older file
    proc = _run(["benchmarks.trend_report", str(old), str(new)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "k-fault tolerance" in proc.stdout
    assert "150.0%" in proc.stdout
    assert "predates the resilience benchmark" in proc.stdout

    # two pre-resilience artifacts: skipped with a message, still exit 0
    old2 = tmp_path / "BENCH_old2.json"
    old2.write_text(json.dumps(_bench_artifact({"alg2_batched_tfs4096": 950.0})))
    proc = _run(["benchmarks.trend_report", str(old), str(old2)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "no artifact carries resilience rows" in proc.stdout


def test_trend_report_churn_rows_graceful(tmp_path):
    """Artifacts predating the churn benchmark must not crash the trend
    report — same contract as the replan/resilience sections."""
    old = tmp_path / "BENCH_old.json"
    new = tmp_path / "BENCH_new.json"
    old.write_text(json.dumps(_bench_artifact({"alg2_batched_tfs4096": 1000.0})))
    new.write_text(json.dumps(_bench_artifact(
        {"alg2_batched_tfs4096": 900.0, "churn_exit_warm_10t": 250.0},
        churn={
            "deep_instance": "10t",
            "exit": {"chosen_rank": 58045, "cold_us": 3.1e5,
                     "warm_us": 2.5e4, "speedup": 12.4, "bit_identical": True},
            "failure": {"chosen_rank": 58045, "cold_us": 3.2e5,
                        "warm_us": 3.2e4, "speedup": 10.0,
                        "bit_identical": True},
            "trace": {"n_events": 200, "n_solved": 156,
                      "warm_hit_rate": 0.95, "rerecords": 60,
                      "speedup": 0.7},
        },
    )))

    # old + new: churn trend renders, with a note about the older file
    proc = _run(["benchmarks.trend_report", str(old), str(new)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "service churn" in proc.stdout
    assert "12.4x" in proc.stdout
    assert "95.0%" in proc.stdout
    assert "predates the churn benchmark" in proc.stdout

    # two pre-churn artifacts: skipped with a message, still exit 0
    old2 = tmp_path / "BENCH_old2.json"
    old2.write_text(json.dumps(_bench_artifact({"alg2_batched_tfs4096": 950.0})))
    proc = _run(["benchmarks.trend_report", str(old), str(old2)])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "no artifact carries churn rows" in proc.stdout


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    proc = _run([
        "repro.launch.dryrun", "--arch", "mamba2-130m",
        "--shape", "decode_32k", "--mesh", "single",
        "--out", str(tmp_path / "d.json"),
    ], timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
