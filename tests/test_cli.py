"""CLI entry-point smoke tests (subprocess)."""

import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=300):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=_ROOT,
    )


def test_schedule_cli():
    proc = _run([
        "repro.launch.schedule",
        "--slices", "4", "--slice-chips", "64",
        "--t-slr", "3600", "--t-cfg", "45",
        "--job", "yi-34b:train_4k:1800:250",
        "--job", "smollm-135m:decode_32k:600:5000",
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "chosen-rank" in proc.stdout
    assert "time slice" in proc.stdout  # Gantt rendered


def test_train_cli(tmp_path):
    proc = _run([
        "repro.launch.train", "--arch", "mamba2-130m",
        "--steps", "3", "--seq-len", "32", "--batch", "2",
        "--ckpt-dir", str(tmp_path / "ck"),
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "done: step=3" in proc.stdout


def test_serve_cli():
    proc = _run([
        "repro.launch.serve", "--arch", "recurrentgemma-2b",
        "--batch", "2", "--prompt-len", "24", "--new-tokens", "4",
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "generated" in proc.stdout


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    proc = _run([
        "repro.launch.dryrun", "--arch", "mamba2-130m",
        "--shape", "decode_32k", "--mesh", "single",
        "--out", str(tmp_path / "d.json"),
    ], timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
