"""Render the §Roofline tables from dryrun.json into EXPERIMENTS.md."""

import json
import re
import sys

sys.path.insert(0, "src")
from benchmarks.roofline_report import render_table  # noqa: E402


def main():
    rows = json.load(open("experiments/dryrun.json"))
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    rows = list(seen.values())
    single = render_table(rows, "single")
    multi = render_table(rows, "multi")

    text = open("EXPERIMENTS.md").read()
    text = re.sub(
        r"<!-- ROOFLINE_TABLE_SINGLE -->.*?(?=\n### Multi-pod)",
        "<!-- ROOFLINE_TABLE_SINGLE -->\n" + single + "\n",
        text,
        flags=re.S,
    )
    text = re.sub(
        r"<!-- ROOFLINE_TABLE_MULTI -->.*?(?=\n## §Perf)",
        "<!-- ROOFLINE_TABLE_MULTI -->\n" + multi + "\n",
        text,
        flags=re.S,
    )
    open("EXPERIMENTS.md", "w").write(text)
    ok = sum(1 for r in rows if r.get("status") == "OK")
    sk = sum(1 for r in rows if r.get("status") == "SKIP")
    print(f"injected tables: {ok} OK rows, {sk} SKIP rows")


if __name__ == "__main__":
    main()
