import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: one (cell, config) measurement per invocation.

Each named iteration is a hypothesis (see EXPERIMENTS.md §Perf); this
script lowers + compiles the cell with that configuration and appends
the roofline terms to experiments/perf.json.

  PYTHONPATH=src python experiments/hillclimb.py <cell> <iter>
  PYTHONPATH=src python experiments/hillclimb.py --list
"""

import dataclasses
import json
import sys

from repro.launch.dryrun import dryrun_cell
from repro.models import ExecConfig


def _ex(**kw) -> ExecConfig:
    return ExecConfig(**{"remat": "full", "scan_layers": True, **kw})


# cell -> iteration name -> kwargs for dryrun_cell
MATRIX = {
    "smollm": {
        "arch": "smollm-135m",
        "shape": "train_4k",
        "mesh": "single",
        "iters": {
            "base": dict(ex=_ex(cp_attention="off")),
            "cp": dict(ex=_ex(cp_attention="on")),
            "cp_pbf16": dict(ex=_ex(cp_attention="on", attn_p_dtype="bfloat16")),
            "cp_pbf16_unroll": dict(
                ex=_ex(cp_attention="on", attn_p_dtype="bfloat16", unroll_causal=True)
            ),
            "cp_unroll": dict(ex=_ex(cp_attention="on", unroll_causal=True)),
        },
    },
    "deepseek": {
        "arch": "deepseek-67b",
        "shape": "train_4k",
        "mesh": "single",
        "iters": {
            "tp_dp": dict(rules_name="tp_dp", ex=_ex(cp_attention="off")),
            "fsdp": dict(rules_name="fsdp_tp", ex=_ex(cp_attention="off")),
            "fsdp_sp": dict(rules_name="fsdp_tp_sp", ex=_ex(cp_attention="off")),
            "pbf16": dict(
                rules_name="fsdp_tp_sp",
                ex=_ex(cp_attention="off", attn_p_dtype="bfloat16"),
            ),
            "pbf16_unroll": dict(
                rules_name="fsdp_tp_sp",
                ex=_ex(cp_attention="off", attn_p_dtype="bfloat16", unroll_causal=True),
            ),
            "pbf16_chunk2k": dict(
                rules_name="fsdp_tp_sp",
                ex=_ex(cp_attention="off", attn_p_dtype="bfloat16", kv_chunk=2048),
            ),
            "chunk2k": dict(
                rules_name="fsdp_tp_sp", ex=_ex(cp_attention="off", kv_chunk=2048)
            ),
            "chunk4k": dict(
                rules_name="fsdp_tp_sp", ex=_ex(cp_attention="off", kv_chunk=4096)
            ),
        },
    },
    "dbrx": {
        "arch": "dbrx-132b",
        "shape": "train_4k",
        "mesh": "single",
        "iters": {
            "base": dict(ex=_ex()),
            "pbf16": dict(ex=_ex(attn_p_dtype="bfloat16")),
            # expert-parallel dispatch buffer constraints (layers.py) —
            # measured with the constraint code active:
            "ep": dict(ex=_ex()),
            "ep_chunk4k": dict(ex=_ex(kv_chunk=4096)),
            # batched (vmap-free) dispatch: batch dim constrainable
            "ep_batched": dict(ex=_ex()),
            "ep_batched_chunk4k": dict(ex=_ex(kv_chunk=4096)),
        },
    },
    "dbrx_multi": {
        "arch": "dbrx-132b",
        "shape": "train_4k",
        "mesh": "multi",
        "iters": {
            "base": dict(ex=_ex()),
            "compress": dict(ex=_ex(), compress_grads=True),
        },
    },
}

OUT = os.path.join(os.path.dirname(__file__), "perf.json")


def main() -> int:
    if "--list" in sys.argv:
        for cell, spec in MATRIX.items():
            print(cell, "->", ", ".join(spec["iters"]))
        return 0
    cell, it = sys.argv[1], sys.argv[2]
    spec = MATRIX[cell]
    kw = dict(spec["iters"][it])
    row = dryrun_cell(spec["arch"], spec["shape"], spec["mesh"], **kw)
    row["cell"] = cell
    row["iter"] = it
    ex = kw.get("ex")
    row["ex"] = dataclasses.asdict(ex) if ex else {}
    try:
        with open(OUT) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        data = []
    data = [r for r in data if not (r.get("cell") == cell and r.get("iter") == it)]
    data.append(row)
    with open(OUT, "w") as f:
        json.dump(data, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
