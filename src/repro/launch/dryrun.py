import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the step function the shape demands
(train_step / prefill_step / serve_step), derives in/out shardings from
the logical-axis rules, lowers from ShapeDtypeStructs (no allocation),
compiles for the production mesh, and records

* ``memory_analysis()``  — proves the cell fits per-device HBM,
* ``cost_analysis()``    — FLOPs / bytes for §Roofline,
* parsed collective bytes + op counts (from the HLO text),
* the three roofline terms + bottleneck + MFU estimate.

Results are appended to a JSON file so a sweep can resume.  Skipped
cells (long_500k on full-attention archs) are recorded as SKIP rows.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun.json
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, list_archs
from repro.configs.shapes import SHAPES, cell_applicability, get_shape
from repro.models import ExecConfig, Model
from repro.models.model import (
    decode_input_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from repro.optim import AdamW
from repro.roofline import analyze_compiled
from repro.sharding import (
    PRESETS,
    activation_sharding,
    batch_axes_tree,
    state_axes_tree,
    tree_shardings,
)
from repro.train.step import TrainState, make_train_step, train_state_axes
from repro.launch.mesh import make_production_mesh

__all__ = ["dryrun_cell", "main"]


def _abstract_train_state(model: Model, *, compress: bool = False) -> TrainState:
    params = model.abstract_params()
    sds = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t
    )
    return TrainState(
        params=params,
        opt_state={"m": sds(params), "v": sds(params)},
        step=jax.ShapeDtypeStruct((), jnp.int32),
        ef_residual=sds(params) if compress else None,
    )


def _model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * shape.tokens


def default_rules(kind: str) -> str:
    """Shape-aware preset: training/prefill wants FSDP + sequence-parallel
    activations; decode wants the KV-cache time axis on 'model' (GQA kv
    head counts don't fill a 16-wide axis)."""
    return "sp_serve" if kind == "decode" else "fsdp_tp_sp"


def dryrun_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    *,
    rules_name: str = "auto",
    ex: ExecConfig | None = None,
    compress_grads: bool = False,
    verbose: bool = True,
) -> dict:
    """Lower + compile one cell; returns the result-row dict."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_applicability(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "SKIP", "reason": reason,
        }

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    if rules_name == "auto":
        rules_name = default_rules(shape.kind)
    rules = PRESETS[rules_name]
    model = Model(cfg, ex or ExecConfig(remat=cfg.remat, scan_layers=True))
    n_chips = mesh.devices.size
    t0 = time.time()

    # In/out shardings are explicit NamedShardings; the activation_sharding
    # context additionally pins intermediate activations at block
    # boundaries (without it GSPMD de-shards the batch — see
    # sharding/ctx.py).
    with activation_sharding(mesh, rules):
        if shape.kind == "train":
            state = _abstract_train_state(model, compress=compress_grads)
            batch = train_batch_specs(cfg, shape)
            axes = train_state_axes(model, compress=compress_grads)
            state_sh = tree_shardings(state, axes, mesh, rules)
            batch_sh = tree_shardings(batch, batch_axes_tree(batch), mesh, rules)
            step = make_train_step(model, AdamW(1e-4), compress_grads=compress_grads)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, NamedSharding(mesh, P())),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            params = model.abstract_params("bfloat16")
            batch = prefill_batch_specs(cfg, shape)
            p_sh = tree_shardings(params, model.param_axes(), mesh, rules)
            b_sh = tree_shardings(batch, batch_axes_tree(batch), mesh, rules)
            step = lambda p, b: model.prefill(p, b)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            params = model.abstract_params("bfloat16")
            inputs = decode_input_specs(cfg, shape)
            p_sh = tree_shardings(params, model.param_axes(), mesh, rules)
            st_sh = tree_shardings(
                inputs["state"], state_axes_tree(inputs["state"]), mesh, rules
            )
            tok_sh = tree_shardings(
                inputs["tokens"], ("batch",), mesh, rules
            )
            idx_sh = NamedSharding(mesh, P())
            from repro.sharding import resolve_spec

            logits_sh = NamedSharding(
                mesh,
                resolve_spec(
                    ("batch", "vocab"),
                    (shape.global_batch, cfg.vocab),
                    mesh,
                    rules,
                ),
            )
            step = lambda p, st, tok, idx: model.decode_step(p, st, tok, idx)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, st_sh, tok_sh, idx_sh),
                out_shardings=(logits_sh, st_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params, inputs["state"], inputs["tokens"], inputs["idx"]
            )

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # Exact resident argument bytes per device from the sharding specs
    # (XLA's memory_analysis().argument_size_in_bytes is unreliable for
    # some partitioned modules on the host backend).
    import numpy as _np

    def _shard_bytes(tree_abs, tree_sh) -> float:
        tot = 0.0
        for sds, sh in zip(jax.tree.leaves(tree_abs), jax.tree.leaves(tree_sh), strict=True):
            shard = sh.shard_shape(sds.shape)
            tot += float(_np.prod(shard)) * sds.dtype.itemsize
        return tot

    if shape.kind == "train":
        args_per_dev = _shard_bytes(state, state_sh) + _shard_bytes(batch, batch_sh)
    elif shape.kind == "prefill":
        args_per_dev = _shard_bytes(params, p_sh) + _shard_bytes(batch, b_sh)
    else:
        args_per_dev = (
            _shard_bytes(params, p_sh)
            + _shard_bytes(inputs["state"], st_sh)
            + _shard_bytes(inputs["tokens"], tok_sh)
        )

    hlo = compiled.as_text()
    res = analyze_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        n_chips=n_chips,
        model_flops=_model_flops(cfg, shape),
        hlo_text=hlo,
    )
    mem = compiled.memory_analysis()
    row = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "OK",
        "rules": rules_name,
        "chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": res.flops_per_device,
        "hbm_bytes_per_device": res.hbm_bytes_per_device,
        "coll_bytes_per_device": res.coll_bytes_per_device,
        "coll_per_op": res.coll.per_op if res.coll else {},
        "coll_counts": res.coll.per_op_count if res.coll else {},
        "arg_bytes": args_per_dev,
        "xla_arg_bytes": float(getattr(mem, "argument_size_in_bytes", 0) or 0),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0) or 0),
        "out_bytes": float(getattr(mem, "output_size_in_bytes", 0) or 0),
        **{k: v for k, v in res.to_row().items() if k not in ("arch", "shape", "mesh", "chips")},
    }
    if verbose:
        t = res.terms()
        print(
            f"[{arch} x {shape_name} x {mesh_name}] OK chips={n_chips} "
            f"compile={t_compile:.1f}s "
            f"compute={t['compute']*1e3:.2f}ms memory={t['memory']*1e3:.2f}ms "
            f"coll={t['collective']*1e3:.2f}ms bottleneck={res.bottleneck()} "
            f"mfu={res.mfu():.3f} "
            f"args/dev={args_per_dev/1e9:.2f}GB"
        )
    return row


def _load(out):
    try:
        with open(out) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="auto", choices=["auto"] + list(PRESETS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--force", action="store_true", help="recompute existing rows")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        cells = [(args.arch, args.shape)]

    results = _load(args.out) if args.out else []
    done = {(r["arch"], r["shape"], r["mesh"], r.get("rules", "fsdp_tp")) for r in results}

    failures = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            key = (arch, shape, mesh_name, args.rules)
            if not args.force and key in done:
                continue
            try:
                row = dryrun_cell(arch, shape, mesh_name, rules_name=args.rules)
            except Exception as e:
                traceback.print_exc()
                row = {
                    "arch": arch, "shape": shape, "mesh": mesh_name,
                    "rules": args.rules, "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                }
                failures += 1
            if row.get("status") == "SKIP":
                print(f"[{arch} x {shape} x {mesh_name}] SKIP — {row['reason']}")
            results = [r for r in results if (r["arch"], r["shape"], r["mesh"], r.get("rules", "fsdp_tp")) != key]
            results.append(row)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
