"""Training driver.

Reduced configs (the default) actually train on the local device(s) —
the end-to-end example trains the ~100M-class smollm-135m for a few
hundred steps with checkpoints + auto-resume.  ``--full`` configs are
for real fleets; on this container use ``repro.launch.dryrun`` instead.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --ckpt-dir /tmp/ckpt --seq-len 256 --batch 8
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, list_archs
from repro.configs.shapes import InputShape
from repro.data.pipeline import make_batch_fn
from repro.models import ExecConfig, Model
from repro.optim import AdamW, linear_warmup_cosine
from repro.train import TrainLoop, TrainLoopConfig

__all__ = ["main", "build_loop"]


def build_loop(
    arch: str,
    *,
    full: bool = False,
    seq_len: int = 256,
    batch: int = 8,
    steps: int = 100,
    ckpt_dir: str = "",
    lr: float = 3e-4,
    microbatch: int = 0,
    compress_grads: bool = False,
    log_every: int = 10,
) -> tuple[TrainLoop, InputShape]:
    cfg = get_arch(arch)
    if not full:
        cfg = cfg.reduced()
    shape = InputShape("cli", seq_len, batch, "train")
    model = Model(cfg, ExecConfig(remat=cfg.remat, scan_layers=cfg.scan_layers))
    opt = AdamW(linear_warmup_cosine(lr, max(steps // 20, 1), steps))
    loop = TrainLoop(
        model,
        opt,
        make_batch_fn(cfg, shape),
        TrainLoopConfig(
            total_steps=steps,
            ckpt_every=max(steps // 4, 1),
            log_every=log_every,
            ckpt_dir=ckpt_dir,
            microbatch=microbatch,
            compress_grads=compress_grads,
        ),
    )
    return loop, shape


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    loop, _ = build_loop(
        args.arch,
        full=args.full,
        seq_len=args.seq_len,
        batch=args.batch,
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        lr=args.lr,
        microbatch=args.microbatch,
        compress_grads=args.compress_grads,
    )
    state = loop.run(jax.random.PRNGKey(args.seed))
    first = loop.history[0]["loss"] if loop.history else float("nan")
    last = loop.history[-1]["loss"] if loop.history else float("nan")
    print(f"done: step={int(state.step)} loss {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
