"""Fleet-level power-aware scheduling — the paper's contribution doing
real work in the framework.

Takes a fleet spec (slices x chips) and a job list (arch x shape x
period); generates parallelism variants for every job (throughput/power
from the analytic roofline + TPU power model), runs PADPS-FR, and emits
the placement plan: per-slice timeline with program switches, warm-ups
and batch splits.

  PYTHONPATH=src python -m repro.launch.schedule \
      --slices 4 --slice-chips 64 --t-slr 3600 --t-cfg 45 \
      --job yi-34b:train_4k:1800:900 --job smollm-135m:decode_32k:600:5000
"""

from __future__ import annotations

import argparse

from repro.configs import get_arch, list_archs
from repro.configs.shapes import get_shape
from repro.core import FleetSpec, PADPSFRScheduler, render_gantt
from repro.core.variants import JobSpec, make_task

__all__ = ["main", "plan_fleet"]


def parse_job(spec: str) -> JobSpec:
    """arch:shape:period_s:steps  e.g. yi-34b:train_4k:1800:900"""
    arch, shape, period, steps = spec.split(":")
    return JobSpec(
        cfg=get_arch(arch),
        shape=get_shape(shape),
        period_s=float(period),
        steps_per_period=int(steps),
    )


def plan_fleet(jobs, fleet: FleetSpec, chip_options=(32, 64, 128, 256)):
    tasks = [make_task(j, chip_options) for j in jobs]
    sched = PADPSFRScheduler(fleet)
    return tasks, sched.schedule(tasks, count_all_rejects=False)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slices", type=int, default=4, help="n_f schedulable slices")
    ap.add_argument("--slice-chips", type=int, default=64)
    ap.add_argument("--t-slr", type=float, default=3600.0, help="time slice (s)")
    ap.add_argument(
        "--t-cfg", type=float, default=45.0,
        help="program-switch cost (s): executable load + weight restore",
    )
    ap.add_argument(
        "--job", action="append", required=True,
        help="arch:shape:period_s:steps (repeatable)",
    )
    args = ap.parse_args(argv)

    jobs = [parse_job(j) for j in args.job]
    fleet = FleetSpec(n_f=args.slices, t_slr=args.t_slr, t_cfg=args.t_cfg, name="tpu-fleet")
    chip_opts = tuple(
        sorted({args.slice_chips // 4, args.slice_chips // 2, args.slice_chips})
    )
    tasks, result = plan_fleet(jobs, fleet, chip_opts)

    print(f"fleet: {args.slices} slices x {args.slice_chips} chips, "
          f"t_slr={args.t_slr:g}s t_cfg={args.t_cfg:g}s")
    for t in tasks:
        vs = ", ".join(
            f"{v.cu}ch:{v.throughput:.3g}st/s/{v.power:.0f}W" for v in t.variants
        )
        print(f"  job {t.name}: period={t.period:g}s steps={t.data:g} [{vs}]")
    print()
    print(result.summary(tasks))
    if result.feasible:
        print(render_gantt(result.plan, tasks, fleet))
    return 0 if result.feasible else 1


if __name__ == "__main__":
    raise SystemExit(main())
