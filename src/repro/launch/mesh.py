"""Production meshes.

``make_production_mesh()`` is a FUNCTION (importing this module never
touches jax device state):

* single-pod:  (16, 16)    axes ('data', 'model')      — 256 chips
* multi-pod:   (2, 16, 16) axes ('pod', 'data', 'model') — 512 chips

The ``pod`` axis is an outer data-parallel axis: batch shards over
('pod', 'data'); cross-pod traffic is only the gradient reduction in
training and nothing in serving.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape, axes = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary sub-mesh (tests use (1,2)/(2,2,2)-sized variants)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
