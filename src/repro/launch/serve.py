"""Serving driver: batched prefill + greedy decode on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --batch 4 --prompt-len 64 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.models import ExecConfig, Model
from repro.serve import ServeConfig, ServeEngine

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    model = Model(cfg, ExecConfig(remat="none", scan_layers=True))
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        P = 8
        batch = {
            "tokens": batch["tokens"][:, : S - P],
            "patch_embeds": jnp.asarray(rng.standard_normal((B, P, cfg.d_model)), jnp.float32),
            "positions": jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)
            ).astype(jnp.int32),
        }

    engine = ServeEngine(
        model,
        params,
        ServeConfig(max_len=S + args.new_tokens, temperature=args.temperature),
    )
    t0 = time.perf_counter()
    out = engine.generate(batch, args.new_tokens, key=jax.random.PRNGKey(args.seed))
    dt = time.perf_counter() - t0
    tput = B * out.shape[1] / dt
    print(f"generated {out.shape} tokens in {dt:.2f}s ({tput:.1f} tok/s)")
    print("first row:", np.asarray(out[0][:16]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
