"""Event vocabulary for the scheduling service.

A data-center fleet is not a one-shot instance: tasks arrive, tasks
finish, devices fail (the scheduler-lifecycle framing — admit / place /
reconfigure — of the energy-efficiency survey arXiv:2309.12884).  The
service consumes a stream of these events and keeps a live plan; each
event is a plain frozen dataclass so traces can be built, logged and
replayed deterministically (``SchedulerService.replay``).

Every event kind has a warm replanning path — arrivals cross-product
against the recorded root (telemetry ``path="warm"``), exits project
the recorded rows onto the surviving task axes (``"warm_exit"``), and
device failures re-rank them against the shrunken fleet
(``"warm_failure"``) — so a long mixed trace mostly reuses one
recording (the churn benchmark in ``benchmarks/scheduler_scale.py``
measures the hit rate).
"""

from __future__ import annotations

import dataclasses
from typing import Union

from ..core.task import Task

__all__ = ["TaskArrival", "TaskExit", "DeviceFailure", "DeviceRecovery", "Event"]


@dataclasses.dataclass(frozen=True)
class TaskArrival:
    """A new periodic task asks to join the fleet."""

    task: Task

    def describe(self) -> str:
        return f"arrival({self.task.name})"


@dataclasses.dataclass(frozen=True)
class TaskExit:
    """A running task leaves (completed or cancelled), freeing capacity."""

    name: str

    def describe(self) -> str:
        return f"exit({self.name})"


@dataclasses.dataclass(frozen=True)
class DeviceFailure:
    """A fleet device goes dark.  ``device`` indexes the failed device;
    ``-1`` means the last one (the only distinguishable choice on a
    homogeneous fleet)."""

    device: int = -1

    def describe(self) -> str:
        return f"device_failure({self.device})"


@dataclasses.dataclass(frozen=True)
class DeviceRecovery:
    """The most recently failed device comes back (repair / restart).

    Recovery is LIFO: the service keeps a stack of failed-device records
    and a recovery pops the newest — enough to express any
    fail-k-then-heal trace the fault-injection simulator replays, without
    needing stable device identities on homogeneous fleets."""

    def describe(self) -> str:
        return "device_recovery"


Event = Union[TaskArrival, TaskExit, DeviceFailure, DeviceRecovery]
