"""Failure-injection simulator: does a k-resilient plan really survive?

The resilience mode (``PADPSFRScheduler.schedule(..., resilience=k)`` /
``SchedulerService(resilience=k)``) *proves* its guarantee analytically —
every accepted combo passes a second placement sweep on the worst-case
survivor fleet.  This module closes the loop empirically: it builds
deterministic seeded traces of :class:`~repro.service.events.DeviceFailure`
(and optional :class:`~repro.service.events.DeviceRecovery`) events,
replays them through a live :class:`~repro.service.SchedulerService`, and
counts **replan-window deadline misses**.

The miss model is the service's own failure semantics: when a device
dies, the *serving* plan keeps running until the replanner answers, and
only switches over when a replan succeeds.  If the serving combo still
places on the surviving fleet (checked against the scalar oracle,
:func:`repro.core.placement.place_combo`), every task's share fits a
slice and no deadline is missed; if it does not, every task misses one
deadline per period that elapses inside the *measured* replan window —
the failure event's own telemetry latency, which the warm-removal path
(``path="warm_failure"``) keeps far below one period, so in practice
each task is charged ``max(1, ceil(latency / period))`` = one miss.

What the simulator demonstrates (asserted in ``tests/test_faultsim.py``
and measured in ``benchmarks/scheduler_scale.py``'s ``bench_resilience``):

* a ``resilience=k`` plan replayed under **any** k seeded failures
  records **zero** replan-window misses — the worst-case-survivor check
  covers every actual k-subset on homogeneous fleets (all k-subsets are
  equivalent) and the documented deterministic adversary on
  heterogeneous ones;
* the same trace against a ``resilience=0`` service on a crafted
  instance records misses — the guarantee is not vacuous;
* the price of the guarantee is the **power premium**
  (:func:`power_premium`): the k-resilient winner's total power over the
  unconstrained winner's.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.placement import place_combo
from ..core.task import FleetSpec, Task
from .events import DeviceFailure, DeviceRecovery, Event
from .service import SchedulerService

__all__ = [
    "FaultEventRecord",
    "FaultSimResult",
    "make_failure_trace",
    "run_fault_injection",
    "power_premium",
]


@dataclasses.dataclass(frozen=True)
class FaultEventRecord:
    """One injected event and what it did to the live plan."""

    step: int
    event: str  # the event's describe() string
    n_f_after: int  # surviving fleet size after the event
    plan_survived: bool  # serving combo still places on the new fleet
    misses: int  # replan-window deadline misses charged to this event
    replanned_feasible: bool  # did the service find a plan afterwards?
    total_power: float  # live plan power after the event (inf if none)


@dataclasses.dataclass
class FaultSimResult:
    """Outcome of one seeded trace replayed through the service."""

    resilience: int
    seed: int
    n_tasks: int
    n_failures: int
    records: list[FaultEventRecord]
    initial_power: float

    @property
    def total_misses(self) -> int:
        return sum(r.misses for r in self.records)

    @property
    def survived(self) -> bool:
        """True when no injected failure caused a replan-window miss."""
        return self.total_misses == 0


def make_failure_trace(
    n_f: int,
    n_failures: int,
    *,
    seed: int = 0,
    recover: bool = False,
) -> list[Event]:
    """Deterministic seeded failure (and optional recovery) trace.

    Each failure targets a uniformly drawn valid index of the fleet as it
    stands at that point in the trace (``n_f``, then ``n_f - 1``, ...),
    so replays are valid on homogeneous and heterogeneous fleets alike.
    With ``recover=True`` the trace heals every failure afterwards (LIFO,
    matching :meth:`~repro.service.SchedulerService.recover_device`), so
    a replay ends on the original fleet size.
    """
    if n_failures >= n_f:
        raise ValueError(
            f"cannot fail {n_failures} of {n_f} devices and keep a fleet"
        )
    rng = np.random.default_rng(seed)
    events: list[Event] = []
    for i in range(n_failures):
        events.append(DeviceFailure(device=int(rng.integers(0, n_f - i))))
    if recover:
        events.extend(DeviceRecovery() for _ in range(n_failures))
    return events


def run_fault_injection(
    fleet: FleetSpec,
    tasks: Sequence[Task],
    *,
    resilience: int = 0,
    n_failures: int = 1,
    seed: int = 0,
    recover: bool = False,
    engine: str = "numpy",
) -> FaultSimResult:
    """Schedule ``tasks`` at the given resilience, then inject failures.

    Builds a :class:`~repro.service.SchedulerService` with
    ``resilience=k``, submits every task (raises if any is rejected —
    the caller's instance must be admissible at the requested k; an
    inadmissible instance proves nothing about survival), replays the
    seeded trace, and charges replan-window misses per the module's miss
    model.  Returns the full per-event record.
    """
    svc = SchedulerService(fleet, engine=engine, resilience=resilience)
    for t in tasks:
        row = svc.submit(t)
        if not row.admitted:
            raise ValueError(
                f"task {t.name!r} rejected at resilience={resilience}: "
                f"{row.reason}"
            )
    assert svc.plan is not None
    initial_power = float(svc.plan.total_power)
    trace = make_failure_trace(
        fleet.n_f, n_failures, seed=seed, recover=recover
    )
    # The combo actually serving traffic.  It only switches when a replan
    # *succeeds* — a real deployment keeps running the old plan while the
    # replanner comes up empty (e.g. a k=2 service on 3 survivors cannot
    # re-prove 2-fault tolerance, but the original k=2 plan still places).
    serving = svc.plan
    records: list[FaultEventRecord] = []
    for step, ev in enumerate(trace):
        pre_fleet = svc.fleet
        if isinstance(ev, DeviceFailure):
            svc.fail_device(ev.device)
        else:
            svc.recover_device()
        if isinstance(ev, DeviceFailure) and svc.fleet.n_f == pre_fleet.n_f:
            # Refused (last device): nothing changed, nothing to miss.
            survived, misses = True, 0
        elif isinstance(ev, DeviceFailure):
            # The replan window: the serving combo keeps running on the
            # surviving fleet until the replanner answers.  The scalar
            # oracle is the ground truth for whether those slices still
            # meet every deadline; if not, each task misses once per
            # period elapsed inside the event's measured replan latency.
            plan = place_combo(serving.combo, svc.tasks, svc.fleet)
            survived = bool(plan.feasible)
            if survived:
                misses = 0
            else:
                window = svc.telemetry[-1].latency_s
                misses = sum(
                    max(1, int(np.ceil(window / t.period)))
                    for t in svc.tasks
                )
        else:
            # Recoveries only add capacity; a plan that served the
            # smaller fleet serves the larger one unchanged.
            survived, misses = True, 0
        post = svc.plan
        if post is not None and post.feasible:
            serving = post  # the replanner answered: switch over
        records.append(
            FaultEventRecord(
                step=step,
                event=ev.describe(),
                n_f_after=svc.fleet.n_f,
                plan_survived=survived,
                misses=misses,
                replanned_feasible=post is not None and post.feasible,
                total_power=(
                    float(post.total_power) if post is not None else float("inf")
                ),
            )
        )
    return FaultSimResult(
        resilience=resilience,
        seed=seed,
        n_tasks=len(tasks),
        n_failures=n_failures,
        records=records,
        initial_power=initial_power,
    )


def power_premium(
    fleet: FleetSpec,
    tasks: Sequence[Task],
    ks: Sequence[int] = (0, 1, 2),
    *,
    engine: str = "numpy",
) -> dict[int, dict]:
    """The cost of the guarantee: total power at each resilience level.

    Schedules the same instance once per ``k`` and reports each level's
    winning power plus its premium over the ``k=0`` baseline (``None``
    when a level is infeasible).  This is the number
    ``benchmarks/scheduler_scale.py`` tracks as ``resilience_k*`` rows.
    """
    from ..core.scheduler import PADPSFRScheduler

    sched = PADPSFRScheduler(fleet, engine=engine)
    out: dict[int, dict] = {}
    base: float | None = None
    for k in ks:
        res = sched.schedule(tuple(tasks), resilience=int(k))
        power = float(res.total_power) if res.feasible else None
        if k == 0:
            base = power
        if power is None or base is None:
            premium = None
        elif base > 0.0:
            premium = (power - base) / base * 100.0
        else:
            # zero-power k=0 baseline: any k-resilient plan is pure premium,
            # but there is no ratio to report — pin it at 0.0
            premium = 0.0
        out[int(k)] = {
            "feasible": bool(res.feasible),
            "power": power,
            "premium_pct": premium,
            "chosen_rank": int(res.chosen_rank),
        }
    return out
