# Scheduler-as-a-service: a live plan maintained across task arrivals,
# exits, device failures and recoveries, with delta replanning
# (repro.core.replan) underneath and a failure-injection simulator
# (repro.service.faultsim) that verifies resilience-mode plans survive.
# See docs/architecture.md for the replan lifecycle and fault tolerance.

from .events import DeviceFailure, DeviceRecovery, Event, TaskArrival, TaskExit
from .faultsim import (
    FaultEventRecord,
    FaultSimResult,
    make_failure_trace,
    power_premium,
    run_fault_injection,
)
from .service import ReplanTelemetry, SchedulerService

__all__ = [
    "DeviceFailure",
    "DeviceRecovery",
    "Event",
    "TaskArrival",
    "TaskExit",
    "ReplanTelemetry",
    "SchedulerService",
    "FaultEventRecord",
    "FaultSimResult",
    "make_failure_trace",
    "run_fault_injection",
    "power_premium",
]
