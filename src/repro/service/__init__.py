# Scheduler-as-a-service: a live plan maintained across task arrivals,
# exits and device failures, with delta replanning (repro.core.replan)
# underneath.  See docs/architecture.md for the replan lifecycle.

from .events import DeviceFailure, Event, TaskArrival, TaskExit
from .service import ReplanTelemetry, SchedulerService

__all__ = [
    "DeviceFailure",
    "Event",
    "TaskArrival",
    "TaskExit",
    "ReplanTelemetry",
    "SchedulerService",
]
