"""A long-running scheduler-as-a-service wrapper around PADPS-FR.

The paper's Algs 1-3 solve a *static* instance; :class:`SchedulerService`
keeps a fleet's plan alive across a stream of
:mod:`~repro.service.events` — task arrivals, task exits, device
failures — with three latency tiers per event:

1. **admission filter** — a closed-form eq-7 lower bound (every task at
   its cheapest share) rejects hopeless arrivals without touching the
   combo walk at all;
2. **plan cache** — a task set the service has already solved on the
   current fleet (steady-state churn: a task leaves and comes back) is
   answered from memory;
3. **delta replanner** — everything else goes through
   :meth:`repro.core.scheduler.PADPSFRScheduler.replan`, which
   warm-starts the Alg 1+2 walk from the previous
   :class:`~repro.core.replan.PlanState` and stays bit-identical to a
   cold ``schedule()`` of the same task set.

Beyond the event stream, :meth:`SchedulerService.what_if_many` answers
speculative batched what-ifs — B candidate arrivals scheduled against the
current task set in one fleet-parallel ``schedule_many`` sweep, with no
service state touched.

Every event returns a :class:`ReplanTelemetry` row, so a trace replay
doubles as a latency/provenance log.  Arrivals that turn out infeasible
are *rolled back* — the previous plan keeps serving and the telemetry
records the rejection; device failures are never rolled back (the
device is gone), so an unlucky fleet can end up with ``feasible=False``
telemetry and a degraded (``None``) plan until exits free capacity.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

from ..core.scheduler import PADPSFRScheduler, ScheduleInstance, ScheduleResult
from ..core.task import DeviceProfile, FleetSpec, Task
from .events import DeviceFailure, DeviceRecovery, Event, TaskArrival, TaskExit

__all__ = ["ReplanTelemetry", "SchedulerService"]

# PlanState.origin -> telemetry path: which replan machinery produced the
# event's result.  Anything the replanner solved fresh (origin "cold")
# reports as "general"; the three warm paths are distinguished so traces
# show *which* event kinds actually reuse work.
_ORIGIN_PATH = {
    "cold": "general",
    "warm_arrival": "warm",
    "warm_exit": "warm_exit",
    "warm_failure": "warm_failure",
}

# Telemetry paths that reused previous work: a solve that skipped the
# fresh branch-and-bound.  (Admission/noop rows never solved at all and
# count separately.)
_WARM_PATHS = ("cache", "warm", "warm_exit", "warm_failure")


@dataclasses.dataclass(frozen=True)
class ReplanTelemetry:
    """What one event cost and what it did to the plan."""

    event: str  # e.g. "arrival(decode-7b)"
    admitted: bool  # did the fleet state actually change?
    # "admission" | "cache" | "warm" | "warm_exit" | "warm_failure"
    # | "general" | "noop"
    path: str
    latency_s: float
    n_tasks: int  # tasks in service after the event
    feasible: bool  # is there a live plan after the event?
    total_power: float  # inf when infeasible / no tasks
    chosen_rank: int  # -1 when infeasible / no tasks
    reason: str = ""  # human detail for rejections / degradations


class SchedulerService:
    """Event-driven scheduling facade with delta replanning.

    ``record_exhaustive=True`` (the default) makes each fresh walk keep
    going past its winner so every TFS row carries a placement verdict —
    the first solve on a big instance costs more, but subsequent arrival
    replans skip dispatch for every recorded reject (the ≥10x
    steady-state path measured in ``benchmarks/scheduler_scale.py``).
    Set it to ``False`` to optimise for one-shot latency instead.

    ``SchedulerService(fleet, resilience=k)`` runs every solve in
    resilience mode (the option rides in ``placement_kw``): admitted
    plans are guaranteed to stay placeable after any k device failures,
    and the admission filter tightens to the worst-case survivor fleet's
    eq-7 budget.  The guarantee is verified empirically by
    :mod:`repro.service.faultsim`.

    **Staleness-bounded re-recording.**  Warm replans carry state
    forward, but each hop narrows it (banded removal states, arrival
    chains against an aging root).  After ``max_stale`` consecutive
    warm-path events, or whenever the live state's
    :attr:`~repro.core.replan.PlanState.frontier_coverage` drops below
    ``min_coverage`` (full roots report 1.0; incumbent-banded removal
    states at most 0.5, so the 0.6 default re-roots after every warm
    removal), the service schedules a *background* re-record —
    a full exhaustive ``record_state=True`` solve of the current tasks,
    run after the event's telemetry row is closed (so it never inflates
    event latency), checked bit-identical to the live plan, and swapped
    in as the new root.  ``rerecord_count`` tallies how often the
    policy fired.
    """

    def __init__(
        self,
        fleet: FleetSpec,
        *,
        engine: str = "numpy",
        record_exhaustive: bool = True,
        cache_plans: bool = True,
        max_stale: int = 8,
        min_coverage: float = 0.6,
        **placement_kw,
    ) -> None:
        self.fleet = fleet
        self.engine = engine
        self.record_exhaustive = record_exhaustive
        self.cache_plans = cache_plans
        self.max_stale = int(max_stale)
        self.min_coverage = float(min_coverage)
        self.placement_kw = dict(placement_kw)
        k = self.placement_kw.get("resilience", 0)
        if isinstance(k, bool) or not isinstance(k, int) or k < 0:
            raise ValueError(
                f"resilience must be a non-negative integer, got {k!r}"
            )
        self.resilience = k
        self._sched = PADPSFRScheduler(fleet, engine=engine)
        self._tasks: tuple[Task, ...] = ()
        self._result: ScheduleResult | None = None
        self._cache: dict[tuple, ScheduleResult] = {}
        # LIFO records of failed devices, for DeviceRecovery: the profile
        # and original index for heterogeneous fleets, (None, None) for
        # homogeneous ones (identical devices need no identity).
        self._failed: list[tuple[int, DeviceProfile] | tuple[None, None]] = []
        self.telemetry: list[ReplanTelemetry] = []
        self._stale = 0  # consecutive warm-path events since a fresh root
        self.rerecord_count = 0

    # -- public state ---------------------------------------------------
    @property
    def tasks(self) -> tuple[Task, ...]:
        return self._tasks

    @property
    def plan(self) -> ScheduleResult | None:
        """The live plan (None while the service holds no tasks)."""
        return self._result

    # -- events ---------------------------------------------------------
    def submit(self, task: Task) -> ReplanTelemetry:
        """Admit ``task`` if a feasible plan including it exists."""
        t0 = time.perf_counter()
        if any(t.name == task.name for t in self._tasks):
            return self._log(
                f"arrival({task.name})", False, "admission", t0,
                reason="duplicate task name",
            )
        target = self._tasks + (task,)
        if self.resilience >= self.fleet.n_f:
            # The fleet cannot survive k failures at all; no task set is
            # admissible until devices recover (or exits are free anyway).
            return self._log(
                f"arrival({task.name})", False, "admission", t0,
                reason="resilience exceeds surviving fleet size",
            )
        # Admission bound against the fleet every plan must survive on:
        # the worst-case survivor fleet when resilience is requested.
        bfleet = (
            self.fleet.survivors(self.resilience)
            if self.resilience
            else self.fleet
        )
        lo = sum(min(t.shares(self.fleet.t_slr)) for t in target)
        if lo > bfleet.workable_budget(len(target)) + 1e-9:
            # Even the cheapest variant of every task overshoots eq. 7:
            # the TFS is provably empty, no walk needed.
            return self._log(
                f"arrival({task.name})", False, "admission", t0,
                reason="eq-7 lower bound exceeds fleet budget",
            )
        res, path = self._solve(target)
        if not res.feasible:
            return self._log(
                f"arrival({task.name})", False, path, t0,
                reason="no placeable combo; arrival rolled back",
            )
        self._tasks, self._result = target, res
        return self._log(f"arrival({task.name})", True, path, t0)

    def remove(self, name: str) -> ReplanTelemetry:
        """Release the named task's capacity and replan the remainder."""
        t0 = time.perf_counter()
        if all(t.name != name for t in self._tasks):
            return self._log(
                f"exit({name})", False, "admission", t0,
                reason="unknown task name",
            )
        target = tuple(t for t in self._tasks if t.name != name)
        if not target:
            self._tasks, self._result = (), None
            return self._log(f"exit({name})", True, "noop", t0)
        res, path = self._solve(target)
        # an exit is never rolled back: the task is gone either way.
        self._tasks, self._result = target, res
        return self._log(f"exit({name})", True, path, t0)

    def fail_device(self, device: int = -1) -> ReplanTelemetry:
        """Drop one device from the fleet and replan on what's left.

        ``device`` must be ``-1`` (the last device) or a valid index
        ``0 <= device < n_f``; anything else raises ``ValueError`` — a
        failure report naming a device the fleet does not have is a
        caller bug, not a schedulable event.  Failing the *final* device
        is refused via telemetry (the service must keep one device to
        stay meaningful), not raised: it is a legal trace event that the
        fleet simply cannot absorb.
        """
        t0 = time.perf_counter()
        if self.fleet.n_f == 0:
            raise ValueError("cannot fail a device on an empty fleet")
        if not -1 <= device < self.fleet.n_f:
            raise ValueError(
                f"device index {device} out of range for fleet with "
                f"n_f={self.fleet.n_f} (expected -1 or 0..{self.fleet.n_f - 1})"
            )
        if self.fleet.n_f <= 1:
            return self._log(
                f"device_failure({device})", False, "admission", t0,
                reason="cannot fail the last device",
            )
        idx = device if device >= 0 else self.fleet.n_f - 1
        if self.fleet.is_heterogeneous:
            self._failed.append((idx, self.fleet.devices[idx]))
            profiles = tuple(
                d for j, d in enumerate(self.fleet.devices) if j != idx
            )
            self.fleet = FleetSpec.heterogeneous(profiles, name=self.fleet.name)
        else:
            self._failed.append((None, None))
            self.fleet = dataclasses.replace(self.fleet, n_f=self.fleet.n_f - 1)
        self._sched = PADPSFRScheduler(self.fleet, engine=self.engine)
        if not self._tasks:
            return self._log(f"device_failure({device})", True, "noop", t0)
        res, path = self._solve(self._tasks)
        # never rolled back; the plan may come back infeasible (degraded).
        self._result = res
        return self._log(f"device_failure({device})", True, path, t0)

    def recover_device(self) -> ReplanTelemetry:
        """Restore the most recently failed device (LIFO) and replan.

        Heterogeneous fleets get the exact profile back at its original
        index; homogeneous fleets simply grow by one.  With no failure on
        record the event is refused via telemetry — recovery of a device
        that never failed is a trace inconsistency, not a crash.
        """
        t0 = time.perf_counter()
        if not self._failed:
            return self._log(
                "device_recovery", False, "admission", t0,
                reason="no failed device to recover",
            )
        idx, profile = self._failed.pop()
        if profile is not None:
            devices = list(self.fleet.devices)
            devices.insert(min(idx, len(devices)), profile)
            self.fleet = FleetSpec.heterogeneous(
                tuple(devices), name=self.fleet.name
            )
        else:
            self.fleet = dataclasses.replace(self.fleet, n_f=self.fleet.n_f + 1)
        self._sched = PADPSFRScheduler(self.fleet, engine=self.engine)
        if not self._tasks:
            return self._log("device_recovery", True, "noop", t0)
        res, path = self._solve(self._tasks)
        self._result = res
        return self._log("device_recovery", True, path, t0)

    def replay(self, events: Iterable[Event]) -> list[ReplanTelemetry]:
        """Apply an event trace in order; returns one telemetry row each."""
        out = []
        for ev in events:
            if isinstance(ev, TaskArrival):
                out.append(self.submit(ev.task))
            elif isinstance(ev, TaskExit):
                out.append(self.remove(ev.name))
            elif isinstance(ev, DeviceFailure):
                out.append(self.fail_device(ev.device))
            elif isinstance(ev, DeviceRecovery):
                out.append(self.recover_device())
            else:
                raise TypeError(f"unknown event {ev!r}")
        return out

    # -- batched what-ifs -----------------------------------------------
    def what_if_many(
        self,
        arrivals: Sequence[Task],
        *,
        shard: int | str | None = None,
    ) -> list[ScheduleResult]:
        """Answer "what would admitting each of these cost?" in one sweep.

        Purely speculative: each candidate arrival is scheduled against
        the *current* tasks + that one candidate — B independent
        instances batched through
        :meth:`~repro.core.scheduler.PADPSFRScheduler.schedule_many` —
        and nothing about the service (tasks, plan, cache, telemetry)
        changes.  Returns one :class:`~repro.core.scheduler.ScheduleResult`
        per candidate, in order; an inadmissible candidate simply comes
        back ``feasible=False``.  ``shard`` is forwarded to the batched
        walk (instance axis over jax devices; ignored off-jax engines).

        This is the service-side fleet-parallel entry point: a placement
        controller probing "which of these 64 queued jobs fits
        cheapest?" pays one batched walk instead of 64 solo walks.
        """
        instances = [
            ScheduleInstance(tasks=self._tasks + (a,), fleet=self.fleet)
            for a in arrivals
        ]
        return self._sched.schedule_many(
            instances, shard=shard, **self.placement_kw
        )

    # -- internals ------------------------------------------------------
    def _cache_key(self, tasks: Sequence[Task]) -> tuple:
        return (tuple(tasks), self.fleet)

    def _solve(self, target: tuple[Task, ...]) -> tuple[ScheduleResult, str]:
        key = self._cache_key(target)
        if self.cache_plans and key in self._cache:
            return self._cache[key], "cache"
        state = self._result.plan_state if self._result is not None else None
        if state is not None:
            res = self._sched.replan(
                state,
                target,
                record_exhaustive=self.record_exhaustive,
                **self.placement_kw,
            )
            # Every replan tags the state it emits with the path that
            # built it; "cold" covers the general fresh-walk fallback.
            st = res.plan_state
            origin = st.origin if st is not None else "cold"
            path = _ORIGIN_PATH.get(origin, "general")
        else:
            res = self._sched.schedule(
                target,
                record_state=True,
                record_exhaustive=self.record_exhaustive,
                **self.placement_kw,
            )
            path = "general"
        if self.cache_plans and res.feasible:
            self._cache[key] = res
        return res, path

    def _log(
        self,
        event: str,
        admitted: bool,
        path: str,
        t0: float,
        *,
        reason: str = "",
    ) -> ReplanTelemetry:
        res = self._result
        row = ReplanTelemetry(
            event=event,
            admitted=admitted,
            path=path,
            latency_s=time.perf_counter() - t0,
            n_tasks=len(self._tasks),
            feasible=res is not None and res.feasible,
            total_power=res.total_power if res is not None else float("inf"),
            chosen_rank=res.chosen_rank if res is not None else -1,
            reason=reason,
        )
        self.telemetry.append(row)
        if admitted and path in _WARM_PATHS:
            self._stale += 1
        elif admitted and path == "general":
            self._stale = 0
        self._maybe_rerecord(path)
        return row

    def _maybe_rerecord(self, path: str) -> None:
        """Swap in a fresh exhaustive root when the live state is stale.

        Runs *after* the event's telemetry row is closed, so the re-record
        cost never shows up in per-event latency.  The fresh solve must be
        bit-identical to the live plan — anything else means the warm
        paths drifted from cold ``schedule()``, which is a bug worth
        crashing on.
        """
        res = self._result
        if (
            path not in _WARM_PATHS
            or not self._tasks
            or res is None
            or not res.feasible
            or res.plan_state is None
        ):
            return
        st = res.plan_state
        root = st.base if st.base is not None else st
        # A sub-2-task root cannot serve future removals (the exit chain
        # needs a survivor), so a grown service on a tiny root re-roots.
        need = (
            self._stale >= self.max_stale
            or st.frontier_coverage < self.min_coverage
            or len(root.tasks) < 2 <= len(st.tasks)
        )
        if not need:
            return
        fresh = self._sched.schedule(
            self._tasks,
            record_state=True,
            record_exhaustive=True,
            **self.placement_kw,
        )
        if (
            fresh.feasible != res.feasible
            or fresh.total_power != res.total_power
            or fresh.chosen_rank != res.chosen_rank
            or str(fresh.plan) != str(res.plan)
        ):
            raise RuntimeError(
                "re-record produced a different plan than the live warm "
                f"result for {len(self._tasks)} tasks on {self.fleet.name}"
            )
        self._result = fresh
        if self.cache_plans:
            self._cache[self._cache_key(self._tasks)] = fresh
        self._stale = 0
        self.rerecord_count += 1
