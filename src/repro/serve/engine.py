"""Batched serving engine.

``make_prefill_step`` / ``make_decode_step`` are the functions the
dry-run lowers for the prefill_* / decode_* / long_* shapes.  The
engine batches requests, prefills them together, and decodes greedily
(or by sampling) with a fixed-size state — KV caches are allocated at
``max_len`` up front so every decode step has a static shape.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model

__all__ = ["ServeConfig", "ServeEngine", "make_prefill_step", "make_decode_step"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = -1  # -1 = never stop early


def make_prefill_step(model: Model) -> Callable:
    """(params, batch) -> (last_logits, state)."""

    def prefill(params, batch):
        return model.prefill(params, batch)

    return prefill


def make_decode_step(model: Model) -> Callable:
    """(params, state, tokens, idx) -> (logits, state)."""

    def decode(params, state, tokens, idx):
        return model.decode_step(params, state, tokens, idx)

    return decode


def _pad_cache_to(state: Any, family: str, max_len: int) -> Any:
    """Grow transformer/encdec prefill caches (length S) to max_len."""

    def pad_kv(arr):
        cur = arr.shape[2]
        if cur >= max_len:
            return arr
        pad = [(0, 0)] * arr.ndim
        pad[2] = (0, max_len - cur)
        return jnp.pad(arr, pad)

    if family in ("dense", "moe", "vlm"):
        return (pad_kv(state[0]), pad_kv(state[1]))
    if family == "encdec":
        return {"self": (pad_kv(state["self"][0]), pad_kv(state["self"][1])),
                "cross": state["cross"]}
    return state  # ssm / hybrid states are fixed-size


class ServeEngine:
    """Prefill-then-decode engine over a fixed request batch."""

    def __init__(self, model: Model, params: Any, config: ServeConfig | None = None,
                 *, jit: bool = True) -> None:
        self.model = model
        self.params = params
        self.config = config or ServeConfig()
        prefill = make_prefill_step(model)
        decode = make_decode_step(model)
        if jit:
            prefill = jax.jit(prefill)
            decode = jax.jit(decode, donate_argnums=(1,))
        self._prefill = prefill
        self._decode = decode

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.config.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.config.temperature, axis=-1).astype(jnp.int32)

    def generate(
        self,
        batch: dict,
        max_new_tokens: int,
        *,
        key: jax.Array | None = None,
    ) -> jnp.ndarray:
        """Prefill `batch` then decode greedily.  Returns (B, new) tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.family == "vlm":
            prompt_len += batch["patch_embeds"].shape[1]
        last_logits, state = self._prefill(self.params, batch)
        state = _pad_cache_to(state, self.model.cfg.family, self.config.max_len)
        tokens = self._sample(last_logits, key)
        out = [tokens]
        done = jnp.zeros(tokens.shape, bool)
        for t in range(1, max_new_tokens):
            idx = jnp.int32(prompt_len + t - 1)
            logits, state = self._decode(self.params, state, tokens, idx)
            key, sub = jax.random.split(key)
            tokens = self._sample(logits, sub)
            if self.config.eos_id >= 0:
                done = done | (tokens == self.config.eos_id)
                if bool(done.all()):
                    out.append(tokens)
                    break
            out.append(tokens)
        return jnp.stack(out, axis=1)
