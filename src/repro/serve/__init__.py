"""Serving: prefill/decode step factories + batched engine."""

from .engine import ServeEngine, ServeConfig, make_prefill_step, make_decode_step

__all__ = ["ServeEngine", "ServeConfig", "make_prefill_step", "make_decode_step"]
