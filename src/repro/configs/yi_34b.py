"""yi-34b — llama-arch dense GQA.

[arXiv:2403.04652; hf]  60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000.
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="yi-34b",
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        head_dim=128,
        rope="rope",
        source="arXiv:2403.04652",
    )
)
