"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per fine-grained expert) vocab=163840, MoE 64e top-6.
"""

from .base import ModelConfig, MoESpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=163840,
        head_dim=128,
        moe=MoESpec(n_experts=64, top_k=6),
        rope="rope",
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
)
