"""dbrx-132b — Databricks DBRX, 16 experts top-4, fine-grained MoE.

[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (GQA kv=8)
d_ff=10752 vocab=100352, MoE 16e top-4.
"""

from .base import ModelConfig, MoESpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        head_dim=128,
        moe=MoESpec(n_experts=16, top_k=4),
        rope="rope",
        source="hf:databricks/dbrx-base",
    )
)
