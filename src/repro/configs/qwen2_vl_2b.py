"""qwen2-vl-2b — VLM backbone with M-RoPE (3-section rotary: t/h/w).

[arXiv:2409.12191; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  The vision patch frontend is a STUB (``input_specs``
provides precomputed patch embeddings + 3-D M-RoPE position ids,
per the assignment); dynamic resolution enters only through the
position-id stream.
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        head_dim=128,
        rope="mrope",
        mrope_sections=(16, 24, 24),
        modality="vision",
        source="arXiv:2409.12191",
    )
)
