"""qwen1.5-110b — dense GQA transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064.
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab=152064,
        head_dim=128,
        qkv_bias=True,
        rope="rope",
        source="hf:Qwen/Qwen1.5-0.5B",
    )
)
