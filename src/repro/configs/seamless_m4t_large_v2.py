"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf]  24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  Enc-dec: 24 encoder + 24 decoder layers on the text/unit
backbone; the speech frontend is a STUB (``input_specs`` provides
precomputed frame embeddings, per the assignment).
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        n_layers=24,  # decoder layers
        enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=256206,
        head_dim=64,
        rope="none",  # learned/sinusoidal positions in m4t; none needed for backbone math
        modality="audio",
        source="arXiv:2308.11596",
    )
)
