"""mamba2-130m — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  24L d_model=768 (attn-free) vocab=50280,
ssm_state=128; expand=2 -> d_inner=1536, head_dim=64 -> 24 SSM heads.
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv=4,
        ssm_chunk=256,
        rope="none",
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
)
