"""Assigned input shapes (4 per LM arch) and arch x shape applicability."""

from __future__ import annotations

import dataclasses

from .base import ModelConfig

__all__ = ["InputShape", "SHAPES", "get_shape", "cell_applicability", "all_cells"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        """Tokens processed per step (decode: one new token per sequence)."""
        if self.kind == "decode":
            return self.global_batch
        return self.seq_len * self.global_batch


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") from None


def cell_applicability(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason).  Per the assignment:

    * ``long_500k`` needs sub-quadratic attention — runs only for
      SSM / hybrid archs (bounded decode state); skipped for pure
      full-attention archs (a 512k dense KV cache is the excluded
      quadratic case).  Recorded as explicit SKIP rows.
    * encoder-only archs would skip decode shapes; none of the assigned
      archs is encoder-only (seamless is enc-dec: its decoder decodes).
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k decode KV is quadratic-memory; skipped per assignment"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    from . import list_archs  # late import to avoid cycle

    return [(a, s) for a in list_archs() for s in SHAPES]
