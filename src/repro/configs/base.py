"""Model configuration schema + architecture registry.

Every assigned architecture registers a :class:`ModelConfig` here; the
model zoo (``repro.models``) builds from these, the launcher selects them
via ``--arch <id>``, and each config can produce a ``reduced()`` twin of
the same family for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = [
    "MoESpec",
    "ModelConfig",
    "ARCH_REGISTRY",
    "register_arch",
    "get_arch",
    "list_archs",
]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    # capacity factor for expert dispatch buffers (tokens per expert =
    # tokens * top_k / n_experts * capacity)
    capacity: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (one instance per assigned arch)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    moe: MoESpec | None = None
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (recurrentgemma / griffin) ---
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    local_window: int = 2048
    lru_width: int = 0  # 0 -> d_model
    # --- positional encoding ---
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # qwen2-vl t/h/w split
    # --- encoder-decoder ---
    enc_layers: int = 0
    # --- modality frontend (STUB per assignment: precomputed embeddings) ---
    modality: str = "text"  # text | audio | vision
    # --- numerics / execution ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # Activation checkpointing. 'full' (remat each layer, save only layer
    # boundaries) is the production default: 'dots' keeps every matmul
    # output alive — including flash-attention score tiles — and costs
    # ~10x the activation memory at 4k sequence length (see §Perf).
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True  # lax.scan over layer-stacked params
    tie_embeddings: bool = False
    source: str = ""  # provenance tag from the assignment table

    def __post_init__(self) -> None:
        if self.family not in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"):
            raise ValueError(f"unknown family {self.family}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoESpec")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is bounded (long_500k applicability)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND FLOPs."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        if self.family == "ssm":
            di = self.ssm_expand * self.d_model
            nh = di // self.ssm_head_dim
            per_layer = (
                d * (2 * di + 2 * self.ssm_state + nh)  # in_proj (x,z,B,C,dt)
                + self.ssm_conv * (di + 2 * self.ssm_state)
                + di * d  # out_proj
                + 2 * nh  # A, D
            )
        elif self.family == "hybrid":
            w = self.lru_width or d
            rec = d * w * 2 + w * d + 3 * w + 2 * (w * w // 8)  # rg-lru gates (block-diag 8)
            mlp = 3 * d * self.d_ff
            n_attn = sum(1 for b in self._pattern() if b == "attn")
            n_rec = self.n_layers - n_attn
            per_layer = 0  # handled below
            blocks = n_rec * (rec + mlp) + n_attn * (attn + mlp)
            return emb + blocks
        elif self.family == "moe":
            assert self.moe is not None
            router = d * self.moe.n_experts
            experts = self.moe.n_experts * 3 * d * self.d_ff
            per_layer = attn + router + experts
        else:
            per_layer = attn + 3 * d * self.d_ff
        n_layers = self.n_layers + self.enc_layers
        if self.family == "encdec":
            # decoder layers add cross-attention
            per_layer_dec = per_layer + attn
            return emb + self.enc_layers * per_layer + self.n_layers * per_layer_dec
        return emb + n_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        assert self.moe is not None
        d = self.d_model
        total = self.param_count()
        experts_all = self.n_layers * self.moe.n_experts * 3 * d * self.d_ff
        experts_active = self.n_layers * self.moe.top_k * 3 * d * self.d_ff
        return total - experts_all + experts_active

    def _pattern(self) -> tuple[str, ...]:
        if not self.block_pattern:
            return ()
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind for hybrid models; uniform otherwise."""
        if self.family == "hybrid":
            return self._pattern()
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        return ("attn",) * self.n_layers

    def reduced(self) -> "ModelConfig":
        """Same-family tiny twin for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            family=self.family,
            n_layers=min(self.n_layers, 3 if self.family != "hybrid" else 3),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab=512,
            head_dim=16,
            qkv_bias=self.qkv_bias,
            moe=MoESpec(4, min(self.moe.top_k, 2)) if self.moe else None,
            ssm_state=16 if self.ssm_state else 0,
            ssm_expand=self.ssm_expand,
            ssm_head_dim=16,
            ssm_conv=self.ssm_conv,
            ssm_chunk=16,
            block_pattern=self.block_pattern,
            local_window=16,
            lru_width=64 if self.lru_width else 0,
            rope=self.rope,
            rope_theta=self.rope_theta,
            mrope_sections=(2, 3, 3) if self.rope == "mrope" else self.mrope_sections,
            enc_layers=min(self.enc_layers, 2),
            modality=self.modality,
            norm_eps=self.norm_eps,
            dtype="float32",
            remat="none",
            scan_layers=self.scan_layers,
            tie_embeddings=self.tie_embeddings,
            source=self.source,
        )
        return ModelConfig(**kw)


ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in ARCH_REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCH_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}"
        ) from None


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)
