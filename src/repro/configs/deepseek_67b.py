"""deepseek-67b — llama-arch dense GQA, 95 layers.

[arXiv:2401.02954; hf]  95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400.
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=102400,
        head_dim=128,
        rope="rope",
        source="arXiv:2401.02954",
    )
)
