"""Config registry: assigned architectures, input shapes, paper task sets."""

from __future__ import annotations

from .base import ARCH_REGISTRY, ModelConfig, get_arch, list_archs, register_arch
from .shapes import SHAPES, InputShape, get_shape

# Import for registration side effects.
from . import (  # noqa: F401  isort: skip
    moonshot_v1_16b_a3b,
    dbrx_132b,
    seamless_m4t_large_v2,
    mamba2_130m,
    qwen15_110b,
    deepseek_67b,
    yi_34b,
    smollm_135m,
    qwen2_vl_2b,
    recurrentgemma_2b,
)

__all__ = [
    "ARCH_REGISTRY",
    "ModelConfig",
    "get_arch",
    "list_archs",
    "register_arch",
    "SHAPES",
    "InputShape",
    "get_shape",
]
