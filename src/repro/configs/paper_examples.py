"""The paper's own task sets — Tables I and II, Examples 1/2/3 (§IV-A).

Shipped as configs so the reproduction tests and benchmarks consume the
exact published numbers.

Power columns in Table I are truncated in the PDF ("5, 6, 7," ...); the
visible ascending-by-CU pattern fixes the missing last entries (T2: 8,
T3: 9, T4: 6).  These values do not affect the TFS/TNFS counts (only
shares enter eq. 7) and reproduce the paper's selected combination.
"""

from __future__ import annotations

from repro.core.task import FleetSpec, Task, TaskVariant

__all__ = [
    "example1_tasks",
    "example1_fleet",
    "example2_tasks",
    "example2_fleet",
    "example3_tasks",
    "example3_fleet",
]


def _task(name, p, ii, td, ths, pws):
    return Task(
        name=name,
        period=p,
        data=td,
        init_interval=ii,
        variants=tuple(
            TaskVariant(cu=j + 1, throughput=th, power=pw, program=f"{name}_{j + 1}cu.xclbin")
            for j, (th, pw) in enumerate(zip(ths, pws, strict=True))
        ),
    )


def example1_tasks() -> tuple[Task, ...]:
    """Table I.  t in ms, data in GB, throughput GB/ms, power mW."""
    return (
        _task("T1", 60, 2, 24, [0.5, 1.0], [5, 6]),
        _task("T2", 60, 4, 18, [0.5, 1.0, 1.5, 2.0], [5, 6, 7, 8]),
        _task("T3", 60, 2, 48, [1.0, 2.0, 3.0, 4.0], [6, 7, 8, 9]),
        _task("T4", 90, 4, 36, [0.25, 0.5, 0.75, 1.0], [3, 4, 5, 6]),
        _task("T5", 90, 6, 72, [1.0, 2.0, 3.0, 4.0], [4, 4.5, 5, 5.5]),
        _task("T6", 90, 6, 72, [1.0, 2.0], [4, 5]),
    )


def example1_fleet() -> FleetSpec:
    return FleetSpec(n_f=4, t_slr=60.0, t_cfg=6.0, name="example1")


def example2_tasks() -> tuple[Task, ...]:
    """Example 2 = Example 1 with II(T3): 2 -> 12 ms (§IV-A2)."""
    tasks = list(example1_tasks())
    t3 = tasks[2]
    tasks[2] = Task(
        name=t3.name,
        period=t3.period,
        data=t3.data,
        init_interval=12.0,
        variants=t3.variants,
    )
    return tuple(tasks)


def example2_fleet() -> FleetSpec:
    return example1_fleet()


def example3_tasks() -> tuple[Task, ...]:
    """Table II.  t in ms, data in KB, throughput KB/ms, power mW.

    LZ-4 / ZSTD are the Vitis lossless-compression kernels, VAdd vector
    addition; xclbins pre-generated per variant (1-3 CU LZ4, 1-2 CU ZSTD,
    1-4 CU VAdd).
    """
    return (
        _task("LZ-4", 600, 2, 107375, [129.37, 165.29, 198.84], [6.38, 6.55, 6.64]),
        _task("ZSTD", 600, 2, 107375, [244.03, 255.65], [6.89, 7.06]),
        _task("VAdd", 600, 2, 19, [0.12, 0.16, 0.18, 0.2], [6.12, 6.21, 6.38, 6.55]),
    )


def example3_fleet() -> FleetSpec:
    return FleetSpec(n_f=2, t_slr=600.0, t_cfg=21.0, name="example3-alveo50")
