"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, local window 2048.  Block pattern (rec, rec, attn)
repeating — two recurrent blocks per local-attention block.
"""

from .base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab=256000,
        head_dim=256,
        block_pattern=("rec", "rec", "attn"),
        local_window=2048,
        lru_width=2560,
        rope="rope",
        source="arXiv:2402.19427",
    )
)
