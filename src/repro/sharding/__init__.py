"""Logical-axis -> mesh sharding rules (DP/TP/EP/SP + pod axis)."""

from .ctx import activation_sharding, shard
from .rules import (
    FSDP_TP_RULES,
    PRESETS,
    SP_SERVE_RULES,
    TP_DP_RULES,
    ShardingRules,
    batch_axes_tree,
    resolve_spec,
    state_axes_tree,
    tree_shardings,
)

__all__ = [
    "activation_sharding",
    "shard",
    "FSDP_TP_RULES",
    "PRESETS",
    "SP_SERVE_RULES",
    "TP_DP_RULES",
    "ShardingRules",
    "batch_axes_tree",
    "resolve_spec",
    "state_axes_tree",
    "tree_shardings",
]
