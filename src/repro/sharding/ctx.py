"""Activation sharding constraints (MaxText's with_logical_constraint).

Without explicit constraints, GSPMD propagates the FSDP weight sharding
into activations: the batch dimension de-shards and every device
computes the full global batch (measured: smollm train_4k activations
at f32[256,4096,...] per device — 16x redundant compute and 300 GB
score copies).  ``shard(x, *logical_axes)`` pins activations at block
boundaries; it is a no-op unless an ``activation_sharding`` context is
active, so CPU tests and eager runs are untouched.

Activation dims use the same logical names as weights where the mapping
coincides (batch/heads/kv/mlp/state/vocab/seq) and ``None`` for the
embedding dim — 'embed' maps to the data axis for *weights* (FSDP), but
activations must keep 'data' for the batch dimension.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding

from .rules import ShardingRules, resolve_spec

__all__ = ["activation_sharding", "shard"]

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, rules: ShardingRules):
    """Enable shard() constraints during tracing/lowering."""
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_ctx():
    """(mesh, rules) of the active activation_sharding context, or None."""
    return _CTX.get()


def mesh_axis_size(name: str) -> int | None:
    """Size of a mesh axis in the active context (None if inactive/absent)."""
    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, _rules = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
    return sizes.get(name)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (trace-time)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} value")
    spec = resolve_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
