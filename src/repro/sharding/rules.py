"""Logical-axis sharding rules (MaxText-style).

Models annotate every parameter/input dimension with a *logical* axis
name; a rules table maps logical axes to mesh axes.  ``jax.jit``
in/out shardings are derived from the table — never hand-written per
model — so a sharding-strategy change for the §Perf hillclimb is a
one-line rule edit that applies to all 10 architectures at once.

Resolution is shape-aware: a mesh axis that does not evenly divide its
dimension, or that was already consumed by an earlier dimension of the
same tensor, is dropped (replicating that dimension).  This keeps every
(arch x shape x mesh) cell compilable — e.g. seamless' vocab 256206 is
not divisible by 16 and silently falls back to replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "ShardingRules",
    "TP_DP_RULES",
    "FSDP_TP_RULES",
    "PRESETS",
    "resolve_spec",
    "tree_shardings",
    "batch_axes",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> candidate mesh axes (applied left to right)."""

    name: str
    table: Mapping[str, tuple[str, ...]]

    def lookup(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.table.get(logical, ()))


# Baseline: plain TP over 'model' + DP batch over ('pod','data').
# Weights replicated across the data axis — the paper-era default
# (its tasks were single-device programs; DP is the 'more CUs' variant).
TP_DP_RULES = ShardingRules(
    "tp_dp",
    {
        "batch": ("pod", "data"),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "state": ("model",),
        "embed": (),
        "layers": (),
        "conv": (),
        "seq": (),
        "act_seq": (),
    },
)

# Beyond-paper: 2-D weight sharding — FSDP over 'data' on the embed
# dimension on top of TP. Params/optimizer memory drops by the data-axis
# size; XLA inserts all-gathers on use (ZeRO-3 semantics).
FSDP_TP_RULES = ShardingRules(
    "fsdp_tp",
    {
        "batch": ("pod", "data"),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "state": ("model",),
        "embed": ("data",),
        "layers": (),
        "conv": (),
        "seq": (),
        "act_seq": (),
    },
)

# + Megatron-style sequence parallelism: the residual stream (and the
# remat-saved per-layer activations — the HBM make-or-break at 95 layers
# x 4k seq) shards its sequence dim over 'model' between blocks; XLA
# inserts the all-gather before qkv/mlp and reduce-scatter after.
FSDP_TP_SP_RULES = ShardingRules(
    "fsdp_tp_sp",
    {
        "batch": ("pod", "data"),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "state": ("model",),
        "embed": ("data",),
        "layers": (),
        "conv": (),
        "seq": (),
        "act_seq": ("model",),
    },
)

# Sequence-parallel variant for long-context serving: KV-cache time axis
# sharded over 'model' (kv heads too few to fill the axis on GQA archs).
SP_SERVE_RULES = ShardingRules(
    "sp_serve",
    {
        "batch": ("pod", "data"),
        "heads": ("model",),
        "kv": (),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "state": ("model",),
        "embed": ("data",),
        "layers": (),
        "conv": (),
        "seq": ("model",),
        "act_seq": (),
    },
)

PRESETS: dict[str, ShardingRules] = {
    r.name: r
    for r in (TP_DP_RULES, FSDP_TP_RULES, FSDP_TP_SP_RULES, SP_SERVE_RULES)
}


def resolve_spec(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules,
) -> PartitionSpec:
    """Logical axes + shape -> PartitionSpec, dropping non-dividing axes."""
    used: set[str] = set()
    parts: list[Any] = []
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
    for dim, logical in zip(shape, axes, strict=True):
        cand = [
            a
            for a in rules.lookup(logical)
            if a in mesh_sizes and a not in used
        ]
        picked: list[str] = []
        rem = dim
        for a in cand:
            if rem % mesh_sizes[a] == 0 and rem >= mesh_sizes[a]:
                picked.append(a)
                used.add(a)
                rem //= mesh_sizes[a]
        if not picked:
            parts.append(None)
        elif len(picked) == 1:
            parts.append(picked[0])
        else:
            parts.append(tuple(picked))
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def tree_shardings(
    abstract: Any, axes_tree: Any, mesh: Mesh, rules: ShardingRules
) -> Any:
    """NamedSharding tree matching an abstract (ShapeDtypeStruct) tree."""

    def one(leaf, axes):
        spec = resolve_spec(tuple(axes), leaf.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    # axes_tree has `abstract` as a structural prefix: each abstract leaf
    # (ShapeDtypeStruct) pairs with its whole axes tuple.
    return jax.tree.map(one, abstract, axes_tree)


# ---------------------------------------------------------------------------
# Logical axes of model inputs / states
# ---------------------------------------------------------------------------


def batch_axes(name: str, ndim: int) -> tuple[str | None, ...]:
    """Logical axes for a batch input by name/rank."""
    if name == "tokens":
        return ("batch", "seq")[:ndim] if ndim == 2 else ("batch",)
    if name == "labels":
        return ("batch", "seq")
    if name in ("enc_embeds", "patch_embeds"):
        return ("batch", "seq", "embed")
    if name == "positions":
        return ("batch", "seq", None)
    if name == "idx":
        return ()
    raise KeyError(name)


def cache_axes(leaf_shape: tuple[int, ...]) -> tuple[str | None, ...]:
    """KV-cache/state leaves: (layers, batch, time, kv, hd)-style."""
    n = len(leaf_shape)
    if n == 5:
        return ("layers", "batch", "seq", "kv", None)
    if n == 4:  # ssm state (L, B, nh|ds, ...) or conv (L, B, k, C)
        return ("layers", "batch", None, "state")
    if n == 3:
        return ("layers", "batch", "state")
    return tuple([None] * n)


def state_axes_tree(state: Any) -> Any:
    """Logical axes for a decode-state tree (shape-driven heuristics)."""

    def one(leaf):
        return cache_axes(tuple(leaf.shape))

    return jax.tree.map(one, state)


def batch_axes_tree(batch: Any) -> Any:
    return {k: batch_axes(k, len(v.shape)) for k, v in batch.items()}
