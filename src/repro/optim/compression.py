"""Gradient compression for cross-pod data-parallel reduction.

int8 block quantisation with error feedback: gradients are quantised
per 256-value block before the (slow, cross-pod ICI) all-reduce and the
quantisation residual is added back into the next step's gradient.
Cuts cross-pod collective bytes 4x (recorded in §Perf for the
collective-bound cell).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ErrorFeedback"]

_BLOCK = 256


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantisation.  Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_int8(q: jax.Array, scale: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


class ErrorFeedback:
    """Stateful error-feedback wrapper (state lives in the train state)."""

    @staticmethod
    def init(params: Any) -> Any:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads: Any, residual: Any) -> tuple[Any, Any]:
        """Quantise (grad + residual); return (dequantised grads, new residual)."""

        def one(g, r):
            gf = g.astype(jnp.float32) + r
            q, s = compress_int8(gf)
            deq = decompress_int8(q, s, gf.shape, jnp.float32)
            return deq.astype(g.dtype), gf - deq

        pairs = jax.tree.map(one, grads, residual)
        newg = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        newr = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return newg, newr
