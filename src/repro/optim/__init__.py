"""Optimizers, LR schedules and gradient compression (no external deps)."""

from .optimizers import AdamW, Optimizer, OptState, SGD, Adafactor, clip_by_global_norm, global_norm
from .schedules import constant_lr, cosine_lr, linear_warmup_cosine
from .compression import compress_int8, decompress_int8, ErrorFeedback

__all__ = [
    "AdamW",
    "SGD",
    "Adafactor",
    "Optimizer",
    "OptState",
    "clip_by_global_norm",
    "global_norm",
    "constant_lr",
    "cosine_lr",
    "linear_warmup_cosine",
    "compress_int8",
    "decompress_int8",
    "ErrorFeedback",
]
