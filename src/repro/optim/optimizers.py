"""Optimizers as pure pytree transforms (optax-style, self-contained).

Optimizer state mirrors the parameter tree, so the sharding rules that
shard a parameter shard its moments identically — with the FSDP preset
this is ZeRO-style optimizer-state sharding for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "OptState",
    "AdamW",
    "SGD",
    "Adafactor",
    "global_norm",
    "clip_by_global_norm",
]

OptState = Any
Schedule = Callable[[jax.Array], jax.Array]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step) ->
    (new_params, new_state)."""

    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], tuple[Any, OptState]]


def AdamW(
    lr: float | Schedule = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    sched = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        if grad_clip > 0:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = sched(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            mhat = m_new / c1
            vhat = v_new / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr_t * (step_ + weight_decay * pf)
            return pf.astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


def SGD(lr: float | Schedule = 1e-2, *, momentum: float = 0.9, grad_clip: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        if grad_clip > 0:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = sched(step)

        def upd(g, mo, p):
            mo_new = momentum * mo + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * mo_new).astype(p.dtype), mo_new

        flat = jax.tree.map(upd, grads, state["mom"], params)
        new_p = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mom": new_m}

    return Optimizer(init=init, update=update)


def Adafactor(
    lr: float | Schedule = 1e-3,
    *,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Memory-frugal Adafactor-lite: factored second moment for matrices
    (row/col running averages), full for vectors.  Halves optimizer HBM
    vs AdamW on the big dense archs."""
    sched = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        lr_t = sched(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t**-0.8

        def upd(g, f, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p.shape):
                row = beta * f["row"] + (1 - beta) * g2.mean(axis=-1)
                col = beta * f["col"] + (1 - beta) * g2.mean(axis=-2)
                rms_approx = (
                    row[..., None]
                    * col[..., None, :]
                    / jnp.maximum(row.mean(axis=-1, keepdims=True)[..., None], eps)
                )
                upd_ = gf / jnp.sqrt(rms_approx + eps)
                new_f = {"row": row, "col": col}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                upd_ = gf / jnp.sqrt(v + eps)
                new_f = {"v": v}
            # update clipping (Adafactor's RMS clip)
            rms_u = jnp.sqrt(jnp.mean(upd_ * upd_))
            upd_ = upd_ / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            pf = pf - lr_t * (upd_ + weight_decay * pf)
            return pf.astype(p.dtype), new_f

        is_state = lambda x: isinstance(x, dict) and ("row" in x or "v" in x)
        flat = jax.tree.map(upd, grads, state["f"], params, is_leaf=None)
        # flat leaves are tuples (p, f)
        new_p = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_f = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"f": new_f}

    return Optimizer(init=init, update=update)
