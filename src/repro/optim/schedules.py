"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant_lr", "cosine_lr", "linear_warmup_cosine"]


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(peak: float, total_steps: int, final_frac: float = 0.1):
    def sched(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak * (final_frac + (1 - final_frac) * cos)

    return sched


def linear_warmup_cosine(
    peak: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = peak * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)

    return sched
