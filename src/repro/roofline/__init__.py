"""Roofline analysis from compiled dry-run artifacts."""

from .analysis import (
    CollectiveStats,
    RooflineResult,
    collective_bytes,
    analyze_compiled,
    roofline_terms,
)

__all__ = [
    "CollectiveStats",
    "RooflineResult",
    "collective_bytes",
    "analyze_compiled",
    "roofline_terms",
]
