"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — a 95-layer
``lax.scan`` model reports ~1/95th of its FLOPs.  This walker parses the
post-optimization HLO module, recovers the call graph (entry -> fusions
-> while bodies, nested), extracts each loop's trip count from its
condition computation, and accumulates

* ``dot_flops``   — exact matmul FLOPs (2 x result x contracted dims),
* ``ew_flops``    — 1 FLOP/element for arithmetic elementwise/reduce ops,
* ``bytes``       — HLO traffic: operand + result bytes of every
                    compute op (the same semantic XLA's cost model uses,
                    loop-scaled; an upper bound on HBM traffic since
                    VMEM-resident fusion internals on TPU don't hit HBM),
* ``collectives`` — operand bytes + counts per collective op,

all multiplied through nested loop trip counts.  Validated against
hand-computed costs and against ``cost_analysis()`` on loop-free
modules (tests/test_roofline.py).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

__all__ = ["HloCosts", "parse_hlo_costs"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0, "u1": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ops costing ~1 flop per output element
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "sqrt", "rsqrt", "power", "cosine", "sine", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "remainder", "atan2",
    "cbrt", "erf", "compare", "select", "clamp", "and", "or", "xor", "not",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "reduce", "reduce-window", "cumsum",
}

# ops whose operands/results do not represent real data movement
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "while", "conditional", "call",
    "fusion", "partition-id", "replica-id", "rng-get-and-update-state",
    "opt-barrier",
}

_TYPE_TOKEN = r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\(.*?\)|" + _TYPE_TOKEN + r")\s*"
    r"(?P<op>[\w\-]+)\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\((?P<params>.*)\)\s*->"
)
_ATTR_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str  # text after the opening paren of the op call


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: list[_Instr]
    defs: dict[str, str]  # instr/param name -> type string


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    ew_flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )
    coll_counts: dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )

    @property
    def flops(self) -> float:
        return self.dot_flops + self.ew_flops

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            dot_flops=self.dot_flops * k,
            ew_flops=self.ew_flops * k,
            bytes=self.bytes * k,
            coll_bytes={o: b * k for o, b in self.coll_bytes.items()},
            coll_counts={o: c * k for o, c in self.coll_counts.items()},
        )

    def add(self, other: "HloCosts") -> None:
        self.dot_flops += other.dot_flops
        self.ew_flops += other.ew_flops
        self.bytes += other.bytes
        for o in _COLLECTIVES:
            self.coll_bytes[o] += other.coll_bytes[o]
            self.coll_counts[o] += other.coll_counts[o]


def _split_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Computation(m.group("name"), [], {})
                # parameter types from the header
                for pname, ptype in re.findall(
                    r"([\w.\-]+):\s*(\(.*?\)|" + _TYPE_TOKEN + r")", m.group("params")
                ):
                    cur.defs[pname] = ptype
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if m:
            name, type_str, op = m.group("name"), m.group("type"), m.group("op")
            rest = s[m.end() :]
            cur.defs[name] = type_str
            cur.instrs.append(_Instr(name, type_str, op, rest))
    return comps


def _operands_text(rest: str) -> str:
    """Text inside the op's parens (bracket-matched)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


def _trip_count(cond: _Computation) -> int:
    """Max integer constant in the loop condition (jax scan: iter < N)."""
    best = 1
    joined = "\n".join(
        f"{i.name} {i.type_str} {i.op}({i.rest}" for i in cond.instrs
    )
    for m in _CONST_INT_RE.finditer(joined):
        best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, comp: _Computation) -> float:
    out_elems = _type_elems(ins.type_str)
    operands = _operands_text(ins.rest)
    names = _OPERAND_NAME_RE.findall(operands)
    m = _CONTRACT_RE.search(ins.rest)
    contracted = 1
    if m and names:
        lhs_type = comp.defs.get(names[0], "")
        dims = _first_shape_dims(lhs_type)
        idxs = [int(x) for x in m.group(1).split(",")] if m.group(1) else []
        for i in idxs:
            if i < len(dims):
                contracted *= dims[i]
    return 2.0 * out_elems * contracted


def parse_hlo_costs(text: str, entry: str | None = None) -> HloCosts:
    comps = _split_computations(text)
    if not comps:
        return HloCosts()
    if entry is None:
        # entry computation: the one marked ENTRY, else heuristic 'main'
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        entry = m.group(1) if m else next(reversed(comps))

    memo: dict[str, HloCosts] = {}

    def cost_of(name: str, stack: tuple[str, ...] = ()) -> HloCosts:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = HloCosts()
        if comp is None or name in stack:
            return out
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                out.dot_flops += _dot_flops(ins, comp)
                out.bytes += _type_bytes(ins.type_str)
                for on in _OPERAND_NAME_RE.findall(_operands_text(ins.rest)):
                    out.bytes += _type_bytes(comp.defs.get(on, ""))
            elif op in _COLLECTIVES:
                b = 0
                for on in _OPERAND_NAME_RE.findall(_operands_text(ins.rest)):
                    b += _type_bytes(comp.defs.get(on, ""))
                out.coll_bytes[op] += b
                out.coll_counts[op] += 1
                out.bytes += b + _type_bytes(ins.type_str)
            elif op == "fusion" or op == "call":
                m = _ATTR_CALLS_RE.search(ins.rest) if op == "fusion" else None
                callee = m.group(1) if m else None
                if op == "call":
                    mc = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                    callee = mc.group(1) if mc else None
                if callee:
                    sub = cost_of(callee, stack + (name,))
                    if op == "fusion":
                        # fusion internals execute in registers/VMEM: keep
                        # their FLOPs and collectives, drop internal bytes —
                        # the fusion's traffic is its boundary (below).
                        sub = dataclasses.replace(
                            sub,
                            bytes=0.0,
                            coll_bytes=dict(sub.coll_bytes),
                            coll_counts=dict(sub.coll_counts),
                        )
                    out.add(sub)
                # boundary traffic: operands + result
                out.bytes += _type_bytes(ins.type_str)
                for on in _OPERAND_NAME_RE.findall(_operands_text(ins.rest)):
                    out.bytes += _type_bytes(comp.defs.get(on, ""))
            elif op == "while":
                mb = _ATTR_BODY_RE.search(ins.rest)
                mc = _ATTR_COND_RE.search(ins.rest)
                trips = 1
                if mc and mc.group(1) in comps:
                    trips = _trip_count(comps[mc.group(1)])
                if mb and mb.group(1) in comps:
                    body_cost = cost_of(mb.group(1), stack + (name,))
                    out.add(body_cost.scaled(trips))
                if mc and mc.group(1) in comps:
                    out.add(cost_of(mc.group(1), stack + (name,)).scaled(trips))
            elif op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.rest)
                names = []
                if branches:
                    names = _OPERAND_NAME_RE.findall(branches[0])
                else:
                    names = [
                        m.group(1)
                        for m in re.finditer(r"(?:true|false)_computation=%?([\w.\-]+)", ins.rest)
                    ]
                sub = [cost_of(n, stack + (name,)) for n in names if n in comps]
                if sub:
                    # worst-case branch
                    worst = max(sub, key=lambda c: c.flops + c.bytes)
                    out.add(worst)
            elif op in _FREE_OPS:
                continue
            elif op == "dynamic-slice" or op == "gather":
                # reads only the slice, not the (potentially stacked-layer)
                # full operand: traffic = 2 x result
                out.bytes += 2 * _type_bytes(ins.type_str)
            elif op == "dynamic-update-slice" or op == "scatter":
                # writes only the update (result aliases the buffer):
                # traffic = 2 x update operand (operand index 1)
                names = _OPERAND_NAME_RE.findall(_operands_text(ins.rest))
                upd = _type_bytes(comp.defs.get(names[1], "")) if len(names) > 1 else 0
                out.bytes += 2 * upd
            else:
                elems = _type_elems(ins.type_str)
                if op in _EW_OPS:
                    out.ew_flops += elems
                out.bytes += _type_bytes(ins.type_str)
                for on in _OPERAND_NAME_RE.findall(_operands_text(ins.rest)):
                    out.bytes += _type_bytes(comp.defs.get(on, ""))
        memo[name] = out
        return out

    # fusions called inside whiles are reached via the call graph; entry-only
    # traversal avoids double-counting shared computations.
    return cost_of(entry)
