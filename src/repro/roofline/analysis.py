"""Three-term roofline from the compiled dry-run.

    compute    = HLO_FLOPs        / (chips x peak_FLOP/s)
    memory     = HLO_bytes        / (chips x HBM_bw)
    collective = collective_bytes / (chips x links x link_bw)

``cost_analysis()`` provides FLOPs/bytes of the *partitioned per-device
module*; collective bytes are parsed from the HLO text (operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), also per device.  Terms are therefore computed with
chips = 1 against per-chip peak numbers — equivalent to the global
formula and robust to mesh size.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.core.power import TPUSpec, V5E

__all__ = [
    "CollectiveStats",
    "RooflineResult",
    "collective_bytes",
    "analyze_compiled",
    "roofline_terms",
]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# a collective instruction line:  %name = <shape> <op>(<operands>), ...
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\s*\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    per_op: dict[str, int]
    per_op_count: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.per_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.per_op_count.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Operand bytes + counts of every collective in an HLO module dump.

    Delegates to the loop-aware walker (``hlo_costs``): operand shapes
    are resolved through per-computation definition maps (HLO dumps
    reference operands by name), and collectives inside ``while`` bodies
    are multiplied by the loop trip count.
    """
    from .hlo_costs import parse_hlo_costs

    walk = parse_hlo_costs(hlo_text)
    return CollectiveStats(
        per_op={k: int(v) for k, v in walk.coll_bytes.items()},
        per_op_count={k: int(v) for k, v in walk.coll_counts.items()},
    )


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    bytes_per_device_peak: float  # memory_analysis: args+temp+output
    model_flops: float  # 6*N*D (train) / 2*N*D (serve), global
    coll: CollectiveStats | None = None

    # --- the three terms (seconds) ---
    def terms(self, spec: TPUSpec = V5E, links: int = 4) -> dict[str, float]:
        return {
            "compute": self.flops_per_device / spec.peak_flops,
            "memory": self.hbm_bytes_per_device / spec.hbm_bw,
            "collective": self.coll_bytes_per_device / (links * spec.ici_bw),
        }

    def bottleneck(self, spec: TPUSpec = V5E) -> str:
        t = self.terms(spec)
        return max(t, key=t.get)

    def step_time(self, spec: TPUSpec = V5E) -> float:
        return max(self.terms(spec).values())

    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — remat/redundancy waste."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    def mfu(self, spec: TPUSpec = V5E) -> float:
        """Model FLOPs utilisation at the roofline step time."""
        t = self.step_time(spec)
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.n_chips * spec.peak_flops)

    def to_row(self) -> dict[str, Any]:
        t = self.terms()
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.n_chips,
            "compute_s": t["compute"],
            "memory_s": t["memory"],
            "collective_s": t["collective"],
            "bottleneck": self.bottleneck(),
            "step_time_s": self.step_time(),
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac(),
            "mfu": self.mfu(),
            "hbm_peak_bytes": self.bytes_per_device_peak,
        }


def _cost_get(cost: dict, key: str) -> float:
    v = cost.get(key, 0.0)
    try:
        return float(v)
    except (TypeError, ValueError):
        return 0.0


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_chips: int,
    model_flops: float,
    hlo_text: str | None = None,
) -> RooflineResult:
    """Build a RooflineResult from a jax compiled executable.

    Costs come from the loop-aware HLO walker (``hlo_costs``) — XLA's
    ``cost_analysis()`` counts while-loop (lax.scan) bodies once and
    would under-report a scanned 95-layer model ~95x.
    """
    from .hlo_costs import parse_hlo_costs

    text = hlo_text if hlo_text is not None else compiled.as_text()
    walk = parse_hlo_costs(text)
    flops = walk.flops
    hbm = walk.bytes
    coll = CollectiveStats(
        per_op={k: int(v) for k, v in walk.coll_bytes.items()},
        per_op_count={k: int(v) for k, v in walk.coll_counts.items()},
    )

    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:
        pass
    peak = 0.0
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes"):
            peak += float(getattr(mem, attr, 0) or 0)

    return RooflineResult(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_chips=n_chips,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        coll_bytes_per_device=float(coll.total_bytes),
        bytes_per_device_peak=peak,
        model_flops=model_flops,
        coll=coll,
    )


def roofline_terms(
    flops_per_device: float,
    hbm_per_device: float,
    coll_per_device: float,
    spec: TPUSpec = V5E,
    links: int = 4,
) -> dict[str, float]:
    return {
        "compute": flops_per_device / spec.peak_flops,
        "memory": hbm_per_device / spec.hbm_bw,
        "collective": coll_per_device / (links * spec.ici_bw),
    }
