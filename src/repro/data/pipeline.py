"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step) — restart-safe (a resumed
run regenerates the identical stream, so checkpoint/restart is exactly
reproducible) and host-shardable (each host materialises only its slice
of the global batch, keyed by the same counters).

The token stream is a learnable-structure Markov-ish sequence (token
t+1 = hash(t) with noise) rather than i.i.d. noise, so small-model
training loss demonstrably falls in the end-to-end example.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape

__all__ = ["SyntheticLM", "make_batch_fn"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Synthetic autoregressive stream over a vocab."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.9  # prob. that t+1 follows the hash rule

    def _rows(self, step: int, row0: int, rows: int) -> np.ndarray:
        """Deterministic (rows, seq_len) int32 block."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row0, rows])
        )
        first = rng.integers(0, self.vocab, size=(rows, 1))
        out = np.empty((rows, self.seq_len), dtype=np.int64)
        out[:, :1] = first
        # hash rule: next = (a * tok + b) % vocab, with structure noise
        a, b = 6364136223846793005 % self.vocab or 1, 1442695040888963407 % self.vocab
        noise = rng.random((rows, self.seq_len))
        rand_toks = rng.integers(0, self.vocab, size=(rows, self.seq_len))
        for t in range(1, self.seq_len):
            nxt = (out[:, t - 1] * a + b) % self.vocab
            out[:, t] = np.where(noise[:, t] < self.structure, nxt, rand_toks[:, t])
        return out.astype(np.int32)

    def batch(self, step: int, *, host_id: int = 0, host_count: int = 1) -> dict:
        """Host-sharded batch: host i materialises rows [i*per, (i+1)*per)."""
        per = self.global_batch // host_count
        rows = self._rows(step, host_id * per, per)
        return {"tokens": rows, "labels": rows}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_fn(cfg: ModelConfig, shape: InputShape, seed: int = 0):
    """Batch generator including modality-stub inputs (audio/vision)."""
    stream = SyntheticLM(cfg.vocab, shape.seq_len, shape.global_batch, seed)

    def fn(step: int) -> dict:
        batch = stream.batch(step)
        B, S = shape.global_batch, shape.seq_len
        rng = np.random.default_rng(np.random.SeedSequence([seed + 7, step]))
        if cfg.family == "encdec":
            batch["enc_embeds"] = rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32
            ).astype(np.dtype(cfg.dtype) if cfg.dtype != "bfloat16" else np.float32)
        if cfg.family == "vlm":
            from repro.models.model import VLM_PATCHES

            P = min(VLM_PATCHES, S // 2)
            batch["tokens"] = batch["tokens"][:, : S - P]
            batch["patch_embeds"] = rng.standard_normal(
                (B, P, cfg.d_model), dtype=np.float32
            )
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 1))
            batch["positions"] = np.broadcast_to(pos, (B, S, 3)).astype(np.int32)
        return batch

    return fn
