"""Deterministic synthetic data pipeline."""

from .pipeline import SyntheticLM, make_batch_fn

__all__ = ["SyntheticLM", "make_batch_fn"]
