"""Performance metrics of the scheduler (paper §IV-B, eqs. 8-10).

* Task Rejection Ratio (eq. 8):    TRR = rejected / |TSS| * 100
* System Workload (eq. 9):         sum_shr / (t_slr * n_f) * 100
* Average Task Weight (eq. 10):    mean_i(e_i / p_i)

``sweep_*`` helpers regenerate the data behind Figs 5-7: for each
(n_f, t_cfg) the TRR over the full TSS, and the *thresholds* — the maximum
system workload / average task weight among accepted combinations (a combo
whose workload/weight exceeds the threshold is rejected, §IV-B).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .feasibility import outer_sum, search_feasible
from .placement_batched import place_batch
from .task import FleetSpec, Task, combo_count

__all__ = [
    "trr",
    "system_workload",
    "avg_task_weight",
    "SweepPoint",
    "sweep_fleet",
]


def trr(n_rejected: int, n_total: int) -> float:
    """Eq. 8, in percent."""
    if n_total == 0:
        return 0.0
    return 100.0 * n_rejected / n_total


def system_workload(sum_shr: float, fleet: FleetSpec) -> float:
    """Eq. 9, in percent (heterogeneous: against sum_j t_slr_j)."""
    return 100.0 * sum_shr / fleet.capacity


def avg_task_weight(exec_times: Sequence[float], periods: Sequence[float]) -> float:
    """Eq. 10."""
    w = [e / p for e, p in zip(exec_times, periods, strict=True)]
    return float(np.mean(w))


@dataclasses.dataclass
class SweepPoint:
    """One (n_f, t_cfg) point of the Fig 5-7 sweeps."""

    n_f: int
    t_cfg: float
    n_tss: int
    n_accepted_eq7: int  # pass workability (Alg 1)
    n_accepted_placed: int  # additionally pass placement (Alg 2)
    trr_eq7: float  # Fig 5 (rejection by eq. 7)
    trr_placed: float  # rejection including placement simulation
    workload_threshold: float  # Fig 6: max eq.-9 workload among accepted
    avg_weight_threshold: float  # Fig 7: max eq.-10 weight among accepted


def _combo_avg_weights(tasks: Sequence[Task], t_slr: float) -> np.ndarray:
    """Average task weight for every TSS row (flat, C order).

    weight_ij = e_ij / p_i = shr_ij / t_slr, so the combo average is
    sum_shr / (n_t * t_slr).
    """
    share_vecs = [t.shares(t_slr) for t in tasks]
    return outer_sum(share_vecs) / (len(tasks) * t_slr)


def sweep_fleet(
    tasks: Sequence[Task],
    base: FleetSpec,
    n_f_values: Sequence[int],
    t_cfg_values: Sequence[float],
    *,
    with_placement: bool = True,
    placement_limit: int = 5_000_000,
) -> list[SweepPoint]:
    """Regenerate Figs 5-7: sweep n_f x t_cfg over the full TSS.

    Heterogeneous base fleets keep their device-class mix across the
    sweep: ``n_f`` repeats the profile pattern round-robin and ``t_cfg``
    rescales every device's cost proportionally (GPU/CPU ~0 stays ~0).
    Placement counting runs the whole TFS through the batched engine, so
    the former 200k-row practicality limit is now 5M.
    """
    tasks = tuple(tasks)
    n = combo_count(tasks)
    iis = [t.init_interval for t in tasks]
    points: list[SweepPoint] = []
    for t_cfg in t_cfg_values:
        for n_f in n_f_values:
            fleet = base.with_devices(n_f).with_t_cfg(t_cfg)
            feas = search_feasible(tasks, fleet)
            acc7 = feas.fit_mask
            n_acc7 = int(acc7.sum())
            n_placed = n_acc7
            if with_placement and n <= placement_limit and n_acc7:
                bp = place_batch(
                    feas.shares_matrix(np.flatnonzero(acc7)), iis, fleet
                )
                n_placed = bp.n_feasible
            workloads = 100.0 * feas.sum_shr / fleet.capacity
            weights = _combo_avg_weights(tasks, fleet.t_slr)
            wl_thr = float(workloads[acc7].max()) if n_acc7 else 0.0
            wt_thr = float(weights[acc7].max()) if n_acc7 else 0.0
            points.append(
                SweepPoint(
                    n_f=n_f,
                    t_cfg=t_cfg,
                    n_tss=n,
                    n_accepted_eq7=n_acc7,
                    n_accepted_placed=n_placed,
                    trr_eq7=trr(n - n_acc7, n),
                    trr_placed=trr(n - n_placed, n),
                    workload_threshold=wl_thr,
                    avg_weight_threshold=wt_thr,
                )
            )
    return points
