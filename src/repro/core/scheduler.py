"""Algorithm 2 top level + the PADPS-FR scheduler facade.

``select_lowest_power`` walks the power-sorted TFS and returns the first
combination whose placement simulation succeeds — by construction the
minimum-power feasible configuration (paper §III-A2).  The facade walks
the TFS in vectorized blocks through a pluggable placement backend
(:mod:`repro.core.placement_backends`): ``engine="numpy"`` (default; alias
``"batched"``) is the zero-dependency block engine, ``"jax"`` a jit'd
``lax.while_loop`` sweep, ``"pallas"`` the fused single-kernel sweep,
``"scalar"`` the exact one-row-at-a-time oracle, and ``"auto"`` the best
available.  Block handoff is array-native end to end:
``feasibility.shares_matrix`` gathers each block and the backend consumes
it whole — no per-row host round-trips.  The facade bundles
Alg 1 + Alg 2 + Alg 3 and reports the statistics the paper quotes
(|TSS|, |TFS|, |TNFS|, placement rejects, chosen index).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Iterator, Sequence

from .feasibility import FeasibilityResult, iter_feasible_pruned, search_feasible
from .placement import PlacementPlan, place_combo
from .placement_backends import (
    PlacementBackend,
    PlacementOptions,
    get_backend,
    resolve_engine,
)
from .task import FleetSpec, Task, TaskSetCombo, combo_count

__all__ = [
    "ScheduleResult",
    "select_lowest_power",
    "select_lowest_power_batched",
    "PADPSFRScheduler",
]

DEFAULT_BLOCK_SIZE = 4096


@dataclasses.dataclass
class ScheduleResult:
    feasible: bool
    combo: TaskSetCombo | None
    plan: PlacementPlan | None
    chosen_rank: int  # 0-based rank in power-sorted TFS (-1 if none)
    n_tss: int
    n_tfs: int
    n_tnfs: int
    n_placement_rejects: int  # TFS rows Alg 2 rejected before success
    total_power: float

    def summary(self, tasks: Sequence[Task] | None = None) -> str:
        if not self.feasible:
            return (
                f"INFEASIBLE: |TSS|={self.n_tss} |TFS|={self.n_tfs} "
                f"|TNFS|={self.n_tnfs}; all TFS rows failed placement"
            )
        assert self.combo is not None
        desc = self.combo.describe(tasks) if tasks else str(self.combo.variant_idx)
        return (
            f"|TSS|={self.n_tss} |TFS|={self.n_tfs} |TNFS|={self.n_tnfs} "
            f"placement-rejects={self.n_placement_rejects} "
            f"chosen-rank={self.chosen_rank} power={self.total_power:g} "
            f"shares={[round(s, 4) for s in self.combo.shares]} [{desc}]"
        )


def select_lowest_power(
    combos_by_power: Iterable[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Alg 2 lines 2-10: first placeable combo in ascending-power order.

    The paper's walk as written — one full scalar placement simulation per
    row, no blocking, no backend indirection; kept as the independent
    reference for the block walk.  Returns (combo, plan, rank,
    rejects_before_success).  With ``count_all_rejects`` the walk continues
    past the winner to count every placement-rejected TFS row (the paper's
    "156 rejected" statistic).
    """
    rejects = 0
    winner: tuple[TaskSetCombo, PlacementPlan, int] | None = None
    for rank, combo in enumerate(combos_by_power):
        plan = place_combo(combo, tasks, fleet, **placement_kw)
        if plan.feasible:
            if winner is None:
                winner = (combo, plan, rank)
            if not count_all_rejects:
                break
        else:
            rejects += 1
    if winner is None:
        return None, None, -1, rejects
    return winner[0], winner[1], winner[2], rejects


def select_lowest_power_batched(
    combos_by_power: Iterable[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str | PlacementBackend = "numpy",
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Alg 2 over vectorized TFS blocks — same contract as
    :func:`select_lowest_power`.

    Blocks of ``block_size`` power-sorted rows go through the placement
    backend at once; the first feasible row wins and its full per-device
    plan comes from the scalar oracle (bit-identical by construction,
    asserted in tests).
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")

    def blocks():
        stream = iter(combos_by_power)
        while True:
            block = list(itertools.islice(stream, block_size))
            if not block:
                return
            yield [c.shares for c in block], block

    return _walk_tfs_blocks(
        blocks(),
        lambda block, r: block[r],
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        **placement_kw,
    )


def _walk_tfs_blocks(
    block_iter,
    materialize,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    backend: str | PlacementBackend,
    count_all_rejects: bool,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Shared Alg-2 walk over batched TFS blocks.

    ``block_iter`` yields ``(shares_rows, ref)`` pairs (a (B, n_t)
    array-like plus an opaque block reference); ``materialize(ref, row)``
    produces the winning row's :class:`TaskSetCombo`.  Winner/rank/reject
    bookkeeping lives only here — backend-agnostic by construction — so
    no two engines can drift apart.  ``backend`` is an engine name (or a
    ready :class:`PlacementBackend`); each block goes to
    ``backend.place_block`` as one shares matrix, no per-row host work.
    """
    if isinstance(backend, str):
        backend = get_backend(backend)
    iis = [t.init_interval for t in tasks]
    t_slr_arr = fleet.t_slr_arr
    t_cfg_arr = fleet.t_cfg_arr
    opts = PlacementOptions(**placement_kw)
    rejects = 0
    winner: tuple[TaskSetCombo, PlacementPlan, int] | None = None
    rank_base = 0
    for shares, ref in block_iter:
        bp = backend.place_block(shares, iis, t_slr_arr, t_cfg_arr, opts)
        n_rows = bp.feasible.shape[0]
        if winner is None:
            r = bp.first_feasible()
            if r >= 0:
                combo = materialize(ref, r)
                plan = place_combo(combo, tasks, fleet, **placement_kw)
                winner = (combo, plan, rank_base + r)
                rejects += r  # rows before the first feasible are all rejects
                if not count_all_rejects:
                    break
                rejects += int((~bp.feasible[r:]).sum())
            else:
                rejects += n_rows
        else:
            rejects += int((~bp.feasible).sum())
        rank_base += n_rows
    if winner is None:
        return None, None, -1, rejects
    return winner[0], winner[1], winner[2], rejects


def _select_from_feasibility(
    feas: FeasibilityResult,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str | PlacementBackend = "numpy",
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Fast exhaustive path: batched sweeps over flat TFS indices.

    Avoids materialising per-row :class:`TaskSetCombo` objects entirely —
    each block is one fancy-indexed shares-matrix gather
    (:meth:`FeasibilityResult.shares_matrix`) handed whole to the backend.
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    order = feas.tfs_indices_by_power()

    def blocks():
        for lo in range(0, order.size, block_size):
            idx = order[lo : lo + block_size]
            yield feas.shares_matrix(idx), idx

    return _walk_tfs_blocks(
        blocks(),
        lambda idx, r: feas.combo_at(int(idx[r])),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        **placement_kw,
    )


class PADPSFRScheduler:
    """Power-Aware DP-fair Scheduling with Full Reconfiguration.

    The paper's contribution as a reusable component: construct with a
    :class:`FleetSpec`, call :meth:`schedule` with the periodic task set.
    ``exhaustive=None`` auto-selects the vectorised exhaustive engine for
    small variant products and the branch-and-bound streaming engine for
    large ones.  ``engine`` selects the placement backend through the
    registry (:mod:`repro.core.placement_backends`): ``"scalar"``,
    ``"numpy"`` (default; alias ``"batched"``), ``"jax"``, ``"pallas"``,
    or ``"auto"`` for the best available.  ``"scalar"`` runs the paper's
    row-at-a-time walk (:func:`select_lowest_power`) directly — early
    exit at the winner, bookkeeping independent of the block walk — so
    scalar-vs-block parity tests cross-check two separate Alg-2
    implementations.
    """

    def __init__(
        self,
        fleet: FleetSpec,
        *,
        exhaustive: bool | None = None,
        exhaustive_limit: int = 2_000_000,
        engine: str = "numpy",
        block_size: int = DEFAULT_BLOCK_SIZE,
    ) -> None:
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.fleet = fleet
        self.exhaustive = exhaustive
        self.exhaustive_limit = exhaustive_limit
        self.engine = resolve_engine(engine)  # raises on unknown names
        self.block_size = block_size
        self._backend = get_backend(self.engine)

    def feasibility(self, tasks: Sequence[Task]) -> FeasibilityResult:
        return search_feasible(tasks, self.fleet)

    def _combo_stream(
        self, tasks: Sequence[Task]
    ) -> tuple[Iterator[TaskSetCombo], FeasibilityResult | None]:
        n = combo_count(tasks)
        use_exhaustive = (
            self.exhaustive
            if self.exhaustive is not None
            else n <= self.exhaustive_limit
        )
        if use_exhaustive:
            feas = search_feasible(tasks, self.fleet)
            return feas.iter_tfs_by_power(), feas
        return iter_feasible_pruned(tasks, self.fleet), None

    def schedule(
        self,
        tasks: Sequence[Task],
        *,
        count_all_rejects: bool = False,
        **placement_kw,
    ) -> ScheduleResult:
        tasks = tuple(tasks)
        stream, feas = self._combo_stream(tasks)
        if self.engine == "scalar":
            # The paper's walk as written: one scalar simulation per row
            # with early exit at the winner, and winner/rank/reject
            # bookkeeping entirely independent of _walk_tfs_blocks — this
            # is what the cross-engine parity tests pin the block walk to.
            combo, plan, rank, rejects = select_lowest_power(
                stream,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                **placement_kw,
            )
        elif feas is not None:
            combo, plan, rank, rejects = _select_from_feasibility(
                feas,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                block_size=self.block_size,
                backend=self._backend,
                **placement_kw,
            )
        else:
            combo, plan, rank, rejects = select_lowest_power_batched(
                stream,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                block_size=self.block_size,
                backend=self._backend,
                **placement_kw,
            )
        n_tss = combo_count(tasks)
        n_tfs = feas.n_tfs if feas is not None else -1
        n_tnfs = feas.n_tnfs if feas is not None else -1
        return ScheduleResult(
            feasible=combo is not None,
            combo=combo,
            plan=plan,
            chosen_rank=rank,
            n_tss=n_tss,
            n_tfs=n_tfs,
            n_tnfs=n_tnfs,
            n_placement_rejects=rejects,
            total_power=combo.total_power if combo else float("inf"),
        )
