"""Algorithm 2 top level + the PADPS-FR scheduler facade.

``select_lowest_power`` walks the power-sorted TFS and returns the first
combination whose placement simulation succeeds — by construction the
minimum-power feasible configuration (paper §III-A2).  The facade walks
the TFS in vectorized blocks through a pluggable placement backend
(:mod:`repro.core.placement_backends`): ``engine="numpy"`` (default; alias
``"batched"``) is the zero-dependency block engine, ``"jax"`` a jit'd
``lax.while_loop`` sweep, ``"pallas"`` the fused single-kernel sweep,
``"scalar"`` the exact one-row-at-a-time oracle, and ``"auto"`` the best
available.

Block handoff is array-native end to end: the exhaustive path gathers
blocks with :meth:`FeasibilityResult.shares_matrix`, the streaming path
pulls whole :class:`repro.core.feasibility.ComboBlock` batches from the
vectorized branch-and-bound enumerator
(:func:`repro.core.feasibility.iter_feasible_pruned_blocks`) — no per-row
heap pushes or ``TaskSetCombo`` objects until the single winning row.
Blocks follow a geometric size ramp (:func:`block_ramp`) so early-winner
instances stop after a few cheap small blocks, and backends exposing
``dispatch_block`` (jax/pallas) are double-buffered: block k+1 is
enqueued while block k's verdict syncs back.  The facade bundles
Alg 1 + Alg 2 + Alg 3 and reports the statistics the paper quotes
(|TSS|, |TFS|, |TNFS|, placement rejects, chosen index).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Iterable, Iterator, Sequence

from .feasibility import (
    FeasibilityResult,
    iter_feasible_pruned,
    iter_feasible_pruned_blocks,
    search_feasible,
)
from .placement import PlacementPlan, place_combo
from .placement_backends import (
    PlacementBackend,
    PlacementOptions,
    get_backend,
    resolve_engine,
)
from .task import FleetSpec, Task, TaskSetCombo, combo_count

__all__ = [
    "ScheduleResult",
    "WalkStats",
    "block_ramp",
    "select_lowest_power",
    "select_lowest_power_batched",
    "PADPSFRScheduler",
]

DEFAULT_BLOCK_SIZE = 4096

# Adaptive walk defaults: early blocks small so a shallow winner exits
# after a few cheap dispatches, late blocks large so deep walks amortise
# per-block overhead (enumeration, padding, device round-trips).
RAMP_START = 64
RAMP_CAP = 65536
RAMP_FACTOR = 8

# How many blocks may be in flight at once when the backend supports
# asynchronous dispatch: one syncing + one enqueued (double buffering).
PIPELINE_DEPTH = 2


def block_ramp(
    start: int = RAMP_START, cap: int = RAMP_CAP, factor: int = RAMP_FACTOR
) -> Iterator[int]:
    """Geometric block-size schedule: ``start``, growing ×``factor`` to
    ``cap``, then ``cap`` forever."""
    size = start
    while True:
        yield size
        size = min(size * factor, cap)


@dataclasses.dataclass
class WalkStats:
    """Per-phase wall-clock breakdown of one Alg-2 block walk.

    ``enumerate_us`` is time producing blocks (Alg-1 streaming or TFS
    gathers), ``place_us`` time enqueueing backend sweeps,
    ``sync_us`` time waiting for verdicts to come back, and
    ``materialize_us`` the winning row's scalar plan.  ``block_sizes``
    records the adaptive ramp actually dispatched.
    """

    enumerate_us: float = 0.0
    place_us: float = 0.0
    sync_us: float = 0.0
    materialize_us: float = 0.0
    rows: int = 0
    block_sizes: list[int] = dataclasses.field(default_factory=list)

    @property
    def total_us(self) -> float:
        return (
            self.enumerate_us + self.place_us + self.sync_us + self.materialize_us
        )

    def as_dict(self) -> dict:
        return {
            "enumerate_us": self.enumerate_us,
            "place_us": self.place_us,
            "sync_us": self.sync_us,
            "materialize_us": self.materialize_us,
            "rows": self.rows,
            "n_blocks": len(self.block_sizes),
            "block_sizes": list(self.block_sizes),
        }


@dataclasses.dataclass
class ScheduleResult:
    feasible: bool
    combo: TaskSetCombo | None
    plan: PlacementPlan | None
    chosen_rank: int  # 0-based rank in power-sorted TFS (-1 if none)
    n_tss: int
    n_tfs: int
    n_tnfs: int
    n_placement_rejects: int  # TFS rows Alg 2 rejected before success
    total_power: float
    # Warm-start snapshot (``schedule(record_state=True)`` / ``replan``):
    # recorded TFS rows + the resumable enumerator, for delta replanning.
    plan_state: "object | None" = dataclasses.field(default=None, repr=False)

    def summary(self, tasks: Sequence[Task] | None = None) -> str:
        if not self.feasible:
            return (
                f"INFEASIBLE: |TSS|={self.n_tss} |TFS|={self.n_tfs} "
                f"|TNFS|={self.n_tnfs}; all TFS rows failed placement"
            )
        assert self.combo is not None
        desc = self.combo.describe(tasks) if tasks else str(self.combo.variant_idx)
        return (
            f"|TSS|={self.n_tss} |TFS|={self.n_tfs} |TNFS|={self.n_tnfs} "
            f"placement-rejects={self.n_placement_rejects} "
            f"chosen-rank={self.chosen_rank} power={self.total_power:g} "
            f"shares={[round(s, 4) for s in self.combo.shares]} [{desc}]"
        )


def select_lowest_power(
    combos_by_power: Iterable[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Alg 2 lines 2-10: first placeable combo in ascending-power order.

    The paper's walk as written — one full scalar placement simulation per
    row, no blocking, no backend indirection; kept as the independent
    reference for the block walk.  Returns (combo, plan, rank,
    rejects_before_success).  With ``count_all_rejects`` the walk continues
    past the winner to count every placement-rejected TFS row (the paper's
    "156 rejected" statistic).
    """
    rejects = 0
    winner: tuple[TaskSetCombo, PlacementPlan, int] | None = None
    for rank, combo in enumerate(combos_by_power):
        plan = place_combo(combo, tasks, fleet, **placement_kw)
        if plan.feasible:
            if winner is None:
                winner = (combo, plan, rank)
            if not count_all_rejects:
                break
        else:
            rejects += 1
    if winner is None:
        return None, None, -1, rejects
    return winner[0], winner[1], winner[2], rejects


def select_lowest_power_batched(
    combos_by_power: Iterable[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str | PlacementBackend = "numpy",
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Alg 2 over vectorized TFS blocks — same contract as
    :func:`select_lowest_power`.

    Chops a per-row :class:`TaskSetCombo` stream into fixed blocks for the
    placement backend.  This is the pre-block-native streaming path (one
    Python object per TFS row); the scheduler facade now feeds the walk
    from :func:`repro.core.feasibility.iter_feasible_pruned_blocks`
    instead, which skips the per-row objects entirely — this entry point
    remains for external combo streams and as the benchmark baseline.
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")

    def blocks():
        stream = iter(combos_by_power)
        while True:
            block = list(itertools.islice(stream, block_size))
            if not block:
                return
            yield [c.shares for c in block], block

    return _walk_tfs_blocks(
        blocks(),
        lambda block, r: block[r],
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        walk_stats=walk_stats,
        **placement_kw,
    )


def _walk_tfs_blocks(
    block_iter,
    materialize,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    backend: str | PlacementBackend,
    count_all_rejects: bool,
    walk_stats: WalkStats | None = None,
    on_verdict=None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Shared Alg-2 walk over batched TFS blocks, pipelined.

    ``block_iter`` yields ``(shares_rows, ref)`` pairs (a (B, n_t)
    array-like plus an opaque block reference); ``materialize(ref, row)``
    produces the winning row's :class:`TaskSetCombo`.  Winner/rank/reject
    bookkeeping lives only here — backend-agnostic by construction — so
    no two engines can drift apart.

    Dispatch is double-buffered: each block is enqueued via the backend's
    ``dispatch_block`` (see :mod:`repro.core.placement_backends.base`;
    asynchronous on jax/pallas, eager elsewhere) and its verdict resolved
    only once the next block is in flight, so enumeration and device
    sweeps overlap.  Blocks resolve strictly in rank order, so the
    bookkeeping is identical to the synchronous walk.

    ``on_verdict(rank_base, feasible)`` — when given — is called with
    every resolved block's boolean verdict vector (including the winning
    block's, before the walk stops).  Blocks enqueued but abandoned once
    the winner is known never reach it: the delta replanner
    (:mod:`repro.core.replan`) records those rows as *unknown* rather
    than inventing verdicts.
    """
    if isinstance(backend, str):
        backend = get_backend(backend)
    iis = [t.init_interval for t in tasks]
    t_slr_arr = fleet.t_slr_arr
    t_cfg_arr = fleet.t_cfg_arr
    opts = PlacementOptions(**placement_kw)
    stats = walk_stats if walk_stats is not None else WalkStats()
    dispatch = getattr(backend, "dispatch_block", None)
    # Eager backends compute at dispatch time, so holding a second block
    # in flight would only enumerate/place one ramp-larger block past the
    # winner for nothing; depth > 1 pays off only with async dispatch.
    depth = PIPELINE_DEPTH if dispatch is not None else 1
    now = time.perf_counter

    rejects = 0
    winner: tuple[TaskSetCombo, PlacementPlan, int] | None = None
    rank_base = 0
    # (resolve, ref, rank_base, n_rows) for blocks enqueued but not synced.
    pending: collections.deque = collections.deque()

    def resolve_oldest() -> bool:
        """Sync the oldest in-flight block; True once the winner is known."""
        nonlocal rejects, winner
        resolve, ref, base, n_rows = pending.popleft()
        t0 = now()
        bp = resolve()
        stats.sync_us += (now() - t0) * 1e6
        if on_verdict is not None:
            on_verdict(base, bp.feasible)
        if winner is None:
            r = bp.first_feasible()
            if r >= 0:
                t0 = now()
                combo = materialize(ref, r)
                plan = place_combo(combo, tasks, fleet, **placement_kw)
                stats.materialize_us += (now() - t0) * 1e6
                winner = (combo, plan, base + r)
                rejects += r  # rows before the first feasible are all rejects
                if count_all_rejects:
                    rejects += int((~bp.feasible[r:]).sum())
                return True
            rejects += n_rows
        else:
            rejects += int((~bp.feasible).sum())
        return winner is not None

    stream = iter(block_iter)
    while True:
        t0 = now()
        item = next(stream, None)
        stats.enumerate_us += (now() - t0) * 1e6
        if item is None:
            break
        shares, ref = item
        n_rows = len(shares)
        t0 = now()
        if dispatch is not None:
            resolve = dispatch(shares, iis, t_slr_arr, t_cfg_arr, opts)
        else:
            bp = backend.place_block(shares, iis, t_slr_arr, t_cfg_arr, opts)
            resolve = lambda bp=bp: bp  # noqa: E731 — eager backends
        stats.place_us += (now() - t0) * 1e6
        stats.rows += n_rows
        stats.block_sizes.append(n_rows)
        pending.append((resolve, ref, rank_base, n_rows))
        rank_base += n_rows
        while len(pending) >= depth:
            if resolve_oldest() and not count_all_rejects:
                # Later in-flight blocks hold strictly higher-rank rows;
                # their verdicts are irrelevant once the winner is known.
                pending.clear()
                break
        if winner is not None and not count_all_rejects:
            break
    while pending:
        if resolve_oldest() and not count_all_rejects:
            pending.clear()
    if winner is None:
        return None, None, -1, rejects
    return winner[0], winner[1], winner[2], rejects


def _block_size_schedule(block_size: int | None) -> Iterator[int]:
    """The walk's block sizes: a fixed size, or the geometric ramp."""
    if block_size is None:
        return block_ramp()
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    return itertools.repeat(block_size)


def _select_from_feasibility(
    feas: FeasibilityResult,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int | None = DEFAULT_BLOCK_SIZE,
    backend: str | PlacementBackend = "numpy",
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Fast exhaustive path: batched sweeps over flat TFS indices.

    Avoids materialising per-row :class:`TaskSetCombo` objects entirely —
    each block is one fancy-indexed shares-matrix gather
    (:meth:`FeasibilityResult.shares_matrix`) handed whole to the backend.
    """
    sizes = _block_size_schedule(block_size)
    order = feas.tfs_indices_by_power()

    def blocks():
        lo = 0
        while lo < order.size:
            idx = order[lo : lo + next(sizes)]
            lo += idx.size
            yield feas.shares_matrix(idx), idx

    return _walk_tfs_blocks(
        blocks(),
        lambda idx, r: feas.combo_at(int(idx[r])),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        walk_stats=walk_stats,
        **placement_kw,
    )


def _select_streaming_blocks(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int | None = None,
    backend: str | PlacementBackend = "numpy",
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Streaming path: block-native branch-and-bound feeding the walk.

    :func:`iter_feasible_pruned_blocks` yields whole power-ordered
    :class:`ComboBlock` batches (arrays, no per-row objects); only the
    winning row is materialised as a :class:`TaskSetCombo`.
    """
    sizes = _block_size_schedule(block_size)

    def blocks():
        for blk in iter_feasible_pruned_blocks(tasks, fleet, sizes):
            yield blk.shares, blk

    return _walk_tfs_blocks(
        blocks(),
        lambda blk, r: blk.materialize(r),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        walk_stats=walk_stats,
        **placement_kw,
    )


class PADPSFRScheduler:
    """Power-Aware DP-fair Scheduling with Full Reconfiguration.

    The paper's contribution as a reusable component: construct with a
    :class:`FleetSpec`, call :meth:`schedule` with the periodic task set.
    ``exhaustive=None`` auto-selects the vectorised exhaustive engine for
    small variant products and the block-native branch-and-bound streaming
    engine for large ones.  ``engine`` selects the placement backend
    through the registry (:mod:`repro.core.placement_backends`):
    ``"scalar"``, ``"numpy"`` (default; alias ``"batched"``), ``"jax"``,
    ``"pallas"``, or ``"auto"`` for the best available.  ``"scalar"``
    runs the paper's row-at-a-time walk (:func:`select_lowest_power`)
    directly — early exit at the winner, bookkeeping independent of the
    block walk — so scalar-vs-block parity tests cross-check two separate
    Alg-2 implementations.

    ``block_size=None`` (the default) walks the TFS on the geometric
    ramp (:func:`block_ramp`): instances whose winner sits in the first
    few rows never pay full-block enumeration or dispatch latency, while
    deep walks grow to ``RAMP_CAP``-row blocks.  Pass an int to pin a
    fixed block size; results are invariant either way.
    """

    def __init__(
        self,
        fleet: FleetSpec,
        *,
        exhaustive: bool | None = None,
        exhaustive_limit: int = 2_000_000,
        engine: str = "numpy",
        block_size: int | None = None,
    ) -> None:
        if block_size is not None and block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.fleet = fleet
        self.exhaustive = exhaustive
        self.exhaustive_limit = exhaustive_limit
        self.engine = resolve_engine(engine)  # raises on unknown names
        self.block_size = block_size
        self._backend = get_backend(self.engine)

    def feasibility(self, tasks: Sequence[Task]) -> FeasibilityResult:
        return search_feasible(tasks, self.fleet)

    def _use_exhaustive(self, tasks: Sequence[Task]) -> bool:
        if self.exhaustive is not None:
            return self.exhaustive
        return combo_count(tasks) <= self.exhaustive_limit

    def schedule(
        self,
        tasks: Sequence[Task],
        *,
        count_all_rejects: bool = False,
        walk_stats: WalkStats | None = None,
        record_state: bool = False,
        record_exhaustive: bool = False,
        **placement_kw,
    ) -> ScheduleResult:
        """Run Alg 1 + Alg 2 + Alg 3 on ``tasks``: enumerate the workable
        combos (eq. 7), walk them in ascending total power through the
        placement backend, and return the first placeable combo with its
        full per-device plan.

        With ``record_state=True`` the walk additionally snapshots every
        enumerated row, its placement verdict, and the live
        branch-and-bound frontier into ``result.plan_state`` — the
        warm-start input :meth:`replan` needs.  Recording always uses the
        streaming block-native engine (results are bit-identical to the
        exhaustive path either way, but ``n_tfs``/``n_tnfs`` are not
        counted and report ``-1``).  ``record_exhaustive=True``
        additionally walks *past* the winner so every TFS row carries a
        placement verdict — slower once, but subsequent arrival replans
        skip dispatch for all recorded rejects (the service layer's
        steady-state mode).

        Example (the eq-5 shares here are 30 or 15 per task against a
        2-device budget of ``2*30 - 3*1 = 57``):

            >>> from repro.core.task import FleetSpec, Task, TaskVariant
            >>> def v(th, pw):
            ...     return TaskVariant(cu=1, throughput=th, power=pw)
            >>> tasks = [
            ...     Task("a", period=10.0, data=20.0, init_interval=1.0,
            ...          variants=(v(2.0, 5.0), v(4.0, 8.0))),
            ...     Task("b", period=10.0, data=40.0, init_interval=1.0,
            ...          variants=(v(4.0, 4.0), v(8.0, 6.0))),
            ... ]
            >>> sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
            >>> res = sched.schedule(tasks)
            >>> res.feasible, res.combo.variant_idx, res.total_power
            (True, (0, 1), 11.0)
        """
        tasks = tuple(tasks)
        if record_state:
            from . import replan as _replan

            return _replan.schedule_recorded(
                tasks,
                self.fleet,
                self._backend,
                block_size=self.block_size,
                count_all_rejects=count_all_rejects,
                walk_stats=walk_stats,
                exhaustive=record_exhaustive,
                **placement_kw,
            )
        use_exhaustive = self._use_exhaustive(tasks)
        feas = search_feasible(tasks, self.fleet) if use_exhaustive else None
        if self.engine == "scalar":
            # The paper's walk as written: one scalar simulation per row
            # with early exit at the winner, and winner/rank/reject
            # bookkeeping entirely independent of _walk_tfs_blocks — this
            # is what the cross-engine parity tests pin the block walk to.
            stream: Iterator[TaskSetCombo] = (
                feas.iter_tfs_by_power()
                if feas is not None
                else iter_feasible_pruned(tasks, self.fleet)
            )
            combo, plan, rank, rejects = select_lowest_power(
                stream,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                **placement_kw,
            )
        elif feas is not None:
            combo, plan, rank, rejects = _select_from_feasibility(
                feas,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                block_size=self.block_size,
                backend=self._backend,
                walk_stats=walk_stats,
                **placement_kw,
            )
        else:
            combo, plan, rank, rejects = _select_streaming_blocks(
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                block_size=self.block_size,
                backend=self._backend,
                walk_stats=walk_stats,
                **placement_kw,
            )
        n_tss = combo_count(tasks)
        n_tfs = feas.n_tfs if feas is not None else -1
        n_tnfs = feas.n_tnfs if feas is not None else -1
        return ScheduleResult(
            feasible=combo is not None,
            combo=combo,
            plan=plan,
            chosen_rank=rank,
            n_tss=n_tss,
            n_tfs=n_tfs,
            n_tnfs=n_tnfs,
            n_placement_rejects=rejects,
            total_power=combo.total_power if combo else float("inf"),
        )

    def replan(
        self,
        state,
        tasks: Sequence[Task],
        *,
        walk_stats: WalkStats | None = None,
        **placement_kw,
    ) -> ScheduleResult:
        """Reschedule ``tasks`` warm-starting from a previous plan.

        ``state`` is the :class:`repro.core.replan.PlanState` recorded by
        ``schedule(..., record_state=True)`` (or by a previous
        :meth:`replan`).  A single task *arrival* (``tasks`` extends
        ``state.tasks`` by one appended task) reuses the recorded rows and
        the surviving branch-and-bound frontier; any other delta (exits,
        fleet changes, multiple arrivals) falls back to a fresh recorded
        walk seeded with the previous winner as an incumbent power bound.
        Either way the returned plan is bit-identical to a cold
        :meth:`schedule` of the same task tuple — only the latency
        differs.  See :mod:`repro.core.replan` for the mechanism and the
        soundness argument.

        Example — continue from the :meth:`schedule` doctest's instance,
        with a third task arriving:

            >>> from repro.core.task import FleetSpec, Task, TaskVariant
            >>> def v(th, pw):
            ...     return TaskVariant(cu=1, throughput=th, power=pw)
            >>> tasks = [
            ...     Task("a", period=10.0, data=20.0, init_interval=1.0,
            ...          variants=(v(2.0, 5.0), v(4.0, 8.0))),
            ...     Task("b", period=10.0, data=40.0, init_interval=1.0,
            ...          variants=(v(4.0, 4.0), v(8.0, 6.0))),
            ... ]
            >>> sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
            >>> res = sched.schedule(tasks, record_state=True)
            >>> c = Task("c", period=10.0, data=30.0, init_interval=1.0,
            ...          variants=(v(6.0, 3.0), v(12.0, 9.0)))
            >>> warm = sched.replan(res.plan_state, tasks + [c])
            >>> warm.feasible, warm.combo.variant_idx, warm.total_power
            (True, (1, 1, 0), 17.0)
            >>> cold = sched.schedule(tasks + [c])
            >>> (warm.combo, warm.total_power, warm.chosen_rank) == (
            ...     cold.combo, cold.total_power, cold.chosen_rank)
            True
        """
        from . import replan as _replan

        return _replan.replan(
            state,
            tuple(tasks),
            backend=self._backend,
            fleet=self.fleet,
            block_size=self.block_size,
            walk_stats=walk_stats,
            **placement_kw,
        )
