"""Algorithm 2 top level + the PADPS-FR scheduler facade.

``select_lowest_power`` walks the power-sorted TFS and returns the first
combination whose placement simulation succeeds — by construction the
minimum-power feasible configuration (paper §III-A2).  The facade walks
the TFS in vectorized blocks through a pluggable placement backend
(:mod:`repro.core.placement_backends`): ``engine="numpy"`` (default; alias
``"batched"``) is the zero-dependency block engine, ``"jax"`` a jit'd
``lax.while_loop`` sweep, ``"pallas"`` the fused single-kernel sweep,
``"scalar"`` the exact one-row-at-a-time oracle, and ``"auto"`` the best
available.

Block handoff is array-native end to end: the exhaustive path gathers
blocks with :meth:`FeasibilityResult.shares_matrix`, the streaming path
pulls whole :class:`repro.core.feasibility.ComboBlock` batches from the
vectorized branch-and-bound enumerator
(:func:`repro.core.feasibility.iter_feasible_pruned_blocks`) — no per-row
heap pushes or ``TaskSetCombo`` objects until the single winning row.
Blocks follow a geometric size ramp (:func:`block_ramp`) so early-winner
instances stop after a few cheap small blocks, and backends exposing
``dispatch_block`` (jax/pallas) are double-buffered: block k+1 is
enqueued while block k's verdict syncs back.  The facade bundles
Alg 1 + Alg 2 + Alg 3 and reports the statistics the paper quotes
(|TSS|, |TFS|, |TNFS|, placement rejects, chosen index).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Iterable, Iterator, Sequence

import numpy as np

from .feasibility import (
    FeasibilityResult,
    iter_feasible_pruned,
    iter_feasible_pruned_blocks,
    search_feasible,
)
from .placement import PlacementPlan, place_combo
from .placement_backends import (
    InstanceBatch,
    PlacementBackend,
    PlacementOptions,
    dispatch_instance_blocks,
    get_backend,
    resolve_engine,
)
from .task import FleetSpec, Task, TaskSetCombo, combo_count

__all__ = [
    "ScheduleInstance",
    "ScheduleResult",
    "WalkStats",
    "block_ramp",
    "select_lowest_power",
    "select_lowest_power_batched",
    "PADPSFRScheduler",
]

DEFAULT_BLOCK_SIZE = 4096

# Adaptive walk defaults: early blocks small so a shallow winner exits
# after a few cheap dispatches, late blocks large so deep walks amortise
# per-block overhead (enumeration, padding, device round-trips).
RAMP_START = 64
RAMP_CAP = 65536
RAMP_FACTOR = 8

# How many blocks may be in flight at once when the backend supports
# asynchronous dispatch: one syncing + one enqueued (double buffering).
PIPELINE_DEPTH = 2


def block_ramp(
    start: int = RAMP_START, cap: int = RAMP_CAP, factor: int = RAMP_FACTOR
) -> Iterator[int]:
    """Geometric block-size schedule: ``start``, growing ×``factor`` to
    ``cap``, then ``cap`` forever."""
    size = start
    while True:
        yield size
        size = min(size * factor, cap)


@dataclasses.dataclass
class WalkStats:
    """Per-phase wall-clock breakdown of one Alg-2 block walk.

    ``enumerate_us`` is time producing blocks (Alg-1 streaming or TFS
    gathers), ``place_us`` time enqueueing backend sweeps,
    ``sync_us`` time waiting for verdicts to come back, and
    ``materialize_us`` the winning row's scalar plan.  ``block_sizes``
    records the adaptive ramp actually dispatched.
    """

    enumerate_us: float = 0.0
    place_us: float = 0.0
    sync_us: float = 0.0
    materialize_us: float = 0.0
    rows: int = 0
    block_sizes: list[int] = dataclasses.field(default_factory=list)

    @property
    def total_us(self) -> float:
        return (
            self.enumerate_us + self.place_us + self.sync_us + self.materialize_us
        )

    def as_dict(self) -> dict:
        return {
            "enumerate_us": self.enumerate_us,
            "place_us": self.place_us,
            "sync_us": self.sync_us,
            "materialize_us": self.materialize_us,
            "rows": self.rows,
            "n_blocks": len(self.block_sizes),
            "block_sizes": list(self.block_sizes),
        }


@dataclasses.dataclass(frozen=True)
class ScheduleInstance:
    """One independent scheduling problem for :meth:`PADPSFRScheduler.schedule_many`.

    ``fleet=None`` inherits the scheduler's own fleet — the common
    what-if shape (same pod, many candidate task mixes); an explicit
    fleet models a different pod sharing the batched sweep.
    """

    tasks: tuple[Task, ...]
    fleet: FleetSpec | None = None

    def __post_init__(self):
        object.__setattr__(self, "tasks", tuple(self.tasks))


@dataclasses.dataclass
class ScheduleResult:
    feasible: bool
    combo: TaskSetCombo | None
    plan: PlacementPlan | None
    chosen_rank: int  # 0-based rank in power-sorted TFS (-1 if none)
    n_tss: int
    n_tfs: int
    n_tnfs: int
    n_placement_rejects: int  # TFS rows Alg 2 rejected before success
    total_power: float
    # Warm-start snapshot (``schedule(record_state=True)`` / ``replan``):
    # recorded TFS rows + the resumable enumerator, for delta replanning.
    plan_state: "object | None" = dataclasses.field(default=None, repr=False)

    def summary(self, tasks: Sequence[Task] | None = None) -> str:
        if not self.feasible:
            return (
                f"INFEASIBLE: |TSS|={self.n_tss} |TFS|={self.n_tfs} "
                f"|TNFS|={self.n_tnfs}; all TFS rows failed placement"
            )
        assert self.combo is not None
        desc = self.combo.describe(tasks) if tasks else str(self.combo.variant_idx)
        return (
            f"|TSS|={self.n_tss} |TFS|={self.n_tfs} |TNFS|={self.n_tnfs} "
            f"placement-rejects={self.n_placement_rejects} "
            f"chosen-rank={self.chosen_rank} power={self.total_power:g} "
            f"shares={[round(s, 4) for s in self.combo.shares]} [{desc}]"
        )


def select_lowest_power(
    combos_by_power: Iterable[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Alg 2 lines 2-10: first placeable combo in ascending-power order.

    The paper's walk as written — one full scalar placement simulation per
    row, no blocking, no backend indirection; kept as the independent
    reference for the block walk.  Returns (combo, plan, rank,
    rejects_before_success).  With ``count_all_rejects`` the walk continues
    past the winner to count every placement-rejected TFS row (the paper's
    "156 rejected" statistic).
    """
    rejects = 0
    winner: tuple[TaskSetCombo, PlacementPlan, int] | None = None
    for rank, combo in enumerate(combos_by_power):
        plan = place_combo(combo, tasks, fleet, **placement_kw)
        if plan.feasible:
            if winner is None:
                winner = (combo, plan, rank)
            if not count_all_rejects:
                break
        else:
            rejects += 1
    if winner is None:
        return None, None, -1, rejects
    return winner[0], winner[1], winner[2], rejects


def select_lowest_power_batched(
    combos_by_power: Iterable[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int = DEFAULT_BLOCK_SIZE,
    backend: str | PlacementBackend = "numpy",
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Alg 2 over vectorized TFS blocks — same contract as
    :func:`select_lowest_power`.

    Chops a per-row :class:`TaskSetCombo` stream into fixed blocks for the
    placement backend.  This is the pre-block-native streaming path (one
    Python object per TFS row); the scheduler facade now feeds the walk
    from :func:`repro.core.feasibility.iter_feasible_pruned_blocks`
    instead, which skips the per-row objects entirely — this entry point
    remains for external combo streams and as the benchmark baseline.
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")

    def blocks():
        stream = iter(combos_by_power)
        while True:
            block = list(itertools.islice(stream, block_size))
            if not block:
                return
            yield [c.shares for c in block], block

    return _walk_tfs_blocks(
        blocks(),
        lambda block, r: block[r],
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        walk_stats=walk_stats,
        **placement_kw,
    )


def _walk_tfs_blocks(
    block_iter,
    materialize,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    backend: str | PlacementBackend,
    count_all_rejects: bool,
    walk_stats: WalkStats | None = None,
    on_verdict=None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Shared Alg-2 walk over batched TFS blocks, pipelined.

    ``block_iter`` yields ``(shares_rows, ref)`` pairs (a (B, n_t)
    array-like plus an opaque block reference); ``materialize(ref, row)``
    produces the winning row's :class:`TaskSetCombo`.  Winner/rank/reject
    bookkeeping lives only here — backend-agnostic by construction — so
    no two engines can drift apart.

    Dispatch is double-buffered: each block is enqueued via the backend's
    ``dispatch_block`` (see :mod:`repro.core.placement_backends.base`;
    asynchronous on jax/pallas, eager elsewhere) and its verdict resolved
    only once the next block is in flight, so enumeration and device
    sweeps overlap.  Blocks resolve strictly in rank order, so the
    bookkeeping is identical to the synchronous walk.

    ``on_verdict(rank_base, feasible, placed_tasks)`` — when given — is
    called with every resolved block's boolean verdict vector and the
    primary sweep's per-row placed-task counts (including the winning
    block's, before the walk stops).  Blocks enqueued but abandoned once
    the winner is known never reach it: the delta replanner
    (:mod:`repro.core.replan`) records those rows as *unknown* rather
    than inventing verdicts.
    """
    if isinstance(backend, str):
        backend = get_backend(backend)
    iis = [t.init_interval for t in tasks]
    t_slr_arr = fleet.t_slr_arr
    t_cfg_arr = fleet.t_cfg_arr
    opts = PlacementOptions(**placement_kw)
    stats = walk_stats if walk_stats is not None else WalkStats()
    dispatch = getattr(backend, "dispatch_block", None)
    # Eager backends compute at dispatch time, so holding a second block
    # in flight would only enumerate/place one ramp-larger block past the
    # winner for nothing; depth > 1 pays off only with async dispatch.
    # Backends declare that via `async_dispatch` (base.py) — every engine
    # spells out the full dispatch surface, so method presence alone no
    # longer distinguishes pipelined from eager.
    pipelined = dispatch is not None and getattr(backend, "async_dispatch", True)
    depth = PIPELINE_DEPTH if pipelined else 1
    now = time.perf_counter

    rejects = 0
    winner: tuple[TaskSetCombo, PlacementPlan, int] | None = None
    rank_base = 0
    # (resolve, ref, rank_base, n_rows) for blocks enqueued but not synced.
    pending: collections.deque = collections.deque()

    def resolve_oldest() -> bool:
        """Sync the oldest in-flight block; True once the winner is known."""
        nonlocal rejects, winner
        resolve, ref, base, n_rows = pending.popleft()
        t0 = now()
        bp = resolve()
        stats.sync_us += (now() - t0) * 1e6
        if on_verdict is not None:
            on_verdict(base, bp.feasible, bp.placed_tasks)
        if winner is None:
            r = bp.first_feasible()
            if r >= 0:
                t0 = now()
                combo = materialize(ref, r)
                plan = place_combo(combo, tasks, fleet, **placement_kw)
                stats.materialize_us += (now() - t0) * 1e6
                winner = (combo, plan, base + r)
                rejects += r  # rows before the first feasible are all rejects
                if count_all_rejects:
                    rejects += int((~bp.feasible[r:]).sum())
                return True
            rejects += n_rows
        else:
            rejects += int((~bp.feasible).sum())
        return winner is not None

    stream = iter(block_iter)
    while True:
        t0 = now()
        item = next(stream, None)
        stats.enumerate_us += (now() - t0) * 1e6
        if item is None:
            break
        shares, ref = item
        n_rows = len(shares)
        t0 = now()
        if dispatch is not None:
            resolve = dispatch(shares, iis, t_slr_arr, t_cfg_arr, opts)
        else:
            bp = backend.place_block(shares, iis, t_slr_arr, t_cfg_arr, opts)
            resolve = lambda bp=bp: bp  # noqa: E731 — eager backends
        stats.place_us += (now() - t0) * 1e6
        stats.rows += n_rows
        stats.block_sizes.append(n_rows)
        pending.append((resolve, ref, rank_base, n_rows))
        rank_base += n_rows
        while len(pending) >= depth:
            if resolve_oldest() and not count_all_rejects:
                # Later in-flight blocks hold strictly higher-rank rows;
                # their verdicts are irrelevant once the winner is known.
                pending.clear()
                break
        if winner is not None and not count_all_rejects:
            break
    while pending:
        if resolve_oldest() and not count_all_rejects:
            pending.clear()
    if winner is None:
        return None, None, -1, rejects
    return winner[0], winner[1], winner[2], rejects


def _validate_resilience(placement_kw: dict) -> int:
    """Extract and validate the ``resilience`` placement option.

    Raised here — at the scheduler facade — so a bad ``resilience`` fails
    loudly at ``schedule()`` time instead of deep inside an enumerator or
    backend sweep.  ``k >= n_f`` is *not* an error (fleets shrink under
    failures); the caller answers it with an infeasible result.
    """
    k = placement_kw.get("resilience", 0)
    if isinstance(k, bool) or not isinstance(k, (int, np.integer)) or k < 0:
        raise ValueError(
            f"resilience must be a non-negative integer, got {k!r}"
        )
    return int(k)


def _resilience_infeasible_result(tasks: Sequence[Task]) -> ScheduleResult:
    """The ``k >= n_f`` answer: no combo can survive losing every device.

    The resilient TFS is empty by definition, so ``n_tfs == 0`` and every
    TSS row is unworkable — returned as a result rather than raised so a
    service whose fleet shrinks below ``k`` degrades instead of crashing.
    """
    n_tss = combo_count(tasks)
    return ScheduleResult(
        feasible=False,
        combo=None,
        plan=None,
        chosen_rank=-1,
        n_tss=n_tss,
        n_tfs=0,
        n_tnfs=n_tss,
        n_placement_rejects=0,
        total_power=float("inf"),
    )


def _block_size_schedule(block_size: int | None) -> Iterator[int]:
    """The walk's block sizes: a fixed size, or the geometric ramp."""
    if block_size is None:
        return block_ramp()
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    return itertools.repeat(block_size)


_GATHER_CHUNK = 4096

# Lockstep many-walk block coalescing: each round block covers this many
# solo-schedule blocks, bounded so one packed round (B instances x R
# rows) stays under _MANY_ROUND_ROWS total rows of float64 shares.
_MANY_BLOCK_SCALE = 8
_MANY_ROUND_ROWS = 1 << 18


def _coalesced_sizes(sizes: Iterator[int], rcap: int) -> Iterator[int]:
    """The many-walk's round-block schedule: the solo schedule, coalesced.

    Each round block covers ``_MANY_BLOCK_SCALE`` solo blocks — one
    round's fixed cost is shared by the whole batch, so the batched
    walk's sweet spot is a coarser granularity than a solo walk's, but
    not *too* coarse: rows past the winner are wasted sweep compute, so
    the factor stays moderate.  Clamped to ``rcap`` rows so a packed
    round stays within the row budget, and never below the solo size (a
    user who pinned big blocks keeps them).  Verdicts, ranks and reject
    counts are block-size invariant, so this only changes how many
    rounds a walk takes — never what it returns.
    """
    for s in sizes:
        yield max(s, min(s * _MANY_BLOCK_SCALE, rcap))


def _sorted_tfs_blocks(feas: FeasibilityResult, sizes: Iterator[int]):
    """Yield ``(shares_rows, idx_rows)`` blocks of the power-sorted TFS.

    Shares are gathered through :meth:`FeasibilityResult.shares_matrix`
    in chunks of ``_GATHER_CHUNK`` sorted rows and sliced per block, so a
    small fixed block size pays one fancy-indexed gather per few hundred
    blocks instead of one per block — the gather's fixed Python cost was
    the dominant per-block overhead of dispatch-heavy walks.  Block
    boundaries (and therefore all rank/reject bookkeeping) are exactly
    those of a per-block gather; only the copy granularity changes.
    """
    order = feas.tfs_indices_by_power()
    lo = 0
    buf = None
    buf_lo = 0
    while lo < order.size:
        hi = min(lo + next(sizes), order.size)
        if buf is None or hi > buf_lo + buf.shape[0]:
            buf_lo = lo
            end = max(hi, min(lo + _GATHER_CHUNK, order.size))
            buf = feas.shares_matrix(order[lo:end])
        yield buf[lo - buf_lo : hi - buf_lo], order[lo:hi]
        lo = hi


def _select_from_feasibility(
    feas: FeasibilityResult,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int | None = DEFAULT_BLOCK_SIZE,
    backend: str | PlacementBackend = "numpy",
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Fast exhaustive path: batched sweeps over flat TFS indices.

    Avoids materialising per-row :class:`TaskSetCombo` objects entirely —
    each block is one fancy-indexed shares-matrix gather
    (:meth:`FeasibilityResult.shares_matrix`) handed whole to the backend.
    """
    sizes = _block_size_schedule(block_size)

    return _walk_tfs_blocks(
        _sorted_tfs_blocks(feas, sizes),
        lambda idx, r: feas.combo_at(int(idx[r])),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        walk_stats=walk_stats,
        **placement_kw,
    )


def _select_streaming_blocks(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    count_all_rejects: bool = False,
    block_size: int | None = None,
    backend: str | PlacementBackend = "numpy",
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> tuple[TaskSetCombo | None, PlacementPlan | None, int, int]:
    """Streaming path: block-native branch-and-bound feeding the walk.

    :func:`iter_feasible_pruned_blocks` yields whole power-ordered
    :class:`ComboBlock` batches (arrays, no per-row objects); only the
    winning row is materialised as a :class:`TaskSetCombo`.
    """
    sizes = _block_size_schedule(block_size)

    def blocks():
        for blk in iter_feasible_pruned_blocks(
            tasks, fleet, sizes,
            resilience=placement_kw.get("resilience", 0),
        ):
            yield blk.shares, blk

    return _walk_tfs_blocks(
        blocks(),
        lambda blk, r: blk.materialize(r),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects,
        walk_stats=walk_stats,
        **placement_kw,
    )


@dataclasses.dataclass
class _InstanceWalk:
    """One instance's private bookkeeping inside the lockstep many-walk.

    Mirrors :func:`_walk_tfs_blocks`' locals exactly — same rank/reject
    accounting, same per-instance block-size ramp — so a batch of one is
    field-identical to a solo walk.
    """

    index: int  # position in the caller's instance list
    tasks: tuple[Task, ...]
    fleet: FleetSpec
    stream: Iterator  # yields (shares_rows, ref)
    materialize: object  # (ref, row) -> TaskSetCombo
    feas: FeasibilityResult | None  # exhaustive-path counts, else None
    iis: list[float] = dataclasses.field(default_factory=list)
    slr_arr: np.ndarray | None = None  # fleet.t_slr_arr, hoisted once
    cfg_arr: np.ndarray | None = None  # fleet.t_cfg_arr, hoisted once
    rank_base: int = 0
    rejects: int = 0
    winner: "tuple[TaskSetCombo, PlacementPlan, int] | None" = None
    done: bool = False  # winner known and no full-reject count requested


def _walk_many_tfs_blocks(
    walks: "list[_InstanceWalk]",
    *,
    backend: PlacementBackend,
    count_all_rejects: bool,
    shard: int | str | None = None,
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> None:
    """Lockstep Alg-2 walk over many instances' TFS blocks.

    Each round pulls the next block from every live instance's own
    stream (each on its own size ramp, exactly as a solo walk would),
    packs them into one :class:`InstanceBatch`, and dispatches the whole
    round through the backend's fleet-parallel surface
    (:func:`dispatch_instance_blocks`) — one device program per round
    instead of one per instance-block.  Rounds are double-buffered like
    the solo walk's blocks.

    Per-instance winner/rank/reject bookkeeping is byte-for-byte the
    solo walk's (``resolve_oldest``'s accounting applied to that
    instance's slice of the round), and blocks of one instance resolve
    strictly in that instance's rank order — so each ``_InstanceWalk``
    finishes exactly as if it had walked alone.  Results are left on the
    walks (``winner``/``rejects``); the caller builds ``ScheduleResult``s.
    """
    opts = PlacementOptions(**placement_kw)
    stats = walk_stats if walk_stats is not None else WalkStats()
    raw_hook = getattr(backend, "dispatch_blocks_raw", None)
    has_dispatch = (
        raw_hook is not None
        or getattr(backend, "dispatch_blocks", None) is not None
        or getattr(backend, "dispatch_block", None) is not None
    )
    # Same declared-pipelining rule as the solo walk: eager engines that
    # spell out the dispatch surface (async_dispatch = False) get depth 1.
    has_async = has_dispatch and getattr(backend, "async_dispatch", True)
    depth = PIPELINE_DEPTH if has_async else 1
    now = time.perf_counter

    # (raw, resolver, entries) per round; entries = [(walk, ref, base, n_rows)].
    pending: collections.deque = collections.deque()

    def apply_verdict(w, ref, base, n_rows, has_feas, first, n_feas, feas_row):
        """One entry's solo-walk bookkeeping, from precomputed reductions.

        ``feas_row`` is a zero-arg thunk for the entry's live (n_rows,)
        feasibility vector — only the rare winning-block path under
        ``count_all_rejects`` actually needs the per-row bits.
        """
        if w.done:
            return  # abandoned in-flight block of a finished walk
        if w.winner is None:
            if has_feas:
                r = first
                t0 = now()
                combo = w.materialize(ref, r)
                plan = place_combo(combo, w.tasks, w.fleet, **placement_kw)
                stats.materialize_us += (now() - t0) * 1e6
                w.winner = (combo, plan, base + r)
                w.rejects += r
                if count_all_rejects:
                    w.rejects += int((~feas_row()[r:]).sum())
                else:
                    w.done = True
            else:
                w.rejects += n_rows
        else:
            w.rejects += n_rows - n_feas

    def resolve_round() -> None:
        raw, resolver, entries = pending.popleft()
        t0 = now()
        results = resolver()
        stats.sync_us += (now() - t0) * 1e6
        if raw:
            # Raw surface: one vectorized reduction pass over the round's
            # (B', Rp) verdict block instead of B trimmed result objects;
            # rows beyond each entry's live count are padding and masked.
            nb = len(entries)
            feas2d = results[0][:nb].astype(bool, copy=False)
            n_rows_arr = np.fromiter(
                (e[3] for e in entries), dtype=np.int64, count=nb
            )
            live2d = feas2d & (np.arange(feas2d.shape[1]) < n_rows_arr[:, None])
            has_l = live2d.any(axis=1).tolist()
            first_l = np.argmax(live2d, axis=1).tolist()
            nfeas_l = live2d.sum(axis=1).tolist()
            for k, (w, ref, base, n_rows) in enumerate(entries):
                apply_verdict(
                    w, ref, base, n_rows, has_l[k], first_l[k], nfeas_l[k],
                    lambda k=k, n=n_rows: live2d[k, :n],
                )
        else:
            for (w, ref, base, n_rows), bp in zip(entries, results, strict=True):
                r = bp.first_feasible()
                apply_verdict(
                    w, ref, base, n_rows, r >= 0, r,
                    int(bp.feasible.sum()), lambda bp=bp: bp.feasible,
                )

    live = list(walks)
    while live:
        entries = []
        blocks = []
        t0 = now()
        for w in live[:]:
            if w.done:
                live.remove(w)
                continue
            item = next(w.stream, None)
            if item is None:
                live.remove(w)  # stream exhausted; verdicts may be in flight
                continue
            shares, ref = item
            n_rows = len(shares)
            entries.append((w, ref, w.rank_base, n_rows))
            blocks.append((shares, w.iis, w.slr_arr, w.cfg_arr))
            w.rank_base += n_rows
            stats.rows += n_rows
            stats.block_sizes.append(n_rows)
        stats.enumerate_us += (now() - t0) * 1e6
        if not entries:
            break
        t0 = now()
        batch = InstanceBatch.pack(blocks)
        raw = raw_hook(batch, opts, shard=shard) if raw_hook is not None else None
        if raw is not None:
            pending.append((True, raw, entries))
        else:
            resolver = dispatch_instance_blocks(backend, batch, opts, shard=shard)
            pending.append((False, resolver, entries))
        stats.place_us += (now() - t0) * 1e6
        while len(pending) >= depth:
            resolve_round()
    while pending:
        resolve_round()


class PADPSFRScheduler:
    """Power-Aware DP-fair Scheduling with Full Reconfiguration.

    The paper's contribution as a reusable component: construct with a
    :class:`FleetSpec`, call :meth:`schedule` with the periodic task set.
    ``exhaustive=None`` auto-selects the vectorised exhaustive engine for
    small variant products and the block-native branch-and-bound streaming
    engine for large ones.  ``engine`` selects the placement backend
    through the registry (:mod:`repro.core.placement_backends`):
    ``"scalar"``, ``"numpy"`` (default; alias ``"batched"``), ``"jax"``,
    ``"pallas"``, or ``"auto"`` for the best available.  ``"scalar"``
    runs the paper's row-at-a-time walk (:func:`select_lowest_power`)
    directly — early exit at the winner, bookkeeping independent of the
    block walk — so scalar-vs-block parity tests cross-check two separate
    Alg-2 implementations.

    ``block_size=None`` (the default) walks the TFS on the geometric
    ramp (:func:`block_ramp`): instances whose winner sits in the first
    few rows never pay full-block enumeration or dispatch latency, while
    deep walks grow to ``RAMP_CAP``-row blocks.  Pass an int to pin a
    fixed block size; results are invariant either way.
    """

    def __init__(
        self,
        fleet: FleetSpec,
        *,
        exhaustive: bool | None = None,
        exhaustive_limit: int = 2_000_000,
        engine: str = "numpy",
        block_size: int | None = None,
    ) -> None:
        if block_size is not None and block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.fleet = fleet
        self.exhaustive = exhaustive
        self.exhaustive_limit = exhaustive_limit
        self.engine = resolve_engine(engine)  # raises on unknown names
        self.block_size = block_size
        self._backend = get_backend(self.engine)

    def feasibility(
        self, tasks: Sequence[Task], *, resilience: int = 0
    ) -> FeasibilityResult:
        return search_feasible(tasks, self.fleet, resilience=resilience)

    def _use_exhaustive(self, tasks: Sequence[Task]) -> bool:
        if self.exhaustive is not None:
            return self.exhaustive
        return combo_count(tasks) <= self.exhaustive_limit

    def schedule(
        self,
        tasks: Sequence[Task],
        *,
        count_all_rejects: bool = False,
        walk_stats: WalkStats | None = None,
        record_state: bool = False,
        record_exhaustive: bool = False,
        **placement_kw,
    ) -> ScheduleResult:
        """Run Alg 1 + Alg 2 + Alg 3 on ``tasks``: enumerate the workable
        combos (eq. 7), walk them in ascending total power through the
        placement backend, and return the first placeable combo with its
        full per-device plan.

        ``resilience=k`` (a placement option, threaded to every backend
        via :class:`PlacementOptions`) requires the chosen combo to stay
        placeable after *any* k device failures: eq. 7 tightens to the
        worst-case survivor fleet's budget and every candidate row must
        pass a second sweep on ``fleet.survivors(k)`` (see the resilience
        contract in :mod:`repro.core.placement_backends.base`).  The
        winning plan carries its survivor placement as ``plan.backup``.
        ``k >= n_f`` returns an infeasible result rather than raising, so
        a service whose fleet shrinks below ``k`` degrades gracefully.

        With ``record_state=True`` the walk additionally snapshots every
        enumerated row, its placement verdict, and the live
        branch-and-bound frontier into ``result.plan_state`` — the
        warm-start input :meth:`replan` needs.  Recording always uses the
        streaming block-native engine (results are bit-identical to the
        exhaustive path either way, but ``n_tfs``/``n_tnfs`` are not
        counted and report ``-1``).  ``record_exhaustive=True``
        additionally walks *past* the winner so every TFS row carries a
        placement verdict — slower once, but subsequent arrival replans
        skip dispatch for all recorded rejects (the service layer's
        steady-state mode).

        Example (the eq-5 shares here are 30 or 15 per task against a
        2-device budget of ``2*30 - 3*1 = 57``):

            >>> from repro.core.task import FleetSpec, Task, TaskVariant
            >>> def v(th, pw):
            ...     return TaskVariant(cu=1, throughput=th, power=pw)
            >>> tasks = [
            ...     Task("a", period=10.0, data=20.0, init_interval=1.0,
            ...          variants=(v(2.0, 5.0), v(4.0, 8.0))),
            ...     Task("b", period=10.0, data=40.0, init_interval=1.0,
            ...          variants=(v(4.0, 4.0), v(8.0, 6.0))),
            ... ]
            >>> sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
            >>> res = sched.schedule(tasks)
            >>> res.feasible, res.combo.variant_idx, res.total_power
            (True, (0, 1), 11.0)
        """
        tasks = tuple(tasks)
        resilience = _validate_resilience(placement_kw)
        if resilience >= self.fleet.n_f and tasks:
            return _resilience_infeasible_result(tasks)
        if record_state:
            from . import replan as _replan

            return _replan.schedule_recorded(
                tasks,
                self.fleet,
                self._backend,
                block_size=self.block_size,
                count_all_rejects=count_all_rejects,
                walk_stats=walk_stats,
                exhaustive=record_exhaustive,
                **placement_kw,
            )
        use_exhaustive = self._use_exhaustive(tasks)
        feas = (
            search_feasible(tasks, self.fleet, resilience=resilience)
            if use_exhaustive
            else None
        )
        if self.engine == "scalar":
            # The paper's walk as written: one scalar simulation per row
            # with early exit at the winner, and winner/rank/reject
            # bookkeeping entirely independent of _walk_tfs_blocks — this
            # is what the cross-engine parity tests pin the block walk to.
            stream: Iterator[TaskSetCombo] = (
                feas.iter_tfs_by_power()
                if feas is not None
                else iter_feasible_pruned(tasks, self.fleet, resilience=resilience)
            )
            combo, plan, rank, rejects = select_lowest_power(
                stream,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                **placement_kw,
            )
        elif feas is not None:
            combo, plan, rank, rejects = _select_from_feasibility(
                feas,
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                block_size=self.block_size,
                backend=self._backend,
                walk_stats=walk_stats,
                **placement_kw,
            )
        else:
            combo, plan, rank, rejects = _select_streaming_blocks(
                tasks,
                self.fleet,
                count_all_rejects=count_all_rejects,
                block_size=self.block_size,
                backend=self._backend,
                walk_stats=walk_stats,
                **placement_kw,
            )
        n_tss = combo_count(tasks)
        n_tfs = feas.n_tfs if feas is not None else -1
        n_tnfs = feas.n_tnfs if feas is not None else -1
        return ScheduleResult(
            feasible=combo is not None,
            combo=combo,
            plan=plan,
            chosen_rank=rank,
            n_tss=n_tss,
            n_tfs=n_tfs,
            n_tnfs=n_tnfs,
            n_placement_rejects=rejects,
            total_power=combo.total_power if combo else float("inf"),
        )

    def _coerce_instance(self, inst) -> ScheduleInstance:
        if isinstance(inst, ScheduleInstance):
            return inst
        return ScheduleInstance(tasks=tuple(inst))

    def _instance_walk(
        self,
        index: int,
        inst: ScheduleInstance,
        n_batch: int = 1,
        resilience: int = 0,
    ) -> _InstanceWalk:
        """Build one instance's block stream for the lockstep many-walk.

        Same source selection and same block producers as :meth:`schedule`
        (exhaustive shares-matrix gathers or the streaming block-native
        enumerator, each on its own geometric ramp) so a batch of one
        replays the solo walk exactly.

        For ``n_batch > 1`` the size schedule is coalesced
        (:func:`_coalesced_sizes`): a round's fixed cost — pack,
        dispatch, resolve — is shared by the whole batch, so the batched
        walk's sweet spot is a coarser granularity than a solo walk's.
        Verdicts, ranks and reject counts are block-size *invariant*
        (the same invariance the ``block_size`` knob rests on), so
        coalescing never changes results — only
        ``WalkStats.block_sizes`` records the coarser schedule.
        """
        tasks = inst.tasks
        fleet = inst.fleet if inst.fleet is not None else self.fleet
        sizes = _block_size_schedule(self.block_size)
        if n_batch > 1:
            sizes = _coalesced_sizes(sizes, max(1, _MANY_ROUND_ROWS // n_batch))
        if self._use_exhaustive(tasks):
            feas = search_feasible(tasks, fleet, resilience=resilience)
            stream = _sorted_tfs_blocks(feas, sizes)
            materialize = lambda idx, r: feas.combo_at(int(idx[r]))  # noqa: E731
        else:
            feas = None

            def blocks():
                for blk in iter_feasible_pruned_blocks(
                    tasks, fleet, sizes, resilience=resilience
                ):
                    yield blk.shares, blk

            stream = blocks()
            materialize = lambda blk, r: blk.materialize(r)  # noqa: E731
        return _InstanceWalk(
            index=index,
            tasks=tasks,
            fleet=fleet,
            stream=stream,
            materialize=materialize,
            feas=feas,
            iis=[t.init_interval for t in tasks],
            slr_arr=fleet.t_slr_arr,
            cfg_arr=fleet.t_cfg_arr,
        )

    def schedule_many(
        self,
        instances: Sequence["ScheduleInstance | Sequence[Task]"],
        *,
        shard: int | str | None = None,
        count_all_rejects: bool = False,
        walk_stats: WalkStats | None = None,
        **placement_kw,
    ) -> list[ScheduleResult]:
        """Schedule many independent instances as one batched program.

        ``instances`` is a sequence of :class:`ScheduleInstance` (or bare
        task sequences, which inherit this scheduler's fleet).  Each
        round of the lockstep walk packs every live instance's next TFS
        block into one :class:`InstanceBatch` and sweeps them through the
        backend's fleet-parallel surface — one vmapped/grid-extended
        device program per round instead of one dispatch per
        instance-block, which is where the throughput win over a Python
        loop of :meth:`schedule` calls comes from.

        Guarantees (tested per engine in ``tests/test_fleet_parallel.py``):

        * ``schedule_many([])`` returns ``[]``;
        * ``schedule_many([i])[0]`` equals ``schedule(i.tasks)`` field
          for field, for every engine;
        * results are per-instance — an infeasible instance yields its
          own ``feasible=False`` result without disturbing, or being
          disturbed by, its batchmates;
        * verdicts are bit-identical to the numpy loop-over-instances
          reference regardless of batch composition or ``shard``.

        ``shard`` lays the instance axis across jax devices via
        ``shard_map`` (``"auto"`` = all local devices; clamped, and a
        single-device host silently degrades to the plain vmap).  The
        scalar engine has no batched surface and simply loops solo
        schedules.  ``walk_stats`` aggregates all instances' phases into
        one :class:`WalkStats` (block sizes interleave round-robin).

            >>> from repro.core.task import FleetSpec, Task, TaskVariant
            >>> def v(th, pw):
            ...     return TaskVariant(cu=1, throughput=th, power=pw)
            >>> a = Task("a", period=10.0, data=20.0, init_interval=1.0,
            ...          variants=(v(2.0, 5.0), v(4.0, 8.0)))
            >>> b = Task("b", period=10.0, data=40.0, init_interval=1.0,
            ...          variants=(v(4.0, 4.0), v(8.0, 6.0)))
            >>> sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
            >>> lo, hi = sched.schedule_many([[a], [a, b]])
            >>> (lo.total_power, hi.total_power)
            (5.0, 11.0)
        """
        insts = [self._coerce_instance(x) for x in instances]
        if not insts:
            return []
        resilience = _validate_resilience(placement_kw)
        if self.engine == "scalar":
            # The row-at-a-time oracle has no block surface to batch; a
            # loop of solo schedules *is* its fleet-parallel semantics
            # (and what the property tests pin the batched engines to).
            return [self._solo_schedule(i, count_all_rejects, placement_kw) for i in insts]
        # Instances whose (own) fleet cannot survive k failures are
        # answered up front, exactly like the solo path — no walk entry.
        results: list[ScheduleResult | None] = [None] * len(insts)
        walks = []
        for i, inst in enumerate(insts):
            fleet = inst.fleet if inst.fleet is not None else self.fleet
            if resilience >= fleet.n_f and inst.tasks:
                results[i] = _resilience_infeasible_result(inst.tasks)
            else:
                walks.append(
                    self._instance_walk(
                        i, inst, n_batch=len(insts), resilience=resilience
                    )
                )
        _walk_many_tfs_blocks(
            walks,
            backend=self._backend,
            count_all_rejects=count_all_rejects,
            shard=shard,
            walk_stats=walk_stats,
            **placement_kw,
        )
        for w in walks:
            combo, plan, rank = w.winner if w.winner is not None else (None, None, -1)
            results[w.index] = ScheduleResult(
                feasible=combo is not None,
                combo=combo,
                plan=plan,
                chosen_rank=rank,
                n_tss=combo_count(w.tasks),
                n_tfs=w.feas.n_tfs if w.feas is not None else -1,
                n_tnfs=w.feas.n_tnfs if w.feas is not None else -1,
                n_placement_rejects=w.rejects,
                total_power=combo.total_power if combo else float("inf"),
            )
        return results

    def _solo_schedule(
        self, inst: ScheduleInstance, count_all_rejects: bool, placement_kw: dict
    ) -> ScheduleResult:
        """One instance through :meth:`schedule`, honouring its fleet."""
        sched = self
        if inst.fleet is not None and inst.fleet is not self.fleet:
            sched = PADPSFRScheduler(
                inst.fleet,
                exhaustive=self.exhaustive,
                exhaustive_limit=self.exhaustive_limit,
                engine=self.engine,
                block_size=self.block_size,
            )
        return sched.schedule(
            inst.tasks, count_all_rejects=count_all_rejects, **placement_kw
        )

    def replan(
        self,
        state,
        tasks: Sequence[Task],
        *,
        fleet: FleetSpec | None = None,
        record_exhaustive: bool = False,
        walk_stats: WalkStats | None = None,
        **placement_kw,
    ) -> ScheduleResult:
        """Reschedule ``tasks`` warm-starting from a previous plan.

        ``state`` is the :class:`repro.core.replan.PlanState` recorded by
        ``schedule(..., record_state=True)`` (or by a previous
        :meth:`replan`).  Three deltas take a warm path: task *arrivals*
        (``tasks`` extends the recorded root's tasks) reuse the recorded
        rows and the surviving branch-and-bound frontier; a single task
        *exit* projects the recorded rows onto the surviving task axes
        and walks only the thin power band the projection cannot cover;
        a single *device failure* (``fleet`` shrinks by one device)
        re-checks the recorded rows against the shrunken fleet's eq-7
        budget, transferring recorded reject verdicts where monotonicity
        makes that sound.  Every warm path emits a fresh carry-over
        ``PlanState``, so consecutive warm events chain.  Any other delta
        falls back to a fresh recorded walk seeded with the previous
        winner as an incumbent power bound; ``record_exhaustive=True``
        makes that fallback a full exhaustive re-record.  Either way the
        returned plan is bit-identical to a cold :meth:`schedule` of the
        same task tuple on the same fleet — only the latency differs.
        See :mod:`repro.core.replan` for the mechanism and the soundness
        argument.

        Example — continue from the :meth:`schedule` doctest's instance,
        with a third task arriving:

            >>> from repro.core.task import FleetSpec, Task, TaskVariant
            >>> def v(th, pw):
            ...     return TaskVariant(cu=1, throughput=th, power=pw)
            >>> tasks = [
            ...     Task("a", period=10.0, data=20.0, init_interval=1.0,
            ...          variants=(v(2.0, 5.0), v(4.0, 8.0))),
            ...     Task("b", period=10.0, data=40.0, init_interval=1.0,
            ...          variants=(v(4.0, 4.0), v(8.0, 6.0))),
            ... ]
            >>> sched = PADPSFRScheduler(FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0))
            >>> res = sched.schedule(tasks, record_state=True)
            >>> c = Task("c", period=10.0, data=30.0, init_interval=1.0,
            ...          variants=(v(6.0, 3.0), v(12.0, 9.0)))
            >>> warm = sched.replan(res.plan_state, tasks + [c])
            >>> warm.feasible, warm.combo.variant_idx, warm.total_power
            (True, (1, 1, 0), 17.0)
            >>> cold = sched.schedule(tasks + [c])
            >>> (warm.combo, warm.total_power, warm.chosen_rank) == (
            ...     cold.combo, cold.total_power, cold.chosen_rank)
            True
        """
        from . import replan as _replan

        return _replan.replan(
            state,
            tuple(tasks),
            backend=self._backend,
            fleet=fleet if fleet is not None else self.fleet,
            block_size=self.block_size,
            record_exhaustive=record_exhaustive,
            walk_stats=walk_stats,
            **placement_kw,
        )
