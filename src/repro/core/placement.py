"""Algorithms 2 & 3 — placement of a task-set combination onto the fleet.

This module implements the paper's ``find_low_power_task_set()`` routine
(Alg 2 lines 11-29 / Alg 3 lines 6-27) once, as a full placement simulator
that both answers *is this combo placeable?* (Alg 2) and produces the
per-device script/Gantt plan with data splits (Alg 3).

Semantics, pinned against the paper's worked examples (Figs 2-4):

* Placing task ``k`` fresh on a device costs ``t_cfg + shr_k``; the share
  *includes one initialization interval* II_k ("The total share of 2CU-T3
  is 24 including II 2 ms", §IV-A1), so T2 (cfg 6 + shr 36) finishes at
  42 ms on F2 exactly as the paper states.
* A task may only *start* on a device whose remaining capacity strictly
  exceeds ``t_cfg + II_k`` (Example 2: remaining 18 vs 6+12=18 → rejected).
* If ``c - t_cfg < shr_k`` the task splits: ``tsd = c - t_cfg`` of its share
  runs here and the remainder carries to the next device, where it pays
  ``t_cfg`` *and a fresh II_k* again ("the hardware again needs 2 ms II",
  §IV-A1 — this is the ``- II_k`` term of pseudocode line 22, which applies
  to carried tasks; charging it to fresh placements would double-count the
  II already inside the share, contradicting the 42 ms figure).
* After fully placing ``k``, if the leftover is within ``t_cfg + II_k`` the
  device is closed (a NULL slice remains) and the next task starts on the
  next device.
* Input data of a split task is divided in the ratio ``tsd : shr_k - tsd``
  (Alg 3 lines 12-14; the paper splits T3's 24 GB 1:1 for a 12:12 share
  split — proportional to share, not to data-generating time).

The pseudocode's success test ``sti == n_t and tsd == 0`` is off by one for
1-based loops; we use the intended condition: every task fully placed
within ``n_f`` devices.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .task import FleetSpec, Task, TaskSetCombo

__all__ = [
    "Segment",
    "DeviceScript",
    "PlacementPlan",
    "place_combo",
    "place_shares",
]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Segment:
    """One contiguous occupancy of a device within the time slice.

    ``kind`` is one of ``cfg`` (reconfiguration), ``init`` (re-paid II of a
    carried split task), ``run`` (share execution; for fresh placements the
    leading II_k is inside ``run``, matching the paper's accounting), or
    ``null`` (NULL slice, Fig 2).
    """

    kind: str
    task: int  # task index, -1 for null
    start: float
    end: float

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class DeviceScript:
    """Per-device placement script (Alg 3's ``fpga_script_j``)."""

    device: int
    segments: list[Segment] = dataclasses.field(default_factory=list)

    @property
    def used(self) -> float:
        return sum(s.dur for s in self.segments if s.kind != "null")

    def null_time(self, t_slr: float) -> float:
        return t_slr - self.used


@dataclasses.dataclass
class DataSplit:
    """How a split task's input data divides across devices (Alg 3 l.12-14)."""

    task: int
    devices: tuple[int, ...]
    share_parts: tuple[float, ...]

    @property
    def ratio(self) -> tuple[float, ...]:
        tot = sum(self.share_parts)
        return tuple(p / tot for p in self.share_parts)


@dataclasses.dataclass
class PlacementPlan:
    """Result of placing one combo on the fleet."""

    feasible: bool
    scripts: list[DeviceScript]
    splits: list[DataSplit]
    unplaced: list[int]  # task indices that did not fit
    executed_share: list[float]  # per task, total share actually placed
    # Resilience mode (``place_shares(..., resilience=k)``): the backup
    # placement on the worst-case survivor fleet that proves the combo
    # still meets deadlines after any k device failures.  ``feasible``
    # is then the combined primary-AND-backup verdict.
    backup: "PlacementPlan | None" = None

    @property
    def n_splits(self) -> int:
        return len(self.splits)

    def device_of(self, task: int) -> list[int]:
        out = []
        for s in self.scripts:
            if any(seg.task == task and seg.kind == "run" for seg in s.segments):
                out.append(s.device)
        return out


def place_shares(
    shares: Sequence[float],
    init_intervals: Sequence[float],
    fleet: FleetSpec,
    *,
    # Baseline knob (refs [9]/[10] comparison, §IV-C): preemptive context
    # switching pays capture+store of the running bitstream instead of a
    # fresh II on resume.  PADPS-FR uses the defaults (0, fresh II).
    t_capture: float = 0.0,
    t_store: float = 0.0,
    repay_init: bool = True,
    resilience: int = 0,
) -> PlacementPlan:
    """Simulate the DP-wrap style placement of per-task shares on the fleet.

    Tasks are walked in order (the combo's task order is the paper's task
    order); each device ``j`` is filled from its capacity ``t_slr_j`` and
    charges its own ``t_cfg_j`` (heterogeneous fleets mix FPGA/GPU/CPU
    profiles; the homogeneous case reduces to the paper's Alg 3 exactly);
    splitting carries the remainder of the current task to device ``j+1``.

    ``resilience=k`` additionally requires a *backup* placement: the same
    shares must place on ``fleet.survivors(k)`` — the worst-case fleet
    left by any k device failures — and ``feasible`` becomes the combined
    primary-AND-backup verdict (the backup plan is attached as
    ``plan.backup``).  ``k >= n_f`` can never be survived, so the plan is
    infeasible outright (unless there are no tasks to place).

    This is the *scalar reference oracle* — the vectorised block engine in
    :mod:`repro.core.placement_batched` must agree with it bit-for-bit.
    """
    n_t = len(shares)
    assert len(init_intervals) == n_t

    scripts = [DeviceScript(device=j) for j in range(fleet.n_f)]
    splits: dict[int, list[tuple[int, float]]] = {}
    executed = [0.0] * n_t

    k = 0  # current task index (paper's sti)
    tsd = 0.0  # share of task k already executed on previous devices
    for j in range(fleet.n_f):
        if k >= n_t:
            break
        t_slr = fleet.t_slr_of(j)
        t_cfg = fleet.t_cfg_of(j)
        c = t_slr
        t = 0.0  # wall position within this device's slice
        script = scripts[j]
        while k < n_t:
            ii = init_intervals[k]
            rem = shares[k] - tsd  # remaining share of task k
            carried = tsd > _EPS
            # Entry cost: fresh config always; carried tasks re-pay II
            # (PADPS-FR) or capture+store of the preempted bitstream
            # (refs [9]/[10] model).
            extra = 0.0
            if carried:
                extra = ii if repay_init else (t_capture + t_store)
            # Start condition (strict): the device must have time to
            # configure + warm up and still produce data.
            if not (c > t_cfg + ii + _EPS):
                break  # task k must start on the next device
            avail = c - t_cfg - extra  # time available for the share
            if avail <= _EPS:
                break
            script.segments.append(Segment("cfg", k, t, t + t_cfg))
            t += t_cfg
            if carried and extra > 0:
                script.segments.append(Segment("init", k, t, t + extra))
                t += extra
            if rem - avail > _EPS:
                # Split: run `avail` worth of share here, carry the rest.
                script.segments.append(Segment("run", k, t, t + avail))
                t += avail
                executed[k] += avail
                splits.setdefault(k, []).append((j, avail))
                tsd += avail
                c = 0.0
                break  # device exhausted; same task continues on j+1
            # Task k fits fully here.
            script.segments.append(Segment("run", k, t, t + rem))
            t += rem
            executed[k] += rem
            if carried:
                splits.setdefault(k, []).append((j, rem))
            c = c - t_cfg - extra - rem
            k += 1
            tsd = 0.0
            # Closure: leftover too small for any further configuration
            # (paper tests against t_cfg + II of the just-placed task).
            if c <= t_cfg + ii + _EPS:
                break
        if t < t_slr - _EPS:
            script.segments.append(Segment("null", -1, t, t_slr))

    feasible = k >= n_t and tsd <= _EPS
    plan_splits = [
        DataSplit(
            task=ti,
            devices=tuple(d for d, _ in parts),
            share_parts=tuple(p for _, p in parts),
        )
        for ti, parts in sorted(splits.items())
    ]
    unplaced = list(range(k, n_t)) if not feasible else []
    if not feasible and tsd > _EPS and k < n_t and k not in unplaced:
        unplaced.insert(0, k)
    plan = PlacementPlan(
        feasible=feasible,
        scripts=scripts,
        splits=plan_splits,
        unplaced=unplaced,
        executed_share=executed,
    )
    if resilience and n_t:
        if resilience >= fleet.n_f:
            plan.feasible = False
        elif plan.feasible:
            plan.backup = place_shares(
                shares,
                init_intervals,
                fleet.survivors(resilience),
                t_capture=t_capture,
                t_store=t_store,
                repay_init=repay_init,
            )
            plan.feasible = plan.backup.feasible
    return plan


def place_combo(
    combo: TaskSetCombo,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    **kw,
) -> PlacementPlan:
    """Place one TSS row (Alg 3 entry point)."""
    iis = [t.init_interval for t in tasks]
    return place_shares(combo.shares, iis, fleet, **kw)
