"""Baseline schedulers the paper compares against (§I, §IV-C, Table III).

* ``preemptive_dpfair`` — the scheduler model of refs [9]/[10]: same
  DP-fair/DP-wrap placement, but a *preempted* (split) task resumes by
  capturing + storing + re-writing its bitstream context instead of paying a
  fresh II.  The papers *ignored* the capture/store cost; with it charged
  honestly (``t_capture + t_store`` per preemption, ~150 ms for an
  Alveo-class xclbin per §IV-C) fewer task sets fit → higher TRR (Fig 8).
* ``edf`` / ``llf`` — greedy Earliest-Deadline-First / Least-Laxity-First
  per-slice assignment, shown by ref. [4] to be non-optimal on parallel
  fleets; they also do not bound context switches.
* ``erfair`` — quantum-level proportional-progress scheduling (ref. [7]);
  optimal on CPUs but each quantum boundary is a potential migration, i.e.
  an uncontrolled number of reconfigurations on FPGA/TPU fleets.  We count
  them to reproduce the paper's cost argument.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from .feasibility import search_feasible
from .placement_batched import place_batch
from .scheduler import ScheduleResult, _select_from_feasibility
from .task import FleetSpec, Task

__all__ = [
    "preemptive_dpfair_schedule",
    "GreedyResult",
    "edf_schedule",
    "llf_schedule",
    "erfair_context_switches",
]


def preemptive_dpfair_schedule(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    t_capture: float,
    t_store: float,
    count_all_rejects: bool = False,
) -> ScheduleResult:
    """Refs [9]/[10] with honest context capture/store accounting.

    Identical search to PADPS-FR but split tasks pay
    ``t_capture + t_store`` on resume instead of a fresh ``II`` —
    and keep their partial context (no data re-split).
    """
    tasks = tuple(tasks)
    feas = search_feasible(tasks, fleet)
    combo, plan, rank, rejects = _select_from_feasibility(
        feas,
        tasks,
        fleet,
        count_all_rejects=count_all_rejects,
        t_capture=t_capture,
        t_store=t_store,
        repay_init=False,
    )
    return ScheduleResult(
        feasible=combo is not None,
        combo=combo,
        plan=plan,
        chosen_rank=rank,
        n_tss=feas.n_combos,
        n_tfs=feas.n_tfs,
        n_tnfs=feas.n_tnfs,
        n_placement_rejects=rejects,
        total_power=combo.total_power if combo else float("inf"),
    )


def count_placeable(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    **placement_kw,
) -> tuple[int, int, int]:
    """(n_tss, n_eq7_accepted, n_placeable) under the given placement model.

    The Fig 8 comparison: ``n_placeable`` with fresh-II re-pay (ours) vs
    with capture/store overhead (refs [9]/[10]).  The whole TFS goes
    through the batched placement engine in one sweep."""
    tasks = tuple(tasks)
    feas = search_feasible(tasks, fleet)
    tfs = np.flatnonzero(feas.fit_mask)
    if tfs.size == 0:
        return feas.n_combos, 0, 0
    bp = place_batch(
        feas.shares_matrix(tfs),
        [t.init_interval for t in tasks],
        fleet,
        **placement_kw,
    )
    return feas.n_combos, feas.n_tfs, bp.n_feasible


@dataclasses.dataclass
class GreedyResult:
    feasible: bool
    assignment: list[list[int]]  # per device, task indices in run order
    finish_times: list[float]  # per task
    missed: list[int]  # tasks missing their period
    n_context_switches: int
    total_power: float


def _greedy_assign(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    priority: str,
) -> GreedyResult:
    """Greedy list scheduling: at each step the highest-priority pending task
    goes to the earliest-available device.  Priorities: EDF (earliest
    period/deadline) or LLF (least laxity = deadline - exec time).

    Every task uses its *fastest* variant (greedy schedulers in the cited
    literature are power-oblivious).  Context switches = number of
    placements (each placement is one reconfiguration).
    """
    n_t = len(tasks)
    # fastest variant = max throughput = min exec time
    exec_t = np.array([t.exec_times().min() for t in tasks])
    power = np.array(
        [t.variants[int(np.argmin(t.exec_times()))].power for t in tasks]
    )
    deadline = np.array([t.period for t in tasks])
    if priority == "edf":
        key = deadline
    elif priority == "llf":
        key = deadline - exec_t
    else:  # pragma: no cover
        raise ValueError(priority)
    order = np.lexsort((np.arange(n_t), key))

    # device heap: (available_time, device)
    heap = [(0.0, j) for j in range(fleet.n_f)]
    heapq.heapify(heap)
    assignment: list[list[int]] = [[] for _ in range(fleet.n_f)]
    finish = [0.0] * n_t
    switches = 0
    for k in order:
        k = int(k)
        avail, j = heapq.heappop(heap)
        start = avail + fleet.t_cfg_of(j) + tasks[k].init_interval
        # Heterogeneous capacity derating: a device with t_slr_j below the
        # reference slice does the same work proportionally slower.
        end = start + exec_t[k] * (fleet.t_slr / fleet.t_slr_of(j))
        assignment[j].append(k)
        finish[k] = end
        switches += 1
        heapq.heappush(heap, (end, j))
    missed = [k for k in range(n_t) if finish[k] > deadline[k] + 1e-9]
    return GreedyResult(
        feasible=not missed,
        assignment=assignment,
        finish_times=finish,
        missed=missed,
        n_context_switches=switches,
        total_power=float(power.sum()),
    )


def edf_schedule(tasks: Sequence[Task], fleet: FleetSpec) -> GreedyResult:
    return _greedy_assign(tasks, fleet, "edf")


def llf_schedule(tasks: Sequence[Task], fleet: FleetSpec) -> GreedyResult:
    return _greedy_assign(tasks, fleet, "llf")


def erfair_context_switches(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    quantum: float,
) -> int:
    """Count the reconfigurations ER-fair (ref. [7]) would incur.

    ER-fair enforces proportional progress every quantum: each task must
    have completed >= w_i * t by slot t.  On a reconfigurable fleet every
    quantum in which a device switches tasks costs a full reconfiguration.
    We simulate the canonical ER-fair allocation over one hyper-slice and
    count switches — the paper's argument is that this number is
    uncontrolled (grows with t_slr / quantum), vs <= n_t + n_f - 1 splits
    for DP-wrap.
    """
    n_t = len(tasks)
    weights = np.array(
        [t.shares(fleet.t_slr)[0] / fleet.t_slr for t in tasks]
    )  # 1-CU weights
    done = np.zeros(n_t)
    running = [-1] * fleet.n_f  # task on each device
    switches = 0
    steps = int(round(fleet.t_slr / quantum))
    for step in range(1, steps + 1):
        t_now = step * quantum
        lag = weights * t_now - done  # ER-fair lag
        order = np.argsort(-lag)
        chosen = [int(k) for k in order[: fleet.n_f] if lag[int(k)] > 1e-12]
        for slot, k in enumerate(chosen):
            if running[slot] != k:
                switches += 1
                running[slot] = k
            done[k] += quantum
        for slot in range(len(chosen), fleet.n_f):
            running[slot] = -1
    return switches
