# The paper's primary contribution: PADPS-FR — power-aware DP-fair/DP-wrap
# scheduling of periodic hardware tasks on accelerator fleets (Algs 1-3),
# plus the baselines and metrics it is evaluated against.

from .task import DeviceProfile, FleetSpec, Task, TaskSetCombo, TaskVariant, combo_count
from .feasibility import (
    BlockEnumerator,
    ComboBlock,
    FeasibilityResult,
    config_overhead_lower_bound,
    iter_feasible_pruned,
    iter_feasible_pruned_blocks,
    outer_sum,
    search_feasible,
)
from .placement import DataSplit, DeviceScript, PlacementPlan, Segment, place_combo, place_shares
from .placement_backends import (
    InstanceBatch,
    PlacementBackend,
    PlacementOptions,
    available_backends,
    backend_names,
    get_backend,
    register_backend,
    resolve_engine,
)
from .placement_batched import BatchPlacement, place_batch, place_combos_batch
from .replan import PlanState
from .scheduler import (
    PADPSFRScheduler,
    ScheduleInstance,
    ScheduleResult,
    WalkStats,
    block_ramp,
    select_lowest_power,
    select_lowest_power_batched,
)
from .metrics import SweepPoint, avg_task_weight, sweep_fleet, system_workload, trr
from .baselines import (
    GreedyResult,
    count_placeable,
    edf_schedule,
    erfair_context_switches,
    llf_schedule,
    preemptive_dpfair_schedule,
)
from .gantt import plan_rows, render_gantt

__all__ = [
    "DeviceProfile",
    "FleetSpec",
    "Task",
    "TaskSetCombo",
    "TaskVariant",
    "combo_count",
    "BlockEnumerator",
    "ComboBlock",
    "FeasibilityResult",
    "config_overhead_lower_bound",
    "iter_feasible_pruned",
    "iter_feasible_pruned_blocks",
    "outer_sum",
    "search_feasible",
    "DataSplit",
    "DeviceScript",
    "PlacementPlan",
    "Segment",
    "place_combo",
    "place_shares",
    "BatchPlacement",
    "InstanceBatch",
    "PlacementBackend",
    "PlacementOptions",
    "available_backends",
    "backend_names",
    "get_backend",
    "register_backend",
    "resolve_engine",
    "place_batch",
    "place_combos_batch",
    "PlanState",
    "PADPSFRScheduler",
    "ScheduleInstance",
    "ScheduleResult",
    "WalkStats",
    "block_ramp",
    "select_lowest_power",
    "select_lowest_power_batched",
    "SweepPoint",
    "avg_task_weight",
    "sweep_fleet",
    "system_workload",
    "trr",
    "GreedyResult",
    "count_placeable",
    "edf_schedule",
    "erfair_context_switches",
    "llf_schedule",
    "preemptive_dpfair_schedule",
    "plan_rows",
    "render_gantt",
]
