"""TPU power model + analytic roofline throughput for task variants.

The paper characterises each task variant by measured (throughput,
power) on synthesized bitstreams (Tables I/II).  On the TPU fleet we
derive both from a calibrated analytic model over the same quantities
the roofline deliverable uses — FLOPs, HBM bytes and collective bytes
per step:

    t_step  = max(compute term, memory term, collective term)
    power   = n_chips * (idle + e_flop * flops/s + e_hbm * B/s + e_ici * B/s)

Hardware constants are TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI); energy coefficients are calibrated so a fully
compute-bound chip draws ~200 W and an idle chip ~75 W (documented
assumption — the scheduler is agnostic to where the (th, pw) tables
come from, and the paper's own tables ship as configs).
"""

from __future__ import annotations

import dataclasses

__all__ = ["TPUSpec", "V5E", "PowerModel", "step_time_roofline"]


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float  # FLOP/s bf16 per chip
    hbm_bw: float  # B/s per chip
    ici_bw: float  # B/s per link
    hbm_bytes: float  # HBM capacity per chip


V5E = TPUSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
)


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Energy model: P(chip) = idle + e_flop*F/s + e_hbm*B/s + e_ici*B/s."""

    idle_w: float = 75.0
    e_flop: float = 0.51e-12  # J/FLOP  -> ~100 W at 197 TFLOP/s
    e_hbm: float = 30e-12  # J/B     -> ~25 W at 819 GB/s
    e_ici: float = 10e-12  # J/B

    def chip_power(
        self, flops_per_s: float, hbm_Bps: float, ici_Bps: float
    ) -> float:
        return (
            self.idle_w
            + self.e_flop * flops_per_s
            + self.e_hbm * hbm_Bps
            + self.e_ici * ici_Bps
        )

    def job_power(
        self,
        n_chips: int,
        step_time_s: float,
        flops: float,
        hbm_bytes: float,
        ici_bytes: float,
    ) -> float:
        """Total W while the job runs (per-chip quantities / step)."""
        if step_time_s <= 0:
            return n_chips * self.idle_w
        per_chip = self.chip_power(
            flops / n_chips / step_time_s,
            hbm_bytes / n_chips / step_time_s,
            ici_bytes / n_chips / step_time_s,
        )
        return n_chips * per_chip


def step_time_roofline(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    n_chips: int,
    spec: TPUSpec = V5E,
    *,
    links_per_chip: int = 4,
) -> tuple[float, dict[str, float]]:
    """Roofline step time = max of the three terms (seconds) + the terms."""
    compute = flops / (n_chips * spec.peak_flops)
    memory = hbm_bytes / (n_chips * spec.hbm_bw)
    collective = coll_bytes / (n_chips * links_per_chip * spec.ici_bw)
    terms = {"compute": compute, "memory": memory, "collective": collective}
    return max(terms.values()), terms
