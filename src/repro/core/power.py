"""TPU power model + analytic roofline throughput for task variants.

The paper characterises each task variant by measured (throughput,
power) on synthesized bitstreams (Tables I/II).  On the TPU fleet we
derive both from a calibrated analytic model over the same quantities
the roofline deliverable uses — FLOPs, HBM bytes and collective bytes
per step:

    t_step  = max(compute term, memory term, collective term)
    power   = n_chips * (idle + e_flop * flops/s + e_hbm * B/s + e_ici * B/s)

Hardware constants are TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI); energy coefficients are calibrated so a fully
compute-bound chip draws ~200 W and an idle chip ~75 W (documented
assumption — the scheduler is agnostic to where the (th, pw) tables
come from, and the paper's own tables ship as configs).
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "TPUSpec",
    "V5E",
    "PowerModel",
    "step_time_roofline",
    "DeviceClass",
    "DEVICE_CLASSES",
    "FPGA_CLASS",
    "GPU_CLASS",
    "CPU_CLASS",
    "TPU_CLASS",
]


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float  # FLOP/s bf16 per chip
    hbm_bw: float  # B/s per chip
    ici_bw: float  # B/s per link
    hbm_bytes: float  # HBM capacity per chip


V5E = TPUSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
)


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """A fleet device class for heterogeneous scheduling (arXiv:2304.04488).

    ``t_cfg_frac`` is the class's program-switch cost as a *fraction of
    the fleet's reference slice* ``t_slr`` — unit-free, so the same class
    table works for the paper's millisecond fleets and second-scale TPU
    fleets alike.  FPGAs pay a full or partial bitstream
    (re)configuration (the paper's Example 1 charges 6/60 = 0.1 of the
    slice; Example 3's Alveo fleet 21/600 = 0.035), GPUs/CPUs only a
    kernel/process launch (~0), TPU slices an executable load + weight
    resharding (45 s against a 3600 s slice = 0.0125).
    ``capacity_scale`` derates the device's effective slice capacity
    relative to the fleet's reference ``t_slr`` (the "effective capacity"
    axis of arXiv:1908.06519 — a CPU does the same share's work slower).
    ``idle_w`` feeds fleet-level idle-power accounting.
    """

    name: str
    t_cfg_frac: float
    capacity_scale: float = 1.0
    idle_w: float = 75.0

    def __post_init__(self) -> None:
        if self.t_cfg_frac < 0:
            raise ValueError("t_cfg_frac must be >= 0")
        if not (0 < self.capacity_scale <= 1.0):
            raise ValueError("capacity_scale must be in (0, 1]")


FPGA_CLASS = DeviceClass(name="fpga", t_cfg_frac=0.1, capacity_scale=1.0, idle_w=40.0)
GPU_CLASS = DeviceClass(name="gpu", t_cfg_frac=0.001, capacity_scale=0.9, idle_w=90.0)
CPU_CLASS = DeviceClass(name="cpu", t_cfg_frac=0.0, capacity_scale=0.35, idle_w=60.0)
TPU_CLASS = DeviceClass(name="tpu", t_cfg_frac=0.0125, capacity_scale=1.0, idle_w=75.0)

DEVICE_CLASSES = {c.name: c for c in (FPGA_CLASS, GPU_CLASS, CPU_CLASS, TPU_CLASS)}


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Energy model: P(chip) = idle + e_flop*F/s + e_hbm*B/s + e_ici*B/s."""

    idle_w: float = 75.0
    e_flop: float = 0.51e-12  # J/FLOP  -> ~100 W at 197 TFLOP/s
    e_hbm: float = 30e-12  # J/B     -> ~25 W at 819 GB/s
    e_ici: float = 10e-12  # J/B

    def chip_power(
        self, flops_per_s: float, hbm_Bps: float, ici_Bps: float
    ) -> float:
        return (
            self.idle_w
            + self.e_flop * flops_per_s
            + self.e_hbm * hbm_Bps
            + self.e_ici * ici_Bps
        )

    def job_power(
        self,
        n_chips: int,
        step_time_s: float,
        flops: float,
        hbm_bytes: float,
        ici_bytes: float,
    ) -> float:
        """Total W while the job runs (per-chip quantities / step)."""
        if step_time_s <= 0:
            return n_chips * self.idle_w
        per_chip = self.chip_power(
            flops / n_chips / step_time_s,
            hbm_bytes / n_chips / step_time_s,
            ici_bytes / n_chips / step_time_s,
        )
        return n_chips * per_chip


def step_time_roofline(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    n_chips: int,
    spec: TPUSpec = V5E,
    *,
    links_per_chip: int = 4,
) -> tuple[float, dict[str, float]]:
    """Roofline step time = max of the three terms (seconds) + the terms."""
    compute = flops / (n_chips * spec.peak_flops)
    memory = hbm_bytes / (n_chips * spec.hbm_bw)
    collective = coll_bytes / (n_chips * links_per_chip * spec.ici_bw)
    terms = {"compute": compute, "memory": memory, "collective": collective}
    return max(terms.values()), terms
