"""Algorithm 1 — Searching of Feasible Task Sets (paper §III-A1).

Builds the TSS (all ``prod(nv_i)`` variant combinations), applies the
workability condition (eq. 7)

    sum_shr  <=  n_f * t_slr - n_t * t_cfg

and partitions TSS into TFS (fit) / TNFS (not fit).

Two engines are provided:

* ``search_feasible`` — the paper's exhaustive enumeration, vectorised:
  the sum-of-shares over the Cartesian product is an outer-sum computed
  by numpy broadcasting, ~1000x faster than the paper's nested loops for
  large products (beyond-paper optimisation; measured in
  ``benchmarks/scheduler_scale.py``).
* ``iter_feasible_pruned`` — branch-and-bound enumeration in ascending
  power order that never materialises TSS; used when ``prod(nv_i)`` is
  too large to hold (the paper's algorithm is O(prod nv_i) memory).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator, Sequence

import numpy as np

from .task import FleetSpec, Task, TaskSetCombo, combo_count, validate_tasks

__all__ = [
    "FeasibilityResult",
    "search_feasible",
    "iter_feasible_pruned",
    "outer_sum",
]


@dataclasses.dataclass
class FeasibilityResult:
    """TFS/TNFS split plus the arrays needed downstream (Alg 2)."""

    tasks: tuple[Task, ...]
    fleet: FleetSpec
    n_combos: int  # |TSS|
    # Arrays over the full TSS, flattened in C order of variant indices.
    sum_shr: np.ndarray  # (n_combos,)
    total_power: np.ndarray  # (n_combos,)
    fit_mask: np.ndarray  # (n_combos,) bool — eq. 7
    budget: float  # RHS of eq. 7

    @property
    def n_tfs(self) -> int:
        return int(self.fit_mask.sum())

    @property
    def n_tnfs(self) -> int:
        return self.n_combos - self.n_tfs

    def combo_at(self, flat_index: int) -> TaskSetCombo:
        """Materialise one TSS row from its flat index."""
        nvs = [t.nv for t in self.tasks]
        idx = np.unravel_index(flat_index, nvs)
        shares = tuple(
            float(t.shares(self.fleet.t_slr)[j]) for t, j in zip(self.tasks, idx)
        )
        powers = tuple(float(t.variants[j].power) for t, j in zip(self.tasks, idx))
        return TaskSetCombo(tuple(int(j) for j in idx), shares, powers)

    def tfs_indices_by_power(self) -> np.ndarray:
        """Flat indices of TFS rows, ascending total power (Alg 2 line 1).

        Ties are broken by ascending sum-of-shares then flat index so the
        ordering is deterministic.
        """
        tfs = np.flatnonzero(self.fit_mask)
        # Stable sort: ties broken by TSS enumeration (flat-index) order,
        # matching the paper's "Assc. Sort on TFS" over the generated list.
        order = np.argsort(self.total_power[tfs], kind="stable")
        return tfs[order]

    def iter_tfs_by_power(self) -> Iterator[TaskSetCombo]:
        for i in self.tfs_indices_by_power():
            yield self.combo_at(int(i))


def outer_sum(vectors: Sequence[np.ndarray]) -> np.ndarray:
    """Sum over the Cartesian product of 1-D vectors, returned flat (C order).

    outer_sum([a, b, c])[i*len(b)*len(c) + j*len(c) + k] == a[i]+b[j]+c[k]
    """
    acc = np.zeros((1,), dtype=np.float64)
    for v in vectors:
        acc = (acc[:, None] + np.asarray(v, dtype=np.float64)[None, :]).reshape(-1)
    return acc


def search_feasible(tasks: Sequence[Task], fleet: FleetSpec) -> FeasibilityResult:
    """Algorithm 1, vectorised. Materialises |TSS| f64 arrays (twice).

    Safe up to ~10^8 combinations on a 32 GB host; beyond that use
    ``iter_feasible_pruned``.
    """
    tasks = tuple(tasks)
    validate_tasks(tasks)
    n_t = len(tasks)
    n_combos = combo_count(tasks)
    if n_combos > 200_000_000:
        raise ValueError(
            f"|TSS|={n_combos:,} too large to materialise; "
            "use iter_feasible_pruned()"
        )
    share_vecs = [t.shares(fleet.t_slr) for t in tasks]
    power_vecs = [t.powers() for t in tasks]
    sum_shr = outer_sum(share_vecs)
    total_power = outer_sum(power_vecs)
    budget = fleet.workable_budget(n_t)
    fit = sum_shr <= budget + 1e-9  # eq. 7 (tolerant <=)
    return FeasibilityResult(
        tasks=tasks,
        fleet=fleet,
        n_combos=n_combos,
        sum_shr=sum_shr,
        total_power=total_power,
        fit_mask=fit,
        budget=budget,
    )


def iter_feasible_pruned(
    tasks: Sequence[Task], fleet: FleetSpec
) -> Iterator[TaskSetCombo]:
    """Yield TFS combos in ascending total-power order WITHOUT building TSS.

    Best-first search over the variant lattice: each frontier node fixes the
    variant of a prefix of tasks; its priority is its exact prefix power plus
    the minimum achievable power of the suffix.  A node is pruned when its
    prefix share plus the minimum achievable suffix share already violates
    eq. 7 — the branch-and-bound step.  Memory is O(frontier), not O(|TSS|).

    This is the engine behind fleet-scale scheduling (hundreds of jobs x
    dozens of variants) where the paper's exhaustive TSS is intractable.
    """
    tasks = tuple(tasks)
    validate_tasks(tasks)
    n_t = len(tasks)
    budget = fleet.workable_budget(n_t)

    shares = [t.shares(fleet.t_slr) for t in tasks]
    powers = [t.powers() for t in tasks]
    # Per-task variant order by power (for monotone sibling expansion) and
    # suffix minima for bounds.
    order = [np.argsort(p, kind="stable") for p in powers]
    min_pow = np.array([p.min() for p in powers])
    min_shr = np.array([s.min() for s in shares])
    suf_min_pow = np.concatenate([np.cumsum(min_pow[::-1])[::-1], [0.0]])
    suf_min_shr = np.concatenate([np.cumsum(min_shr[::-1])[::-1], [0.0]])

    # Node: (priority, tiebreak, depth, chosen tuple, prefix_pow, prefix_shr,
    #        rank) where rank is the index into order[depth] *to try next*.
    heap: list = []
    counter = 0

    def push(depth: int, chosen: tuple[int, ...], ppow: float, pshr: float) -> None:
        nonlocal counter
        if pshr + suf_min_shr[depth] > budget + 1e-9:
            return  # bound: no completion can satisfy eq. 7
        prio = ppow + suf_min_pow[depth]
        heapq.heappush(heap, (prio, counter, depth, chosen, ppow, pshr))
        counter += 1

    push(0, (), 0.0, 0.0)
    while heap:
        _, _, depth, chosen, ppow, pshr = heapq.heappop(heap)
        if depth == n_t:
            shr = tuple(float(shares[k][j]) for k, j in enumerate(chosen))
            pw = tuple(float(powers[k][j]) for k, j in enumerate(chosen))
            yield TaskSetCombo(chosen, shr, pw)
            continue
        for rank in range(tasks[depth].nv):
            j = int(order[depth][rank])
            push(
                depth + 1,
                chosen + (j,),
                ppow + float(powers[depth][j]),
                pshr + float(shares[depth][j]),
            )
