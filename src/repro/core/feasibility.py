"""Algorithm 1 — Searching of Feasible Task Sets (paper §III-A1).

Builds the TSS (all ``prod(nv_i)`` variant combinations), applies the
workability condition (eq. 7)

    sum_shr  <=  n_f * t_slr - n_t * t_cfg

and partitions TSS into TFS (fit) / TNFS (not fit).

Three engines are provided:

* ``search_feasible`` — the paper's exhaustive enumeration, vectorised:
  the sum-of-shares over the Cartesian product is an outer-sum computed
  by numpy broadcasting, ~1000x faster than the paper's nested loops for
  large products (beyond-paper optimisation; measured in
  ``benchmarks/scheduler_scale.py``).
* ``iter_feasible_pruned`` — branch-and-bound enumeration in ascending
  power order that never materialises TSS; used when ``prod(nv_i)`` is
  too large to hold (the paper's algorithm is O(prod nv_i) memory).
* ``iter_feasible_pruned_blocks`` — the same search, block-native: the
  frontier lives in numpy arrays and whole power-ordered
  :class:`ComboBlock` batches come out at once, ready for a placement
  backend's ``place_block`` — no per-row heap pushes or
  :class:`TaskSetCombo` objects on the hot path.

All three engines emit the TFS in the *same* total order — ascending
total power, exact-power ties broken by TSS flat (C-order) index — so
the scheduler's chosen rank and reject counts are engine-independent
even when distinct combos share a power value.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Iterable, Iterator, Sequence

import numpy as np

from .task import FleetSpec, Task, TaskSetCombo, combo_count, validate_tasks

__all__ = [
    "FeasibilityResult",
    "ComboBlock",
    "BlockEnumerator",
    "search_feasible",
    "iter_feasible_pruned",
    "iter_feasible_pruned_blocks",
    "outer_sum",
    "config_overhead_lower_bound",
]


@dataclasses.dataclass
class FeasibilityResult:
    """TFS/TNFS split plus the arrays needed downstream (Alg 2)."""

    tasks: tuple[Task, ...]
    fleet: FleetSpec
    n_combos: int  # |TSS|
    # Arrays over the full TSS, flattened in C order of variant indices.
    sum_shr: np.ndarray  # (n_combos,)
    total_power: np.ndarray  # (n_combos,)
    fit_mask: np.ndarray  # (n_combos,) bool — eq. 7
    budget: float  # RHS of eq. 7

    @property
    def n_tfs(self) -> int:
        return int(self.fit_mask.sum())

    @property
    def n_tnfs(self) -> int:
        return self.n_combos - self.n_tfs

    def combo_at(self, flat_index: int) -> TaskSetCombo:
        """Materialise one TSS row from its flat index."""
        nvs = [t.nv for t in self.tasks]
        idx = np.unravel_index(flat_index, nvs)
        shares = tuple(
            float(t.shares(self.fleet.t_slr)[j]) for t, j in zip(self.tasks, idx, strict=True)
        )
        powers = tuple(float(t.variants[j].power) for t, j in zip(self.tasks, idx, strict=True))
        return TaskSetCombo(tuple(int(j) for j in idx), shares, powers)

    def _share_columns(self) -> "tuple[list[np.ndarray], list[int]]":
        """Per-task eq-5 share vectors (and nv list), computed once.

        :meth:`shares_matrix` runs once per dispatched block on the
        scheduler's hot path — recomputing ``t.shares`` (a fresh
        exec-times array per call) for every gather dominated deep
        walks, and dominated the whole batched ``schedule_many`` floor.
        """
        cached = getattr(self, "_share_cols", None)
        if cached is None:
            cached = (
                [t.shares(self.fleet.t_slr) for t in self.tasks],
                [t.nv for t in self.tasks],
            )
            self._share_cols = cached
        return cached

    def shares_matrix(self, flat_indices: np.ndarray) -> np.ndarray:
        """Materialise a block of TSS rows as a ``(B, n_t)`` shares matrix.

        The vectorised counterpart of :meth:`combo_at` — one fancy-indexed
        gather per task instead of B Python round-trips; this is what feeds
        the batched placement engine
        (:func:`repro.core.placement_batched.place_batch`).
        """
        flat_indices = np.asarray(flat_indices, dtype=np.int64)
        cols, nvs = self._share_columns()
        idx = np.unravel_index(flat_indices, nvs)
        out = np.empty((flat_indices.size, len(cols)), dtype=np.float64)
        for i, (col, ji) in enumerate(zip(cols, idx, strict=True)):
            np.take(col, ji, out=out[:, i])
        return out

    def tfs_indices_by_power(self) -> np.ndarray:
        """Flat indices of TFS rows, ascending total power (Alg 2 line 1).

        Exact-power ties are broken by ascending flat (C-order TSS) index
        — the stable sort below — so the ordering is deterministic and
        matches the streamed engines (``iter_feasible_pruned*``) exactly.
        """
        tfs = np.flatnonzero(self.fit_mask)
        # Stable sort: ties broken by TSS enumeration (flat-index) order,
        # matching the paper's "Assc. Sort on TFS" over the generated list.
        order = np.argsort(self.total_power[tfs], kind="stable")
        return tfs[order]

    def iter_tfs_by_power(self) -> Iterator[TaskSetCombo]:
        for i in self.tfs_indices_by_power():
            yield self.combo_at(int(i))


def outer_sum(vectors: Sequence[np.ndarray]) -> np.ndarray:
    """Sum over the Cartesian product of 1-D vectors, returned flat (C order).

    outer_sum([a, b, c])[i*len(b)*len(c) + j*len(c) + k] == a[i]+b[j]+c[k]

    The result buffer is allocated once at its final ``prod(len(v))`` size
    and each level accumulates in place through a strided view, so peak
    memory is one f64 output array (the old broadcast-per-level fold held
    the previous level alive while materialising the next — up to 1.5x
    the output at the last level).  The accumulation order is the same
    left-to-right fold, so results are bit-identical.
    """
    sizes = [np.asarray(v).shape[0] for v in vectors]
    total = int(np.prod(sizes, dtype=np.int64)) if sizes else 1
    out = np.zeros(total, dtype=np.float64)
    if total == 0:
        return out  # a zero-length factor: the Cartesian product is empty
    stride = total
    for level, v in enumerate(vectors):
        v = np.asarray(v, dtype=np.float64)
        stride //= v.shape[0]
        view = out.reshape(-1, v.shape[0], stride)
        if level == 0:
            view[...] = v[None, :, None]
        else:
            view += v[None, :, None]
    return out


def config_overhead_lower_bound(
    fleet: FleetSpec, n_t: int, sum_shr: np.ndarray, extra_cfgs: int = 1
) -> np.ndarray:
    """Per-class refinement of the eq. 7 configuration charge, vectorised.

    For a heterogeneous fleet the paper's flat ``(n_t + 1) * t_cfg`` charge
    has no single ``t_cfg``.  The sound necessary-condition charge is a
    *lower bound* on the total reconfiguration time any placement of a
    combo with total share ``W = sum_shr`` must pay:

    * a combo needs at least ``d(W)`` devices, where ``d(W)`` is the
      smallest count of devices (taken largest-capacity-first) whose
      ``t_slr_j`` sum covers ``W`` — and every used device pays at least
      one of its own ``t_cfg_j`` (lower-bounded by the ``d(W)`` cheapest
      cfgs in the fleet);
    * there are at least ``max(n_t + extra_cfgs, d(W))`` configuration
      events in total; events beyond the per-device minimum pay at least
      the fleet-wide cheapest ``t_cfg``.

    On a homogeneous fleet with ``d(W) <= n_t + extra_cfgs`` this reduces
    exactly to the paper's ``(n_t + extra_cfgs) * t_cfg``.

    Soundness: with ``extra_cfgs=0`` every placement really pays at least
    this overhead (each task one cfg, each necessarily-used device one of
    its own cfgs), so rejection is a strict necessary condition.  The
    default ``extra_cfgs=1`` inherits the paper's one-split allowance —
    like eq. 7 itself it can reject a combo that happens to place with no
    split (the documented Example-1 deviation); it is the same charge the
    homogeneous pre-filter applies, refined per device class.
    """
    sum_shr = np.asarray(sum_shr, dtype=np.float64)
    m = n_t + extra_cfgs
    cap_desc = np.sort(fleet.t_slr_arr)[::-1]
    cfg_asc = np.sort(fleet.t_cfg_arr)
    cfg_min = float(cfg_asc[0]) if cfg_asc.size else 0.0
    # d(W): min devices whose (descending) capacities cover W.
    cum_cap = np.cumsum(cap_desc)
    d = np.searchsorted(cum_cap, sum_shr - 1e-9) + 1
    d = np.minimum(d, fleet.n_f)
    # Sum of the d cheapest per-device cfgs, one per necessarily-used device.
    cum_cfg = np.concatenate([[0.0], np.cumsum(cfg_asc)])
    per_device = cum_cfg[d]
    extra_events = np.maximum(m - d, 0)
    return per_device + extra_events * cfg_min


def search_feasible(
    tasks: Sequence[Task], fleet: FleetSpec, *, resilience: int = 0
) -> FeasibilityResult:
    """Algorithm 1, vectorised. Materialises |TSS| f64 arrays (twice).

    Safe up to ~10^8 combinations on a 32 GB host; beyond that use
    ``iter_feasible_pruned``.

    Heterogeneous fleets additionally apply the per-class configuration
    charge of :func:`config_overhead_lower_bound` (eq. 7 generalises to
    ``sum_shr <= sum_j t_slr_j - overhead_lb``); homogeneous fleets keep
    the paper's flat charge so the published Example-1/3 counts hold.

    ``resilience=k`` tightens eq. 7 to the *worst-case survivor fleet*
    (``fleet.survivors(k)``): a k-resilient verdict requires placement on
    the surviving ``n_f - k`` devices, so their smaller budget is the
    sound necessary condition — shares stay computed against the full
    fleet's reference ``t_slr`` (eq. 5 is a task property, not a fleet
    head-count property).  Raises ``ValueError`` when ``k >= n_f`` (the
    scheduler answers that case with an infeasible result up front).
    """
    tasks = tuple(tasks)
    validate_tasks(tasks)
    n_t = len(tasks)
    n_combos = combo_count(tasks)
    if n_combos > 200_000_000:
        raise ValueError(
            f"|TSS|={n_combos:,} too large to materialise; "
            "use iter_feasible_pruned()"
        )
    # n_t == 0 is vacuously resilient (nothing to place), so the empty
    # task set skips the survivor tightening even when k >= n_f.
    bfleet = fleet.survivors(resilience) if resilience and n_t else fleet
    share_vecs = [t.shares(fleet.t_slr) for t in tasks]
    power_vecs = [t.powers() for t in tasks]
    sum_shr = outer_sum(share_vecs)
    total_power = outer_sum(power_vecs)
    budget = bfleet.workable_budget(n_t)
    fit = sum_shr <= budget + 1e-9  # eq. 7 (tolerant <=)
    if bfleet.is_heterogeneous:
        overhead = config_overhead_lower_bound(bfleet, n_t, sum_shr)
        fit &= sum_shr <= bfleet.capacity - overhead + 1e-9
    return FeasibilityResult(
        tasks=tasks,
        fleet=fleet,
        n_combos=n_combos,
        sum_shr=sum_shr,
        total_power=total_power,
        fit_mask=fit,
        budget=budget,
    )


def _suffix_min_bounds(vecs: Sequence[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Suffix minima plus a strictly-admissible float underestimate.

    ``suf[d]`` is the minimum achievable sum over tasks ``d..n_t-1``
    (backward cumsum of per-task minima).  Prefix sums accumulate
    *forward*, so ``suf`` can exceed the true forward-folded completion
    sum by a few ulps of association error — enough to break best-first
    pop order or prune an on-the-boundary leaf.  ``lo`` subtracts a
    relative margin dwarfing any accumulated rounding, making
    ``prefix + lo[d]`` a certain lower bound on every completion; the
    margin is orders of magnitude below the 1e-9 eq-7 tolerance, so it
    admits no spurious rows.  ``lo[n_t] == 0.0`` exactly: leaf-depth
    checks and priorities stay bit-identical to the exhaustive engine's.
    """
    mins = np.asarray([v.min() for v in vecs], dtype=np.float64)
    suf = np.concatenate([np.cumsum(mins[::-1])[::-1], [0.0]])
    lo = suf - (np.abs(suf) + 1.0) * 1e-12
    lo[-1] = 0.0
    return suf, lo


def _suffix_max_bounds(vecs: Sequence[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Suffix maxima plus a certain float *over*estimate — the mirror of
    :func:`_suffix_min_bounds` for cover pruning.

    ``suf[d]`` is the maximum achievable sum over tasks ``d..n_t-1``;
    ``hi`` adds a relative margin dwarfing any fold-association error, so
    ``prefix + hi[d]`` certainly bounds every completion's forward-folded
    sum from above.  ``hi[n_t] == 0.0`` exactly (nothing left to add).
    Used by the delta replanner's removal-gap enumeration: a subtree
    whose *over*estimated completion still passes the old instance's
    eq. 7 is provably covered by the old recording and can be pruned.
    """
    maxs = np.asarray([v.max() for v in vecs], dtype=np.float64)
    suf = np.concatenate([np.cumsum(maxs[::-1])[::-1], [0.0]])
    hi = suf + (np.abs(suf) + 1.0) * 1e-12
    hi[-1] = 0.0
    return suf, hi


def _emission_order(pp: np.ndarray, ch: np.ndarray) -> np.ndarray:
    """Permutation sorting rows by the cold emission key.

    Same key as :func:`_sort_emission` — ``(total_power, flat TSS
    index)``, the flat index realised as a lexsort over the variant
    columns — but returned as an index permutation so callers can
    reorder side arrays (verdicts, provenance) along with the rows.
    """
    order = np.argsort(pp, kind="stable")
    pps = pp[order]
    eq = pps[1:] == pps[:-1]
    if eq.any():
        n_t = ch.shape[1]
        starts = np.flatnonzero(np.concatenate([[True], ~eq]))
        ends = np.append(starts[1:], pps.size)
        for a, b in zip(starts, ends, strict=True):
            if b - a > 1:
                sub = ch[order[a:b]]
                o = np.lexsort(tuple(sub[:, k] for k in range(n_t - 1, -1, -1)))
                order[a:b] = order[a:b][o]
    return order


def _scalar_overhead_lb(fleet: FleetSpec, n_t: int, extra_cfgs: int = 1):
    """Scalar-call twin of :func:`config_overhead_lower_bound`.

    Precomputes the capacity/cfg cumsums once and answers single-``W``
    queries with a bisect — bit-identical to the vectorised version (same
    float64 operations in the same order), cheap enough for the per-node
    pushes of the Python heap enumerator.
    """
    cap_desc = np.sort(fleet.t_slr_arr)[::-1]
    cfg_asc = np.sort(fleet.t_cfg_arr)
    cfg_min = float(cfg_asc[0]) if cfg_asc.size else 0.0
    cum_cap = np.cumsum(cap_desc).tolist()
    cum_cfg = np.concatenate([[0.0], np.cumsum(cfg_asc)]).tolist()
    m = n_t + extra_cfgs
    n_f = fleet.n_f

    def overhead(w: float) -> float:
        d = min(bisect.bisect_left(cum_cap, w - 1e-9) + 1, n_f)
        return cum_cfg[d] + max(m - d, 0) * cfg_min

    return overhead


def iter_feasible_pruned(
    tasks: Sequence[Task], fleet: FleetSpec, *, resilience: int = 0
) -> Iterator[TaskSetCombo]:
    """Yield TFS combos in ascending total-power order WITHOUT building TSS.

    Best-first search over the variant lattice: each frontier node fixes the
    variant of a prefix of tasks; its priority is its exact prefix power plus
    a certain lower bound on the suffix power.  A node is pruned when its
    prefix share plus the minimum achievable suffix share already violates
    eq. 7, and — on heterogeneous fleets — when the capacity-aware min-cost
    device-cover refinement (:func:`config_overhead_lower_bound`) already
    rejects every completion; both prefix bounds are exact at leaf depth,
    so the streamed TFS equals the exhaustive ``fit_mask`` row set.
    Memory is O(frontier), not O(|TSS|).

    Exact-power ties are broken by the chosen variant-index tuple
    (lexicographic == TSS flat C order), so the emission order matches
    :meth:`FeasibilityResult.tfs_indices_by_power` combo for combo.

    ``resilience=k`` prunes against the worst-case survivor fleet's
    budget instead (see :func:`search_feasible`) so the streamed TFS
    matches the exhaustive engine's resilience-mode ``fit_mask``.

    This is the reference engine for fleet-scale scheduling; the block
    walk uses the vectorised :func:`iter_feasible_pruned_blocks`.
    """
    tasks = tuple(tasks)
    validate_tasks(tasks)
    n_t = len(tasks)
    bfleet = fleet.survivors(resilience) if resilience and n_t else fleet
    budget = bfleet.workable_budget(n_t)

    shares = [t.shares(fleet.t_slr) for t in tasks]
    powers = [t.powers() for t in tasks]
    _, suf_pow_lo = _suffix_min_bounds(powers) if n_t else (None, np.zeros(1))
    _, suf_shr_lo = _suffix_min_bounds(shares) if n_t else (None, np.zeros(1))

    hetero = bfleet.is_heterogeneous
    capacity = bfleet.capacity
    overhead_lb = _scalar_overhead_lb(bfleet, n_t) if hetero else None

    # Node: (priority, chosen tuple, depth, prefix_pow, prefix_shr).  The
    # chosen tuple is the tiebreak: a prefix sorts before its extensions
    # and full-length tuples compare in TSS flat order, which (with the
    # strictly-admissible priorities) makes the pop order of leaves the
    # exact (total_power, flat_index) order of the materialised TFS.
    heap: list = []

    def push(depth: int, chosen: tuple[int, ...], ppow: float, pshr: float) -> None:
        w_min = pshr + suf_shr_lo[depth]
        if w_min > budget + 1e-9:
            return  # bound: no completion can satisfy eq. 7
        if hetero and w_min > capacity - overhead_lb(w_min) + 1e-9:
            return  # bound: the eq-7 device-cover refinement rejects all
        heapq.heappush(heap, (ppow + suf_pow_lo[depth], chosen, depth, ppow, pshr))

    push(0, (), 0.0, 0.0)
    while heap:
        _, chosen, depth, ppow, pshr = heapq.heappop(heap)
        if depth == n_t:
            # Both prefix bounds were exact at leaf depth (zero suffix),
            # so every popped leaf is a genuine TFS row.
            shr = tuple(float(shares[k][j]) for k, j in enumerate(chosen))
            pw = tuple(float(powers[k][j]) for k, j in enumerate(chosen))
            yield TaskSetCombo(chosen, shr, pw)
            continue
        for j in range(tasks[depth].nv):
            push(
                depth + 1,
                chosen + (j,),
                ppow + float(powers[depth][j]),
                pshr + float(shares[depth][j]),
            )


@dataclasses.dataclass
class ComboBlock:
    """A block of power-ordered TFS rows as arrays — the streaming twin of
    :meth:`FeasibilityResult.shares_matrix` over a slice of
    :meth:`FeasibilityResult.tfs_indices_by_power`.

    ``shares`` feeds a placement backend's ``place_block`` whole; a
    :class:`TaskSetCombo` is materialised (``materialize(row)``) only for
    the single winning row, exactly like the exhaustive block walk.
    ``sum_shr`` carries each row's left-to-right-folded total share — the
    exact value the eq-7 leaf test saw — so a recorded walk
    (:mod:`repro.core.replan`) can re-apply eq. 7 to row *extensions*
    bit-identically to a cold enumeration of the extended task set.
    """

    variant_idx: np.ndarray  # (B, n_t) int64 — variant choice per task
    shares: np.ndarray  # (B, n_t) float64 — eq-5 shares, task-major
    total_power: np.ndarray  # (B,) float64 — bit-identical to outer_sum rows
    sum_shr: np.ndarray | None = None  # (B,) float64 — folded eq-7 LHS
    _share_vecs: tuple = dataclasses.field(default=(), repr=False)
    _power_vecs: tuple = dataclasses.field(default=(), repr=False)

    def __len__(self) -> int:
        return int(self.variant_idx.shape[0])

    def materialize(self, row: int) -> TaskSetCombo:
        idx = self.variant_idx[row]
        shr = tuple(float(v[j]) for v, j in zip(self._share_vecs, idx, strict=True))
        pw = tuple(float(v[j]) for v, j in zip(self._power_vecs, idx, strict=True))
        return TaskSetCombo(tuple(int(j) for j in idx), shr, pw)


class _Frontier:
    """Struct-of-arrays frontier with O(popped) pops and amortised appends.

    Rows live in capacity-doubling buffers; ``pop_smallest`` extracts the
    M cheapest rows (argpartition on the float bound only) and refills the
    holes with rows swapped in from the tail, so a pop copies O(M) rows —
    not the whole frontier, which made tiny-block walks quadratic.
    Frontier-internal row order is irrelevant: emission order is decided
    by the exact leaf keys, the bound only gates it.
    """

    def __init__(self, n_t: int, cap: int = 1024) -> None:
        self.n = 0
        self._n_t = n_t
        self.bound = np.empty(cap)
        self.ppow = np.empty(cap)
        self.pshr = np.empty(cap)
        self.depth = np.empty(cap, dtype=np.int64)
        self.chosen = np.empty((cap, n_t), dtype=np.int64)

    def _grow(self, need: int) -> None:
        cap = self.bound.shape[0]
        if self.n + need <= cap:
            return
        new_cap = max(cap * 2, self.n + need)
        for name in ("bound", "ppow", "pshr", "depth"):
            arr = getattr(self, name)
            buf = np.empty(new_cap, dtype=arr.dtype)
            buf[: self.n] = arr[: self.n]
            setattr(self, name, buf)
        buf = np.empty((new_cap, self._n_t), dtype=np.int64)
        buf[: self.n] = self.chosen[: self.n]
        self.chosen = buf

    def append(self, bound, ppow, pshr, depth: int, chosen) -> None:
        m = bound.shape[0]
        self._grow(m)
        lo, hi = self.n, self.n + m
        self.bound[lo:hi] = bound
        self.ppow[lo:hi] = ppow
        self.pshr[lo:hi] = pshr
        self.depth[lo:hi] = depth
        self.chosen[lo:hi] = chosen
        self.n = hi

    def min_bound(self) -> float:
        return float(self.bound[: self.n].min()) if self.n else np.inf

    def clone(self) -> "_Frontier":
        """Independent copy (buffers trimmed to the live rows)."""
        out = _Frontier.__new__(_Frontier)
        out.n = self.n
        out._n_t = self._n_t
        cap = max(self.n, 1)
        out.bound = self.bound[:cap].copy()
        out.ppow = self.ppow[:cap].copy()
        out.pshr = self.pshr[:cap].copy()
        out.depth = self.depth[:cap].copy()
        out.chosen = self.chosen[:cap].copy()
        return out

    def keep_where(self, mask: np.ndarray) -> None:
        """Drop live rows where ``mask`` is False (bound-pruning on resume)."""
        sel = np.flatnonzero(mask[: self.n])
        m = sel.size
        self.bound[:m] = self.bound[sel]
        self.ppow[:m] = self.ppow[sel]
        self.pshr[:m] = self.pshr[sel]
        self.depth[:m] = self.depth[sel]
        self.chosen[:m] = self.chosen[sel]
        self.n = m

    def pop_smallest(self, m: int):
        n = self.n
        m = min(m, n)
        if m == n:
            sel = np.arange(n)
        else:
            sel = np.argpartition(self.bound[:n], m - 1)[:m]
        out = (
            self.ppow[sel].copy(),
            self.pshr[sel].copy(),
            self.depth[sel].copy(),
            self.chosen[sel].copy(),
        )
        if m < n:
            # Swap tail rows into the popped holes: O(m), order-agnostic.
            in_tail = sel >= n - m
            holes = sel[~in_tail]
            tail_keep = np.ones(m, dtype=bool)
            tail_keep[sel[in_tail] - (n - m)] = False
            tail = (n - m) + np.flatnonzero(tail_keep)
            self.bound[holes] = self.bound[tail]
            self.ppow[holes] = self.ppow[tail]
            self.pshr[holes] = self.pshr[tail]
            self.depth[holes] = self.depth[tail]
            self.chosen[holes] = self.chosen[tail]
        self.n = n - m
        return out


def _sort_emission(
    pp: np.ndarray, ps: np.ndarray, ch: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Order an emission run by ``(total_power, flat TSS index)``.

    Stable argsort on the float powers, then a lexicographic
    variant-index fixup applied only to runs of *exactly* equal power —
    so the common no-tie case never pays an n_t-key lexsort.
    """
    order = np.argsort(pp, kind="stable")
    pp, ps, ch = pp[order], ps[order], ch[order]
    eq = pp[1:] == pp[:-1]
    if eq.any():
        n_t = ch.shape[1]
        starts = np.flatnonzero(np.concatenate([[True], ~eq]))
        ends = np.append(starts[1:], pp.size)
        for a, b in zip(starts, ends, strict=True):
            if b - a > 1:
                sub = ch[a:b]
                o = np.lexsort(tuple(sub[:, k] for k in range(n_t - 1, -1, -1)))
                ch[a:b] = sub[o]
                ps[a:b] = ps[a:b][o]
    return pp, ps, ch


def _drain_chunks(
    chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]], n: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pop exactly ``n`` rows off the front of a list of (pp, ps, chosen) runs."""
    pp_parts, ps_parts, ch_parts, got = [], [], [], 0
    while got < n:
        pp, ps, ch = chunks[0]
        need = n - got
        if pp.size <= need:
            pp_parts.append(pp)
            ps_parts.append(ps)
            ch_parts.append(ch)
            got += pp.size
            chunks.pop(0)
        else:
            pp_parts.append(pp[:need])
            ps_parts.append(ps[:need])
            ch_parts.append(ch[:need])
            chunks[0] = (pp[need:], ps[need:], ch[need:])
            got = n
    return (
        np.concatenate(pp_parts),
        np.concatenate(ps_parts),
        np.concatenate(ch_parts, axis=0),
    )


def _size_stream(block_sizes: int | Iterable[int] | None) -> Iterator[int]:
    """Normalise a block-size spec into an endless iterator of sizes."""
    if block_sizes is None:
        block_sizes = 4096
    if isinstance(block_sizes, int):
        if block_sizes < 1:
            raise ValueError(f"block_size must be >= 1, got {block_sizes}")
        return itertools.repeat(block_sizes)

    def gen():
        last = None
        for s in block_sizes:
            s = int(s)
            if s < 1:
                raise ValueError(f"block_size must be >= 1, got {s}")
            last = s
            yield s
        if last is None:
            raise ValueError("block_sizes iterable produced no sizes")
        while True:
            yield last

    return gen()


class BlockEnumerator:
    """Stateful block-native TFS enumerator — the resumable core of
    :func:`iter_feasible_pruned_blocks`.

    The same best-first branch-and-bound search as
    :func:`iter_feasible_pruned`, vectorised: the frontier is a
    struct-of-arrays (priority, prefix power/share, depth, chosen-index
    matrix) and every round pops the cheapest nodes *in bulk*
    (``argpartition``), expands each depth group with one broadcast add
    per task, and prunes children with the vectorised eq-7 prefix bounds
    — including the heterogeneous capacity-aware device-cover refinement
    of :func:`config_overhead_lower_bound`, which shrinks the TFS every
    placement backend has to scan.  Completed rows buffer until no
    frontier node could still produce a cheaper row, then come out
    lexsorted by ``(total_power, flat_index)`` — the exact
    :meth:`FeasibilityResult.tfs_indices_by_power` order, asserted
    combo-for-combo in ``tests/test_block_enumeration.py``.

    Being an explicit object (rather than a generator) buys the delta
    replanner (:mod:`repro.core.replan`) two things:

    * **snapshot/restore** — :meth:`clone` copies the live frontier,
      buffered leaves and ready runs, so a later replan can *resume* the
      walk exactly where a previous schedule stopped instead of
      re-enumerating the combo space from scratch;
    * **incumbent-bound pruning** — :meth:`prune_above` installs an upper
      bound on total power (a known-placeable plan's power): frontier
      nodes whose admissible bound exceeds it can never produce a better
      row and are dropped, before and during expansion.

    ``next_block(want)`` returns the next ``want`` rows in emission order
    as a :class:`ComboBlock` (short only when the walk is exhausted), or
    ``None`` when nothing remains.
    """

    def __init__(
        self,
        tasks: Sequence[Task],
        fleet: FleetSpec,
        *,
        min_expand: int = 16384,
        incumbent_power: float | None = None,
        resilience: int = 0,
        cover_prune=None,
    ) -> None:
        tasks = tuple(tasks)
        validate_tasks(tasks)
        self.tasks = tasks
        self.fleet = fleet
        self.n_t = n_t = len(tasks)
        self.min_expand = min_expand
        self.incumbent_power = (
            float(incumbent_power) if incumbent_power is not None else np.inf
        )
        self.resilience = int(resilience)
        # Optional subtree-coverage hook for the delta replanner's removal
        # gap walk: ``cover_prune(depth, pshr)`` returns a boolean mask of
        # prefix nodes *all* of whose completions are provably present in
        # a previous recording — those subtrees are dropped, so the walk
        # enumerates only the rows projection could have missed.  Dropping
        # covered rows never loses a row the caller cannot recover (they
        # are recovered from the recording), and keeping an uncovered row
        # is always sound: the hook must only return True on certainty.
        self.cover_prune = cover_prune
        # eq. 7 prunes against the worst-case survivor fleet when a
        # resilience guarantee is requested (see search_feasible): its
        # budget is a necessary condition for the survivor sweep, hence
        # for the combined primary-AND-backup verdict.  Shares keep the
        # *original* fleet's reference t_slr.
        bfleet = (
            fleet.survivors(self.resilience) if self.resilience and n_t else fleet
        )
        self.budget = bfleet.workable_budget(n_t)
        self.share_vecs = tuple(t.shares(fleet.t_slr) for t in tasks)
        self.power_vecs = tuple(t.powers() for t in tasks)
        self._bfleet = bfleet
        self._hetero = bfleet.is_heterogeneous
        self._capacity = bfleet.capacity
        self.rows_emitted = 0

        # Completed rows buffer as (pp, ps, chosen) chunks until emittable;
        # the cheap min-per-chunk cache gates nothing-to-emit rounds.
        self._leaf_chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._leaf_min = np.inf
        self._ready: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._n_ready = 0
        self._empty_set_pending = False

        if n_t == 0:
            # The empty task set has exactly one (empty) combo.
            self._frontier = _Frontier(0)
            self._empty_set_pending = bool(self._passes(np.zeros(1))[0]) and (
                0.0 <= self.incumbent_power
            )
            if self._empty_set_pending and self.cover_prune is not None:
                self._empty_set_pending = not bool(
                    self.cover_prune(0, np.zeros(1))[0]
                )
            return

        _, self._pow_lo = _suffix_min_bounds(self.power_vecs)
        _, self._shr_lo = _suffix_min_bounds(self.share_vecs)

        # Frontier: internal nodes only.  ``chosen`` columns beyond a
        # node's depth are 0 and ignored.
        self._frontier = _Frontier(n_t)
        root_bound = 0.0 + self._pow_lo[0]
        root_covered = self.cover_prune is not None and bool(
            self.cover_prune(0, np.zeros(1))[0]
        )
        if (
            self._passes(np.asarray([0.0 + self._shr_lo[0]]))[0]
            and not (root_bound > self.incumbent_power)
            and not root_covered
        ):
            self._frontier.append(
                np.asarray([root_bound]),
                np.zeros(1),
                np.zeros(1),
                0,
                np.zeros((1, n_t), dtype=np.int64),
            )

    # -- construction helpers ------------------------------------------------

    def clone(self) -> "BlockEnumerator":
        """Independent copy of the live search state (frontier, buffered
        leaves, ready runs) sharing the immutable per-task arrays.  The
        clone resumes emission exactly where this enumerator stands; the
        original is untouched — this is the frontier snapshot a
        :class:`repro.core.replan.PlanState` keeps between replans."""
        out = BlockEnumerator.__new__(BlockEnumerator)
        out.__dict__.update(self.__dict__)
        out._frontier = self._frontier.clone()
        # Chunk/run arrays are never mutated in place after creation, so a
        # shallow list copy keeps the clone independent.
        out._leaf_chunks = list(self._leaf_chunks)
        out._ready = list(self._ready)
        return out

    def prune_above(self, incumbent_power: float) -> None:
        """Install an incumbent upper bound on total power.

        Drops every frontier node whose admissible bound — and every
        buffered/ready row whose exact power — exceeds ``incumbent_power``;
        subsequent expansions prune children the same way.  Rows with
        power exactly equal to the bound are kept (the incumbent row
        itself must still be emitted).  Sound because frontier bounds are
        strict underestimates of any completion's power."""
        inc = float(incumbent_power)
        self.incumbent_power = min(self.incumbent_power, inc)
        if self._frontier.n:
            self._frontier.keep_where(
                self._frontier.bound[: self._frontier.n] <= inc
            )
        kept_chunks = []
        self._leaf_min = np.inf
        for pp, ps, ch in self._leaf_chunks:
            m = pp <= inc
            if m.any():
                pp, ps, ch = pp[m], ps[m], ch[m]
                kept_chunks.append((pp, ps, ch))
                self._leaf_min = min(self._leaf_min, float(pp.min()))
        self._leaf_chunks = kept_chunks
        kept_ready = []
        self._n_ready = 0
        for pp, ps, ch in self._ready:
            k = int(np.searchsorted(pp, inc, side="right"))
            if k:
                kept_ready.append((pp[:k], ps[:k], ch[:k]))
                self._n_ready += k
        self._ready = kept_ready

    # -- search internals ----------------------------------------------------

    def _passes(self, w: np.ndarray) -> np.ndarray:
        ok = w <= self.budget + 1e-9
        if self._hetero and ok.any():
            overhead = config_overhead_lower_bound(self._bfleet, self.n_t, w)
            ok &= ~(w > self._capacity - overhead + 1e-9)
        return ok

    def _build_block(
        self, pp: np.ndarray, ps: np.ndarray, ch: np.ndarray
    ) -> ComboBlock:
        if self.n_t:
            shr = np.stack(
                [self.share_vecs[k][ch[:, k]] for k in range(self.n_t)], axis=1
            )
        else:
            shr = np.zeros((pp.shape[0], 0), dtype=np.float64)
        self.rows_emitted += pp.shape[0]
        return ComboBlock(
            variant_idx=ch,
            shares=shr,
            total_power=pp,
            sum_shr=ps,
            _share_vecs=self.share_vecs,
            _power_vecs=self.power_vecs,
        )

    def _expand_round(self, want: int) -> None:
        """One bulk best-first step: pop, expand, prune, gate-emit."""
        frontier = self._frontier
        tasks, n_t = self.tasks, self.n_t
        inc = self.incumbent_power
        # Pop the cheapest M frontier nodes (bulk best-first step).
        M = int(min(frontier.n, max(want, self.min_expand)))
        pop_ppow, pop_pshr, pop_depth, pop_chosen = frontier.pop_smallest(M)

        for d in np.unique(pop_depth):
            d = int(d)
            g = pop_depth == d
            nv = tasks[d].nv
            # One broadcast add per (depth group, task): child prefixes.
            ppow_c = (pop_ppow[g][:, None] + self.power_vecs[d][None, :]).ravel()
            pshr_c = (pop_pshr[g][:, None] + self.share_vecs[d][None, :]).ravel()
            chosen_c = np.repeat(pop_chosen[g], nv, axis=0)
            chosen_c[:, d] = np.tile(
                np.arange(nv, dtype=np.int64), int(g.sum())
            )
            ok = self._passes(pshr_c + self._shr_lo[d + 1])
            if inc != np.inf:
                # Incumbent bound: the admissible power bound (exact at
                # leaf depth) already exceeds a known-placeable plan.
                ok &= ppow_c + self._pow_lo[d + 1] <= inc
            if self.cover_prune is not None and ok.any():
                ok &= ~self.cover_prune(d + 1, pshr_c)
            if not ok.any():
                continue
            ppow_c, pshr_c, chosen_c = ppow_c[ok], pshr_c[ok], chosen_c[ok]
            if d + 1 == n_t:
                self._leaf_chunks.append((ppow_c, pshr_c, chosen_c))
                self._leaf_min = min(self._leaf_min, float(ppow_c.min()))
            else:
                frontier.append(
                    ppow_c + self._pow_lo[d + 1], ppow_c, pshr_c, d + 1, chosen_c
                )

        # A buffered leaf is emittable once every remaining frontier node's
        # (strictly admissible) bound exceeds its exact power: no cheaper
        # row can appear later, so the emission order is final.
        fmin = frontier.min_bound()
        if self._leaf_min < fmin:
            leaf_pp = np.concatenate([c[0] for c in self._leaf_chunks])
            leaf_ps = np.concatenate([c[1] for c in self._leaf_chunks])
            leaf_ch = np.concatenate([c[2] for c in self._leaf_chunks], axis=0)
            emit = leaf_pp < fmin
            self._ready.append(
                _sort_emission(leaf_pp[emit], leaf_ps[emit], leaf_ch[emit])
            )
            self._n_ready += int(emit.sum())
            held = ~emit
            if held.any():
                self._leaf_chunks = [
                    (leaf_pp[held], leaf_ps[held], leaf_ch[held])
                ]
                self._leaf_min = float(leaf_pp[held].min())
            else:
                self._leaf_chunks = []
                self._leaf_min = np.inf

    def _flush_leaves(self) -> None:
        if not self._leaf_chunks:
            return
        leaf_pp = np.concatenate([c[0] for c in self._leaf_chunks])
        leaf_ps = np.concatenate([c[1] for c in self._leaf_chunks])
        leaf_ch = np.concatenate([c[2] for c in self._leaf_chunks], axis=0)
        self._ready.append(_sort_emission(leaf_pp, leaf_ps, leaf_ch))
        self._n_ready += leaf_pp.size
        self._leaf_chunks = []
        self._leaf_min = np.inf

    # -- emission ------------------------------------------------------------

    def next_block(self, want: int) -> ComboBlock | None:
        """The next ``want`` emission-ordered rows, or ``None`` at the end.

        Blocks are full-size while the walk can still produce rows; only
        the final block is short.  Successive calls with varying ``want``
        reproduce :func:`iter_feasible_pruned_blocks` with the same size
        stream exactly."""
        if want < 1:
            raise ValueError(f"block size must be >= 1, got {want}")
        if self.n_t == 0:
            if not self._empty_set_pending:
                return None
            self._empty_set_pending = False
            return self._build_block(
                np.zeros(1), np.zeros(1), np.zeros((1, 0), dtype=np.int64)
            )
        while self._frontier.n and self._n_ready < want:
            self._expand_round(want)
        if not self._frontier.n:
            self._flush_leaves()
        if not self._n_ready:
            return None
        take = min(want, self._n_ready)
        pp, ps, ch = _drain_chunks(self._ready, take)
        self._n_ready -= take
        return self._build_block(pp, ps, ch)

    @property
    def exhausted(self) -> bool:
        """True when no further row can be emitted."""
        return not (
            self._frontier.n
            or self._n_ready
            or self._leaf_chunks
            or self._empty_set_pending
        )


def iter_feasible_pruned_blocks(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    block_sizes: int | Iterable[int] | None = None,
    *,
    min_expand: int = 16384,
    resilience: int = 0,
) -> Iterator[ComboBlock]:
    """Yield the TFS as power-ordered :class:`ComboBlock` array batches.

    Generator facade over :class:`BlockEnumerator` (see its docstring for
    the search itself).  ``block_sizes`` is an int, an iterable (e.g. the
    scheduler's geometric ramp — early blocks small so a shallow winner
    stops the walk cheaply, later blocks large to amortise dispatch), or
    None for a constant 4096.  The final block may be short.

    Example — stream the feasible rows of a 2-task instance:

        >>> from repro.core.task import FleetSpec, Task, TaskVariant
        >>> def v(th, pw):
        ...     return TaskVariant(cu=1, throughput=th, power=pw)
        >>> tasks = [
        ...     Task("a", period=10.0, data=20.0, init_interval=1.0,
        ...          variants=(v(2.0, 5.0), v(4.0, 8.0))),
        ...     Task("b", period=10.0, data=40.0, init_interval=1.0,
        ...          variants=(v(4.0, 4.0), v(8.0, 6.0))),
        ... ]
        >>> fleet = FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0)
        >>> for blk in iter_feasible_pruned_blocks(tasks, fleet, 4):
        ...     for r in range(len(blk)):
        ...         print(blk.variant_idx[r], blk.total_power[r])
        [0 1] 11.0
        [1 0] 12.0
        [1 1] 14.0

    Rows arrive in ascending total power; the one combo whose summed
    share violates eq. 7 — both tasks in their big-share variant, 60
    against a workable budget of 57 — is pruned without ever being
    materialised.
    """
    sizes = _size_stream(block_sizes)
    enum = BlockEnumerator(
        tasks, fleet, min_expand=min_expand, resilience=resilience
    )
    want = next(sizes)
    while True:
        blk = enum.next_block(want)
        if blk is None:
            return
        yield blk
        want = next(sizes)
