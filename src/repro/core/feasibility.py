"""Algorithm 1 — Searching of Feasible Task Sets (paper §III-A1).

Builds the TSS (all ``prod(nv_i)`` variant combinations), applies the
workability condition (eq. 7)

    sum_shr  <=  n_f * t_slr - n_t * t_cfg

and partitions TSS into TFS (fit) / TNFS (not fit).

Two engines are provided:

* ``search_feasible`` — the paper's exhaustive enumeration, vectorised:
  the sum-of-shares over the Cartesian product is an outer-sum computed
  by numpy broadcasting, ~1000x faster than the paper's nested loops for
  large products (beyond-paper optimisation; measured in
  ``benchmarks/scheduler_scale.py``).
* ``iter_feasible_pruned`` — branch-and-bound enumeration in ascending
  power order that never materialises TSS; used when ``prod(nv_i)`` is
  too large to hold (the paper's algorithm is O(prod nv_i) memory).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator, Sequence

import numpy as np

from .task import FleetSpec, Task, TaskSetCombo, combo_count, validate_tasks

__all__ = [
    "FeasibilityResult",
    "search_feasible",
    "iter_feasible_pruned",
    "outer_sum",
    "config_overhead_lower_bound",
]


@dataclasses.dataclass
class FeasibilityResult:
    """TFS/TNFS split plus the arrays needed downstream (Alg 2)."""

    tasks: tuple[Task, ...]
    fleet: FleetSpec
    n_combos: int  # |TSS|
    # Arrays over the full TSS, flattened in C order of variant indices.
    sum_shr: np.ndarray  # (n_combos,)
    total_power: np.ndarray  # (n_combos,)
    fit_mask: np.ndarray  # (n_combos,) bool — eq. 7
    budget: float  # RHS of eq. 7

    @property
    def n_tfs(self) -> int:
        return int(self.fit_mask.sum())

    @property
    def n_tnfs(self) -> int:
        return self.n_combos - self.n_tfs

    def combo_at(self, flat_index: int) -> TaskSetCombo:
        """Materialise one TSS row from its flat index."""
        nvs = [t.nv for t in self.tasks]
        idx = np.unravel_index(flat_index, nvs)
        shares = tuple(
            float(t.shares(self.fleet.t_slr)[j]) for t, j in zip(self.tasks, idx)
        )
        powers = tuple(float(t.variants[j].power) for t, j in zip(self.tasks, idx))
        return TaskSetCombo(tuple(int(j) for j in idx), shares, powers)

    def shares_matrix(self, flat_indices: np.ndarray) -> np.ndarray:
        """Materialise a block of TSS rows as a ``(B, n_t)`` shares matrix.

        The vectorised counterpart of :meth:`combo_at` — one fancy-indexed
        gather per task instead of B Python round-trips; this is what feeds
        the batched placement engine
        (:func:`repro.core.placement_batched.place_batch`).
        """
        flat_indices = np.asarray(flat_indices, dtype=np.int64)
        nvs = [t.nv for t in self.tasks]
        idx = np.unravel_index(flat_indices, nvs)
        cols = [
            t.shares(self.fleet.t_slr)[ji] for t, ji in zip(self.tasks, idx)
        ]
        return np.stack(cols, axis=1)

    def tfs_indices_by_power(self) -> np.ndarray:
        """Flat indices of TFS rows, ascending total power (Alg 2 line 1).

        Ties are broken by ascending sum-of-shares then flat index so the
        ordering is deterministic.
        """
        tfs = np.flatnonzero(self.fit_mask)
        # Stable sort: ties broken by TSS enumeration (flat-index) order,
        # matching the paper's "Assc. Sort on TFS" over the generated list.
        order = np.argsort(self.total_power[tfs], kind="stable")
        return tfs[order]

    def iter_tfs_by_power(self) -> Iterator[TaskSetCombo]:
        for i in self.tfs_indices_by_power():
            yield self.combo_at(int(i))


def outer_sum(vectors: Sequence[np.ndarray]) -> np.ndarray:
    """Sum over the Cartesian product of 1-D vectors, returned flat (C order).

    outer_sum([a, b, c])[i*len(b)*len(c) + j*len(c) + k] == a[i]+b[j]+c[k]
    """
    acc = np.zeros((1,), dtype=np.float64)
    for v in vectors:
        acc = (acc[:, None] + np.asarray(v, dtype=np.float64)[None, :]).reshape(-1)
    return acc


def config_overhead_lower_bound(
    fleet: FleetSpec, n_t: int, sum_shr: np.ndarray, extra_cfgs: int = 1
) -> np.ndarray:
    """Per-class refinement of the eq. 7 configuration charge, vectorised.

    For a heterogeneous fleet the paper's flat ``(n_t + 1) * t_cfg`` charge
    has no single ``t_cfg``.  The sound necessary-condition charge is a
    *lower bound* on the total reconfiguration time any placement of a
    combo with total share ``W = sum_shr`` must pay:

    * a combo needs at least ``d(W)`` devices, where ``d(W)`` is the
      smallest count of devices (taken largest-capacity-first) whose
      ``t_slr_j`` sum covers ``W`` — and every used device pays at least
      one of its own ``t_cfg_j`` (lower-bounded by the ``d(W)`` cheapest
      cfgs in the fleet);
    * there are at least ``max(n_t + extra_cfgs, d(W))`` configuration
      events in total; events beyond the per-device minimum pay at least
      the fleet-wide cheapest ``t_cfg``.

    On a homogeneous fleet with ``d(W) <= n_t + extra_cfgs`` this reduces
    exactly to the paper's ``(n_t + extra_cfgs) * t_cfg``.

    Soundness: with ``extra_cfgs=0`` every placement really pays at least
    this overhead (each task one cfg, each necessarily-used device one of
    its own cfgs), so rejection is a strict necessary condition.  The
    default ``extra_cfgs=1`` inherits the paper's one-split allowance —
    like eq. 7 itself it can reject a combo that happens to place with no
    split (the documented Example-1 deviation); it is the same charge the
    homogeneous pre-filter applies, refined per device class.
    """
    sum_shr = np.asarray(sum_shr, dtype=np.float64)
    m = n_t + extra_cfgs
    cap_desc = np.sort(fleet.t_slr_arr)[::-1]
    cfg_asc = np.sort(fleet.t_cfg_arr)
    cfg_min = float(cfg_asc[0]) if cfg_asc.size else 0.0
    # d(W): min devices whose (descending) capacities cover W.
    cum_cap = np.cumsum(cap_desc)
    d = np.searchsorted(cum_cap, sum_shr - 1e-9) + 1
    d = np.minimum(d, fleet.n_f)
    # Sum of the d cheapest per-device cfgs, one per necessarily-used device.
    cum_cfg = np.concatenate([[0.0], np.cumsum(cfg_asc)])
    per_device = cum_cfg[d]
    extra_events = np.maximum(m - d, 0)
    return per_device + extra_events * cfg_min


def search_feasible(tasks: Sequence[Task], fleet: FleetSpec) -> FeasibilityResult:
    """Algorithm 1, vectorised. Materialises |TSS| f64 arrays (twice).

    Safe up to ~10^8 combinations on a 32 GB host; beyond that use
    ``iter_feasible_pruned``.

    Heterogeneous fleets additionally apply the per-class configuration
    charge of :func:`config_overhead_lower_bound` (eq. 7 generalises to
    ``sum_shr <= sum_j t_slr_j - overhead_lb``); homogeneous fleets keep
    the paper's flat charge so the published Example-1/3 counts hold.
    """
    tasks = tuple(tasks)
    validate_tasks(tasks)
    n_t = len(tasks)
    n_combos = combo_count(tasks)
    if n_combos > 200_000_000:
        raise ValueError(
            f"|TSS|={n_combos:,} too large to materialise; "
            "use iter_feasible_pruned()"
        )
    share_vecs = [t.shares(fleet.t_slr) for t in tasks]
    power_vecs = [t.powers() for t in tasks]
    sum_shr = outer_sum(share_vecs)
    total_power = outer_sum(power_vecs)
    budget = fleet.workable_budget(n_t)
    fit = sum_shr <= budget + 1e-9  # eq. 7 (tolerant <=)
    if fleet.is_heterogeneous:
        overhead = config_overhead_lower_bound(fleet, n_t, sum_shr)
        fit &= sum_shr <= fleet.capacity - overhead + 1e-9
    return FeasibilityResult(
        tasks=tasks,
        fleet=fleet,
        n_combos=n_combos,
        sum_shr=sum_shr,
        total_power=total_power,
        fit_mask=fit,
        budget=budget,
    )


def iter_feasible_pruned(
    tasks: Sequence[Task], fleet: FleetSpec
) -> Iterator[TaskSetCombo]:
    """Yield TFS combos in ascending total-power order WITHOUT building TSS.

    Best-first search over the variant lattice: each frontier node fixes the
    variant of a prefix of tasks; its priority is its exact prefix power plus
    the minimum achievable power of the suffix.  A node is pruned when its
    prefix share plus the minimum achievable suffix share already violates
    eq. 7 — the branch-and-bound step.  Memory is O(frontier), not O(|TSS|).

    This is the engine behind fleet-scale scheduling (hundreds of jobs x
    dozens of variants) where the paper's exhaustive TSS is intractable.
    """
    tasks = tuple(tasks)
    validate_tasks(tasks)
    n_t = len(tasks)
    budget = fleet.workable_budget(n_t)

    shares = [t.shares(fleet.t_slr) for t in tasks]
    powers = [t.powers() for t in tasks]
    # Per-task variant order by power (for monotone sibling expansion) and
    # suffix minima for bounds.
    order = [np.argsort(p, kind="stable") for p in powers]
    min_pow = np.array([p.min() for p in powers])
    min_shr = np.array([s.min() for s in shares])
    suf_min_pow = np.concatenate([np.cumsum(min_pow[::-1])[::-1], [0.0]])
    suf_min_shr = np.concatenate([np.cumsum(min_shr[::-1])[::-1], [0.0]])

    # Node: (priority, tiebreak, depth, chosen tuple, prefix_pow, prefix_shr,
    #        rank) where rank is the index into order[depth] *to try next*.
    heap: list = []
    counter = 0

    def push(depth: int, chosen: tuple[int, ...], ppow: float, pshr: float) -> None:
        nonlocal counter
        if pshr + suf_min_shr[depth] > budget + 1e-9:
            return  # bound: no completion can satisfy eq. 7
        prio = ppow + suf_min_pow[depth]
        heapq.heappush(heap, (prio, counter, depth, chosen, ppow, pshr))
        counter += 1

    hetero = fleet.is_heterogeneous
    capacity = fleet.capacity

    push(0, (), 0.0, 0.0)
    while heap:
        _, _, depth, chosen, ppow, pshr = heapq.heappop(heap)
        if depth == n_t:
            # Leaf filter: heterogeneous fleets apply the same per-class
            # eq-7 refinement as search_feasible, so the streamed TFS is
            # identical to the exhaustive fit_mask (same rejects/ranks).
            if hetero:
                overhead = config_overhead_lower_bound(
                    fleet, n_t, np.asarray([pshr])
                )[0]
                if pshr > capacity - overhead + 1e-9:
                    continue
            shr = tuple(float(shares[k][j]) for k, j in enumerate(chosen))
            pw = tuple(float(powers[k][j]) for k, j in enumerate(chosen))
            yield TaskSetCombo(chosen, shr, pw)
            continue
        for rank in range(tasks[depth].nv):
            j = int(order[depth][rank])
            push(
                depth + 1,
                chosen + (j,),
                ppow + float(powers[depth][j]),
                pshr + float(shares[depth][j]),
            )
