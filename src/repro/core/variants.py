"""Parallelism-variant generation: ML jobs -> PADPS-FR tasks.

The paper's variants are "j parallel CUs in one FPGA"; ours are
"``n_chips``-chip slice with the framework's sharding".  For each
assigned (architecture x input shape) job we build the variant table
(throughput, power) from the analytic roofline + power model, and emit
a :class:`repro.core.task.Task` the unchanged PADPS-FR algorithms
schedule — the paper's scheduler doing real work inside the framework.

Analytic per-step costs (documented approximations, same quantities the
compiled dry-run reports exactly):

* train:   FLOPs = 6 * N_active * tokens  (fwd+bwd), HBM = params read
           + grads + optimizer traffic + activation spill, collectives =
           grad all-reduce (2 * P bytes ring) over the DP axes.
* prefill: FLOPs = 2 * N_active * tokens + attention quadratic term.
* decode:  FLOPs = 2 * N_active * batch; HBM dominated by weights + KV
           cache read per token; collectives = TP all-reduces.
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape

from .power import DEVICE_CLASSES, V5E, DeviceClass, PowerModel, TPUSpec, step_time_roofline
from .task import DeviceProfile, FleetSpec, Task, TaskVariant

__all__ = [
    "JobSpec",
    "job_costs",
    "make_task",
    "variant_table",
    "make_hetero_fleet",
]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A periodic ML job: run `shape` for `arch` every `period_s` seconds,
    processing `steps_per_period` steps."""

    cfg: ModelConfig
    shape: InputShape
    period_s: float
    steps_per_period: int = 1
    name: str = ""

    @property
    def job_name(self) -> str:
        return self.name or f"{self.cfg.name}:{self.shape.name}"


def _bytes_per_param(kind: str) -> float:
    # bf16 weights; training adds f32 grads + AdamW moments traffic
    return 2.0 if kind != "train" else 2.0 + 4.0 + 8.0


def job_costs(cfg: ModelConfig, shape: InputShape) -> dict[str, float]:
    """Per-step analytic (FLOPs, HBM bytes, collective bytes at 1 chip).

    Collective bytes returned separately as per-replica ring volume:
    gradient all-reduce 2*P*4 bytes (f32) for train; TP activation
    reductions approximated as 2 * tokens * d_model * 2 bytes * L.
    """
    N = cfg.active_param_count()
    P = cfg.param_count()
    tokens = shape.tokens
    L = cfg.n_layers + cfg.enc_layers
    d = cfg.d_model
    kind = shape.kind

    if kind == "train":
        flops = 6.0 * N * tokens
    else:
        flops = 2.0 * N * tokens
    # attention quadratic term (full-attention archs; window for hybrid)
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    if cfg.family not in ("ssm",):
        ctx = min(shape.seq_len, cfg.local_window) if cfg.family == "hybrid" else shape.seq_len
        if kind == "decode":
            att = 2.0 * 2.0 * shape.global_batch * ctx * H * hd * (L if cfg.family != "hybrid" else L / 3)
        else:
            att = 2.0 * 2.0 * tokens * ctx * H * hd * (L if cfg.family != "hybrid" else L / 3)
            att *= 0.5  # causal
            if kind == "train":
                att *= 3.0  # fwd + bwd recompute
        flops += att

    hbm = P * _bytes_per_param(kind)
    if kind == "decode":
        # KV cache read per decoded token
        kv_bytes = (
            2.0 * L * shape.global_batch * shape.seq_len * cfg.n_kv_heads * hd * 2.0
            if cfg.family not in ("ssm", "hybrid")
            else 2.0 * L * shape.global_batch * (cfg.ssm_state * d if cfg.family == "ssm" else cfg.local_window * cfg.n_kv_heads * hd) * 2.0
        )
        hbm += kv_bytes
    else:
        hbm += 2.0 * tokens * d * 2.0 * L  # activation traffic

    if kind == "train":
        coll = 2.0 * P * 4.0  # ring all-reduce of f32 grads
    else:
        coll = 2.0 * tokens * d * 2.0 * math.log2(max(L, 2))  # TP reduces
    return {"flops": flops, "hbm": hbm, "coll": coll}


def variant_table(
    job: JobSpec,
    chip_options: tuple[int, ...] = (32, 64, 128, 256),
    spec: TPUSpec = V5E,
    power: PowerModel | None = None,
) -> list[TaskVariant]:
    """One TaskVariant per slice size, throughput in steps/sec."""
    power = power or PowerModel()
    costs = job_costs(job.cfg, job.shape)
    out = []
    for n in chip_options:
        t_step, _terms = step_time_roofline(
            costs["flops"], costs["hbm"], costs["coll"], n, spec
        )
        # weight-memory feasibility: params (+opt state for train) must fit
        state_bytes = job.cfg.param_count() * (
            2.0 if job.shape.kind != "train" else 2.0 + 4.0 + 8.0
        )
        if state_bytes > n * spec.hbm_bytes * 0.8:
            continue  # this slice size cannot hold the job
        th = 1.0 / t_step  # steps per second
        pw = power.job_power(n, t_step, costs["flops"], costs["hbm"], costs["coll"])
        out.append(TaskVariant(cu=n, throughput=th, power=pw, program=f"{job.job_name}@{n}"))
    return out


def make_hetero_fleet(
    class_counts: dict[str, int] | list[tuple[DeviceClass | str, int]],
    t_slr: float,
    *,
    name: str = "hetero-fleet",
) -> FleetSpec:
    """Build a mixed FPGA/GPU/CPU/TPU fleet from device-class counts.

    Each class contributes ``count`` devices with capacity
    ``t_slr * capacity_scale`` and reconfiguration cost
    ``t_slr * t_cfg_frac`` (:data:`repro.core.power.DEVICE_CLASSES`) —
    both derived from the reference slice, so the class table is
    unit-free (an FPGA costs 0.1 of the slice whether ``t_slr`` is the
    paper's 60 ms or a TPU fleet's 3600 s).  ``t_slr`` is the fleet's
    reference slice — eq. 5 shares are defined against it, per-device
    capacities derate from it.

    Example — two FPGAs plus one GPU (slightly derated capacity, near-free
    reconfiguration):

        >>> fleet = make_hetero_fleet({"fpga": 2, "gpu": 1}, t_slr=60.0)
        >>> fleet.n_f, [d.klass for d in fleet.devices]
        (3, ['fpga', 'fpga', 'gpu'])
        >>> [(d.t_slr, round(d.t_cfg, 2)) for d in fleet.devices]
        [(60.0, 6.0), (60.0, 6.0), (54.0, 0.06)]
    """
    items = class_counts.items() if isinstance(class_counts, dict) else class_counts
    profiles: list[DeviceProfile] = []
    for klass, count in items:
        dc = DEVICE_CLASSES[klass] if isinstance(klass, str) else klass
        if count < 0:
            raise ValueError(f"{dc.name}: count must be >= 0")
        profiles.extend(
            DeviceProfile(
                t_slr=t_slr * dc.capacity_scale,
                t_cfg=t_slr * dc.t_cfg_frac,
                klass=dc.name,
            )
            for _ in range(count)
        )
    if not profiles:
        raise ValueError("fleet needs at least one device")
    return FleetSpec.heterogeneous(tuple(profiles), name=name)


def make_task(
    job: JobSpec,
    chip_options: tuple[int, ...] = (32, 64, 128, 256),
    spec: TPUSpec = V5E,
    power: PowerModel | None = None,
) -> Task:
    """PADPS-FR task: data volume = steps per period, throughput = steps/s.

    ``init_interval`` models program-switch warm-up (first-step dispatch);
    the fleet's ``t_cfg`` models executable load + weight restore.
    """
    variants = variant_table(job, chip_options, spec, power)
    if not variants:
        raise ValueError(
            f"{job.job_name}: no slice size in {chip_options} fits the job"
        )
    return Task(
        name=job.job_name,
        period=job.period_s,
        data=float(job.steps_per_period),
        init_interval=0.5,  # s — first-step dispatch/warm-up
        variants=tuple(variants),
    )
