"""Batched Alg-2/Alg-3 placement — a numpy array program over TFS blocks.

The paper's ``find_low_power_task_set()`` walks the power-sorted TFS one
combination at a time through the scalar placement simulation
(:func:`repro.core.placement.place_shares`) — O(|TFS|) Python round-trips
on the hot path of every scheduling decision.  This module evaluates an
entire block of TFS rows at once: the block is a shares matrix ``(B, n_t)``
and the simulation state (device cursor ``j``, remaining capacity ``c``,
task cursor ``k``, carried share ``tsd``) lives in (B,) arrays advanced by
vectorized carry/split steps.

Each step, every live row either advances its task cursor (the current
task fits on the current device) or its device cursor (no-start, split
carry, or post-placement closure), so the loop runs at most ``n_t + n_f``
iterations *regardless of B* — the per-row Python interpreter cost of the
scalar walk is amortised over the whole block.

The arithmetic replays the scalar oracle's float64 operations in the same
order (``avail = (c - t_cfg_j) - extra``; ``c' = avail - rem``), so the
two engines agree bit-for-bit — asserted on the paper's worked examples
(Figs 2-4) and on randomized heterogeneous fleets in
``tests/test_placement_batched.py``.

Heterogeneity is native: capacities ``t_slr_j`` and reconfiguration costs
``t_cfg_j`` are per-device gathers, so mixed FPGA/GPU/CPU fleets
(:class:`repro.core.power.DeviceClass`) cost nothing extra.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .placement import _EPS
from .task import FleetSpec, Task, TaskSetCombo

__all__ = ["BatchPlacement", "place_batch", "place_combos_batch"]


@dataclasses.dataclass
class BatchPlacement:
    """Vectorised placement verdicts for a block of TFS rows.

    The batched engine answers Alg 2's *is this combo placeable?* for every
    row; the full per-device script of the (single) winning row is then
    produced by the scalar oracle, which is exact by construction.
    """

    feasible: np.ndarray  # (B,) bool
    placed_tasks: np.ndarray  # (B,) int — tasks fully placed (== n_t iff feasible)
    n_splits: np.ndarray  # (B,) int — tasks that split across devices
    devices_used: np.ndarray  # (B,) int — 1 + highest device index holding a
    # placement (on heterogeneous fleets, skipped too-small devices in
    # between still count toward this span)

    @property
    def n_feasible(self) -> int:
        return int(self.feasible.sum())

    def first_feasible(self) -> int:
        """Row index of the first feasible row, or -1."""
        idx = np.flatnonzero(self.feasible)
        return int(idx[0]) if idx.size else -1


def place_batch(
    shares: np.ndarray,
    init_intervals: Sequence[float],
    fleet: FleetSpec,
    *,
    t_capture: float = 0.0,
    t_store: float = 0.0,
    repay_init: bool = True,
) -> BatchPlacement:
    """Simulate DP-wrap placement of ``B`` share rows on the fleet at once.

    ``shares`` is ``(B, n_t)`` — one power-sorted TFS row per line, tasks in
    the paper's fixed order.  Semantics (start condition, split carry,
    re-paid II / capture+store, closure) are exactly those of
    :func:`repro.core.placement.place_shares`; see that module's docstring
    for the Fig-2/3/4 pinning.
    """
    shares = np.ascontiguousarray(shares, dtype=np.float64)
    if shares.ndim != 2:
        raise ValueError(f"shares must be (B, n_t), got shape {shares.shape}")
    B, n_t = shares.shape
    iis = np.asarray(init_intervals, dtype=np.float64)
    if iis.shape != (n_t,):
        raise ValueError(f"init_intervals must have length {n_t}")
    t_slr_arr = fleet.t_slr_arr
    t_cfg_arr = fleet.t_cfg_arr
    n_f = fleet.n_f
    resume_cost = t_capture + t_store

    # Per-row simulation state (mirrors the scalar walk's locals).
    j = np.zeros(B, dtype=np.int64)  # device cursor
    k = np.zeros(B, dtype=np.int64)  # task cursor (paper's sti)
    c = np.full(B, t_slr_arr[0] if n_f else 0.0, dtype=np.float64)
    tsd = np.zeros(B, dtype=np.float64)  # carried share of task k
    dead = np.zeros(B, dtype=bool)
    n_splits = np.zeros(B, dtype=np.int64)
    devices_used = np.zeros(B, dtype=np.int64)

    if n_t == 0:
        return BatchPlacement(
            feasible=np.ones(B, dtype=bool),
            placed_tasks=k,
            n_splits=n_splits,
            devices_used=devices_used,
        )

    while True:
        act = np.flatnonzero(~dead & (k < n_t))
        if act.size == 0:
            break
        jj = j[act]
        kk = k[act]
        cc = c[act]
        ii = iis[kk]
        tcfg = t_cfg_arr[jj]
        carried = tsd[act] > _EPS
        extra = np.where(carried, ii if repay_init else resume_cost, 0.0)
        rem = shares[act, kk] - tsd[act]
        avail = (cc - tcfg) - extra
        can_start = (cc > tcfg + ii + _EPS) & (avail > _EPS)
        split = can_start & (rem - avail > _EPS)
        fits = can_start & ~split

        # Any placement (split or full) occupies the current device.
        devices_used[act] = np.where(
            can_start, np.maximum(devices_used[act], jj + 1), devices_used[act]
        )

        # Split: run `avail` here, carry the remainder to the next device.
        tsd[act] = np.where(split, tsd[act] + avail, tsd[act])
        n_splits[act] += (split & ~carried).astype(np.int64)

        # Fits: consume cfg + extra + remaining share, advance the task.
        c_after = avail - rem
        closure = fits & (c_after <= tcfg + ii + _EPS)
        c[act] = np.where(fits, c_after, c[act])
        k[act] = kk + fits.astype(np.int64)
        tsd[act] = np.where(fits, 0.0, tsd[act])

        # Device advance: no-start, split carry, or closure after a fit.
        advance = ~can_start | split | closure
        j_next = jj + advance.astype(np.int64)
        j[act] = j_next
        still_working = k[act] < n_t
        overflow = advance & (j_next >= n_f) & still_working
        dead[act] |= overflow
        refill = advance & (j_next < n_f)
        c[act] = np.where(refill, t_slr_arr[np.minimum(j_next, n_f - 1)], c[act])

    return BatchPlacement(
        feasible=(k >= n_t) & ~dead,
        placed_tasks=k,
        n_splits=n_splits,
        devices_used=devices_used,
    )


def place_combos_batch(
    combos: Sequence[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    **kw,
) -> BatchPlacement:
    """Batch-place a block of materialised TSS rows (Alg 3 entry point)."""
    shares = np.asarray([cb.shares for cb in combos], dtype=np.float64)
    iis = [t.init_interval for t in tasks]
    return place_batch(shares, iis, fleet, **kw)
