"""Batched Alg-2/Alg-3 placement — compatibility facade over the backends.

The vectorised block engine introduced in PR 1 now lives in the pluggable
backend package :mod:`repro.core.placement_backends` (the numpy loop moved
verbatim to ``numpy_backend.py``; jit'd jax and fused Pallas engines sit
beside it).  This module keeps the original entry points stable:

* :func:`place_batch` — place a ``(B, n_t)`` shares block on the fleet,
  now with a ``backend=`` knob (``"numpy"`` default, ``"scalar"``,
  ``"jax"``, ``"pallas"``, or ``"auto"``);
* :class:`BatchPlacement` — re-exported from the backend package;
* :func:`place_combos_batch` — the Alg-3 combo-block entry point.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .placement_backends import BatchPlacement, PlacementOptions, get_backend
from .task import FleetSpec, Task, TaskSetCombo

__all__ = ["BatchPlacement", "place_batch", "place_combos_batch"]


def place_batch(
    shares: np.ndarray,
    init_intervals: Sequence[float],
    fleet: FleetSpec,
    *,
    t_capture: float = 0.0,
    t_store: float = 0.0,
    repay_init: bool = True,
    backend: str = "numpy",
) -> BatchPlacement:
    """Simulate DP-wrap placement of ``B`` share rows on the fleet at once.

    ``shares`` is ``(B, n_t)`` — one power-sorted TFS row per line, tasks in
    the paper's fixed order.  Semantics (start condition, split carry,
    re-paid II / capture+store, closure) are exactly those of
    :func:`repro.core.placement.place_shares`; see that module's docstring
    for the Fig-2/3/4 pinning.  ``backend`` selects the block engine
    (:mod:`repro.core.placement_backends`); every backend agrees with the
    scalar oracle bit-for-bit.

    Example — two rows on a 2x30 fleet (``t_cfg=1``): the first fits with
    one DP-wrap split, the second still has share left after the last
    device and is rejected:

        >>> import numpy as np
        >>> from repro.core.task import FleetSpec
        >>> fleet = FleetSpec(n_f=2, t_slr=30.0, t_cfg=1.0)
        >>> bp = place_batch(
        ...     np.array([[20.0, 30.0], [40.0, 25.0]]), [1.0, 1.0], fleet)
        >>> bp.feasible.tolist(), bp.n_splits.tolist()
        ([True, False], [1, 2])
        >>> bp.first_feasible()
        0
    """
    opts = PlacementOptions(
        t_capture=t_capture, t_store=t_store, repay_init=repay_init
    )
    return get_backend(backend).place_block(
        shares, init_intervals, fleet.t_slr_arr, fleet.t_cfg_arr, opts
    )


def place_combos_batch(
    combos: Sequence[TaskSetCombo],
    tasks: Sequence[Task],
    fleet: FleetSpec,
    **kw,
) -> BatchPlacement:
    """Batch-place a block of materialised TSS rows (Alg 3 entry point)."""
    shares = np.asarray([cb.shares for cb in combos], dtype=np.float64)
    iis = [t.init_interval for t in tasks]
    return place_batch(shares, iis, fleet, **kw)
