"""Task model for PADPS-FR (paper §II, Table I/II).

A periodic hardware task ``T_i = [p_i, td_i, nv_i, II_i, {th_ij}, {pw_ij}]``:
period, input data volume, number of variants, initialization interval, and
per-variant throughput / power.  A *variant* is one hardware realisation of
the task with ``j`` parallel computation units (CUs); on the TPU fleet a
variant is a (chips, sharding) realisation of a compiled step function.

Shares follow eq. 5:  ``shr_ij = td_i / (th_ij * p_i) * t_slr``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "TaskVariant",
    "Task",
    "FleetSpec",
    "TaskSetCombo",
    "validate_tasks",
]


@dataclasses.dataclass(frozen=True)
class TaskVariant:
    """One hardware realisation of a task.

    ``cu`` is the number of parallel computation units (paper) or the
    parallelism degree of the compiled program (TPU adaptation).
    ``throughput`` is in data-units per time-unit (GB/ms in Table I,
    KB/ms in Table II, bytes/s for TPU jobs); ``power`` in mW (paper)
    or W (TPU).  ``program`` optionally names the pre-generated artifact
    (xclbin in the paper; an AOT-compiled executable key here).
    """

    cu: int
    throughput: float
    power: float
    program: str = ""

    def __post_init__(self) -> None:
        if self.throughput <= 0:
            raise ValueError(f"variant throughput must be > 0, got {self.throughput}")
        if self.power < 0:
            raise ValueError(f"variant power must be >= 0, got {self.power}")


@dataclasses.dataclass(frozen=True)
class Task:
    """A periodic hardware task (paper §II)."""

    name: str
    period: float  # p_i — completion-time requirement
    data: float  # td_i — input data volume per period
    init_interval: float  # II_i — warm-up before the task produces data
    variants: tuple[TaskVariant, ...]

    def __post_init__(self) -> None:
        if self.period <= 0:
            raise ValueError(f"{self.name}: period must be > 0")
        if self.data <= 0:
            raise ValueError(f"{self.name}: data must be > 0")
        if self.init_interval < 0:
            raise ValueError(f"{self.name}: init_interval must be >= 0")
        if not self.variants:
            raise ValueError(f"{self.name}: at least one variant required")

    @property
    def nv(self) -> int:
        return len(self.variants)

    def exec_times(self) -> np.ndarray:
        """e_ij = td_i / th_ij (eq. 2-4)."""
        return np.asarray([self.data / v.throughput for v in self.variants], dtype=np.float64)

    def shares(self, t_slr: float) -> np.ndarray:
        """shr_ij = td_i / (th_ij * p_i) * t_slr (eq. 5)."""
        return self.exec_times() / self.period * t_slr

    def powers(self) -> np.ndarray:
        return np.asarray([v.power for v in self.variants], dtype=np.float64)

    def weight(self, j: int) -> float:
        """Task weight e_ij / p_i of variant ``j`` (DP-Fair weight)."""
        return (self.data / self.variants[j].throughput) / self.period


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """The schedulable fleet: ``n_f`` devices, time slice ``t_slr``,
    reconfiguration overhead ``t_cfg`` (paper §II).

    On the TPU adaptation a *device* is a pod slice and ``t_cfg`` is the
    program-switch cost (executable load + weight resharding).
    """

    n_f: int
    t_slr: float
    t_cfg: float
    name: str = "fleet"

    def __post_init__(self) -> None:
        if self.n_f < 1:
            raise ValueError("n_f must be >= 1")
        if self.t_slr <= 0:
            raise ValueError("t_slr must be > 0")
        if self.t_cfg < 0:
            raise ValueError("t_cfg must be >= 0")

    @property
    def capacity(self) -> float:
        """Total HPC capacity per slice: t_slr * n_f (eq. 6 RHS)."""
        return self.t_slr * self.n_f

    def workable_budget(self, n_t: int, extra_cfgs: int = 1) -> float:
        """RHS of the workability condition eq. 7.

        The paper's eq. 7 text charges ``n_t * t_cfg`` (one configuration
        per task), but its published counts (620 TFS in Example 1, 6 in
        Example 3) only emerge from ``(n_t + 1) * t_cfg`` — one extra
        reconfiguration for the DP-wrap split task (Fig 2 indeed shows 7
        configurations for 6 tasks).  We default to the implemented
        condition (``extra_cfgs=1``) and expose the knob; the discrepancy
        is documented in EXPERIMENTS.md.
        """
        return self.n_f * self.t_slr - (n_t + extra_cfgs) * self.t_cfg

    def with_devices(self, n_f: int) -> "FleetSpec":
        return dataclasses.replace(self, n_f=n_f)


@dataclasses.dataclass(frozen=True)
class TaskSetCombo:
    """One row of the TSS list: a choice of variant index per task."""

    variant_idx: tuple[int, ...]
    shares: tuple[float, ...]
    powers: tuple[float, ...]

    @property
    def sum_shr(self) -> float:
        return float(sum(self.shares))

    @property
    def total_power(self) -> float:
        return float(sum(self.powers))

    def describe(self, tasks: Sequence[Task]) -> str:
        parts = []
        for t, j, s in zip(tasks, self.variant_idx, self.shares):
            parts.append(f"{t.variants[j].cu}CU-{t.name}(shr={s:g})")
        return ", ".join(parts)


def validate_tasks(tasks: Iterable[Task]) -> None:
    names = [t.name for t in tasks]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate task names: {names}")


def combo_count(tasks: Sequence[Task]) -> int:
    """|TSS| = prod(nv_i)."""
    return int(math.prod(t.nv for t in tasks))
