"""Task model for PADPS-FR (paper §II, Table I/II).

A periodic hardware task ``T_i = [p_i, td_i, nv_i, II_i, {th_ij}, {pw_ij}]``:
period, input data volume, number of variants, initialization interval, and
per-variant throughput / power.  A *variant* is one hardware realisation of
the task with ``j`` parallel computation units (CUs); on the TPU fleet a
variant is a (chips, sharding) realisation of a compiled step function.

Shares follow eq. 5:  ``shr_ij = td_i / (th_ij * p_i) * t_slr``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "TaskVariant",
    "Task",
    "DeviceProfile",
    "FleetSpec",
    "TaskSetCombo",
    "validate_tasks",
    "worst_case_survivor_indices",
]


def worst_case_survivor_indices(
    t_slr: np.ndarray, t_cfg: np.ndarray, k: int
) -> np.ndarray:
    """Ascending indices of the devices left alive by the worst ``k`` failures.

    The adversary removes the ``k`` devices whose loss hurts most: the
    largest-capacity ones, breaking capacity ties toward the cheaper
    reconfiguration cost (so the survivors keep the expensive-cfg
    devices), then toward the lowest index.  Deterministic and a function
    of the fleet alone — never of the candidate row — so resilience
    verdicts keep the reject-monotonicity the replanner relies on.  On a
    homogeneous fleet every k-subset of survivors is equivalent, so the
    worst case is exact; on heterogeneous fleets it is the documented
    adversary the guarantee is verified against.
    """
    t_slr = np.asarray(t_slr, dtype=np.float64)
    t_cfg = np.asarray(t_cfg, dtype=np.float64)
    n_f = t_slr.shape[0]
    if not 0 <= k < n_f:
        raise ValueError(f"resilience must satisfy 0 <= k < n_f={n_f}, got {k}")
    if k == 0:
        return np.arange(n_f)
    order = np.lexsort((np.arange(n_f), t_cfg, -t_slr))
    return np.sort(order[k:])


@dataclasses.dataclass(frozen=True)
class TaskVariant:
    """One hardware realisation of a task.

    ``cu`` is the number of parallel computation units (paper) or the
    parallelism degree of the compiled program (TPU adaptation).
    ``throughput`` is in data-units per time-unit (GB/ms in Table I,
    KB/ms in Table II, bytes/s for TPU jobs); ``power`` in mW (paper)
    or W (TPU).  ``program`` optionally names the pre-generated artifact
    (xclbin in the paper; an AOT-compiled executable key here).
    """

    cu: int
    throughput: float
    power: float
    program: str = ""

    def __post_init__(self) -> None:
        if self.throughput <= 0:
            raise ValueError(f"variant throughput must be > 0, got {self.throughput}")
        if self.power < 0:
            raise ValueError(f"variant power must be >= 0, got {self.power}")


@dataclasses.dataclass(frozen=True)
class Task:
    """A periodic hardware task (paper §II)."""

    name: str
    period: float  # p_i — completion-time requirement
    data: float  # td_i — input data volume per period
    init_interval: float  # II_i — warm-up before the task produces data
    variants: tuple[TaskVariant, ...]

    def __post_init__(self) -> None:
        if self.period <= 0:
            raise ValueError(f"{self.name}: period must be > 0")
        if self.data <= 0:
            raise ValueError(f"{self.name}: data must be > 0")
        if self.init_interval < 0:
            raise ValueError(f"{self.name}: init_interval must be >= 0")
        if not self.variants:
            raise ValueError(f"{self.name}: at least one variant required")

    @property
    def nv(self) -> int:
        return len(self.variants)

    def exec_times(self) -> np.ndarray:
        """e_ij = td_i / th_ij (eq. 2-4)."""
        return np.asarray([self.data / v.throughput for v in self.variants], dtype=np.float64)

    def shares(self, t_slr: float) -> np.ndarray:
        """shr_ij = td_i / (th_ij * p_i) * t_slr (eq. 5)."""
        return self.exec_times() / self.period * t_slr

    def powers(self) -> np.ndarray:
        return np.asarray([v.power for v in self.variants], dtype=np.float64)

    def weight(self, j: int) -> float:
        """Task weight e_ij / p_i of variant ``j`` (DP-Fair weight)."""
        return (self.data / self.variants[j].throughput) / self.period


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One fleet device: its slice capacity, reconfiguration overhead and
    hardware class.

    The source paper assumes a homogeneous FPGA fleet; real data-center
    fleets mix FPGAs (large ``t_cfg`` — full/partial bitstream load),
    GPUs and CPUs (``t_cfg`` ~ 0 — a kernel/program launch), and devices
    of differing effective capacity (arXiv:1908.06519, arXiv:2304.04488).
    """

    t_slr: float
    t_cfg: float
    klass: str = "fpga"

    def __post_init__(self) -> None:
        if self.t_slr <= 0:
            raise ValueError("device t_slr must be > 0")
        if self.t_cfg < 0:
            raise ValueError("device t_cfg must be >= 0")


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """The schedulable fleet (paper §II, generalised to heterogeneity).

    Homogeneous form (the paper's): ``n_f`` devices, time slice ``t_slr``,
    reconfiguration overhead ``t_cfg``.  Heterogeneous form: per-device
    :class:`DeviceProfile` tuples built with :meth:`heterogeneous`; the
    scalar ``t_slr`` then serves as the *reference* slice used by eq. 5
    shares (``shr_ij = e_ij / p_i * t_slr``) while each device ``j``
    contributes its own capacity ``t_slr_j`` and pays its own ``t_cfg_j``.

    On the TPU adaptation a *device* is a pod slice and ``t_cfg`` is the
    program-switch cost (executable load + weight resharding).
    """

    n_f: int
    t_slr: float
    t_cfg: float
    name: str = "fleet"
    devices: tuple[DeviceProfile, ...] = ()

    def __post_init__(self) -> None:
        if self.n_f < 1:
            raise ValueError("n_f must be >= 1")
        if self.t_slr <= 0:
            raise ValueError("t_slr must be > 0")
        if self.t_cfg < 0:
            raise ValueError("t_cfg must be >= 0")
        if self.devices and len(self.devices) != self.n_f:
            raise ValueError(
                f"devices has {len(self.devices)} profiles but n_f={self.n_f}"
            )

    @classmethod
    def heterogeneous(
        cls, devices: Sequence[DeviceProfile], *, name: str = "hetero-fleet"
    ) -> "FleetSpec":
        """Fleet from per-device profiles; reference t_slr is the maximum
        device slice (shares are defined against the largest device)."""
        devices = tuple(devices)
        if not devices:
            raise ValueError("at least one device profile required")
        return cls(
            n_f=len(devices),
            t_slr=max(d.t_slr for d in devices),
            t_cfg=max(d.t_cfg for d in devices),
            name=name,
            devices=devices,
        )

    @property
    def is_heterogeneous(self) -> bool:
        return bool(self.devices)

    def profile(self, j: int) -> DeviceProfile:
        if self.devices:
            return self.devices[j]
        return DeviceProfile(t_slr=self.t_slr, t_cfg=self.t_cfg)

    def t_slr_of(self, j: int) -> float:
        return self.devices[j].t_slr if self.devices else self.t_slr

    def t_cfg_of(self, j: int) -> float:
        return self.devices[j].t_cfg if self.devices else self.t_cfg

    @property
    def t_slr_arr(self) -> np.ndarray:
        """Per-device capacities ``t_slr_j`` as an (n_f,) float64 array."""
        if self.devices:
            return np.asarray([d.t_slr for d in self.devices], dtype=np.float64)
        return np.full(self.n_f, self.t_slr, dtype=np.float64)

    @property
    def t_cfg_arr(self) -> np.ndarray:
        """Per-device reconfiguration costs ``t_cfg_j`` as (n_f,) float64."""
        if self.devices:
            return np.asarray([d.t_cfg for d in self.devices], dtype=np.float64)
        return np.full(self.n_f, self.t_cfg, dtype=np.float64)

    @property
    def t_cfg_min(self) -> float:
        return min(d.t_cfg for d in self.devices) if self.devices else self.t_cfg

    @property
    def t_cfg_max(self) -> float:
        return max(d.t_cfg for d in self.devices) if self.devices else self.t_cfg

    @property
    def capacity(self) -> float:
        """Total HPC capacity per slice: sum_j t_slr_j (eq. 6 RHS)."""
        if self.devices:
            return float(sum(d.t_slr for d in self.devices))
        return self.t_slr * self.n_f

    def workable_budget(self, n_t: int, extra_cfgs: int = 1) -> float:
        """RHS of the workability condition eq. 7.

        The paper's eq. 7 text charges ``n_t * t_cfg`` (one configuration
        per task), but its published counts (620 TFS in Example 1, 6 in
        Example 3) only emerge from ``(n_t + 1) * t_cfg`` — one extra
        reconfiguration for the DP-wrap split task (Fig 2 indeed shows 7
        configurations for 6 tasks).  We default to the implemented
        condition (``extra_cfgs=1``) and expose the knob; the discrepancy
        is documented in EXPERIMENTS.md.

        Heterogeneous fleets charge the *minimum* per-device ``t_cfg`` —
        the loosest reading of eq. 7, so the heterogeneous pre-filter
        rejects no combo the paper's homogeneous charge would keep (a
        combo Alg 2 could still place on the cheap-cfg devices must not
        be pre-rejected); the tighter per-class refinement lives in
        :func:`repro.core.feasibility.config_overhead_lower_bound`.
        """
        return self.capacity - (n_t + extra_cfgs) * self.t_cfg_min

    def survivors(self, k: int) -> "FleetSpec":
        """Worst-case surviving fleet after any ``k`` device failures.

        This is the backup fleet the resilience mode verifies against
        (see :func:`worst_case_survivor_indices` for the adversary).  The
        reference ``t_slr``/``t_cfg`` scalars are preserved so eq-5
        shares stay defined against the original fleet; only the device
        set shrinks.  ``k=0`` returns ``self``; ``k >= n_f`` is a
        ``ValueError`` — no plan survives losing every device.
        """
        k = int(k)
        if not 0 <= k < self.n_f:
            raise ValueError(
                f"resilience must satisfy 0 <= k < n_f={self.n_f}, got {k}"
            )
        if k == 0:
            return self
        if not self.devices:
            return dataclasses.replace(self, n_f=self.n_f - k)
        keep = worst_case_survivor_indices(self.t_slr_arr, self.t_cfg_arr, k)
        return dataclasses.replace(
            self,
            n_f=self.n_f - k,
            devices=tuple(self.devices[int(j)] for j in keep),
        )

    def with_devices(self, n_f: int) -> "FleetSpec":
        """Resize the fleet.  Heterogeneous fleets repeat their device
        pattern round-robin (the sweep semantics of Figs 5-7)."""
        if not self.devices:
            return dataclasses.replace(self, n_f=n_f)
        profiles = tuple(self.devices[j % len(self.devices)] for j in range(n_f))
        return dataclasses.replace(self, n_f=n_f, devices=profiles)

    def with_t_cfg(self, t_cfg: float) -> "FleetSpec":
        """Rescale reconfiguration cost (the Fig 5-7 t_cfg sweeps).
        Heterogeneous device cfgs scale proportionally to preserve the
        class mix (a GPU's ~0 cfg stays ~0).  A heterogeneous fleet whose
        devices all reconfigure for free has nothing to rescale and is
        returned unchanged."""
        if not self.devices:
            return dataclasses.replace(self, t_cfg=t_cfg)
        if self.t_cfg == 0:
            return self
        scale = t_cfg / self.t_cfg
        profiles = tuple(
            dataclasses.replace(d, t_cfg=d.t_cfg * scale) for d in self.devices
        )
        return dataclasses.replace(self, t_cfg=t_cfg, devices=profiles)


@dataclasses.dataclass(frozen=True)
class TaskSetCombo:
    """One row of the TSS list: a choice of variant index per task."""

    variant_idx: tuple[int, ...]
    shares: tuple[float, ...]
    powers: tuple[float, ...]

    @property
    def sum_shr(self) -> float:
        return float(sum(self.shares))

    @property
    def total_power(self) -> float:
        return float(sum(self.powers))

    def describe(self, tasks: Sequence[Task]) -> str:
        parts = []
        for t, j, s in zip(tasks, self.variant_idx, self.shares, strict=True):
            parts.append(f"{t.variants[j].cu}CU-{t.name}(shr={s:g})")
        return ", ".join(parts)


def validate_tasks(tasks: Iterable[Task]) -> None:
    names = [t.name for t in tasks]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate task names: {names}")


def combo_count(tasks: Sequence[Task]) -> int:
    """|TSS| = prod(nv_i)."""
    return int(math.prod(t.nv for t in tasks))
