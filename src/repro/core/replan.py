"""Delta replanning: warm-start the Alg 1+2 walk from a previous plan.

A long-running fleet (:mod:`repro.service`) sees task arrivals, task
exits and device failures continuously; re-running the full power-sorted
TFS walk from scratch on every event is wasted work when almost
everything about the instance is unchanged.  This module makes one
``schedule()`` pay for the events that follow it:

* :func:`schedule_recorded` runs the normal streaming walk but snapshots
  a :class:`PlanState` — every emitted TFS row (power, folded eq-7 share
  sum, variant choice), every placement verdict the walk actually
  resolved, and the live :class:`~repro.core.feasibility.BlockEnumerator`
  (the surviving branch-and-bound frontier) at the point the walk
  stopped.
* :func:`replan` reschedules a new task tuple / fleet from that state.
  Three deltas take a warm path — an **arrival** (tasks appended to the
  state's root task tuple), an **exit** (one task removed) and a
  **device failure** (one device dropped, reference ``t_slr``
  preserved); anything else falls back to a fresh recorded walk that
  still seeds the projected previous winner as an *incumbent* upper
  power bound.

Every warm path reduces the event to the same shape: build the exact
set of new-TFS rows with total power at or below an incumbent bound
``P_inc`` (each row carrying the bit-exact left-to-right float64 folds a
cold enumeration would produce), order them by the cold emission key
``(total_power, TSS flat index)``, transfer recorded placement verdicts
where provably sound, and walk the ordered candidates through the
backend dispatching only the unknowns.  The first placeable row is the
cold winner at the cold rank with the cold plan — bit-identical,
including under ``resilience=k`` (`tests/test_service_replay.py` pins
this over randomized event traces, engines and k).

Soundness facts per delta
-------------------------

**Arrival** (``T' = root + appended``): eq-7's budget shrinks and the
heterogeneous overhead bound grows as tasks are appended, so every
workable row of ``T'`` restricts to a workable row of the root — the new
TFS is a filtered cross product of already-enumerated root rows with the
appended tasks' variants.  Recorded *rejects* transfer to every
extension (the placement simulator walks tasks in order, so a failing
prefix fails forever); placeable verdicts do not.

**Exit** (task at position ``p`` removed): the budget *grows*, so the
new TFS is the recorded rows projected onto the surviving columns
(dedup over the dropped variant axis) **plus** a gap: rows whose every
extension broke the old budget and were therefore never enumerated.
The gap walk is a fresh enumeration of the shrunken task set whose
subtrees are pruned whenever provably *covered* by the recording —
covered means some extension passed the old eq-7, and because the eq-7
pass is antitone in the folded share sum (heterogeneous overhead is
monotone), it suffices to test the removed task's minimum-share variant.
Recorded placeable verdicts transfer to the projection only when ``p``
is the last position (the simulator's first ``n-1`` steps are exactly
the shrunken instance's walk).  Rejects transfer through the recorded
**death depth**: the placement simulator walks tasks in order, so its
primary sweep dying at depth ``d`` (``d`` tasks fully placed, task
``d`` unplaceable) is a fact about tasks ``0..d`` and the fleet alone
— a recorded row that died at ``d < p`` rejects on the shrunken
instance too, whatever sits after position ``p``.  Rows that died at
or past ``p`` (or whose reject came from the resilience survivor
sweep, which reports depth ``n``) never transfer.

**Failure** (device dropped, same reference ``t_slr`` so recorded share
folds keep their meaning): task set and variants are unchanged, so
candidates are the recorded rows re-checked against the shrunken
fleet's eq-7.  On a homogeneous fleet the budget is float-monotone in
``n_f`` so the new TFS is a subset of the old (no gap walk) and the
smaller fleet is a device-prefix of the old — recorded rejects transfer
for any ``k``.  On a heterogeneous fleet rejects transfer only when the
*last* device dropped with ``k=0`` (survivor prefix), and a covered-gap
walk against the old fleet's eq-7 recovers rows the old enumeration
pruned.

State carry-over
----------------

Each warm replan emits a *live* state, not a thin one: the ordered
candidate band with its learned verdicts becomes the new ``rec_*``
arrays, ``complete_below`` records the band's coverage bound (``P_inc``,
or ``inf`` when the source state was exhaustive and no incumbent
bounded the walk), and arrival states keep a one-hop ``base`` pointer
to the exhaustive root so consecutive arrivals re-run the cross product
against the root's full recording (``appended`` grows by one task per
event) instead of going cold.  ``origin`` tags the path that built the
state (``cold`` / ``warm_arrival`` / ``warm_exit`` / ``warm_failure``)
— :class:`repro.service.SchedulerService` maps it to telemetry and
bounds chain staleness with a background re-record policy keyed on
:attr:`PlanState.frontier_coverage`.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from .feasibility import (
    BlockEnumerator,
    _emission_order,
    _suffix_max_bounds,
    config_overhead_lower_bound,
)
from .placement import place_combo, place_shares
from .placement_backends import PlacementBackend, PlacementOptions
from .scheduler import (
    ScheduleResult,
    WalkStats,
    _block_size_schedule,
    _resilience_infeasible_result,
    _walk_tfs_blocks,
)
from .task import FleetSpec, Task, TaskSetCombo, combo_count

__all__ = [
    "PlanState",
    "VERDICT_REJECT",
    "VERDICT_PLACEABLE",
    "VERDICT_UNKNOWN",
    "schedule_recorded",
    "replan",
]

# Per-row placement verdicts recorded by the walk.  A recorded verdict is
# always a *truth* about (tasks, fleet, options) — transfers across
# events only happen where the soundness facts above allow, so chained
# warm states never launder a guess into a fact.
VERDICT_REJECT = 0
VERDICT_PLACEABLE = 1
VERDICT_UNKNOWN = 2

_WARM_BLOCK = 4096  # dispatch block size for the candidate mini-walk
_WARM_PROBE = 6  # scalar-oracle prefix probes before block dispatch
_EXIT_CAP = 65536  # phase-1 parent-row cap for the exit projection

# Adaptive guard for the arrival cross product: candidate generation
# touches prod(appended variant counts) * recorded-rows floats; past
# this, a fresh bounded walk is cheaper than the projection.
_APPEND_CELL_CAP = 64_000_000


@dataclasses.dataclass
class PlanState:
    """Everything a later :func:`replan` can reuse from one walk.

    ``rec_*`` arrays hold rows of the instance's power-ordered TFS
    exactly as emitted (power and eq-7 share sum are the enumerator's
    own left-to-right folds).  Together with ``enum`` (which resumes
    emission where the recording stopped; ``None`` once drained or for
    warm states) they cover every TFS row with total power ``<=
    complete_below`` — ``inf`` for an exhaustive or unbounded cold walk,
    the incumbent band for warm states, ``-inf`` for a thin state with
    no coverage claim.  ``enum`` is private mutable state — replanners
    only ever touch a :meth:`BlockEnumerator.clone` of it.

    ``origin`` names the path that built the state; ``base`` points a
    warm-arrival state back at the exhaustive root it projected from
    (one hop, never a chain) with ``appended`` holding the tasks beyond
    the root's tuple.
    """

    tasks: tuple[Task, ...]
    fleet: FleetSpec
    engine: str  # backend name whose verdicts rec_verdict holds
    placement_kw: dict
    result: ScheduleResult = dataclasses.field(repr=False)
    rec_pow: np.ndarray = dataclasses.field(repr=False)  # (R,) float64
    rec_sumshr: np.ndarray = dataclasses.field(repr=False)  # (R,) float64
    rec_chosen: np.ndarray = dataclasses.field(repr=False)  # (R, n_t) int64
    rec_verdict: np.ndarray = dataclasses.field(repr=False)  # (R,) int8
    # (R,) int16 — tasks the *primary* placement sweep fully placed when
    # the row was dispatched (-1 = never dispatched / fleet changed since).
    # A row that died at depth d rejects on every instance sharing tasks
    # 0..d on the same fleet — the exit path's reject-transfer key.
    rec_depth: np.ndarray = dataclasses.field(repr=False)
    enum: BlockEnumerator | None = dataclasses.field(repr=False)
    complete_below: float = np.inf
    origin: str = "cold"
    base: "PlanState | None" = dataclasses.field(default=None, repr=False)
    appended: tuple[Task, ...] = ()

    @property
    def n_recorded(self) -> int:
        return int(self.rec_pow.size)

    @property
    def frontier_coverage(self) -> float:
        """How much of a fresh exhaustive recording this state retains,
        in [0, 1].  Chain states inherit their root's coverage (the root
        is what their replans consume); a banded state is worth at most
        half an exhaustive one (band reuse works, appends from it
        usually cannot), scaled by its known-verdict fraction.  The
        service's re-record policy triggers below a threshold."""
        if self.base is not None:
            return self.base.frontier_coverage
        if self.complete_below == -np.inf:
            return 0.0
        if self.complete_below == np.inf:
            return 1.0
        if not self.n_recorded:
            return 0.0
        known = float((self.rec_verdict != VERDICT_UNKNOWN).mean())
        return 0.5 * known


class _Recorder:
    """Accumulates emitted blocks + resolved verdicts during one walk."""

    def __init__(self, n_t: int) -> None:
        self._n_t = n_t
        self._chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._verdicts: dict[int, np.ndarray] = {}  # rank_base -> int8 block
        self._depths: dict[int, np.ndarray] = {}  # rank_base -> int16 block
        self._bases: list[int] = []
        self._total = 0

    def on_emit(self, blk) -> None:
        self._chunks.append((blk.total_power, blk.sum_shr, blk.variant_idx))
        self._bases.append(self._total)
        self._total += len(blk)

    def on_verdict(
        self, base: int, feasible: np.ndarray, placed: np.ndarray
    ) -> None:
        self._verdicts[base] = np.where(
            feasible, VERDICT_PLACEABLE, VERDICT_REJECT
        ).astype(np.int8)
        self._depths[base] = placed.astype(np.int16)

    def arrays(
        self,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        if not self._chunks:
            return (
                np.empty(0),
                np.empty(0),
                np.empty((0, self._n_t), dtype=np.int64),
                np.empty(0, dtype=np.int8),
                np.empty(0, dtype=np.int16),
            )
        pow_ = np.concatenate([c[0] for c in self._chunks])
        sumshr = np.concatenate([c[1] for c in self._chunks])
        chosen = np.concatenate([c[2] for c in self._chunks], axis=0)
        verdict = np.full(self._total, VERDICT_UNKNOWN, dtype=np.int8)
        for base, v in self._verdicts.items():
            verdict[base : base + v.size] = v
        depth = np.full(self._total, -1, dtype=np.int16)
        for base, d in self._depths.items():
            depth[base : base + d.size] = d
        return pow_, sumshr, chosen, verdict, depth


def _eq7_leaf_mask(
    fleet: FleetSpec, n_t: int, w: np.ndarray, resilience: int = 0
) -> np.ndarray:
    """The enumerator's leaf-level eq-7 test, bit-identical (same float64
    comparisons as :meth:`BlockEnumerator._passes` on a completed row).
    ``resilience`` switches to the worst-case survivor fleet's budget,
    matching the enumerator's resilience-mode pruning."""
    bfleet = fleet.survivors(resilience) if resilience and n_t else fleet
    ok = w <= bfleet.workable_budget(n_t) + 1e-9
    if bfleet.is_heterogeneous and ok.any():
        overhead = config_overhead_lower_bound(bfleet, n_t, w)
        ok &= ~(w > bfleet.capacity - overhead + 1e-9)
    return ok


def _combo_from_idx(
    idx: Sequence[int],
    share_vecs: Sequence[np.ndarray],
    power_vecs: Sequence[np.ndarray],
) -> TaskSetCombo:
    return TaskSetCombo(
        tuple(int(j) for j in idx),
        tuple(float(v[j]) for v, j in zip(share_vecs, idx, strict=True)),
        tuple(float(v[j]) for v, j in zip(power_vecs, idx, strict=True)),
    )


def schedule_recorded(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    backend: PlacementBackend,
    *,
    block_size: int | None = None,
    count_all_rejects: bool = False,
    walk_stats: WalkStats | None = None,
    incumbent_power: float | None = None,
    exhaustive: bool = False,
    **placement_kw,
) -> ScheduleResult:
    """The streaming ``schedule()`` walk, with :class:`PlanState` capture.

    Identical winner/rank/reject bookkeeping to the cold streaming path —
    the only additions are the recorder taps and the optional
    ``incumbent_power`` bound, which prunes rows *after* the winner-to-be
    (emission is power-ordered, so every row up to and including the
    winner survives the bound and the result is unchanged).

    ``exhaustive`` keeps walking past the winner so *every* TFS row gets
    a recorded placement verdict and the enumerator drains dry.  The
    reported result is still bit-identical to the cold default (rank
    rejects, same winner); what changes is the state's warmth — a later
    arrival replan needs no band drain and dispatches only extensions of
    known-placeable rows.  Pay once, replan cheap thereafter: this is the
    service layer's steady-state mode.
    """
    tasks = tuple(tasks)
    k_res = int(placement_kw.get("resilience", 0))
    if k_res >= fleet.n_f and tasks:
        # A fleet that cannot survive k failures admits nothing; answered
        # here (not just in the facade) because replans re-enter after
        # fleet shrinkage.  Thin state: the next replan walks fresh.
        res = _resilience_infeasible_result(tasks)
        res.plan_state = _thin_state(tasks, fleet, backend, placement_kw, res)
        return res
    enum = BlockEnumerator(tasks, fleet, resilience=k_res)
    complete_below = np.inf
    if incumbent_power is not None:
        enum.prune_above(incumbent_power)
        complete_below = float(incumbent_power)
    sizes = _block_size_schedule(block_size)
    rec = _Recorder(len(tasks))

    def blocks():
        while True:
            blk = enum.next_block(next(sizes))
            if blk is None:
                return
            rec.on_emit(blk)
            yield blk.shares, blk

    combo, plan, rank, rejects = _walk_tfs_blocks(
        blocks(),
        lambda blk, r: blk.materialize(r),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects or exhaustive,
        walk_stats=walk_stats,
        on_verdict=rec.on_verdict,
        **placement_kw,
    )
    if exhaustive and not count_all_rejects and combo is not None:
        rejects = rank  # mirror the cold default's stop-at-winner count
    res = ScheduleResult(
        feasible=combo is not None,
        combo=combo,
        plan=plan,
        chosen_rank=rank,
        n_tss=combo_count(tasks),
        n_tfs=-1,
        n_tnfs=-1,
        n_placement_rejects=rejects,
        total_power=combo.total_power if combo else float("inf"),
    )
    rec_pow, rec_sumshr, rec_chosen, rec_verdict, rec_depth = rec.arrays()
    res.plan_state = PlanState(
        tasks=tasks,
        fleet=fleet,
        engine=backend.name,
        placement_kw=dict(placement_kw),
        result=res,
        rec_pow=rec_pow,
        rec_sumshr=rec_sumshr,
        rec_chosen=rec_chosen,
        rec_verdict=rec_verdict,
        rec_depth=rec_depth,
        enum=enum,
        complete_below=complete_below,
    )
    return res


def replan(
    state: PlanState,
    tasks: Sequence[Task],
    *,
    backend: PlacementBackend,
    fleet: FleetSpec | None = None,
    block_size: int | None = None,
    walk_stats: WalkStats | None = None,
    record_exhaustive: bool = False,
    **placement_kw,
) -> ScheduleResult:
    """Reschedule ``tasks`` (on ``fleet``) reusing whatever ``state``
    makes sound.

    Warm dispatch, in preference order (backend/options must match the
    state's, so recorded verdicts and folds are meaningful):

    * ``tasks`` extends the state's *root* task tuple on an unchanged
      fleet — cross-product arrival path (consecutive arrivals chain
      through the root via :attr:`PlanState.base`, so the second and
      later arrivals stay warm too);
    * ``tasks`` removes exactly one of ``state.tasks`` on an unchanged
      fleet — projection exit path;
    * ``tasks`` unchanged but ``fleet`` drops one device of
      ``state.fleet`` (same reference ``t_slr``) — failure path.

    Anything else — or a warm path declining because the state's band
    cannot cover the event — falls back to an incumbent-seeded fresh
    recorded walk (``record_exhaustive=True`` makes that walk drain the
    enumerator so the fallback restores full warmth, the service
    layer's choice).  Always bit-identical to a cold ``schedule(tasks)``
    on the target fleet.
    """
    tasks = tuple(tasks)
    if fleet is None:
        fleet = state.fleet
    if tasks == state.tasks and fleet == state.fleet:
        return state.result
    compatible = (
        backend.name == state.engine and dict(placement_kw) == state.placement_kw
    )
    if compatible and fleet == state.fleet:
        root = state.base if state.base is not None else state
        nb = len(root.tasks)
        if root.fleet == fleet and len(tasks) >= nb and tasks[:nb] == root.tasks:
            if len(tasks) == nb:
                return root.result
            out = _replan_append(
                root,
                tasks[nb:],
                cur_tasks=state.tasks,
                cur_result=state.result,
                backend=backend,
                walk_stats=walk_stats,
                **placement_kw,
            )
            if out is not None:
                return out
        if tasks and len(tasks) == len(state.tasks) - 1:
            p = _removed_position(state.tasks, tasks)
            if p is not None:
                out = _replan_exit(
                    state, p, backend=backend, walk_stats=walk_stats, **placement_kw
                )
                if out is not None:
                    return out
                # Arrival-chained state losing a *root* task: the chain
                # state's band rarely covers the exit horizon, but the
                # (usually exhaustive) root does.  Project the exit out
                # of the root, then re-append the chain's arrivals —
                # both hops warm, both exact.
                if state.base is not None and p < nb and nb >= 2 and state.appended:
                    # Band headroom for the re-append hop: its incumbent
                    # is at most the current winner minus the exiting
                    # task's chosen variant, and its band reaches down
                    # by the appended tasks' cheapest variants.
                    mb = None
                    if state.result.feasible:
                        tot = state.result.total_power
                        pw_p = float(
                            state.tasks[p].powers()[
                                state.result.combo.variant_idx[p]
                            ]
                        )
                        min_app = sum(
                            float(t.powers().min()) for t in state.appended
                        )
                        mb = tot - pw_p - min_app + 1e-6 * max(1.0, abs(tot))
                    mid = _replan_exit(
                        root,
                        p,
                        backend=backend,
                        walk_stats=walk_stats,
                        min_band=mb,
                        **placement_kw,
                    )
                    if mid is not None and mid.plan_state is not None:
                        out = _replan_append(
                            mid.plan_state,
                            state.appended,
                            cur_tasks=state.tasks,
                            cur_result=state.result,
                            backend=backend,
                            walk_stats=walk_stats,
                            origin="warm_exit",
                            **placement_kw,
                        )
                        if out is not None:
                            return out
    elif compatible and tasks == state.tasks:
        dropped = _dropped_device(state.fleet, fleet)
        if dropped is not None:
            out = _replan_failure(
                state,
                fleet,
                dropped,
                backend=backend,
                walk_stats=walk_stats,
                **placement_kw,
            )
            if out is not None:
                return out
            # Same two-hop rescue as the exit chain: replay the failure
            # against the exhaustive root, then re-append the chain's
            # arrivals on the shrunken fleet.
            if state.base is not None and state.appended:
                mb = None
                if state.result.feasible:
                    tot = state.result.total_power
                    min_app = sum(
                        float(t.powers().min()) for t in state.appended
                    )
                    mb = tot - min_app + 1e-6 * max(1.0, abs(tot))
                mid = _replan_failure(
                    state.base,
                    fleet,
                    dropped,
                    backend=backend,
                    walk_stats=walk_stats,
                    min_band=mb,
                    **placement_kw,
                )
                if mid is not None and mid.plan_state is not None:
                    out = _replan_append(
                        mid.plan_state,
                        state.appended,
                        cur_tasks=state.tasks,
                        cur_result=state.result,
                        backend=backend,
                        walk_stats=walk_stats,
                        origin="warm_failure",
                        **placement_kw,
                    )
                    if out is not None:
                        return out
    return _replan_general(
        state,
        tasks,
        fleet,
        backend=backend,
        block_size=block_size,
        walk_stats=walk_stats,
        exhaustive=record_exhaustive,
        **placement_kw,
    )


def _removed_position(
    old: tuple[Task, ...], new: tuple[Task, ...]
) -> int | None:
    """Position ``p`` with ``old`` minus ``old[p]`` == ``new``, else None."""
    p = len(new)
    for i, (a, b) in enumerate(zip(old, new, strict=False)):
        if a != b:
            p = i
            break
    return p if old[:p] + old[p + 1 :] == new else None


def _dropped_device(old: FleetSpec, new: FleetSpec) -> int | None:
    """Index of the single device whose removal turns ``old`` into
    ``new``, or None when the edit is not a one-device drop (or changes
    the reference ``t_slr`` — recorded share folds would be meaningless).

    On a homogeneous fleet every device is interchangeable, so the
    *last* index is reported; ties in a heterogeneous fleet also prefer
    the last matching index (it is the one position whose drop keeps the
    survivor set a prefix, enabling reject transfer at ``k=0``)."""
    if new.n_f != old.n_f - 1 or new.n_f < 1 or new.t_slr != old.t_slr:
        return None
    if not old.is_heterogeneous:
        if not new.is_heterogeneous and (
            dataclasses.replace(old, n_f=new.n_f, name=new.name) == new
        ):
            return new.n_f
        return None
    if not new.is_heterogeneous:
        return None
    devs = old.devices
    for i in range(old.n_f - 1, -1, -1):
        if new.devices != devs[:i] + devs[i + 1 :]:
            continue
        # The scalar t_cfg must also be what a pure drop recomputes.
        if FleetSpec.heterogeneous(new.devices, name=new.name) == new:
            return i
        return None
    return None


def _probe_row(
    shares_row: np.ndarray,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    opts: PlacementOptions,
) -> tuple[bool, int]:
    """Scalar-oracle placement probe: ``(feasible, primary death depth)``.

    Depth counts the tasks the *primary* sweep fully placed — ``n_t``
    when placement walked past the last task (whatever the resilience
    survivor sweep then said), matching the block backends'
    ``placed_tasks`` semantics.
    """
    plan = place_shares(
        [float(s) for s in shares_row],
        [t.init_interval for t in tasks],
        fleet,
        t_capture=opts.t_capture,
        t_store=opts.t_store,
        repay_init=opts.repay_init,
        resilience=opts.resilience,
    )
    depth = min(plan.unplaced) if plan.unplaced else len(tasks)
    return bool(plan.feasible), depth


def _row_placeable(
    shares_row: np.ndarray,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    backend: PlacementBackend,
    opts: PlacementOptions,
) -> bool:
    """Single-row placement probe via the scalar oracle.

    Every backend must agree bit-for-bit with ``place_shares`` (the
    engine contract, asserted in ``tests/test_placement_backends.py``),
    so a one-row probe can skip the vectorized block sweep — whose
    per-iteration numpy overhead dwarfs the work at B=1 — and ask the
    oracle directly.  ``backend`` stays in the signature: probes are
    backend-truths the verdict arrays record, and a future engine with a
    cheaper resident probe would hook in here.
    """
    del backend
    return _probe_row(shares_row, tasks, fleet, opts)[0]


def _replan_general(
    state: PlanState,
    tasks: tuple[Task, ...],
    fleet: FleetSpec,
    *,
    backend: PlacementBackend,
    block_size: int | None,
    walk_stats: WalkStats | None,
    exhaustive: bool = False,
    **placement_kw,
) -> ScheduleResult:
    """Bulk deltas and declined warm paths: fresh recorded walk, seeded
    with the old winner projected onto the new task tuple as an
    incumbent.

    The projection keeps each surviving task's previous variant choice;
    it is only a *bound*, verified from scratch (eq. 7 + a placement
    probe) against the new instance and fleet, so no monotonicity
    assumption about the delta is needed — if the probe fails, the walk
    simply runs unbounded and the replan degrades to a plain cold
    recorded walk.  ``exhaustive`` skips the incumbent bound entirely:
    the point is then a full re-recording (the service's re-anchoring
    fallback), and a pruned walk could not claim ``complete_below=inf``.
    """
    incumbent = None
    k_res = int(placement_kw.get("resilience", 0))
    if not exhaustive and state.result.feasible and k_res < fleet.n_f:
        prev = {
            t.name: j
            for t, j in zip(state.tasks, state.result.combo.variant_idx, strict=True)
        }
        if all(t.name in prev and prev[t.name] < t.nv for t in tasks):
            share_vecs = [t.shares(fleet.t_slr) for t in tasks]
            power_vecs = [t.powers() for t in tasks]
            idx = [prev[t.name] for t in tasks]
            combo = _combo_from_idx(idx, share_vecs, power_vecs)
            w = np.asarray([float(sum(combo.shares))])
            if _eq7_leaf_mask(fleet, len(tasks), w, k_res)[0] and _row_placeable(
                np.asarray(combo.shares),
                tasks,
                fleet,
                backend,
                PlacementOptions(**placement_kw),
            ):
                incumbent = combo.total_power
    return schedule_recorded(
        tasks,
        fleet,
        backend,
        block_size=block_size,
        walk_stats=walk_stats,
        incumbent_power=incumbent,
        exhaustive=exhaustive,
        **placement_kw,
    )


def _thin_state(
    tasks: tuple[Task, ...],
    fleet: FleetSpec,
    backend: PlacementBackend,
    placement_kw: dict,
    res: ScheduleResult,
    origin: str = "cold",
) -> PlanState:
    """State with no recording/frontier (``complete_below = -inf``): the
    next replan from it silently takes the general fresh-walk path."""
    return PlanState(
        tasks=tasks,
        fleet=fleet,
        engine=backend.name,
        placement_kw=dict(placement_kw),
        result=res,
        rec_pow=np.empty(0),
        rec_sumshr=np.empty(0),
        rec_chosen=np.empty((0, len(tasks)), dtype=np.int64),
        rec_verdict=np.empty(0, dtype=np.int8),
        rec_depth=np.empty(0, dtype=np.int16),
        enum=None,
        complete_below=-np.inf,
        origin=origin,
    )


def _drain_band(
    state: PlanState, band_hi: float
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Recorded rows plus the snapshot frontier drained through
    ``band_hi`` (power-inclusive), as one emission-ordered array set.

    Sound whenever ``band_hi <= state.complete_below`` — the recording
    and the frontier then jointly cover every TFS row in the band.  The
    drain touches only a :meth:`BlockEnumerator.clone`; drained rows get
    UNKNOWN verdicts and ``-1`` depths (the original walk never
    dispatched them)."""
    chunks_pow = [state.rec_pow]
    chunks_sumshr = [state.rec_sumshr]
    chunks_chosen = [state.rec_chosen]
    chunks_verdict = [state.rec_verdict]
    chunks_depth = [state.rec_depth]
    if state.enum is not None and not state.enum.exhausted:
        resume = state.enum.clone()
        if np.isfinite(band_hi):
            resume.prune_above(band_hi)
        while True:
            blk = resume.next_block(65536)
            if blk is None:
                break
            chunks_pow.append(blk.total_power)
            chunks_sumshr.append(blk.sum_shr)
            chunks_chosen.append(blk.variant_idx)
            chunks_verdict.append(np.full(len(blk), VERDICT_UNKNOWN, dtype=np.int8))
            chunks_depth.append(np.full(len(blk), -1, dtype=np.int16))

    def _cat(chunks, axis=0):  # skip the full copy when nothing was drained
        return chunks[0] if len(chunks) == 1 else np.concatenate(chunks, axis=axis)

    return (
        _cat(chunks_pow),
        _cat(chunks_sumshr),
        _cat(chunks_chosen),
        _cat(chunks_verdict),
        _cat(chunks_depth),
    )


def _walk_candidates(
    cand_chosen: np.ndarray,
    cand_verdict: np.ndarray,
    cand_depth: np.ndarray,
    tasks: tuple[Task, ...],
    fleet: FleetSpec,
    backend: PlacementBackend,
    opts: PlacementOptions,
    walk_stats: WalkStats | None,
) -> tuple[int, np.ndarray, np.ndarray]:
    """Walk emission-ordered candidate rows to the first placeable one.

    Every verdict in ``cand_verdict`` is a *truth* about this exact
    (tasks, fleet, options) instance, so the walk can stop at the first
    known-PLACEABLE row without dispatching it and skip every
    known-REJECT row (they only count toward the winner's rank by
    position).  UNKNOWN rows before the stop point are dispatched in
    power order, exactly the rows a cold walk would have dispatched.

    Returns ``(win, verdicts, depths)``: the winner's candidate index
    (``-1`` when nothing places) plus the verdict and death-depth arrays
    updated with everything the walk learned.
    """
    n_t = len(tasks)
    out = cand_verdict.copy()
    dep = cand_depth.copy()
    kp = np.flatnonzero(cand_verdict == VERDICT_PLACEABLE)
    stop = int(kp[0]) if kp.size else cand_chosen.shape[0]
    win = stop if stop < cand_chosen.shape[0] else -1
    todo = np.flatnonzero(cand_verdict[:stop] == VERDICT_UNKNOWN)
    if not todo.size:
        return win, out, dep
    share_vecs = tuple(t.shares(fleet.t_slr) for t in tasks)
    iis = [t.init_interval for t in tasks]
    # Scalar prefix probe: most warm walks settle within a handful of
    # rows, where the scalar oracle (bit-identical by the engine
    # contract) costs a fraction of a vectorized sweep's fixed overhead.
    # Only if the prefix does not settle it does the block path below
    # take over for the remaining rows.
    head = todo[: min(_WARM_PROBE, todo.size)]
    probed = 0
    for i in head:
        probed += 1
        row = np.array([share_vecs[c][cand_chosen[i, c]] for c in range(n_t)])
        ok, d = _probe_row(row, tasks, fleet, opts)
        dep[i] = d
        if ok:
            out[i] = VERDICT_PLACEABLE
            win = int(i)
            break
        out[i] = VERDICT_REJECT
    if walk_stats is not None and probed:
        walk_stats.rows += probed
        walk_stats.block_sizes.append(probed)
    if probed and out[head[probed - 1]] == VERDICT_PLACEABLE:
        return win, out, dep
    todo = todo[probed:]
    if not todo.size:
        return win, out, dep
    t_slr_arr, t_cfg_arr = fleet.t_slr_arr, fleet.t_cfg_arr
    for lo in range(0, todo.size, _WARM_BLOCK):
        sel = todo[lo : lo + _WARM_BLOCK]
        shares = np.empty((sel.size, n_t))
        ch = cand_chosen[sel]
        for c in range(n_t):
            shares[:, c] = share_vecs[c][ch[:, c]]
        bp = backend.place_block(shares, iis, t_slr_arr, t_cfg_arr, opts)
        if walk_stats is not None:
            walk_stats.rows += sel.size
            walk_stats.block_sizes.append(sel.size)
        r = int(bp.first_feasible())
        if r >= 0:
            out[sel[:r]] = VERDICT_REJECT
            out[sel[r]] = VERDICT_PLACEABLE
            dep[sel[: r + 1]] = bp.placed_tasks[: r + 1].astype(np.int16)
            win = int(sel[r])
            break
        out[sel] = VERDICT_REJECT
        dep[sel] = bp.placed_tasks.astype(np.int16)
    return win, out, dep


def _finish_warm(
    tasks: tuple[Task, ...],
    fleet: FleetSpec,
    backend: PlacementBackend,
    placement_kw: dict,
    cand_pow: np.ndarray,
    cand_sumshr: np.ndarray,
    cand_chosen: np.ndarray,
    cand_verdict: np.ndarray,
    cand_depth: np.ndarray,
    win: int,
    P_inc: float,
    origin: str,
    base: PlanState | None,
    appended: tuple[Task, ...],
    share_vecs: Sequence[np.ndarray],
    power_vecs: Sequence[np.ndarray],
) -> ScheduleResult:
    """Result + carried-over state shared by all three warm paths.

    The candidates are the *exact* new TFS restricted to total power
    ``<= P_inc`` in exact emission order, so: winner index == cold rank
    == cold stop-at-winner reject count, and when nothing places the
    candidate count equals the full |TFS| a cold infeasible walk would
    have dispatched (``P_inc`` is infinite then — a finite incumbent's
    own row is always among the candidates, so feasibility cannot be
    lost; that invariant is asserted).  The candidate band with its
    learned verdicts *is* the new state (state carry-over): coverage
    holds below ``P_inc`` — below everything, when the source state was
    exhaustive and the walk unbounded.
    """
    if win < 0:
        assert not np.isfinite(P_inc), "warm replan lost its incumbent row"
        res = ScheduleResult(
            feasible=False,
            combo=None,
            plan=None,
            chosen_rank=-1,
            n_tss=combo_count(tasks),
            n_tfs=-1,
            n_tnfs=-1,
            n_placement_rejects=int(cand_pow.size),
            total_power=float("inf"),
        )
    else:
        combo = _combo_from_idx(cand_chosen[win], share_vecs, power_vecs)
        plan = place_combo(combo, tasks, fleet, **placement_kw)
        res = ScheduleResult(
            feasible=True,
            combo=combo,
            plan=plan,
            chosen_rank=win,
            n_tss=combo_count(tasks),
            n_tfs=-1,
            n_tnfs=-1,
            n_placement_rejects=win,
            total_power=combo.total_power,
        )
    res.plan_state = PlanState(
        tasks=tasks,
        fleet=fleet,
        engine=backend.name,
        placement_kw=dict(placement_kw),
        result=res,
        rec_pow=cand_pow,
        rec_sumshr=cand_sumshr,
        rec_chosen=cand_chosen,
        rec_verdict=cand_verdict,
        rec_depth=cand_depth,
        enum=None,
        complete_below=float(P_inc) if np.isfinite(P_inc) else np.inf,
        origin=origin,
        base=base,
        appended=appended,
    )
    return res


def _replan_append(
    root: PlanState,
    appended: tuple[Task, ...],
    *,
    cur_tasks: tuple[Task, ...],
    cur_result: ScheduleResult,
    backend: PlacementBackend,
    walk_stats: WalkStats | None,
    origin: str = "warm_arrival",
    **placement_kw,
) -> ScheduleResult | None:
    """Warm path for arrivals: ``tasks = root.tasks + appended``; None
    means *fall back*.

    Generalises the single-arrival cross product to any number of
    appended tasks so consecutive arrivals replay against the same
    exhaustive root (``cur_tasks``/``cur_result`` — the live state the
    service holds, usually ``root + appended[:-1]`` — only seed the
    incumbent).  Every comparison uses the exact float64 folds a cold
    enumeration of the extended set would produce, so winner, rank and
    plan are bit-identical to cold.  ``origin`` tags the emitted state
    (the exit chain re-enters here and wants ``"warm_exit"``).
    """
    fleet = root.fleet
    tasks2 = root.tasks + appended
    n2 = len(tasks2)
    nb = len(root.tasks)
    opts = PlacementOptions(**placement_kw)
    k = opts.resilience
    share_vecs = tuple(t.shares(fleet.t_slr) for t in tasks2)
    power_vecs = tuple(t.powers() for t in tasks2)
    shr_app = share_vecs[nb:]
    pow_app = power_vecs[nb:]

    # --- incumbent: the current winner, extended with the cheapest
    # placeable variant of the (at most one) task it does not cover.
    # Variants probed in ascending power; eq. 7 first (cheap), then one
    # single-row backend dispatch.  A failed probe does NOT force a
    # fallback: the walk below simply runs unbounded when the root is
    # exhaustive — the common shape of an arrival the saturated fleet
    # cannot admit, where the recorded rejects prove infeasibility
    # almost for free.
    P_inc = np.inf
    if cur_result.feasible:
        prev = {
            t.name: int(j)
            for t, j in zip(cur_tasks, cur_result.combo.variant_idx, strict=True)
        }
        missing = [
            i
            for i, t in enumerate(tasks2)
            if t.name not in prev or prev[t.name] >= t.nv
        ]
        if len(missing) <= 1:
            idx = [prev.get(t.name, 0) for t in tasks2]
            probe_vs = (
                np.argsort(power_vecs[missing[0]], kind="stable")
                if missing
                else np.zeros(1, dtype=np.int64)
            )
            for vv in probe_vs:
                if missing:
                    idx[missing[0]] = int(vv)
                combo = _combo_from_idx(idx, share_vecs, power_vecs)
                w = np.asarray([float(sum(combo.shares))])
                if not _eq7_leaf_mask(fleet, n2, w, k)[0]:
                    continue
                if _row_placeable(
                    np.asarray(combo.shares), tasks2, fleet, backend, opts
                ):
                    P_inc = combo.total_power
                    break

    # Root rows that could extend into a candidate at or below P_inc.
    # Over-inclusive margin: the exact per-candidate filter is below.
    min_app = sum(float(p.min()) for p in pow_app)
    if np.isfinite(P_inc):
        band_hi = P_inc - min_app + 1e-9 * max(1.0, abs(P_inc))
    else:
        band_hi = np.inf
    if band_hi > root.complete_below:
        return None  # recording + frontier don't cover the band: fall back
    all_pow, all_sumshr, all_chosen, all_verdict, all_depth = _drain_band(
        root, band_hi
    )
    n_ext = 1
    for t in appended:
        n_ext *= t.nv
    if n_ext * max(all_pow.size, 1) > _APPEND_CELL_CAP:
        return None  # deep chain over a huge recording: fresh walk wins

    # --- candidates: every recorded/drained root row crossed with every
    # appended-variant tuple, filtered by the exact eq-7 fold and the
    # incumbent bound.  Reject parents transfer (reject monotonicity);
    # everything else dispatches as UNKNOWN.
    cps: list[np.ndarray] = []
    css: list[np.ndarray] = []
    cch: list[np.ndarray] = []
    cvd: list[np.ndarray] = []
    cdp: list[np.ndarray] = []
    for vt in itertools.product(*(range(t.nv) for t in appended)):
        cp = all_pow
        cs = all_sumshr
        for m, v in enumerate(vt):
            cp = cp + pow_app[m][v]
            cs = cs + shr_app[m][v]
        keep = (cp <= P_inc) & _eq7_leaf_mask(fleet, n2, cs, k)
        sel = np.flatnonzero(keep)
        if not sel.size:
            continue
        vt_cols = np.repeat(
            np.asarray(vt, dtype=np.int64)[None, :], sel.size, axis=0
        )
        cps.append(cp[sel])
        css.append(cs[sel])
        cch.append(np.concatenate([all_chosen[sel], vt_cols], axis=1))
        pv = all_verdict[sel]
        cvd.append(
            np.where(pv == VERDICT_REJECT, VERDICT_REJECT, VERDICT_UNKNOWN).astype(
                np.int8
            )
        )
        # A death inside the shared prefix (tasks are appended at the
        # end) stays a death for every extension; depths at or past the
        # root's length describe completed prefixes, not facts here.
        pd = all_depth[sel]
        cdp.append(np.where((pd >= 0) & (pd < nb), pd, -1).astype(np.int16))
    if cps:
        cand_pow = np.concatenate(cps)
        cand_sumshr = np.concatenate(css)
        cand_chosen = np.concatenate(cch, axis=0)
        cand_verdict = np.concatenate(cvd)
        cand_depth = np.concatenate(cdp)
    else:
        cand_pow = np.empty(0)
        cand_sumshr = np.empty(0)
        cand_chosen = np.empty((0, n2), dtype=np.int64)
        cand_verdict = np.empty(0, dtype=np.int8)
        cand_depth = np.empty(0, dtype=np.int16)
    order = _emission_order(cand_pow, cand_chosen)
    cand_pow = cand_pow[order]
    cand_sumshr = cand_sumshr[order]
    cand_chosen = cand_chosen[order]
    cand_verdict = cand_verdict[order]
    cand_depth = cand_depth[order]
    win, verd, dep = _walk_candidates(
        cand_chosen,
        cand_verdict,
        cand_depth,
        tasks2,
        fleet,
        backend,
        opts,
        walk_stats,
    )
    return _finish_warm(
        tasks2,
        fleet,
        backend,
        placement_kw,
        cand_pow,
        cand_sumshr,
        cand_chosen,
        verd,
        dep,
        win,
        P_inc,
        origin,
        root,
        appended,
        share_vecs,
        power_vecs,
    )

def _replan_exit(
    state: PlanState,
    p: int,
    *,
    backend: PlacementBackend,
    walk_stats: WalkStats | None,
    min_band: float | None = None,
    **placement_kw,
) -> ScheduleResult | None:
    """Warm path for one task exit (position ``p``); None means fall back.

    Projects the recorded rows onto the surviving task axes — drop
    column ``p``, re-fold power and eq-7 share sums left-to-right over
    the surviving columns (the exact association a cold enumeration of
    the shrunken set uses), dedup over the dropped variant axis — then
    closes the enumeration *gap* (shrunken-TFS rows none of whose
    extensions fit the old budget) with a covered-subtree-pruned fresh
    walk.  Recorded placeable verdicts transfer to projections only when
    the exiting task was last in placement order; rejects transfer
    whenever the recorded row's primary sweep died *before* position
    ``p`` (prefix death — see the module docstring).

    ``min_band`` widens the candidate band past the incumbent (the exit
    chain asks for enough headroom that re-appending the chain's
    arrivals finds its band already recorded); extra rows sort after the
    winner, so the result is unaffected — only the emitted state grows.
    """
    fleet = state.fleet
    n = len(state.tasks)
    tasks2 = state.tasks[:p] + state.tasks[p + 1 :]
    n2 = n - 1
    if n2 == 0:
        return None  # empty survivor set has no walk to warm-start
    opts = PlacementOptions(**placement_kw)
    k = opts.resilience
    removed = state.tasks[p]
    share_vecs = tuple(t.shares(fleet.t_slr) for t in tasks2)
    power_vecs = tuple(t.powers() for t in tasks2)
    pow_p = removed.powers()
    shr_min = float(removed.shares(fleet.t_slr).min())

    # --- incumbent: the old winner minus the exiting task, re-verified
    # from scratch (the greedy simulator is not monotone under removals).
    P_inc = np.inf
    if state.result.feasible:
        prev = state.result.combo
        idx2 = [int(j) for i, j in enumerate(prev.variant_idx) if i != p]
        combo = _combo_from_idx(idx2, share_vecs, power_vecs)
        w = np.asarray([float(sum(combo.shares))])
        if _eq7_leaf_mask(fleet, n2, w, k)[0] and _row_placeable(
            np.asarray(combo.shares), tasks2, fleet, backend, opts
        ):
            P_inc = combo.total_power
    band = P_inc if min_band is None else max(P_inc, float(min_band))

    # Horizon: every extension of an in-band projected row — and of any
    # gap row's covering extension — has total power at most the band
    # plus the exiting task's costliest variant.  Recording coverage
    # through H decides band membership *and* gap coverage exactly.
    pmax = float(pow_p.max())
    if np.isfinite(band):
        H = band + pmax + 1e-9 * max(1.0, abs(band) + pmax)
    else:
        H = np.inf
    if H > state.complete_below:
        return None
    all_pow, all_sumshr, all_chosen, all_verdict, all_depth = _drain_band(
        state, H
    )

    # --- projection: coarse power prefilter, then exact per-column
    # refolds over the surviving axes, then the exact eq-7 and incumbent
    # filters, then dedup over the dropped variant axis.  The prefilter
    # compares each row's total minus its dropped variant's power — that
    # differs from the exact refolded survivor sum only by fold
    # association (ulps), so padding the threshold by a relative 1e-7
    # guarantees no row the exact ``keep`` filter would accept is lost.
    #
    # Banded phases: the post-exit winner usually sits far below the
    # incumbent band (a removal frees capacity), while the band's width
    # exists to seed the carry-over state.  Projecting and deduping the
    # whole band on every event would dwarf the walk itself on large
    # recordings, so phase 1 caps the candidate set at the ``_EXIT_CAP``
    # cheapest recorded parents; every candidate left out has a strictly
    # higher survivor power than any phase-1 winner, so a winner found
    # in phase 1 is the global one with the exact cold rank.  Only a
    # winnerless phase 1 falls through to the full band.  The emitted
    # ``complete_below`` is the band the returning phase actually
    # covered, so the carry-over state stays honest either way.
    approx2 = None
    tol_max = 0.0
    if np.isfinite(band) and all_pow.size:
        if removed.nv == 1:
            approx2 = all_pow - float(pow_p[0])  # no per-row gather needed
        else:
            approx2 = all_pow - pow_p[all_chosen[:, p]]
        tol_max = 1e-7 * max(1.0, float(np.max(np.abs(all_pow))))
    phases: list[tuple[float, float]] = []
    if approx2 is not None and approx2.size > _EXIT_CAP:
        b_sel = float(np.partition(approx2, _EXIT_CAP)[_EXIT_CAP])
        b_cov = b_sel - tol_max
        if min_band is not None and b_cov < float(min_band):
            b_cov = float(min_band)
            b_sel = b_cov + tol_max
        if b_cov < band:
            phases.append((b_sel, b_cov))
    phases.append((np.inf, band))

    # --- gap walk: shrunken-set rows whose every extension broke the old
    # budget.  A subtree is covered (pruned) when even its largest
    # completion, extended with the exiting task's *minimum*-share
    # variant, passes the old eq-7 — the pass is antitone in the folded
    # sum, so that one variant decides the existential.  Survivor leaves
    # get the exact insert-fold test below.
    _, shr_hi2 = _suffix_max_bounds(share_vecs) if n2 else (None, np.zeros(1))

    def covered(d: int, pshr: np.ndarray) -> np.ndarray:
        u = pshr + shr_hi2[d] + shr_min
        u = u + (np.abs(u) + 1.0) * 1e-12
        return _eq7_leaf_mask(fleet, n, u, k)

    for b_sel, b_cov in phases:
        last_phase = b_cov >= band or not np.isfinite(band)
        if approx2 is None:
            idxc = np.arange(all_pow.size)
        elif last_phase:
            tol = 1e-7 * np.maximum(1.0, np.abs(all_pow))
            idxc = np.flatnonzero(approx2 <= band + tol)
        else:
            idxc = np.flatnonzero(approx2 <= b_sel)
        ch2 = all_chosen[idxc][:, [c for c in range(n) if c != p]]
        pw2 = np.zeros(idxc.size)
        w2 = np.zeros(idxc.size)
        for m in range(n2):
            col = ch2[:, m]
            pw2 = pw2 + power_vecs[m][col]
            w2 = w2 + share_vecs[m][col]
        keep = (pw2 <= b_cov) & _eq7_leaf_mask(fleet, n2, w2, k)
        sel = idxc[keep]
        ch2 = ch2[keep]
        pw2 = pw2[keep]
        w2 = w2[keep]
        if removed.nv == 1:
            # One dropped variant => distinct parents stay distinct on
            # the surviving axes: the dedup is the identity.
            uniq = first = inv = np.arange(ch2.shape[0])
        elif ch2.shape[0]:
            flat = np.ravel_multi_index(
                tuple(ch2[:, m] for m in range(n2)), tuple(t.nv for t in tasks2)
            )
            uniq, first, inv = np.unique(
                flat, return_index=True, return_inverse=True
            )
        else:
            uniq = first = inv = np.empty(0, dtype=np.int64)
        proj_pow = pw2[first]
        proj_sumshr = w2[first]
        proj_chosen = ch2[first]
        proj_depth = np.full(uniq.size, -1, dtype=np.int16)
        if uniq.size:
            # Verdict transfer, best-of-group over the dropped variant
            # axis (rows in a dedup group agree on every surviving
            # column, hence share the whole placement prefix):
            #   0  PLACEABLE — only when the exiting task was last (the
            #      simulator's first n-1 steps are exactly the shrunken
            #      instance's walk);
            #   1  REJECT — the recorded primary sweep died at depth
            #      d < p, a fact about the unchanged prefix alone;
            #   2  UNKNOWN.
            # 0 and 1 cannot collide within a group (the shared prefix
            # cannot both place fully and die before p).
            dsel = all_depth[sel]
            dep_rej = (dsel >= 0) & (dsel < p)
            code = np.where(dep_rej, 1, 2).astype(np.int8)
            if p == n - 1:
                code[all_verdict[sel] == VERDICT_PLACEABLE] = 0
            best = np.full(uniq.size, 2, dtype=np.int8)
            np.minimum.at(best, inv, code)
            proj_verdict = np.where(
                best == 0,
                VERDICT_PLACEABLE,
                np.where(best == 1, VERDICT_REJECT, VERDICT_UNKNOWN),
            ).astype(np.int8)
            if dep_rej.any():
                acc = np.full(uniq.size, np.iinfo(np.int16).max, dtype=np.int16)
                np.minimum.at(acc, inv[dep_rej], dsel[dep_rej])
                proj_depth = np.where(best == 1, acc, -1).astype(np.int16)
        else:
            proj_verdict = np.full(uniq.size, VERDICT_UNKNOWN, dtype=np.int8)

        genum = BlockEnumerator(
            tasks2,
            fleet,
            resilience=k,
            incumbent_power=float(b_cov) if np.isfinite(b_cov) else None,
            cover_prune=covered,
        )
        gpow: list[np.ndarray] = []
        gsum: list[np.ndarray] = []
        gch: list[np.ndarray] = []
        while True:
            blk = genum.next_block(65536)
            if blk is None:
                break
            acc = np.zeros(len(blk))
            for m in range(p):
                acc = acc + share_vecs[m][blk.variant_idx[:, m]]
            acc = acc + shr_min
            for m in range(p, n2):
                acc = acc + share_vecs[m][blk.variant_idx[:, m]]
            g = ~_eq7_leaf_mask(fleet, n, acc, k)
            if g.any():
                gpow.append(blk.total_power[g])
                gsum.append(blk.sum_shr[g])
                gch.append(blk.variant_idx[g])
        if gpow:
            cand_pow = np.concatenate([proj_pow] + gpow)
            cand_sumshr = np.concatenate([proj_sumshr] + gsum)
            cand_chosen = np.concatenate([proj_chosen] + gch, axis=0)
            cand_verdict = np.concatenate(
                [proj_verdict]
                + [np.full(a.size, VERDICT_UNKNOWN, dtype=np.int8) for a in gpow]
            )
            cand_depth = np.concatenate(
                [proj_depth]
                + [np.full(a.size, -1, dtype=np.int16) for a in gpow]
            )
        else:
            cand_pow, cand_sumshr = proj_pow, proj_sumshr
            cand_chosen, cand_verdict = proj_chosen, proj_verdict
            cand_depth = proj_depth
        order = _emission_order(cand_pow, cand_chosen)
        cand_pow = cand_pow[order]
        cand_sumshr = cand_sumshr[order]
        cand_chosen = cand_chosen[order]
        cand_verdict = cand_verdict[order]
        cand_depth = cand_depth[order]
        win, verd, dep = _walk_candidates(
            cand_chosen,
            cand_verdict,
            cand_depth,
            tasks2,
            fleet,
            backend,
            opts,
            walk_stats,
        )
        if win < 0 and not last_phase:
            continue  # winner above the phase-1 band: run the full band
        return _finish_warm(
            tasks2,
            fleet,
            backend,
            placement_kw,
            cand_pow,
            cand_sumshr,
            cand_chosen,
            verd,
            dep,
            win,
            b_cov,
            "warm_exit",
            None,
            (),
            share_vecs,
            power_vecs,
        )
    return None  # unreachable: the full-band phase always returns


def _replan_failure(
    state: PlanState,
    new_fleet: FleetSpec,
    dropped: int,
    *,
    backend: PlacementBackend,
    walk_stats: WalkStats | None,
    min_band: float | None = None,
    **placement_kw,
) -> ScheduleResult | None:
    """Warm path for one dropped device; None means fall back.

    Task set and variants are unchanged, so the recorded rows — powers,
    folds, variant choices — describe the new instance verbatim; only
    the eq-7 membership test moves to the shrunken fleet.  Homogeneous
    fleets need no gap walk (the budget is float-monotone in ``n_f``,
    so the new TFS is a subset of the old) and keep every recorded
    reject (the smaller fleet is a device prefix — with ``resilience=k``
    its worst-case survivors are a prefix of the old survivors too).
    Heterogeneous drops keep rejects only for the last device at
    ``k=0`` and recover old-eq-7-pruned rows with a covered gap walk.
    """
    old = state.fleet
    tasks = state.tasks
    n = len(tasks)
    opts = PlacementOptions(**placement_kw)
    k = opts.resilience
    if k >= new_fleet.n_f:
        return None  # shrunken below the guarantee: general path answers
    share_vecs = tuple(t.shares(new_fleet.t_slr) for t in tasks)
    power_vecs = tuple(t.powers() for t in tasks)

    # --- incumbent: the old winner re-verified against the new fleet.
    P_inc = np.inf
    if state.result.feasible:
        combo = state.result.combo
        w = np.asarray([float(sum(combo.shares))])
        if _eq7_leaf_mask(new_fleet, n, w, k)[0] and _row_placeable(
            np.asarray(combo.shares), tasks, new_fleet, backend, opts
        ):
            P_inc = combo.total_power
    # The failure chain widens the band past the incumbent so the
    # re-append of the chain's arrivals finds its rows recorded; extra
    # rows sort after the winner and cannot change the result.
    band = P_inc if min_band is None else max(P_inc, float(min_band))
    if band > state.complete_below:
        return None
    all_pow, all_sumshr, all_chosen, all_verdict, _ = _drain_band(state, band)

    mask = _eq7_leaf_mask(new_fleet, n, all_sumshr, k)
    if np.isfinite(band):
        mask &= all_pow <= band
    sel = np.flatnonzero(mask)
    cand_pow = all_pow[sel]
    cand_sumshr = all_sumshr[sel]
    cand_chosen = all_chosen[sel]
    # Recorded death depths describe the *old* fleet's sweep — a fleet
    # change invalidates them, so every carried row restarts at -1.
    cand_depth = np.full(sel.size, -1, dtype=np.int16)
    transfer = (not old.is_heterogeneous) or (dropped == old.n_f - 1 and k == 0)
    if transfer:
        cand_verdict = np.where(
            all_verdict[sel] == VERDICT_REJECT, VERDICT_REJECT, VERDICT_UNKNOWN
        ).astype(np.int8)
    else:
        cand_verdict = np.full(sel.size, VERDICT_UNKNOWN, dtype=np.int8)

    if old.is_heterogeneous:
        # --- gap walk: rows the *old* fleet's tighter eq-7 pruned but the
        # new fleet admits (device mixes can tighten non-monotonically).
        # A subtree is covered when even its largest completion passes
        # the old eq-7; survivor leaves get the exact old-fold test.
        _, shr_hi = _suffix_max_bounds(share_vecs)

        def covered(d: int, pshr: np.ndarray) -> np.ndarray:
            u = pshr + shr_hi[d]
            u = u + (np.abs(u) + 1.0) * 1e-12
            return _eq7_leaf_mask(old, n, u, k)

        genum = BlockEnumerator(
            tasks,
            new_fleet,
            resilience=k,
            incumbent_power=float(band) if np.isfinite(band) else None,
            cover_prune=covered,
        )
        gpow: list[np.ndarray] = []
        gsum: list[np.ndarray] = []
        gch: list[np.ndarray] = []
        while True:
            blk = genum.next_block(65536)
            if blk is None:
                break
            g = ~_eq7_leaf_mask(old, n, blk.sum_shr, k)
            if g.any():
                gpow.append(blk.total_power[g])
                gsum.append(blk.sum_shr[g])
                gch.append(blk.variant_idx[g])
        if gpow:
            cand_pow = np.concatenate([cand_pow] + gpow)
            cand_sumshr = np.concatenate([cand_sumshr] + gsum)
            cand_chosen = np.concatenate([cand_chosen] + gch, axis=0)
            cand_verdict = np.concatenate(
                [cand_verdict]
                + [np.full(a.size, VERDICT_UNKNOWN, dtype=np.int8) for a in gpow]
            )
            cand_depth = np.full(cand_pow.size, -1, dtype=np.int16)
            order = _emission_order(cand_pow, cand_chosen)
            cand_pow = cand_pow[order]
            cand_sumshr = cand_sumshr[order]
            cand_chosen = cand_chosen[order]
            cand_verdict = cand_verdict[order]
    # (No merge -> no reorder: recorded rows are already emission-ordered
    # and filtering preserves that.)
    win, verd, dep = _walk_candidates(
        cand_chosen,
        cand_verdict,
        cand_depth,
        tasks,
        new_fleet,
        backend,
        opts,
        walk_stats,
    )
    return _finish_warm(
        tasks,
        new_fleet,
        backend,
        placement_kw,
        cand_pow,
        cand_sumshr,
        cand_chosen,
        verd,
        dep,
        win,
        band,
        "warm_failure",
        None,
        (),
        share_vecs,
        power_vecs,
    )
