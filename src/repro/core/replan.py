"""Delta replanning: warm-start the Alg 1+2 walk from a previous plan.

A long-running fleet (:mod:`repro.service`) sees task arrivals and exits
continuously; re-running the full power-sorted TFS walk from scratch on
every event is wasted work when almost everything about the instance is
unchanged.  This module makes one ``schedule()`` pay for the next:

* :func:`schedule_recorded` runs the normal streaming walk but snapshots
  a :class:`PlanState` — every emitted TFS row (power, folded eq-7 share
  sum, variant choice), every placement verdict the walk actually
  resolved, and the live :class:`~repro.core.feasibility.BlockEnumerator`
  (the surviving branch-and-bound frontier) at the point the walk
  stopped.
* :func:`replan` reschedules a new task tuple from that state.  A single
  appended **arrival** takes the warm path below; everything else
  (exits, fleet edits, bulk changes) falls back to a fresh recorded walk
  that still seeds the projected previous winner as an *incumbent* upper
  power bound (:meth:`BlockEnumerator.prune_above`).

Warm arrival path
-----------------

Let the old task set be ``T`` (``n`` tasks) and ``T' = T + [j]``.  Three
facts make the old walk's work reusable bit-for-bit:

1. **TFS projection.**  Appending a task only shrinks the eq-7 budget
   (``n_f*t_slr - (n+2)*t_cfg``) and only grows the heterogeneous
   config-overhead bound, so every eq-7-workable row of ``T'`` restricts
   to a workable row of ``T``.  The new TFS is therefore exactly
   ``{(r, v) : r in TFS(T), v a variant of j, eq7'(sum_shr(r)+shr_jv)}``
   — a filtered cross product of *already enumerated* rows with the new
   task's variants.  Because :class:`~repro.core.feasibility.ComboBlock`
   carries each row's left-to-right folded share sum (``sum_shr``), the
   filter re-applies eq. 7 with the identical float64 operations a cold
   enumeration of ``T'`` would fold — same bits, same verdicts.  With
   ``resilience=k`` the same argument holds against the worst-case
   survivor fleet's budget: the survivor set is a function of the fleet
   alone (never the task set), so it is unchanged across arrivals.
2. **Reject monotonicity.**  The placement simulator
   (:func:`repro.core.placement.place_shares`) walks tasks strictly in
   order, so a row that failed placement for ``T`` fails for every
   extension ``(r, v)``: recorded *reject* verdicts transfer to the new
   instance and those candidates skip backend dispatch entirely — they
   only count toward the winner's rank.
3. **Incumbent bound.**  The old winner extended with the cheapest
   placeable variant of ``j`` is a feasible plan of ``T'``; its power
   ``P_inc`` caps the search.  Candidates above ``P_inc`` are discarded
   and the resumed frontier walk (:meth:`BlockEnumerator.clone` +
   :meth:`~BlockEnumerator.prune_above`) only pulls old-TFS rows that
   could still beat it — typically none when the old walk ran deep.

The surviving candidates are sorted by the cold emission key — ``(total
power, TSS flat index)``, realised as a lexsort over ``(power, parent
variant columns, new-variant index)`` — and walked through the backend
in order.  The first placeable candidate is *provably* the same row a
cold ``schedule(T')`` would choose, at the same rank, with the same
scalar plan.  ``tests/test_service_replay.py`` asserts this bit-identity
property over randomized event sequences and engines.

The warm path returns a *thin* state (no recorded rows, no frontier):
replanning again from it silently takes the incumbent-seeded fresh-walk
path, which re-records and restores full warmth.  The
:class:`repro.service.SchedulerService` layers a plan cache on top so
steady-state churn (a task leaving and returning) skips even that.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .feasibility import BlockEnumerator, config_overhead_lower_bound
from .placement import place_combo
from .placement_backends import PlacementBackend, PlacementOptions
from .scheduler import (
    ScheduleResult,
    WalkStats,
    _block_size_schedule,
    _resilience_infeasible_result,
    _walk_tfs_blocks,
)
from .task import FleetSpec, Task, TaskSetCombo, combo_count

__all__ = [
    "PlanState",
    "VERDICT_REJECT",
    "VERDICT_PLACEABLE",
    "VERDICT_UNKNOWN",
    "schedule_recorded",
    "replan",
]

# Per-row placement verdicts recorded by the walk.  Only REJECT is
# exploitable across arrivals (reject monotonicity); PLACEABLE children
# still need dispatch — a feasible row's extension may well not place.
VERDICT_REJECT = 0
VERDICT_PLACEABLE = 1
VERDICT_UNKNOWN = 2

_WARM_BLOCK = 4096  # dispatch block size for the candidate mini-walk


@dataclasses.dataclass
class PlanState:
    """Everything a later :func:`replan` can reuse from one walk.

    ``rec_*`` arrays hold the first ``R`` rows of the instance's
    power-ordered TFS exactly as emitted (power and eq-7 share sum are
    the enumerator's own left-to-right folds); ``enum`` resumes emission
    at row ``R``.  Together they cover every TFS row with total power
    ``<= complete_below`` (``inf`` for an unbounded cold walk; the
    incumbent bound when one pruned the walk; ``-inf`` for the thin
    state a warm replan returns).  ``enum`` is private mutable state —
    replanners only ever touch a :meth:`BlockEnumerator.clone` of it.
    """

    tasks: tuple[Task, ...]
    fleet: FleetSpec
    engine: str  # backend name whose verdicts rec_verdict holds
    placement_kw: dict
    result: ScheduleResult = dataclasses.field(repr=False)
    rec_pow: np.ndarray = dataclasses.field(repr=False)  # (R,) float64
    rec_sumshr: np.ndarray = dataclasses.field(repr=False)  # (R,) float64
    rec_chosen: np.ndarray = dataclasses.field(repr=False)  # (R, n_t) int64
    rec_verdict: np.ndarray = dataclasses.field(repr=False)  # (R,) int8
    enum: BlockEnumerator | None = dataclasses.field(repr=False)
    complete_below: float = np.inf

    @property
    def n_recorded(self) -> int:
        return int(self.rec_pow.size)


class _Recorder:
    """Accumulates emitted blocks + resolved verdicts during one walk."""

    def __init__(self, n_t: int) -> None:
        self._n_t = n_t
        self._chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._verdicts: dict[int, np.ndarray] = {}  # rank_base -> int8 block
        self._bases: list[int] = []
        self._total = 0

    def on_emit(self, blk) -> None:
        self._chunks.append((blk.total_power, blk.sum_shr, blk.variant_idx))
        self._bases.append(self._total)
        self._total += len(blk)

    def on_verdict(self, base: int, feasible: np.ndarray) -> None:
        self._verdicts[base] = np.where(
            feasible, VERDICT_PLACEABLE, VERDICT_REJECT
        ).astype(np.int8)

    def arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        if not self._chunks:
            return (
                np.empty(0),
                np.empty(0),
                np.empty((0, self._n_t), dtype=np.int64),
                np.empty(0, dtype=np.int8),
            )
        pow_ = np.concatenate([c[0] for c in self._chunks])
        sumshr = np.concatenate([c[1] for c in self._chunks])
        chosen = np.concatenate([c[2] for c in self._chunks], axis=0)
        verdict = np.full(self._total, VERDICT_UNKNOWN, dtype=np.int8)
        for base, v in self._verdicts.items():
            verdict[base : base + v.size] = v
        return pow_, sumshr, chosen, verdict


def _eq7_leaf_mask(
    fleet: FleetSpec, n_t: int, w: np.ndarray, resilience: int = 0
) -> np.ndarray:
    """The enumerator's leaf-level eq-7 test, bit-identical (same float64
    comparisons as :meth:`BlockEnumerator._passes` on a completed row).
    ``resilience`` switches to the worst-case survivor fleet's budget,
    matching the enumerator's resilience-mode pruning."""
    bfleet = fleet.survivors(resilience) if resilience and n_t else fleet
    ok = w <= bfleet.workable_budget(n_t) + 1e-9
    if bfleet.is_heterogeneous and ok.any():
        overhead = config_overhead_lower_bound(bfleet, n_t, w)
        ok &= ~(w > bfleet.capacity - overhead + 1e-9)
    return ok


def _combo_from_idx(
    idx: Sequence[int],
    share_vecs: Sequence[np.ndarray],
    power_vecs: Sequence[np.ndarray],
) -> TaskSetCombo:
    return TaskSetCombo(
        tuple(int(j) for j in idx),
        tuple(float(v[j]) for v, j in zip(share_vecs, idx, strict=True)),
        tuple(float(v[j]) for v, j in zip(power_vecs, idx, strict=True)),
    )


def schedule_recorded(
    tasks: Sequence[Task],
    fleet: FleetSpec,
    backend: PlacementBackend,
    *,
    block_size: int | None = None,
    count_all_rejects: bool = False,
    walk_stats: WalkStats | None = None,
    incumbent_power: float | None = None,
    exhaustive: bool = False,
    **placement_kw,
) -> ScheduleResult:
    """The streaming ``schedule()`` walk, with :class:`PlanState` capture.

    Identical winner/rank/reject bookkeeping to the cold streaming path —
    the only additions are the recorder taps and the optional
    ``incumbent_power`` bound, which prunes rows *after* the winner-to-be
    (emission is power-ordered, so every row up to and including the
    winner survives the bound and the result is unchanged).

    ``exhaustive`` keeps walking past the winner so *every* TFS row gets
    a recorded placement verdict and the enumerator drains dry.  The
    reported result is still bit-identical to the cold default (rank
    rejects, same winner); what changes is the state's warmth — a later
    arrival replan needs no band drain and dispatches only extensions of
    known-placeable rows.  Pay once, replan cheap thereafter: this is the
    service layer's steady-state mode.
    """
    tasks = tuple(tasks)
    k_res = int(placement_kw.get("resilience", 0))
    if k_res >= fleet.n_f and tasks:
        # A fleet that cannot survive k failures admits nothing; answered
        # here (not just in the facade) because replans re-enter after
        # fleet shrinkage.  Thin state: the next replan walks fresh.
        res = _resilience_infeasible_result(tasks)
        res.plan_state = _thin_state(tasks, fleet, backend, placement_kw, res)
        return res
    enum = BlockEnumerator(tasks, fleet, resilience=k_res)
    complete_below = np.inf
    if incumbent_power is not None:
        enum.prune_above(incumbent_power)
        complete_below = float(incumbent_power)
    sizes = _block_size_schedule(block_size)
    rec = _Recorder(len(tasks))

    def blocks():
        while True:
            blk = enum.next_block(next(sizes))
            if blk is None:
                return
            rec.on_emit(blk)
            yield blk.shares, blk

    combo, plan, rank, rejects = _walk_tfs_blocks(
        blocks(),
        lambda blk, r: blk.materialize(r),
        tasks,
        fleet,
        backend=backend,
        count_all_rejects=count_all_rejects or exhaustive,
        walk_stats=walk_stats,
        on_verdict=rec.on_verdict,
        **placement_kw,
    )
    if exhaustive and not count_all_rejects and combo is not None:
        rejects = rank  # mirror the cold default's stop-at-winner count
    res = ScheduleResult(
        feasible=combo is not None,
        combo=combo,
        plan=plan,
        chosen_rank=rank,
        n_tss=combo_count(tasks),
        n_tfs=-1,
        n_tnfs=-1,
        n_placement_rejects=rejects,
        total_power=combo.total_power if combo else float("inf"),
    )
    rec_pow, rec_sumshr, rec_chosen, rec_verdict = rec.arrays()
    res.plan_state = PlanState(
        tasks=tasks,
        fleet=fleet,
        engine=backend.name,
        placement_kw=dict(placement_kw),
        result=res,
        rec_pow=rec_pow,
        rec_sumshr=rec_sumshr,
        rec_chosen=rec_chosen,
        rec_verdict=rec_verdict,
        enum=enum,
        complete_below=complete_below,
    )
    return res


def replan(
    state: PlanState,
    tasks: Sequence[Task],
    *,
    backend: PlacementBackend,
    fleet: FleetSpec | None = None,
    block_size: int | None = None,
    walk_stats: WalkStats | None = None,
    **placement_kw,
) -> ScheduleResult:
    """Reschedule ``tasks`` reusing whatever ``state`` makes sound.

    Dispatches to the warm arrival path when ``tasks`` appends exactly
    one task to ``state.tasks`` on an unchanged fleet (and
    backend/options match, so recorded verdicts are meaningful);
    otherwise runs an incumbent-seeded fresh recorded walk against
    ``fleet`` (default: the state's fleet).  Always bit-identical to a
    cold ``schedule(tasks)`` on that fleet.
    """
    tasks = tuple(tasks)
    if fleet is None:
        fleet = state.fleet
    if tasks == state.tasks and fleet == state.fleet:
        return state.result
    compatible = (
        fleet == state.fleet
        and backend.name == state.engine
        and dict(placement_kw) == state.placement_kw
    )
    if (
        compatible
        and len(tasks) == len(state.tasks) + 1
        and tasks[:-1] == state.tasks
    ):
        out = _replan_arrival(
            state, tasks[-1], backend=backend, walk_stats=walk_stats,
            **placement_kw,
        )
        if out is not None:
            return out
    return _replan_general(
        state,
        tasks,
        fleet,
        backend=backend,
        block_size=block_size,
        walk_stats=walk_stats,
        **placement_kw,
    )


def _row_placeable(
    shares_row: np.ndarray,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    backend: PlacementBackend,
    opts: PlacementOptions,
) -> bool:
    bp = backend.place_block(
        shares_row[None, :],
        [t.init_interval for t in tasks],
        fleet.t_slr_arr,
        fleet.t_cfg_arr,
        opts,
    )
    return bool(bp.feasible[0])


def _replan_general(
    state: PlanState,
    tasks: tuple[Task, ...],
    fleet: FleetSpec,
    *,
    backend: PlacementBackend,
    block_size: int | None,
    walk_stats: WalkStats | None,
    **placement_kw,
) -> ScheduleResult:
    """Exits / fleet edits / bulk deltas: fresh recorded walk, seeded with
    the old winner projected onto the new task tuple as an incumbent.

    The projection keeps each surviving task's previous variant choice;
    it is only a *bound*, verified from scratch (eq. 7 + a placement
    probe) against the new instance and fleet, so no monotonicity
    assumption about removals is needed — if the probe fails, the walk
    simply runs unbounded and the replan degrades to a plain cold
    recorded walk.
    """
    incumbent = None
    if state.result.feasible:
        prev = {
            t.name: j
            for t, j in zip(state.tasks, state.result.combo.variant_idx, strict=True)
        }
        if all(t.name in prev and prev[t.name] < t.nv for t in tasks):
            share_vecs = [t.shares(fleet.t_slr) for t in tasks]
            power_vecs = [t.powers() for t in tasks]
            idx = [prev[t.name] for t in tasks]
            combo = _combo_from_idx(idx, share_vecs, power_vecs)
            w = np.asarray([float(sum(combo.shares))])
            k_res = int(placement_kw.get("resilience", 0))
            if _eq7_leaf_mask(fleet, len(tasks), w, k_res)[0] and _row_placeable(
                np.asarray(combo.shares),
                tasks,
                fleet,
                backend,
                PlacementOptions(**placement_kw),
            ):
                incumbent = combo.total_power
    return schedule_recorded(
        tasks,
        fleet,
        backend,
        block_size=block_size,
        walk_stats=walk_stats,
        incumbent_power=incumbent,
        **placement_kw,
    )


def _thin_state(
    tasks: tuple[Task, ...],
    fleet: FleetSpec,
    backend: PlacementBackend,
    placement_kw: dict,
    res: ScheduleResult,
) -> PlanState:
    """State with no recording/frontier (``complete_below = -inf``): the
    next replan from it silently takes the general fresh-walk path."""
    return PlanState(
        tasks=tasks,
        fleet=fleet,
        engine=backend.name,
        placement_kw=dict(placement_kw),
        result=res,
        rec_pow=np.empty(0),
        rec_sumshr=np.empty(0),
        rec_chosen=np.empty((0, len(tasks)), dtype=np.int64),
        rec_verdict=np.empty(0, dtype=np.int8),
        enum=None,
        complete_below=-np.inf,
    )


def _count_lex_less(rows: np.ndarray, ref: np.ndarray) -> int:
    """How many ``rows`` sort lexicographically before ``ref`` (all rows
    are assumed distinct from ``ref``)."""
    if not rows.size:
        return 0
    neq = rows != ref[None, :]
    first = np.argmax(neq, axis=1)
    r = np.arange(rows.shape[0])
    return int((rows[r, first] < ref[first]).sum())


def _replan_arrival(
    state: PlanState,
    new_task: Task,
    *,
    backend: PlacementBackend,
    walk_stats: WalkStats | None,
    **placement_kw,
) -> ScheduleResult | None:
    """Warm path for one appended arrival; None means *fall back*.

    See the module docstring for the three soundness facts this leans
    on.  Every comparison against recorded folds uses the exact float64
    values a cold enumeration of the extended set would produce, so the
    winner, its rank, and its plan are bit-identical to cold.
    """
    if not state.result.feasible:
        return None
    fleet = state.fleet
    tasks2 = state.tasks + (new_task,)
    n2 = len(tasks2)
    shr_j = new_task.shares(fleet.t_slr)
    pow_j = new_task.powers()
    opts = PlacementOptions(**placement_kw)
    prev = state.result.combo
    prev_sumshr = float(sum(prev.shares))

    # --- incumbent: old winner ⊕ cheapest placeable variant of the new
    # task.  Variants probed in ascending power; eq. 7 first (cheap),
    # then one single-row backend dispatch.  A failed probe does NOT
    # force a fallback: the walk below simply runs unbounded — the
    # common shape of an arrival the saturated fleet cannot admit, where
    # the recorded rejects let us prove infeasibility almost for free.
    P_inc = np.inf
    for vv in np.argsort(pow_j, kind="stable"):
        vv = int(vv)
        w = np.asarray([prev_sumshr + shr_j[vv]])
        if not _eq7_leaf_mask(fleet, n2, w, opts.resilience)[0]:
            continue
        row = np.asarray(list(prev.shares) + [float(shr_j[vv])])
        if _row_placeable(row, tasks2, fleet, backend, opts):
            P_inc = float(prev.total_power + pow_j[vv])
            break

    # Parent rows that could extend into a candidate at or below P_inc.
    # Over-inclusive margin: the exact per-candidate filter is below.
    if np.isfinite(P_inc):
        band_hi = P_inc - float(pow_j.min()) + 1e-9 * max(1.0, abs(P_inc))
    else:
        band_hi = np.inf
    if band_hi > state.complete_below:
        return None  # recording + frontier don't cover the band: fall back

    # --- band rows: resume the snapshot frontier for old-TFS rows the
    # previous walk never emitted (usually none when it ran deep).
    chunks_pow = [state.rec_pow]
    chunks_sumshr = [state.rec_sumshr]
    chunks_chosen = [state.rec_chosen]
    chunks_verdict = [state.rec_verdict]
    if state.enum is not None and not state.enum.exhausted:
        resume = state.enum.clone()
        if np.isfinite(band_hi):
            resume.prune_above(band_hi)
        while True:
            blk = resume.next_block(65536)
            if blk is None:
                break
            chunks_pow.append(blk.total_power)
            chunks_sumshr.append(blk.sum_shr)
            chunks_chosen.append(blk.variant_idx)
            chunks_verdict.append(
                np.full(len(blk), VERDICT_UNKNOWN, dtype=np.int8)
            )
    def _cat(chunks, axis=0):  # skip the full copy when nothing was drained
        return chunks[0] if len(chunks) == 1 else np.concatenate(chunks, axis=axis)

    all_pow = _cat(chunks_pow)
    all_sumshr = _cat(chunks_sumshr)
    all_chosen = _cat(chunks_chosen, axis=0)
    all_verdict = _cat(chunks_verdict)
    n_t = len(state.tasks)
    nv_j = new_task.nv

    # --- dispatch candidates: extensions of non-reject parents (reject
    # parents can't place — reject monotonicity — and only count toward
    # rank).  Exact filters: cold's eq-7 fold and the incumbent bound.
    disp = np.flatnonzero(all_verdict != VERDICT_REJECT)
    rej = np.flatnonzero(all_verdict == VERDICT_REJECT)
    cand_parent: list[np.ndarray] = []
    cand_v: list[np.ndarray] = []
    for v in range(nv_j):
        cp = all_pow[disp] + pow_j[v]
        cs = all_sumshr[disp] + shr_j[v]
        keep = (cp <= P_inc) & _eq7_leaf_mask(fleet, n2, cs, opts.resilience)
        sel = disp[keep]
        cand_parent.append(sel)
        cand_v.append(np.full(sel.size, v, dtype=np.int64))
    parent = np.concatenate(cand_parent)
    vcol = np.concatenate(cand_v)
    cpow = all_pow[parent] + pow_j[vcol]
    # Cold emission order is (total_power, TSS flat index).  Power alone
    # determines the winner's *power* (the walk below goes block-by-block
    # in nondecreasing power), so sort on that single cheap key; the flat
    # -index tie-break is resolved exactly, but only among the handful of
    # candidates that share the winner's power.
    order = np.argsort(cpow, kind="stable")
    parent, vcol, cpow = parent[order], vcol[order], cpow[order]

    # --- mini-walk: the power-ordered candidates through the backend.
    share_vecs = tuple(t.shares(fleet.t_slr) for t in tasks2)
    power_vecs = tuple(t.powers() for t in tasks2)
    iis2 = [t.init_interval for t in tasks2]
    t_slr_arr, t_cfg_arr = fleet.t_slr_arr, fleet.t_cfg_arr

    def dispatch(sel_parent, sel_v):
        shares = np.empty((sel_parent.size, n2))
        ch = all_chosen[sel_parent]
        for k in range(n_t):
            shares[:, k] = share_vecs[k][ch[:, k]]
        shares[:, n_t] = shr_j[sel_v]
        bp = backend.place_block(shares, iis2, t_slr_arr, t_cfg_arr, opts)
        if walk_stats is not None:
            walk_stats.rows += sel_parent.size
            walk_stats.block_sizes.append(sel_parent.size)
        return bp

    win = -1
    for lo in range(0, parent.size, _WARM_BLOCK):
        hi = min(lo + _WARM_BLOCK, parent.size)
        r = dispatch(parent[lo:hi], vcol[lo:hi]).first_feasible()
        if r >= 0:
            win = lo + r
            break
    if win < 0:
        # No extension places.  Cold would have dispatched every row of
        # the new TFS (dispatchable + reject-parent candidates) and
        # returned infeasible with that many rejects — bit-identical.
        # The incumbent row is always among the candidates, so a finite
        # P_inc guarantees a winner; reaching here without one is a
        # soundness bug worth failing loudly on.
        assert not np.isfinite(P_inc), "warm replan lost its incumbent row"
        n_rej_cand = 0
        for v in range(nv_j):
            cp = all_pow[rej] + pow_j[v]
            cs = all_sumshr[rej] + shr_j[v]
            n_rej_cand += int(
                ((cp <= P_inc) & _eq7_leaf_mask(fleet, n2, cs, opts.resilience)).sum()
            )
        res = ScheduleResult(
            feasible=False,
            combo=None,
            plan=None,
            chosen_rank=-1,
            n_tss=combo_count(tasks2),
            n_tfs=-1,
            n_tnfs=-1,
            n_placement_rejects=int(parent.size) + n_rej_cand,
            total_power=float("inf"),
        )
        res.plan_state = _thin_state(tasks2, fleet, backend, placement_kw, res)
        return res

    # --- exact winner among the candidates sharing the winning power:
    # cold breaks power ties by TSS flat index, i.e. lexicographically on
    # (parent variant columns, new-variant index).  Re-dispatch the tie
    # group (tiny; usually size 1) and keep the lex-least feasible row.
    win_pow = float(cpow[win])
    t_lo = int(np.searchsorted(cpow, win_pow, side="left"))
    t_hi = int(np.searchsorted(cpow, win_pow, side="right"))
    if t_hi - t_lo > 1:
        ties = np.arange(t_lo, t_hi)
        bp = dispatch(parent[ties], vcol[ties])
        feas = np.flatnonzero(np.asarray(bp.feasible))
        tie_keys = np.concatenate(
            [all_chosen[parent[ties]], vcol[ties][:, None]], axis=1
        )
        fk = tie_keys[feas]
        best = feas[
            np.lexsort(tuple(fk[:, c] for c in range(fk.shape[1] - 1, -1, -1)))[0]
        ]
        win = t_lo + int(best)

    # --- global rank: candidates strictly cheaper than the winner, plus
    # equal-power candidates that sort lexicographically before it —
    # counting both dispatched and reject-parent extensions.
    win_parent_row = all_chosen[parent[win]]
    win_key = np.append(win_parent_row, vcol[win])
    rank = t_lo
    if t_hi - t_lo > 1:
        rank += _count_lex_less(tie_keys, win_key)
    for v in range(nv_j):
        cp = all_pow[rej] + pow_j[v]
        cs = all_sumshr[rej] + shr_j[v]
        ok = (cp <= win_pow) & _eq7_leaf_mask(fleet, n2, cs, opts.resilience)
        sel = rej[ok]
        cps = cp[ok]
        rank += int((cps < win_pow).sum())
        ties = sel[cps == win_pow]
        if ties.size:
            tie_keys = np.concatenate(
                [
                    all_chosen[ties],
                    np.full((ties.size, 1), v, dtype=np.int64),
                ],
                axis=1,
            )
            rank += _count_lex_less(tie_keys, win_key)

    # --- materialise the winner exactly like the cold walk does.
    idx_full = list(int(j) for j in win_parent_row) + [int(vcol[win])]
    combo = _combo_from_idx(idx_full, share_vecs, power_vecs)
    plan = place_combo(combo, tasks2, fleet, **placement_kw)
    res = ScheduleResult(
        feasible=True,
        combo=combo,
        plan=plan,
        chosen_rank=rank,
        n_tss=combo_count(tasks2),
        n_tfs=-1,
        n_tnfs=-1,
        n_placement_rejects=rank,
        total_power=combo.total_power,
    )
    # Thin state: correct for cache/inspection; the next replan from it
    # takes the general path (which restores a full recording).
    res.plan_state = _thin_state(tasks2, fleet, backend, placement_kw, res)
    return res
