"""ASCII Gantt rendering of placement plans (reproduces Figs 2-4 as text)."""

from __future__ import annotations

from typing import Sequence

from .placement import PlacementPlan
from .task import FleetSpec, Task

__all__ = ["render_gantt", "plan_rows"]


def plan_rows(
    plan: PlacementPlan, tasks: Sequence[Task]
) -> list[list[tuple[str, float, float]]]:
    """Per device: list of (label, start, end)."""
    rows = []
    for script in plan.scripts:
        row = []
        for seg in script.segments:
            if seg.kind == "null":
                label = "NULL"
            elif seg.kind == "cfg":
                label = f"cfg:{tasks[seg.task].name}"
            elif seg.kind == "init":
                label = f"II:{tasks[seg.task].name}"
            else:
                label = tasks[seg.task].name
            row.append((label, seg.start, seg.end))
        rows.append(row)
    return rows


def render_gantt(
    plan: PlacementPlan,
    tasks: Sequence[Task],
    fleet: FleetSpec,
    *,
    width: int = 96,
) -> str:
    """Fixed-width ASCII Gantt chart, one row per device.

    Heterogeneous fleets render each device's row to its own ``t_slr_j``
    (shorter devices end early, annotated with their class)."""
    scale = width / max(fleet.t_slr_of(j) for j in range(fleet.n_f))
    if fleet.is_heterogeneous:
        mix = ",".join(
            f"F{j + 1}:{fleet.profile(j).klass}(t_slr={fleet.t_slr_of(j):g},"
            f"t_cfg={fleet.t_cfg_of(j):g})"
            for j in range(fleet.n_f)
        )
        lines = [f"heterogeneous fleet n_f={fleet.n_f}: {mix}"]
    else:
        lines = [
            f"time slice t_slr={fleet.t_slr:g}, t_cfg={fleet.t_cfg:g}, n_f={fleet.n_f}"
        ]
    for dev, row in enumerate(plan_rows(plan, tasks)):
        cells = []
        for label, s, e in row:
            w = max(1, int(round((e - s) * scale)))
            txt = label[: w - 1] if w > 1 else ""
            cells.append(f"|{txt:<{w - 1}}" if w > 1 else "|")
        tag = f"F{dev + 1}"
        if fleet.is_heterogeneous:
            tag += f"[{fleet.profile(dev).klass[0]}]"
        lines.append(f"{tag} " + "".join(cells) + "|")
    if plan.splits:
        for sp in plan.splits:
            ratio = ":".join(f"{r:.3g}" for r in sp.ratio)
            devs = ",".join(f"F{d + 1}" for d in sp.devices)
            parts = ":".join(f"{p:g}" for p in sp.share_parts)
            lines.append(
                f"split {tasks[sp.task].name}: share {parts} across {devs} "
                f"-> input data ratio {ratio}"
            )
    if not plan.feasible:
        un = ",".join(tasks[k].name for k in plan.unplaced)
        lines.append(f"INFEASIBLE — unplaced: {un}")
    return "\n".join(lines)
