"""Pluggable Alg-2 block-placement backends.

The scheduler's hot path — *is this TFS row placeable?* for a whole block
of power-sorted rows — dispatches through a registry of interchangeable
engines (see :mod:`.base` for the contract and how to register new ones):

* ``"scalar"`` — the exact Alg-2/Alg-3 oracle, one row at a time;
* ``"numpy"``  — vectorized (B,) state advance, zero-dependency default
  (alias: ``"batched"``, the pre-refactor name);
* ``"jax"``    — jit'd ``lax.while_loop`` sweep, float64 via scoped
  ``enable_x64`` (lazy: registered on first lookup);
* ``"pallas"`` — the fused Pallas kernel
  (:mod:`repro.kernels.placement_step`), blocks tiled through VMEM
  (lazy; interpret mode off-TPU);
* ``"auto"``   — best available of the above.
"""

from .base import (
    BatchPlacement,
    InstanceBatch,
    PlacementBackend,
    PlacementOptions,
    available_backends,
    backend_names,
    dispatch_instance_blocks,
    get_backend,
    place_instance_blocks,
    prepare_block,
    register_backend,
    resolve_engine,
)

# Importing the zero-dependency backends registers them; jax/pallas are
# registered lazily by the registry (see base._LAZY_BACKENDS).
from . import numpy_backend as _numpy_backend  # noqa: F401
from . import scalar_backend as _scalar_backend  # noqa: F401

__all__ = [
    "BatchPlacement",
    "InstanceBatch",
    "PlacementBackend",
    "PlacementOptions",
    "available_backends",
    "backend_names",
    "dispatch_instance_blocks",
    "get_backend",
    "place_instance_blocks",
    "prepare_block",
    "register_backend",
    "resolve_engine",
]
