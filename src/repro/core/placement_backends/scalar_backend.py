"""Scalar block-placement backend — the reference oracle, one row at a time.

Routes every row of the block through the exact Alg-2/Alg-3 placement
simulation (:func:`repro.core.placement.place_shares`), which is the
ground truth all vectorized backends must agree with bit-for-bit.  It is
O(B) Python round-trips and exists for verification and tiny fleets, not
for throughput.

Eager by nature, its ``dispatch_block`` / ``dispatch_blocks`` hooks run
the sweep synchronously and hand back an already-resolved result —
pipelining a synchronous oracle would only reorder the Python work it is
meant to pin down — and ``dispatch_blocks_raw`` always answers ``None``
(no zero-copy surface; callers fall back per the base.py contract).  The
full five-method surface is still spelled out, and checked by
``tools/repro_lint`` rule B101, so every backend's fallback behavior is
explicit rather than an accident of ``getattr`` probing.
"""

from __future__ import annotations

import numpy as np

from ..placement import place_shares
from ..task import DeviceProfile, FleetSpec
from .base import (
    BatchPlacement,
    InstanceBatch,
    PlacementOptions,
    place_instance_blocks,
    prepare_block,
    register_backend,
)

__all__ = ["ScalarPlacementBackend"]


@register_backend("scalar")
class ScalarPlacementBackend:
    """Row-by-row scalar oracle behind the block-backend contract."""

    name = "scalar"
    async_dispatch = False

    @classmethod
    def available(cls) -> bool:
        return True

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return early
        B, n_t = shares.shape
        fleet = FleetSpec.heterogeneous(
            tuple(
                DeviceProfile(t_slr=float(s), t_cfg=float(c))
                for s, c in zip(t_slr_arr, t_cfg_arr, strict=True)
            )
        )
        feasible = np.zeros(B, dtype=bool)
        placed = np.zeros(B, dtype=np.int64)
        n_splits = np.zeros(B, dtype=np.int64)
        devices_used = np.zeros(B, dtype=np.int64)
        iis_list = [float(v) for v in iis]
        for r in range(B):
            plan = place_shares(
                [float(s) for s in shares[r]],
                iis_list,
                fleet,
                t_capture=opts.t_capture,
                t_store=opts.t_store,
                repay_init=opts.repay_init,
                resilience=opts.resilience,
            )
            feasible[r] = plan.feasible
            placed[r] = n_t - len(plan.unplaced) if not plan.feasible else n_t
            n_splits[r] = plan.n_splits
            used = [
                s.device + 1
                for s in plan.scripts
                if any(seg.kind != "null" for seg in s.segments)
            ]
            devices_used[r] = max(used, default=0)
        return BatchPlacement(
            feasible=feasible,
            placed_tasks=placed,
            n_splits=n_splits,
            devices_used=devices_used,
        )

    def dispatch_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ):
        """Eager dispatch: the oracle sweep runs now, the resolver returns it.

        Indistinguishable from ``place_block`` by the dispatch contract;
        there is no asynchrony to exploit in a scalar Python loop.
        """
        result = self.place_block(shares, iis, t_slr, t_cfg, opts)
        return lambda: result

    def place_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ) -> list[BatchPlacement]:
        """Loop-over-instances — for the oracle this *is* the definition.

        ``shard`` is accepted per the batching contract and ignored (no
        device mesh; verdicts may never depend on it).
        """
        return place_instance_blocks(self, batch, opts)

    def dispatch_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ):
        """Eager batched dispatch over :meth:`place_blocks`."""
        result = self.place_blocks(batch, opts, shard=shard)
        return lambda: result

    def dispatch_blocks_raw(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ):
        """No zero-copy verdict surface for the scalar oracle: always ``None``.

        ``None`` marks the batch degenerate for this backend, steering the
        many-walk onto :meth:`dispatch_blocks` (base.py's raw contract).
        """
        return None
