"""Scalar block-placement backend — the reference oracle, one row at a time.

Routes every row of the block through the exact Alg-2/Alg-3 placement
simulation (:func:`repro.core.placement.place_shares`), which is the
ground truth all vectorized backends must agree with bit-for-bit.  It is
O(B) Python round-trips and exists for verification and tiny fleets, not
for throughput.  Eager by nature, it omits the optional
``dispatch_block`` hook (``base.py``): pipelining a synchronous oracle
would only reorder the Python work it is meant to pin down.

It likewise omits the fleet-parallel ``place_blocks`` surface: the walk's
:func:`repro.core.placement_backends.base.place_instance_blocks` fallback
loops ``schedule_many`` batches through this oracle one instance at a
time, which *is* the definition of correct here.
"""

from __future__ import annotations

import numpy as np

from ..placement import place_shares
from ..task import DeviceProfile, FleetSpec
from .base import (
    BatchPlacement,
    PlacementOptions,
    prepare_block,
    register_backend,
)

__all__ = ["ScalarPlacementBackend"]


@register_backend("scalar")
class ScalarPlacementBackend:
    """Row-by-row scalar oracle behind the block-backend contract."""

    name = "scalar"

    @classmethod
    def available(cls) -> bool:
        return True

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return early
        B, n_t = shares.shape
        fleet = FleetSpec.heterogeneous(
            tuple(
                DeviceProfile(t_slr=float(s), t_cfg=float(c))
                for s, c in zip(t_slr_arr, t_cfg_arr)
            )
        )
        feasible = np.zeros(B, dtype=bool)
        placed = np.zeros(B, dtype=np.int64)
        n_splits = np.zeros(B, dtype=np.int64)
        devices_used = np.zeros(B, dtype=np.int64)
        iis_list = [float(v) for v in iis]
        for r in range(B):
            plan = place_shares(
                [float(s) for s in shares[r]],
                iis_list,
                fleet,
                t_capture=opts.t_capture,
                t_store=opts.t_store,
                repay_init=opts.repay_init,
                resilience=opts.resilience,
            )
            feasible[r] = plan.feasible
            placed[r] = n_t - len(plan.unplaced) if not plan.feasible else n_t
            n_splits[r] = plan.n_splits
            used = [
                s.device + 1
                for s in plan.scripts
                if any(seg.kind != "null" for seg in s.segments)
            ]
            devices_used[r] = max(used, default=0)
        return BatchPlacement(
            feasible=feasible,
            placed_tasks=placed,
            n_splits=n_splits,
            devices_used=devices_used,
        )
