"""Pallas block-placement backend — the whole carry/split sweep fused.

Wraps :func:`repro.kernels.ops.placement_sweep`: row tiles of the TFS
block stream through VMEM and an in-kernel ``fori_loop`` runs all
``n_t + n_f`` placement steps per tile in one fused kernel — no
intermediate HBM round-trips between steps, so ~10^6-row blocks sweep
per call.  Off-TPU the kernel executes in Pallas interpret mode (correct
but slow — useful for parity testing, not throughput; ``"auto"`` only
selects this backend on a TPU host).

Float64 comes from the same scoped ``enable_x64`` as the jax backend, so
interpret-mode verdicts are bit-identical to the scalar oracle.  On TPU
hardware float64 is unavailable; there the kernel lowers at float32 and
bit-parity relaxes to float32 accuracy (see ``kernels/placement_step.py``).

Fleet-parallel batching: ``dispatch_blocks`` wraps the grid-extended
kernel (:func:`repro.kernels.ops.placement_sweep_batch`) — the pallas
grid gains a leading instance axis, so one kernel launch sweeps every
instance's block with its own task/device tables.  ``shard`` is accepted
and ignored: a pallas_call runs on one device, and instance-axis device
layout is the jax backend's ``shard_map`` job (see ``base.py``).
"""

from __future__ import annotations

import numpy as np

from .base import (
    BatchPlacement,
    InstanceBatch,
    PlacementOptions,
    place_instance_blocks,
    prepare_block,
    register_backend,
    survivor_batch_tables,
    survivor_tables,
)

__all__ = ["PallasPlacementBackend"]


@register_backend("pallas")
class PallasPlacementBackend:
    """Fused single-kernel sweep (interpret mode off-TPU)."""

    name = "pallas"
    async_dispatch = True

    def __init__(self, block_rows: int = 1024) -> None:
        self.block_rows = block_rows

    @classmethod
    def available(cls) -> bool:
        try:
            from jax.experimental import pallas  # noqa: F401
        except ImportError:
            return False
        return True

    def dispatch_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ):
        """Enqueue the fused kernel; the returned resolver syncs verdicts.

        On TPU the pallas_call dispatches asynchronously like any jit'd
        computation, so the walk's double buffering overlaps the next
        block's enumeration with this sweep; in interpret mode execution
        is eager and the resolver just repackages (see ``base.py``).
        """
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return lambda: early
        import contextlib

        from jax.experimental import enable_x64

        from repro.kernels.ops import on_tpu, placement_sweep

        # Survivor tables are selected at float64 (the lexsort that picks
        # the worst-case adversary must match the other backends) before
        # any TPU float32 cast.
        surv = None
        if opts.resilience:
            surv = survivor_tables(t_slr_arr, t_cfg_arr, opts.resilience)
        # TPUs have no float64: lower the kernel at float32 there (verdicts
        # are float32-accurate, not bit-pinned); everywhere else the kernel
        # interprets at float64 under scoped x64 and stays bit-identical.
        if on_tpu():
            precision_ctx = contextlib.nullcontext()
            shares = shares.astype(np.float32)
            iis = iis.astype(np.float32)
            t_slr_arr = t_slr_arr.astype(np.float32)
            t_cfg_arr = t_cfg_arr.astype(np.float32)
            if surv is not None:
                surv = tuple(a.astype(np.float32) for a in surv)
        else:
            precision_ctx = enable_x64()
        with precision_ctx:
            outs = placement_sweep(
                shares,
                iis,
                t_slr_arr,
                t_cfg_arr,
                resume_cost=opts.resume_cost,
                repay_init=opts.repay_init,
                block_rows=self.block_rows,
            )
            outs_s = None
            if surv is not None:
                # Second, constrained pass: same rows on the worst-case
                # survivor fleet, enqueued back-to-back so both kernels
                # overlap the walk's next-block enumeration.
                outs_s = placement_sweep(
                    shares,
                    iis,
                    surv[0],
                    surv[1],
                    resume_cost=opts.resume_cost,
                    repay_init=opts.repay_init,
                    block_rows=self.block_rows,
                )

        def resolve() -> BatchPlacement:
            out = [np.asarray(a) for a in outs]
            feasible = out[0].astype(bool)
            if outs_s is not None:
                feasible = feasible & np.asarray(outs_s[0]).astype(bool)
            return BatchPlacement(
                feasible=feasible,
                placed_tasks=out[1].astype(np.int64),
                n_splits=out[2].astype(np.int64),
                devices_used=out[3].astype(np.int64),
            )

        return resolve

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        return self.dispatch_block(shares, iis, t_slr, t_cfg, opts)()

    def dispatch_blocks_raw(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ):
        """Enqueue the grid-extended launch; resolver returns raw arrays.

        Same raw batching contract as the jax backend (see ``base.py``):
        the resolver yields the four untrimmed ``(B', Rp)`` verdict
        arrays, and ``None`` signals a degenerate batch the kernel cannot
        express (callers fall back to the per-instance surface).
        ``shard`` is ignored: sharding the instance axis is ``shard_map``
        territory (engine="jax"); a single kernel launch lives on one
        device.
        """
        B = len(batch)
        if B == 0:
            return None
        if opts is None:
            opts = PlacementOptions()
        if batch.shares.shape[2] == 0 or batch.t_slr.shape[1] == 0:
            # Zero-width task/device tables cannot flow through the kernel;
            # prepare_block's early paths answer every instance.
            return None
        import contextlib

        from jax.experimental import enable_x64

        from repro.kernels.ops import on_tpu, placement_sweep_batch

        shares, iis = batch.shares, batch.iis
        t_slr, t_cfg = batch.t_slr, batch.t_cfg
        surv = None
        if opts.resilience:
            # Per-instance worst-case survivor tables, selected at float64
            # before any TPU cast (see dispatch_block).
            surv = survivor_batch_tables(
                t_slr, t_cfg, batch.n_f_eff, opts.resilience
            )
        if on_tpu():
            precision_ctx = contextlib.nullcontext()
            shares = shares.astype(np.float32)
            iis = iis.astype(np.float32)
            t_slr = t_slr.astype(np.float32)
            t_cfg = t_cfg.astype(np.float32)
            if surv is not None:
                surv = (
                    surv[0].astype(np.float32),
                    surv[1].astype(np.float32),
                    surv[2],
                )
        else:
            precision_ctx = enable_x64()
        with precision_ctx:
            outs = placement_sweep_batch(
                shares,
                iis,
                t_slr,
                t_cfg,
                batch.n_t_eff,
                batch.n_f_eff,
                resume_cost=opts.resume_cost,
                repay_init=opts.repay_init,
                block_rows=self.block_rows,
            )
            outs_s = None
            if surv is not None:
                outs_s = placement_sweep_batch(
                    shares,
                    iis,
                    surv[0],
                    surv[1],
                    batch.n_t_eff,
                    surv[2],
                    resume_cost=opts.resume_cost,
                    repay_init=opts.repay_init,
                    block_rows=self.block_rows,
                )

        def resolve_raw():
            feas, placed, n_splits, devices_used = (np.asarray(a) for a in outs)
            if outs_s is not None:
                feas = feas.astype(bool) & np.asarray(outs_s[0]).astype(bool)
            return feas, placed, n_splits, devices_used

        return resolve_raw

    def dispatch_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ):
        """Enqueue one grid-extended kernel launch over all B instances.

        The grid's leading axis walks instances, so every instance's
        block sweeps in the same ``pallas_call`` — resolver contract as
        the jax backend's (trimmed per-instance verdicts, bit-identical
        to the numpy loop reference in interpret mode).
        """
        B = len(batch)
        if B == 0:
            return lambda: []
        raw = self.dispatch_blocks_raw(batch, opts, shard=shard)
        if raw is None:
            result = place_instance_blocks(
                self, batch, opts if opts is not None else PlacementOptions()
            )
            return lambda: result

        def resolve() -> list[BatchPlacement]:
            feas, placed, n_splits, devices_used = raw()
            out = []
            for i in range(B):
                r = int(batch.n_rows[i])
                out.append(
                    BatchPlacement(
                        feasible=feas[i, :r].astype(bool),
                        placed_tasks=placed[i, :r].astype(np.int64),
                        n_splits=n_splits[i, :r].astype(np.int64),
                        devices_used=devices_used[i, :r].astype(np.int64),
                    )
                )
            return out

        return resolve

    def place_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ) -> list[BatchPlacement]:
        return self.dispatch_blocks(batch, opts, shard=shard)()
