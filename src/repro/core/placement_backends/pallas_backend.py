"""Pallas block-placement backend — the whole carry/split sweep fused.

Wraps :func:`repro.kernels.ops.placement_sweep`: row tiles of the TFS
block stream through VMEM and an in-kernel ``fori_loop`` runs all
``n_t + n_f`` placement steps per tile in one fused kernel — no
intermediate HBM round-trips between steps, so ~10^6-row blocks sweep
per call.  Off-TPU the kernel executes in Pallas interpret mode (correct
but slow — useful for parity testing, not throughput; ``"auto"`` only
selects this backend on a TPU host).

Float64 comes from the same scoped ``enable_x64`` as the jax backend, so
interpret-mode verdicts are bit-identical to the scalar oracle.  On TPU
hardware float64 is unavailable; there the kernel lowers at float32 and
bit-parity relaxes to float32 accuracy (see ``kernels/placement_step.py``).
"""

from __future__ import annotations

import numpy as np

from .base import (
    BatchPlacement,
    PlacementOptions,
    prepare_block,
    register_backend,
)

__all__ = ["PallasPlacementBackend"]


@register_backend("pallas")
class PallasPlacementBackend:
    """Fused single-kernel sweep (interpret mode off-TPU)."""

    name = "pallas"

    def __init__(self, block_rows: int = 1024) -> None:
        self.block_rows = block_rows

    @classmethod
    def available(cls) -> bool:
        try:
            from jax.experimental import pallas  # noqa: F401
        except ImportError:
            return False
        return True

    def dispatch_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ):
        """Enqueue the fused kernel; the returned resolver syncs verdicts.

        On TPU the pallas_call dispatches asynchronously like any jit'd
        computation, so the walk's double buffering overlaps the next
        block's enumeration with this sweep; in interpret mode execution
        is eager and the resolver just repackages (see ``base.py``).
        """
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return lambda: early
        import contextlib

        from jax.experimental import enable_x64

        from repro.kernels.ops import on_tpu, placement_sweep

        # TPUs have no float64: lower the kernel at float32 there (verdicts
        # are float32-accurate, not bit-pinned); everywhere else the kernel
        # interprets at float64 under scoped x64 and stays bit-identical.
        if on_tpu():
            precision_ctx = contextlib.nullcontext()
            shares = shares.astype(np.float32)
            iis = iis.astype(np.float32)
            t_slr_arr = t_slr_arr.astype(np.float32)
            t_cfg_arr = t_cfg_arr.astype(np.float32)
        else:
            precision_ctx = enable_x64()
        with precision_ctx:
            outs = placement_sweep(
                shares,
                iis,
                t_slr_arr,
                t_cfg_arr,
                resume_cost=opts.resume_cost,
                repay_init=opts.repay_init,
                block_rows=self.block_rows,
            )

        def resolve() -> BatchPlacement:
            out = [np.asarray(a) for a in outs]
            return BatchPlacement(
                feasible=out[0].astype(bool),
                placed_tasks=out[1].astype(np.int64),
                n_splits=out[2].astype(np.int64),
                devices_used=out[3].astype(np.int64),
            )

        return resolve

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        return self.dispatch_block(shares, iis, t_slr, t_cfg, opts)()
