"""Placement-backend contract and registry.

The Alg-2 hot path — *is this TFS row placeable on the fleet?* for a block
of ``B`` power-sorted rows at once — is pluggable.  A backend is any object
implementing :class:`PlacementBackend`:

    place_block(shares, iis, t_slr, t_cfg, opts) -> BatchPlacement

where ``shares`` is the ``(B, n_t)`` float64 shares matrix (one TFS row per
line, tasks in the paper's fixed order), ``iis`` the ``(n_t,)`` per-task
initialization intervals, ``t_slr`` / ``t_cfg`` the ``(n_f,)`` per-device
capacities and reconfiguration costs, and ``opts`` a
:class:`PlacementOptions` carrying the baseline-model knobs
(``t_capture``/``t_store``/``repay_init`` — see
:func:`repro.core.placement.place_shares`).

Every backend must reproduce the scalar oracle's verdicts **bit-for-bit**:
the arithmetic replays the same float64 operations in the same order
(``avail = (c - t_cfg_j) - extra``; ``c' = avail - rem``), asserted on the
paper's worked examples (Figs 2-4) and randomized heterogeneous fleets in
``tests/test_placement_backends.py``.

Block-enumeration handoff contract
----------------------------------

The walk (``repro.core.scheduler._walk_tfs_blocks``) feeds backends whole
blocks of *power-ordered* TFS rows and owns all winner/rank/reject
bookkeeping; a backend only ever sees a shares matrix.  The two block
producers are interchangeable by construction:

* exhaustive — ``FeasibilityResult.shares_matrix`` gathers a slice of
  ``tfs_indices_by_power()``;
* streaming — ``feasibility.iter_feasible_pruned_blocks`` yields
  :class:`repro.core.feasibility.ComboBlock` batches straight from the
  vectorized branch-and-bound frontier.

Both emit the same total order (ascending total power, exact ties by TSS
flat index) and the same float64 share values, so a backend's verdicts —
and therefore the chosen rank — cannot depend on which producer ran or on
how the stream was chopped into blocks.  Block sizes follow the walk's
geometric ramp (``scheduler.block_ramp``); a backend must accept any
``B >= 1`` and may not carry state between blocks.

The delta replanner (``repro.core.replan``) leans on the same two
guarantees: recorded per-row verdicts from a previous solve are *reused*
across calls (sound only because verdicts are bit-identical and
block-shape-independent), and its warm mini-walk feeds gathered candidate
blocks — power-sorted but not contiguous in any enumerator's emission —
through the very same ``place_block`` / ``dispatch_block`` entry points.
A backend that met this contract before the service layer existed needs
no changes to serve replans.

Asynchronous dispatch (optional)
--------------------------------

A backend may additionally expose::

    dispatch_block(shares, iis, t_slr, t_cfg, opts) -> () -> BatchPlacement

which *enqueues* the sweep and returns a zero-argument resolver that
blocks until the verdicts are back.  ``dispatch_block(...)()`` must be
indistinguishable from ``place_block(...)`` — same arrays, same bits.
The walk uses it to double-buffer: block k+1 is enqueued while block k
syncs, hiding enumeration and host↔device latency behind the sweep (jax
and pallas dispatch asynchronously; eager backends simply omit the hook
and the walk falls back to ``place_block``).

Registering a new backend
-------------------------

Decorate a class with :func:`register_backend` and implement the protocol::

    from repro.core.placement_backends import base

    @base.register_backend("mybackend")
    class MyBackend(base.PlacementBackend):
        name = "mybackend"

        def place_block(self, shares, iis, t_slr, t_cfg, opts=None):
            shares, iis, t_slr, t_cfg, opts, early = base.prepare_block(
                shares, iis, t_slr, t_cfg, opts
            )
            if early is not None:
                return early          # degenerate n_t == 0 / n_f == 0 block
            ...

``PADPSFRScheduler(engine="mybackend")`` then resolves it through
:func:`get_backend`.  Backends whose dependencies may be missing override
:meth:`PlacementBackend.available` (see ``jax_backend.py``); ``"auto"``
selection only considers available backends.  Backends living in modules
with heavyweight imports are registered lazily via ``_LAZY_BACKENDS`` so
that the numpy core stays importable with zero optional dependencies.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Protocol, runtime_checkable

import numpy as np

__all__ = [
    "BatchPlacement",
    "PlacementOptions",
    "PlacementBackend",
    "register_backend",
    "get_backend",
    "resolve_engine",
    "backend_names",
    "available_backends",
    "prepare_block",
]


@dataclasses.dataclass
class BatchPlacement:
    """Vectorised placement verdicts for a block of TFS rows.

    A placement backend answers Alg 2's *is this combo placeable?* for every
    row; the full per-device script of the (single) winning row is then
    produced by the scalar oracle, which is exact by construction.
    """

    feasible: np.ndarray  # (B,) bool
    placed_tasks: np.ndarray  # (B,) int — tasks fully placed (== n_t iff feasible)
    n_splits: np.ndarray  # (B,) int — tasks that split across devices
    devices_used: np.ndarray  # (B,) int — 1 + highest device index holding a
    # placement (on heterogeneous fleets, skipped too-small devices in
    # between still count toward this span)

    @property
    def n_feasible(self) -> int:
        return int(self.feasible.sum())

    def first_feasible(self) -> int:
        """Row index of the first feasible row, or -1."""
        idx = np.flatnonzero(self.feasible)
        return int(idx[0]) if idx.size else -1


@dataclasses.dataclass(frozen=True)
class PlacementOptions:
    """Placement-model knobs shared by every backend.

    Defaults are PADPS-FR (carried split tasks re-pay a fresh II); the
    capture/store pair models the refs-[9]/[10] preemptive baseline
    (see :func:`repro.core.placement.place_shares`).
    """

    t_capture: float = 0.0
    t_store: float = 0.0
    repay_init: bool = True

    @property
    def resume_cost(self) -> float:
        return self.t_capture + self.t_store


@runtime_checkable
class PlacementBackend(Protocol):
    """The pluggable Alg-2 block-placement engine contract."""

    name: str

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        """Place every row of a ``(B, n_t)`` shares block on the fleet.

        Backends with asynchronous execution may also implement
        ``dispatch_block`` (same signature, returns a zero-arg resolver)
        — see the module docstring's handoff contract; the walk
        double-buffers through it when present.
        """
        ...

    @classmethod
    def available(cls) -> bool:
        """Whether this backend's dependencies are importable here."""
        return True


def prepare_block(
    shares,
    iis,
    t_slr,
    t_cfg,
    opts: PlacementOptions | None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, PlacementOptions, BatchPlacement | None]:
    """Canonicalise backend inputs and resolve degenerate blocks.

    Returns ``(shares, iis, t_slr, t_cfg, opts, early)`` with float64
    contiguous arrays; ``early`` is a ready :class:`BatchPlacement` for the
    trivial cases every backend must agree on:

    * ``n_t == 0`` — nothing to place, every row is feasible;
    * ``n_f == 0`` with ``n_t > 0`` — an empty fleet places nothing, every
      row is infeasible (regression: this used to IndexError in the numpy
      engine's ``t_cfg_arr[jj]`` gather).
    """
    shares = np.ascontiguousarray(shares, dtype=np.float64)
    if shares.ndim != 2:
        raise ValueError(f"shares must be (B, n_t), got shape {shares.shape}")
    B, n_t = shares.shape
    iis = np.asarray(iis, dtype=np.float64)
    if iis.shape != (n_t,):
        raise ValueError(f"init_intervals must have length {n_t}")
    t_slr = np.asarray(t_slr, dtype=np.float64).reshape(-1)
    t_cfg = np.asarray(t_cfg, dtype=np.float64).reshape(-1)
    if t_slr.shape != t_cfg.shape:
        raise ValueError(
            f"t_slr/t_cfg must have matching shapes, got {t_slr.shape} vs {t_cfg.shape}"
        )
    if opts is None:
        opts = PlacementOptions()
    n_f = t_slr.shape[0]
    early = None
    if n_t == 0:
        early = BatchPlacement(
            feasible=np.ones(B, dtype=bool),
            placed_tasks=np.zeros(B, dtype=np.int64),
            n_splits=np.zeros(B, dtype=np.int64),
            devices_used=np.zeros(B, dtype=np.int64),
        )
    elif n_f == 0:
        early = BatchPlacement(
            feasible=np.zeros(B, dtype=bool),
            placed_tasks=np.zeros(B, dtype=np.int64),
            n_splits=np.zeros(B, dtype=np.int64),
            devices_used=np.zeros(B, dtype=np.int64),
        )
    return shares, iis, t_slr, t_cfg, opts, early


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
_INSTANCES: dict[str, PlacementBackend] = {}

# Engines whose modules import optional dependencies (jax) register on first
# lookup instead of at package import, keeping the numpy core zero-dependency.
_LAZY_BACKENDS: dict[str, str] = {
    "jax": "repro.core.placement_backends.jax_backend",
    "pallas": "repro.core.placement_backends.pallas_backend",
}

# Historical engine names kept working across the PR-1 -> PR-2 refactor.
_ALIASES: dict[str, str] = {"batched": "numpy"}


def register_backend(name: str):
    """Class decorator: register a :class:`PlacementBackend` under ``name``.

    Re-registering an existing name replaces the backend everywhere: any
    cached instance of the previous class is dropped so the next
    :func:`get_backend` lookup constructs the new one.
    """

    def deco(cls):
        _REGISTRY[name] = cls
        _INSTANCES.pop(name, None)
        return cls

    return deco


def backend_names() -> list[str]:
    """All registered engine names (including not-currently-available ones)."""
    return sorted(set(_REGISTRY) | set(_LAZY_BACKENDS))


def _check_known(name: str) -> None:
    if name not in _REGISTRY and name not in _LAZY_BACKENDS:
        raise ValueError(
            f"unknown placement engine {name!r}; known engines: "
            f"{', '.join(backend_names() + ['auto'] + sorted(_ALIASES))}"
        )


def _load(name: str) -> type:
    _check_known(name)
    if name not in _REGISTRY:
        try:
            importlib.import_module(_LAZY_BACKENDS[name])
        except ImportError as e:
            raise RuntimeError(
                f"placement backend {name!r} needs jax — install the [jax] "
                f"extra (pip install -e '.[jax]'): {e}"
            ) from e
    return _REGISTRY[name]


def available_backends() -> list[str]:
    """Engine names whose dependencies are importable in this process."""
    out = []
    for name in backend_names():
        try:
            if _load(name).available():
                out.append(name)
        except RuntimeError:
            continue
    return out


def resolve_engine(engine: str) -> str:
    """Canonical engine name for ``engine`` (aliases and ``"auto"``).

    ``"auto"`` picks the best available backend: the fused Pallas kernel on
    a TPU host, the jit'd jax sweep when jax is importable, the numpy block
    engine otherwise.
    """
    engine = _ALIASES.get(engine, engine)
    if engine != "auto":
        _check_known(engine)
        return engine
    avail = set(available_backends())
    if "pallas" in avail:
        try:
            import jax

            if jax.default_backend() == "tpu":
                return "pallas"
        except ImportError:  # pragma: no cover - pallas implies jax
            pass
    if "jax" in avail:
        return "jax"
    return "numpy"


def get_backend(engine: str) -> PlacementBackend:
    """Resolve ``engine`` (name, alias, or ``"auto"``) to a backend instance.

    Instances are cached — backends are stateless apart from compilation
    caches, which this sharing deliberately preserves across schedulers.
    """
    name = resolve_engine(engine)
    if name not in _INSTANCES:
        cls = _load(name)
        if not cls.available():
            hint = (
                " — install the [jax] extra (pip install -e '.[jax]')"
                if name in _LAZY_BACKENDS
                else ""
            )
            raise RuntimeError(
                f"placement backend {name!r} is registered but not available "
                f"in this environment (missing optional dependency?){hint}"
            )
        _INSTANCES[name] = cls()
    return _INSTANCES[name]
