"""Placement-backend contract and registry.

The Alg-2 hot path — *is this TFS row placeable on the fleet?* for a block
of ``B`` power-sorted rows at once — is pluggable.  A backend is any object
implementing :class:`PlacementBackend`:

    place_block(shares, iis, t_slr, t_cfg, opts) -> BatchPlacement

where ``shares`` is the ``(B, n_t)`` float64 shares matrix (one TFS row per
line, tasks in the paper's fixed order), ``iis`` the ``(n_t,)`` per-task
initialization intervals, ``t_slr`` / ``t_cfg`` the ``(n_f,)`` per-device
capacities and reconfiguration costs, and ``opts`` a
:class:`PlacementOptions` carrying the baseline-model knobs
(``t_capture``/``t_store``/``repay_init`` — see
:func:`repro.core.placement.place_shares`).

Every backend must reproduce the scalar oracle's verdicts **bit-for-bit**:
the arithmetic replays the same float64 operations in the same order
(``avail = (c - t_cfg_j) - extra``; ``c' = avail - rem``), asserted on the
paper's worked examples (Figs 2-4) and randomized heterogeneous fleets in
``tests/test_placement_backends.py``.

Block-enumeration handoff contract
----------------------------------

The walk (``repro.core.scheduler._walk_tfs_blocks``) feeds backends whole
blocks of *power-ordered* TFS rows and owns all winner/rank/reject
bookkeeping; a backend only ever sees a shares matrix.  The two block
producers are interchangeable by construction:

* exhaustive — ``FeasibilityResult.shares_matrix`` gathers a slice of
  ``tfs_indices_by_power()``;
* streaming — ``feasibility.iter_feasible_pruned_blocks`` yields
  :class:`repro.core.feasibility.ComboBlock` batches straight from the
  vectorized branch-and-bound frontier.

Both emit the same total order (ascending total power, exact ties by TSS
flat index) and the same float64 share values, so a backend's verdicts —
and therefore the chosen rank — cannot depend on which producer ran or on
how the stream was chopped into blocks.  Block sizes follow the walk's
geometric ramp (``scheduler.block_ramp``); a backend must accept any
``B >= 1`` and may not carry state between blocks.

The delta replanner (``repro.core.replan``) leans on the same two
guarantees: recorded per-row verdicts from a previous solve are *reused*
across calls (sound only because verdicts are bit-identical and
block-shape-independent), and its warm mini-walk feeds gathered candidate
blocks — power-sorted but not contiguous in any enumerator's emission —
through the very same ``place_block`` / ``dispatch_block`` entry points.
A backend that met this contract before the service layer existed needs
no changes to serve replans.

Fleet-parallel batching (optional)
----------------------------------

``schedule_many`` runs *many independent scheduling instances* — same
array shapes after padding, different fleets/tasks — through one batched
program.  The batched unit of work is an :class:`InstanceBatch`: the B
instances' current blocks stacked on a leading instance axis and padded to
common ``(R, n_t, n_f)`` extents, with per-instance effective counts
(``n_t_eff``/``n_f_eff``/``n_rows``) marking the live region of each
slice.  A backend may implement::

    place_blocks(batch, opts, *, shard=None)    -> list[BatchPlacement]
    dispatch_blocks(batch, opts, *, shard=None) -> () -> list[BatchPlacement]

(one :class:`BatchPlacement` per instance; ``shard`` requests an
instance-axis device mesh — ``"auto"`` = all devices, clamped to what the
host offers, ignored by meshless backends, never verdict-changing.)

Each returned :class:`BatchPlacement` is trimmed to that instance's
``n_rows`` and must be **bit-identical** to a solo ``place_block`` on the
trimmed instance (``batch.instance_view(i)``) — padding may never leak
into verdicts.  The canonical reference is :func:`place_instance_blocks`,
the loop-over-instances fallback the walk uses for any backend that does
not implement the batched surface; the numpy backend's ``place_blocks``
is exactly that loop, and every vmapped/grid-extended path is tested
bit-for-bit against it.  Padding rules (also the rules ``pack`` applies):

* rows ``r >= n_rows[i]``: zero shares, verdicts are garbage and sliced
  off before the trimmed result is built;
* task columns ``t >= n_t_eff[i]``: never read — the sweep's task cursor
  stops at ``n_t_eff``, so padded columns cannot perturb the float64
  chain (padding with zero-*share* tasks instead would change verdicts,
  because a zero-share task still pays ``t_cfg`` on placement);
* device slots ``j >= n_f_eff[i]``: never read — the device cursor dies
  (row infeasible) before touching them.  ``n_f_eff == 0`` with live
  tasks reproduces the empty-fleet early path (all rows infeasible);
  ``n_t_eff == 0`` reproduces the empty-block path (all rows feasible).

A batched backend may further expose the zero-copy raw surface::

    dispatch_blocks_raw(batch, opts, *, shard=None)
        -> (() -> (feasible, placed_tasks, n_splits, devices_used)) | None

where the resolver returns the four *untrimmed* verdict arrays of shape
``(B', Rp)`` with ``B' >= len(batch)`` and ``Rp >= max(n_rows)`` —
entries outside an instance's live region are padding and undefined,
live entries bit-identical to the trimmed surface.  ``None`` means the
batch is degenerate for this backend (caller falls back to
``dispatch_blocks`` / the per-instance loop).  The lockstep many-walk
prefers this surface so its round bookkeeping can run as a handful of
vectorized reductions instead of B per-instance result objects.

Resilience: the second, constrained pass
----------------------------------------

``opts.resilience = k`` (k > 0) turns every placement call into *two*
sweeps: the primary sweep on the full fleet, and a worst-case-survivor
sweep on :func:`survivor_tables` — the fleet minus the k devices whose
loss hurts most (``repro.core.task.worst_case_survivor_indices``; exact
on homogeneous fleets, a documented deterministic adversary on
heterogeneous ones).  ``feasible`` is the AND of both verdicts;
``placed_tasks`` / ``n_splits`` / ``devices_used`` keep describing the
*primary* sweep (the plan that actually runs pre-failure — the backup
placement is materialised only for the single winning row, by
``place_shares(..., resilience=k)``).  The survivor set is a function of
``(t_slr, t_cfg, k)`` alone, never of the candidate row, so resilient
verdicts inherit the reject monotonicity the replanner relies on.
``k >= n_f`` cannot be survived: every row with live tasks is infeasible
(a ``prepare_block`` early path).

Asynchronous dispatch (optional)
--------------------------------

A backend may additionally expose::

    dispatch_block(shares, iis, t_slr, t_cfg, opts) -> () -> BatchPlacement

which *enqueues* the sweep and returns a zero-argument resolver that
blocks until the verdicts are back.  ``dispatch_block(...)()`` must be
indistinguishable from ``place_block(...)`` — same arrays, same bits.
The walk uses it to double-buffer: block k+1 is enqueued while block k
syncs, hiding enumeration and host↔device latency behind the sweep (jax
and pallas dispatch asynchronously; eager backends simply omit the hook
and the walk falls back to ``place_block``).

Registering a new backend
-------------------------

Decorate a class with :func:`register_backend` and implement the protocol::

    from repro.core.placement_backends import base

    @base.register_backend("mybackend")
    class MyBackend(base.PlacementBackend):
        name = "mybackend"

        def place_block(self, shares, iis, t_slr, t_cfg, opts=None):
            shares, iis, t_slr, t_cfg, opts, early = base.prepare_block(
                shares, iis, t_slr, t_cfg, opts
            )
            if early is not None:
                return early          # degenerate n_t == 0 / n_f == 0 block
            ...

``PADPSFRScheduler(engine="mybackend")`` then resolves it through
:func:`get_backend`.  Backends whose dependencies may be missing override
:meth:`PlacementBackend.available` (see ``jax_backend.py``); ``"auto"``
selection only considers available backends.  Backends living in modules
with heavyweight imports are registered lazily via ``_LAZY_BACKENDS`` so
that the numpy core stays importable with zero optional dependencies.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Protocol, runtime_checkable

import numpy as np

from ..task import worst_case_survivor_indices

__all__ = [
    "BatchPlacement",
    "InstanceBatch",
    "PlacementOptions",
    "PlacementBackend",
    "register_backend",
    "get_backend",
    "resolve_engine",
    "backend_names",
    "available_backends",
    "prepare_block",
    "place_instance_blocks",
    "dispatch_instance_blocks",
    "survivor_tables",
    "survivor_batch_tables",
]


@dataclasses.dataclass
class BatchPlacement:
    """Vectorised placement verdicts for a block of TFS rows.

    A placement backend answers Alg 2's *is this combo placeable?* for every
    row; the full per-device script of the (single) winning row is then
    produced by the scalar oracle, which is exact by construction.
    """

    feasible: np.ndarray  # (B,) bool
    placed_tasks: np.ndarray  # (B,) int — tasks fully placed (== n_t iff feasible)
    n_splits: np.ndarray  # (B,) int — tasks that split across devices
    devices_used: np.ndarray  # (B,) int — 1 + highest device index holding a
    # placement (on heterogeneous fleets, skipped too-small devices in
    # between still count toward this span)

    @property
    def n_feasible(self) -> int:
        return int(self.feasible.sum())

    def first_feasible(self) -> int:
        """Row index of the first feasible row, or -1."""
        idx = np.flatnonzero(self.feasible)
        return int(idx[0]) if idx.size else -1


@dataclasses.dataclass(frozen=True)
class PlacementOptions:
    """Placement-model knobs shared by every backend.

    Defaults are PADPS-FR (carried split tasks re-pay a fresh II); the
    capture/store pair models the refs-[9]/[10] preemptive baseline
    (see :func:`repro.core.placement.place_shares`).
    """

    t_capture: float = 0.0
    t_store: float = 0.0
    repay_init: bool = True
    # k-fault tolerance: > 0 adds the worst-case-survivor sweep (see the
    # module docstring's resilience contract).
    resilience: int = 0

    @property
    def resume_cost(self) -> float:
        return self.t_capture + self.t_store


def survivor_tables(
    t_slr: np.ndarray, t_cfg: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-device tables of the worst-case surviving fleet (k failures).

    The array-level twin of ``FleetSpec.survivors``: survivors keep their
    original relative order, so the survivor sweep is exactly a solo sweep
    on a smaller fleet.  Callers guard ``k < n_f`` (``prepare_block``'s
    early path answers ``k >= n_f``).
    """
    keep = worst_case_survivor_indices(t_slr, t_cfg, k)
    return t_slr[keep], t_cfg[keep]


def survivor_batch_tables(
    t_slr: np.ndarray,
    t_cfg: np.ndarray,
    n_f_eff: np.ndarray,
    k: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-instance survivor tables for the fleet-parallel batched sweep.

    For each instance the k worst-case failures are dropped from its live
    device prefix and the survivors left-packed; instances with
    ``n_f_eff <= k`` get ``n_f_eff_s == 0`` — the batched sweep's
    empty-fleet semantics (all rows with live tasks infeasible, zero-task
    rows feasible), matching the scalar oracle's ``resilience >= n_f``
    verdicts.
    """
    B = t_slr.shape[0]
    t_slr_s = np.zeros_like(t_slr)
    t_cfg_s = np.zeros_like(t_cfg)
    n_f_eff = np.asarray(n_f_eff)
    n_f_eff_s = np.maximum(n_f_eff - k, 0).astype(n_f_eff.dtype)
    for i in range(B):
        nf = int(n_f_eff[i])
        if nf <= k:
            continue
        keep = worst_case_survivor_indices(t_slr[i, :nf], t_cfg[i, :nf], k)
        t_slr_s[i, : nf - k] = t_slr[i, keep]
        t_cfg_s[i, : nf - k] = t_cfg[i, keep]
    return t_slr_s, t_cfg_s, n_f_eff_s


@dataclasses.dataclass(frozen=True)
class InstanceBatch:
    """B independent scheduling instances' blocks, stacked and padded.

    The fleet-parallel unit of work (see the module docstring's batching
    contract).  Build one with :meth:`pack`; recover instance ``i``'s
    trimmed solo-call arguments with :meth:`instance_view`.  Padded
    regions hold zeros and are never read by a conforming backend.
    """

    shares: np.ndarray  # (B, R, n_t) float64 — rows padded to max r_i
    iis: np.ndarray  # (B, n_t) float64
    t_slr: np.ndarray  # (B, n_f) float64
    t_cfg: np.ndarray  # (B, n_f) float64
    n_t_eff: np.ndarray  # (B,) int32 — live task columns per instance
    n_f_eff: np.ndarray  # (B,) int32 — live device slots per instance
    n_rows: np.ndarray  # (B,) int32 — live rows per instance

    def __len__(self) -> int:
        return self.shares.shape[0]

    @classmethod
    def pack(
        cls,
        blocks: "list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]",
    ) -> "InstanceBatch":
        """Stack per-instance ``(shares, iis, t_slr, t_cfg)`` tuples.

        Instances may disagree on row count, task count and fleet size;
        everything is zero-padded up to the batch maxima and the effective
        counts record each instance's live extents.  An empty list packs
        to a valid zero-instance batch.
        """
        B = len(blocks)
        if B == 0:
            z = np.zeros((0, 0), dtype=np.float64)
            zi = np.zeros(0, dtype=np.int32)
            return cls(
                shares=np.zeros((0, 0, 0), dtype=np.float64),
                iis=z, t_slr=z, t_cfg=z,
                n_t_eff=zi, n_f_eff=zi, n_rows=zi,
            )
        canon = []
        for shares_i, iis_i, slr_i, cfg_i in blocks:
            shares_i = np.ascontiguousarray(shares_i, dtype=np.float64)
            if shares_i.ndim != 2:
                raise ValueError(
                    f"each shares block must be (r, n_t), got {shares_i.shape}"
                )
            iis_i = np.asarray(iis_i, dtype=np.float64).reshape(-1)
            slr_i = np.asarray(slr_i, dtype=np.float64).reshape(-1)
            cfg_i = np.asarray(cfg_i, dtype=np.float64).reshape(-1)
            if iis_i.shape[0] != shares_i.shape[1]:
                raise ValueError(
                    f"init_intervals length {iis_i.shape[0]} != n_t {shares_i.shape[1]}"
                )
            if slr_i.shape != cfg_i.shape:
                raise ValueError("t_slr/t_cfg must have matching shapes")
            canon.append((shares_i, iis_i, slr_i, cfg_i))
        r0, nt0 = canon[0][0].shape
        nf0 = canon[0][2].shape[0]
        if all(
            s.shape[0] == r0 and s.shape[1] == nt0 and sl.shape[0] == nf0
            for s, _, sl, _ in canon
        ):
            # Uniform batch (the lockstep walk's steady state: every live
            # instance on the same ramp step): one C-level stack per
            # field, no padding pass.
            return cls(
                shares=np.stack([s for s, _, _, _ in canon]),
                iis=np.stack([x for _, x, _, _ in canon]),
                t_slr=np.stack([x for _, _, x, _ in canon]),
                t_cfg=np.stack([x for _, _, _, x in canon]),
                n_t_eff=np.full(B, nt0, dtype=np.int32),
                n_f_eff=np.full(B, nf0, dtype=np.int32),
                n_rows=np.full(B, r0, dtype=np.int32),
            )
        R = max(s.shape[0] for s, _, _, _ in canon)
        n_t = max(s.shape[1] for s, _, _, _ in canon)
        n_f = max(sl.shape[0] for _, _, sl, _ in canon)
        shares = np.zeros((B, R, n_t), dtype=np.float64)
        iis = np.zeros((B, n_t), dtype=np.float64)
        t_slr = np.zeros((B, n_f), dtype=np.float64)
        t_cfg = np.zeros((B, n_f), dtype=np.float64)
        n_t_eff = np.zeros(B, dtype=np.int32)
        n_f_eff = np.zeros(B, dtype=np.int32)
        n_rows = np.zeros(B, dtype=np.int32)
        for i, (shares_i, iis_i, slr_i, cfg_i) in enumerate(canon):
            r_i, nt_i = shares_i.shape
            nf_i = slr_i.shape[0]
            shares[i, :r_i, :nt_i] = shares_i
            iis[i, :nt_i] = iis_i
            t_slr[i, :nf_i] = slr_i
            t_cfg[i, :nf_i] = cfg_i
            n_t_eff[i] = nt_i
            n_f_eff[i] = nf_i
            n_rows[i] = r_i
        return cls(
            shares=shares, iis=iis, t_slr=t_slr, t_cfg=t_cfg,
            n_t_eff=n_t_eff, n_f_eff=n_f_eff, n_rows=n_rows,
        )

    def instance_view(
        self, i: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Instance ``i``'s trimmed ``(shares, iis, t_slr, t_cfg)``.

        Exactly what a solo ``place_block`` call on the original
        (pre-padding) instance would receive.
        """
        r, nt, nf = int(self.n_rows[i]), int(self.n_t_eff[i]), int(self.n_f_eff[i])
        return (
            self.shares[i, :r, :nt],
            self.iis[i, :nt],
            self.t_slr[i, :nf],
            self.t_cfg[i, :nf],
        )


def place_instance_blocks(
    backend: "PlacementBackend",
    batch: InstanceBatch,
    opts: PlacementOptions | None = None,
) -> list[BatchPlacement]:
    """Loop-over-instances reference for the batched surface.

    Runs ``backend.place_block`` on each instance's trimmed view; every
    batched ``place_blocks`` implementation must match this bit-for-bit
    per instance.  Also the walk's fallback for backends that predate the
    batched contract.
    """
    return [
        backend.place_block(*batch.instance_view(i), opts) for i in range(len(batch))
    ]


def dispatch_instance_blocks(
    backend: "PlacementBackend",
    batch: InstanceBatch,
    opts: PlacementOptions | None = None,
    *,
    shard: int | str | None = None,
):
    """Batched async dispatch with per-instance fallback.

    Prefers the backend's ``dispatch_blocks``; else its ``place_blocks``;
    else per-instance ``dispatch_block``/``place_block``.  Returns a
    zero-arg resolver yielding ``list[BatchPlacement]`` either way.

    ``shard`` asks the backend to split the instance axis across that many
    jax devices (``"auto"`` = as many as available); backends without a
    device mesh — and the per-instance fallbacks — accept and ignore it,
    clamping is the backend's job, and verdicts must not depend on it.
    """
    hook = getattr(backend, "dispatch_blocks", None)
    if hook is not None:
        return hook(batch, opts, shard=shard)
    batched = getattr(backend, "place_blocks", None)
    if batched is not None:
        result = batched(batch, opts, shard=shard)
        return lambda: result
    solo = getattr(backend, "dispatch_block", None)
    if solo is not None:
        resolvers = [solo(*batch.instance_view(i), opts) for i in range(len(batch))]
        return lambda: [r() for r in resolvers]
    result = place_instance_blocks(backend, batch, opts)
    return lambda: result


@runtime_checkable
class PlacementBackend(Protocol):
    """The pluggable Alg-2 block-placement engine contract."""

    name: str

    #: Whether ``dispatch_block`` / ``dispatch_blocks`` actually overlap
    #: device work with the caller (jax/pallas enqueue, sync later).  The
    #: walk only holds extra blocks in flight when this is True — an eager
    #: backend that merely *spells out* the dispatch surface must say
    #: ``False`` or the scheduler speculates blocks past the winner for
    #: nothing.  Pipelining is declared, not inferred from method presence.
    async_dispatch: bool

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        """Place every row of a ``(B, n_t)`` shares block on the fleet.

        Backends with asynchronous execution may also implement
        ``dispatch_block`` (same signature, returns a zero-arg resolver)
        — see the module docstring's handoff contract; the walk
        double-buffers through it when present.
        """
        ...

    @classmethod
    def available(cls) -> bool:
        """Whether this backend's dependencies are importable here."""
        return True


def prepare_block(
    shares,
    iis,
    t_slr,
    t_cfg,
    opts: PlacementOptions | None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, PlacementOptions, BatchPlacement | None]:
    """Canonicalise backend inputs and resolve degenerate blocks.

    Returns ``(shares, iis, t_slr, t_cfg, opts, early)`` with float64
    contiguous arrays; ``early`` is a ready :class:`BatchPlacement` for the
    trivial cases every backend must agree on:

    * ``n_t == 0`` — nothing to place, every row is feasible;
    * ``n_f == 0`` with ``n_t > 0`` — an empty fleet places nothing, every
      row is infeasible (regression: this used to IndexError in the numpy
      engine's ``t_cfg_arr[jj]`` gather);
    * ``opts.resilience >= n_f`` with ``n_t > 0`` — losing every device
      cannot be survived, every row is infeasible.
    """
    shares = np.ascontiguousarray(shares, dtype=np.float64)
    if shares.ndim != 2:
        raise ValueError(f"shares must be (B, n_t), got shape {shares.shape}")
    B, n_t = shares.shape
    iis = np.asarray(iis, dtype=np.float64)
    if iis.shape != (n_t,):
        raise ValueError(f"init_intervals must have length {n_t}")
    t_slr = np.asarray(t_slr, dtype=np.float64).reshape(-1)
    t_cfg = np.asarray(t_cfg, dtype=np.float64).reshape(-1)
    if t_slr.shape != t_cfg.shape:
        raise ValueError(
            f"t_slr/t_cfg must have matching shapes, got {t_slr.shape} vs {t_cfg.shape}"
        )
    if opts is None:
        opts = PlacementOptions()
    n_f = t_slr.shape[0]
    early = None
    if n_t == 0:
        early = BatchPlacement(
            feasible=np.ones(B, dtype=bool),
            placed_tasks=np.zeros(B, dtype=np.int64),
            n_splits=np.zeros(B, dtype=np.int64),
            devices_used=np.zeros(B, dtype=np.int64),
        )
    elif n_f == 0 or opts.resilience >= n_f:
        early = BatchPlacement(
            feasible=np.zeros(B, dtype=bool),
            placed_tasks=np.zeros(B, dtype=np.int64),
            n_splits=np.zeros(B, dtype=np.int64),
            devices_used=np.zeros(B, dtype=np.int64),
        )
    return shares, iis, t_slr, t_cfg, opts, early


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
_INSTANCES: dict[str, PlacementBackend] = {}

# Engines whose modules import optional dependencies (jax) register on first
# lookup instead of at package import, keeping the numpy core zero-dependency.
_LAZY_BACKENDS: dict[str, str] = {
    "jax": "repro.core.placement_backends.jax_backend",
    "pallas": "repro.core.placement_backends.pallas_backend",
}

# Historical engine names kept working across the PR-1 -> PR-2 refactor.
_ALIASES: dict[str, str] = {"batched": "numpy"}


def register_backend(name: str):
    """Class decorator: register a :class:`PlacementBackend` under ``name``.

    Re-registering an existing name replaces the backend everywhere: any
    cached instance of the previous class is dropped so the next
    :func:`get_backend` lookup constructs the new one.
    """

    def deco(cls):
        _REGISTRY[name] = cls
        _INSTANCES.pop(name, None)
        return cls

    return deco


def backend_names() -> list[str]:
    """All registered engine names (including not-currently-available ones)."""
    return sorted(set(_REGISTRY) | set(_LAZY_BACKENDS))


def _check_known(name: str) -> None:
    if name not in _REGISTRY and name not in _LAZY_BACKENDS:
        raise ValueError(
            f"unknown placement engine {name!r}; known engines: "
            f"{', '.join(backend_names() + ['auto'] + sorted(_ALIASES))}"
        )


def _load(name: str) -> type:
    _check_known(name)
    if name not in _REGISTRY:
        try:
            importlib.import_module(_LAZY_BACKENDS[name])
        except ImportError as e:
            raise RuntimeError(
                f"placement backend {name!r} needs jax — install the [jax] "
                f"extra (pip install -e '.[jax]'): {e}"
            ) from e
    return _REGISTRY[name]


def available_backends() -> list[str]:
    """Engine names whose dependencies are importable in this process."""
    out = []
    for name in backend_names():
        try:
            if _load(name).available():
                out.append(name)
        except RuntimeError:
            continue
    return out


def resolve_engine(engine: str) -> str:
    """Canonical engine name for ``engine`` (aliases and ``"auto"``).

    ``"auto"`` picks the best available backend: the fused Pallas kernel on
    a TPU host, the jit'd jax sweep when jax is importable, the numpy block
    engine otherwise.
    """
    engine = _ALIASES.get(engine, engine)
    if engine != "auto":
        _check_known(engine)
        return engine
    avail = set(available_backends())
    if "pallas" in avail:
        try:
            import jax

            if jax.default_backend() == "tpu":
                return "pallas"
        except ImportError:  # pragma: no cover - pallas implies jax
            pass
    if "jax" in avail:
        return "jax"
    return "numpy"


def get_backend(engine: str) -> PlacementBackend:
    """Resolve ``engine`` (name, alias, or ``"auto"``) to a backend instance.

    Instances are cached — backends are stateless apart from compilation
    caches, which this sharing deliberately preserves across schedulers.
    """
    name = resolve_engine(engine)
    if name not in _INSTANCES:
        cls = _load(name)
        if not cls.available():
            hint = (
                " — install the [jax] extra (pip install -e '.[jax]')"
                if name in _LAZY_BACKENDS
                else ""
            )
            raise RuntimeError(
                f"placement backend {name!r} is registered but not available "
                f"in this environment (missing optional dependency?){hint}"
            )
        _INSTANCES[name] = cls()
    return _INSTANCES[name]
