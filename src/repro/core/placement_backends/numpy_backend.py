"""Numpy block-placement backend — the default, zero-dependency engine.

The paper's ``find_low_power_task_set()`` walks the power-sorted TFS one
combination at a time through the scalar placement simulation
(:func:`repro.core.placement.place_shares`) — O(|TFS|) Python round-trips
on the hot path of every scheduling decision.  This backend evaluates an
entire block of TFS rows at once: the block is a shares matrix ``(B, n_t)``
and the simulation state (device cursor ``j``, remaining capacity ``c``,
task cursor ``k``, carried share ``tsd``) lives in (B,) arrays advanced by
vectorized carry/split steps.

Each step, every live row either advances its task cursor (the current
task fits on the current device) or its device cursor (no-start, split
carry, or post-placement closure), so the loop runs at most ``n_t + n_f``
iterations *regardless of B* — the per-row Python interpreter cost of the
scalar walk is amortised over the whole block.

The arithmetic replays the scalar oracle's float64 operations in the same
order (``avail = (c - t_cfg_j) - extra``; ``c' = avail - rem``), so the
two engines agree bit-for-bit — asserted on the paper's worked examples
(Figs 2-4) and on randomized heterogeneous fleets in
``tests/test_placement_batched.py`` / ``tests/test_placement_backends.py``.

Heterogeneity is native: capacities ``t_slr_j`` and reconfiguration costs
``t_cfg_j`` are per-device gathers, so mixed FPGA/GPU/CPU fleets
(:class:`repro.core.power.DeviceClass`) cost nothing extra.

This backend is deliberately eager — it computes in the caller's thread.
Its ``dispatch_block`` / ``dispatch_blocks`` hooks therefore run the sweep
synchronously and return an already-resolved result (indistinguishable
from the eager calls, per the dispatch contract in ``base.py``), and
``dispatch_blocks_raw`` answers ``None`` so the many-walk uses the trimmed
surface.  Running unpipelined is the right call when the "device" is the
host CPU itself; spelling the full surface out anyway keeps the fallback
behavior explicit — ``tools/repro_lint`` rule B101 enforces it.
"""

from __future__ import annotations

import numpy as np

from ..placement import _EPS
from .base import (
    BatchPlacement,
    InstanceBatch,
    PlacementOptions,
    place_instance_blocks,
    prepare_block,
    register_backend,
    survivor_tables,
)

__all__ = ["NumpyPlacementBackend"]


def _sweep(
    shares: np.ndarray,
    iis: np.ndarray,
    t_slr_arr: np.ndarray,
    t_cfg_arr: np.ndarray,
    resume_cost: float,
    repay_init: bool,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One vectorized Alg-2 sweep; returns (feasible, k, n_splits, devices)."""
    B, n_t = shares.shape
    n_f = t_slr_arr.shape[0]

    # Per-row simulation state (mirrors the scalar walk's locals).
    j = np.zeros(B, dtype=np.int64)  # device cursor
    k = np.zeros(B, dtype=np.int64)  # task cursor (paper's sti)
    c = np.full(B, t_slr_arr[0], dtype=np.float64)
    tsd = np.zeros(B, dtype=np.float64)  # carried share of task k
    dead = np.zeros(B, dtype=bool)
    n_splits = np.zeros(B, dtype=np.int64)
    devices_used = np.zeros(B, dtype=np.int64)

    while True:
        act = np.flatnonzero(~dead & (k < n_t))
        if act.size == 0:
            break
        jj = j[act]
        kk = k[act]
        cc = c[act]
        ii = iis[kk]
        tcfg = t_cfg_arr[jj]
        carried = tsd[act] > _EPS
        extra = np.where(carried, ii if repay_init else resume_cost, 0.0)
        rem = shares[act, kk] - tsd[act]
        avail = (cc - tcfg) - extra
        can_start = (cc > tcfg + ii + _EPS) & (avail > _EPS)
        split = can_start & (rem - avail > _EPS)
        fits = can_start & ~split

        # Any placement (split or full) occupies the current device.
        devices_used[act] = np.where(
            can_start, np.maximum(devices_used[act], jj + 1), devices_used[act]
        )

        # Split: run `avail` here, carry the remainder to the next device.
        tsd[act] = np.where(split, tsd[act] + avail, tsd[act])
        n_splits[act] += (split & ~carried).astype(np.int64)

        # Fits: consume cfg + extra + remaining share, advance the task.
        c_after = avail - rem
        closure = fits & (c_after <= tcfg + ii + _EPS)
        c[act] = np.where(fits, c_after, c[act])
        k[act] = kk + fits.astype(np.int64)
        tsd[act] = np.where(fits, 0.0, tsd[act])

        # Device advance: no-start, split carry, or closure after a fit.
        advance = ~can_start | split | closure
        j_next = jj + advance.astype(np.int64)
        j[act] = j_next
        still_working = k[act] < n_t
        overflow = advance & (j_next >= n_f) & still_working
        dead[act] |= overflow
        refill = advance & (j_next < n_f)
        c[act] = np.where(refill, t_slr_arr[np.minimum(j_next, n_f - 1)], c[act])

    return (k >= n_t) & ~dead, k, n_splits, devices_used


@register_backend("numpy")
class NumpyPlacementBackend:
    """Vectorized (B,) state advance in numpy; the portable fallback."""

    name = "numpy"
    async_dispatch = False

    @classmethod
    def available(cls) -> bool:
        return True

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return early
        feasible, k, n_splits, devices_used = _sweep(
            shares, iis, t_slr_arr, t_cfg_arr, opts.resume_cost, opts.repay_init
        )
        if opts.resilience:
            # Second, constrained pass: the same rows must also place on
            # the worst-case survivor fleet (see base.py's resilience
            # contract); the primary sweep keeps describing the plan.
            t_slr_s, t_cfg_s = survivor_tables(
                t_slr_arr, t_cfg_arr, opts.resilience
            )
            feasible = feasible & _sweep(
                shares, iis, t_slr_s, t_cfg_s, opts.resume_cost, opts.repay_init
            )[0]
        return BatchPlacement(
            feasible=feasible,
            placed_tasks=k,
            n_splits=n_splits,
            devices_used=devices_used,
        )

    def place_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ) -> list[BatchPlacement]:
        """Loop-over-instances — the bit-exact fleet-parallel reference.

        Deliberately *not* vectorized over the instance axis: each
        instance's trimmed view goes through the plain ``place_block``
        path, so this is the ground truth every vmapped / grid-extended
        batched backend is tested against (see the batching contract in
        ``base.py``).  ``shard`` is accepted for signature compatibility
        and ignored — there is no device mesh here.
        """
        return place_instance_blocks(self, batch, opts)

    def dispatch_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ):
        """Eager dispatch: the vectorized sweep runs now, resolver returns it."""
        result = self.place_block(shares, iis, t_slr, t_cfg, opts)
        return lambda: result

    def dispatch_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ):
        """Eager batched dispatch over :meth:`place_blocks`."""
        result = self.place_blocks(batch, opts, shard=shard)
        return lambda: result

    def dispatch_blocks_raw(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard=None,
    ):
        """No zero-copy surface here: ``None`` steers callers to the trimmed path."""
        return None
