"""JAX block-placement backend — a jit'd ``lax.while_loop`` over (B,) state.

The numpy engine's vectorized carry/split step becomes one XLA program:
the whole per-row simulation state (device cursor, task cursor, remaining
capacity, carried share) is a tuple of ``(B,)`` arrays advanced inside a
``lax.while_loop`` with ``n_t`` / ``n_f`` static, so a TFS block of 10^6
rows sweeps in a single device call with no per-step host round-trip.

Bit-compatibility with the scalar oracle: the step arithmetic (defined
once in :func:`repro.kernels.ref.placement_sweep_ref`) replays the same
float64 add/sub chains in the same order — no multiply-add pairs, so XLA
cannot FMA-contract them — and runs under a scoped ``enable_x64`` so the
global jax float32 default (which the model/training substrate relies on)
is untouched.

Block shapes are padded to the next power of two, bounding recompilation
to O(log B) specializations per (n_t, n_f) topology; padded rows are
sliced off before the verdicts leave the backend.

``dispatch_block`` exposes jax's async dispatch to the scheduler walk:
the jit'd sweep is *enqueued* and a resolver returned; converting the
outputs to numpy (the only blocking step) happens when the walk calls
it, one block later — so enumeration of block k+1 overlaps the device
sweep of block k (double buffering, see ``base.py``).

Fleet-parallel batching: ``dispatch_blocks`` vmaps the same sweep over a
stacked :class:`repro.core.placement_backends.base.InstanceBatch` — one
XLA program places B instances' blocks, amortising the per-dispatch
overhead that dominates a Python loop of solo calls.  Ragged instances
arrive padded; the vmapped kernel threads each instance's traced
``n_t_eff``/``n_f_eff`` so padded columns are never read and verdicts
stay bit-identical to the numpy loop-over-instances reference.  Both the
instance axis (to a power of two) and the row axis are padded outside
jit, bounding recompiles to O(log B · log R) per (n_t, n_f) topology.
With ``shard=`` the instance axis is additionally laid out across a 1-D
device mesh via ``shard_map`` (clamped to the largest power of two that
the host's device count and the padded batch allow — a single-device
host degrades to the plain vmap, never an error).
"""

from __future__ import annotations

import functools

import numpy as np

from .base import (
    BatchPlacement,
    InstanceBatch,
    PlacementOptions,
    prepare_block,
    register_backend,
    survivor_batch_tables,
    survivor_tables,
)

__all__ = ["JaxPlacementBackend", "resolve_shard"]

_MIN_PAD = 8


def _pad_pow2(n: int, minimum: int = 1) -> int:
    """Next power of two >= n (>= minimum)."""
    p = minimum
    while p < n:
        p <<= 1
    return p


def _pad_rows(B: int) -> int:
    """Next power of two >= B (>= _MIN_PAD) — the static block height."""
    return _pad_pow2(B, _MIN_PAD)


@functools.cache
def _jitted_sweep():
    """Build the jit'd sweep lazily so importing this module stays cheap."""
    import jax

    from repro.kernels.ref import placement_sweep_ref

    return jax.jit(placement_sweep_ref, static_argnames=("repay_init",))


@functools.cache
def _jitted_resilient_sweep():
    """Jit'd resilience-mode sweep: primary AND worst-case-survivor pass.

    Both sweeps live in one jit program, so the second, constrained pass
    of ``opts.resilience`` costs one extra while_loop inside the same
    dispatch — not a second host round-trip.
    """
    import jax

    from repro.kernels.ref import placement_sweep_resilient_ref

    return jax.jit(placement_sweep_resilient_ref, static_argnames=("repay_init",))


def resolve_shard(shard: int | str | None, Bp: int) -> int:
    """Clamp a ``shard=`` request to a usable instance-axis mesh size.

    Returns the number of devices to lay the (padded, power-of-two)
    instance axis over: the largest power of two that is <= the request
    (``"auto"`` = all local jax devices), <= the host's device count, and
    <= ``Bp`` so the axis divides evenly.  ``None``, one device, or an
    empty batch all resolve to 1 — plain vmap, no mesh — which is the
    graceful single-device degrade the benchmarks rely on.
    """
    if shard is None or Bp == 0:
        return 1
    import jax

    n_dev = len(jax.devices())
    want = n_dev if shard == "auto" else int(shard)
    if want < 1:
        raise ValueError(f"shard must be >= 1 or 'auto', got {shard!r}")
    limit = min(want, n_dev, Bp)
    nd = 1
    while nd * 2 <= limit:
        nd *= 2
    return nd


@functools.cache
def _jitted_batch_sweep(n_shards: int):
    """Jit'd fleet-parallel sweep, optionally shard_map'd over devices.

    Cached per mesh size: ``n_shards == 1`` is the plain vmapped sweep;
    larger meshes wrap it in ``shard_map`` with the instance axis
    partitioned (every other operand axis replicated), so each device
    sweeps ``Bp / n_shards`` instances of the same compiled program.
    """
    import jax

    from repro.kernels.ref import placement_sweep_batch_ref

    if n_shards <= 1:
        return jax.jit(placement_sweep_batch_ref, static_argnames=("repay_init",))

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("i",))

    def sweep(shares, iis, t_slr, t_cfg, n_t_eff, n_f_eff, resume_cost, *, repay_init):
        return shard_map(
            functools.partial(placement_sweep_batch_ref, repay_init=repay_init),
            mesh=mesh,
            in_specs=(P("i"), P("i"), P("i"), P("i"), P("i"), P("i"), P()),
            out_specs=(P("i"), P("i"), P("i"), P("i")),
            # jax has no replication rule for while_loop; every output is
            # instance-axis partitioned anyway, so the check adds nothing.
            check_rep=False,
        )(shares, iis, t_slr, t_cfg, n_t_eff, n_f_eff, resume_cost)

    return jax.jit(sweep, static_argnames=("repay_init",))


@functools.cache
def _jitted_batch_resilient_sweep(n_shards: int):
    """Jit'd fleet-parallel resilience sweep, optionally shard_map'd.

    The resilience-mode twin of :func:`_jitted_batch_sweep`: three extra
    instance-axis operands carry the per-instance worst-case-survivor
    tables (``base.survivor_batch_tables``), partitioned alongside the
    primary tables on meshes > 1.
    """
    import jax

    from repro.kernels.ref import placement_sweep_batch_resilient_ref

    if n_shards <= 1:
        return jax.jit(
            placement_sweep_batch_resilient_ref, static_argnames=("repay_init",)
        )

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("i",))

    def sweep(
        shares,
        iis,
        t_slr,
        t_cfg,
        n_t_eff,
        n_f_eff,
        t_slr_s,
        t_cfg_s,
        n_f_eff_s,
        resume_cost,
        *,
        repay_init,
    ):
        return shard_map(
            functools.partial(
                placement_sweep_batch_resilient_ref, repay_init=repay_init
            ),
            mesh=mesh,
            in_specs=(P("i"),) * 9 + (P(),),
            out_specs=(P("i"), P("i"), P("i"), P("i")),
            check_rep=False,
        )(
            shares,
            iis,
            t_slr,
            t_cfg,
            n_t_eff,
            n_f_eff,
            t_slr_s,
            t_cfg_s,
            n_f_eff_s,
            resume_cost,
        )

    return jax.jit(sweep, static_argnames=("repay_init",))


@register_backend("jax")
class JaxPlacementBackend:
    """``lax.while_loop`` sweep, float64 via scoped ``enable_x64``."""

    name = "jax"
    async_dispatch = True

    @classmethod
    def available(cls) -> bool:
        try:
            import jax  # noqa: F401
        except ImportError:
            return False
        return True

    def dispatch_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ):
        """Enqueue the jit'd sweep; the returned resolver syncs verdicts.

        The outputs stay on-device until the resolver runs, so callers
        can overlap enumeration/dispatch of the next block with this
        one's execution (see the ``dispatch_block`` contract in
        ``base.py``).
        """
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return lambda: early
        from jax.experimental import enable_x64

        B = shares.shape[0]
        Bp = _pad_rows(B)
        if Bp != B:
            shares = np.pad(shares, ((0, Bp - B), (0, 0)))
        with enable_x64():
            if opts.resilience:
                t_slr_s, t_cfg_s = survivor_tables(
                    t_slr_arr, t_cfg_arr, opts.resilience
                )
                outs = _jitted_resilient_sweep()(
                    shares,
                    iis,
                    t_slr_arr,
                    t_cfg_arr,
                    t_slr_s,
                    t_cfg_s,
                    np.float64(opts.resume_cost),
                    repay_init=opts.repay_init,
                )
            else:
                outs = _jitted_sweep()(
                    shares,
                    iis,
                    t_slr_arr,
                    t_cfg_arr,
                    np.float64(opts.resume_cost),
                    repay_init=opts.repay_init,
                )

        def resolve() -> BatchPlacement:
            out = [np.asarray(a)[:B] for a in outs]
            return BatchPlacement(
                feasible=out[0].astype(bool),
                placed_tasks=out[1].astype(np.int64),
                n_splits=out[2].astype(np.int64),
                devices_used=out[3].astype(np.int64),
            )

        return resolve

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        return self.dispatch_block(shares, iis, t_slr, t_cfg, opts)()

    def dispatch_blocks_raw(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard: int | str | None = None,
    ):
        """Enqueue one vmapped sweep; resolver returns untrimmed arrays.

        The zero-copy variant of :meth:`dispatch_blocks` (see the raw
        batching contract in ``base.py``): the resolver yields the four
        verdict arrays ``(feasible, placed_tasks, n_splits,
        devices_used)`` with shape ``(B', Rp)`` where ``B' >= len(batch)``
        and ``Rp >= max(n_rows)`` — entries beyond an instance's
        ``n_rows[i]`` (or beyond ``len(batch)``) are padding and
        undefined; live entries are bit-identical to the solo sweep.
        Returns ``None`` for degenerate batches the traced sweep cannot
        express (zero instances / zero-width task or device tables) —
        callers fall back to the trimmed per-instance surface.
        """
        B = len(batch)
        if B == 0:
            return None
        if opts is None:
            opts = PlacementOptions()
        if batch.shares.shape[2] == 0 or batch.t_slr.shape[1] == 0:
            # Degenerate padded widths (no tasks / no devices anywhere in
            # the batch): the traced sweep cannot index zero-width tables,
            # but prepare_block's early paths answer every instance.
            return None
        from jax.experimental import enable_x64

        Bp = _pad_pow2(B)
        Rp = _pad_rows(batch.shares.shape[1])
        shares = batch.shares
        pad_b, pad_r = Bp - B, Rp - shares.shape[1]
        if opts.resilience:
            # Survivor tables are computed per live instance before padding
            # (padded instances keep n_f_eff_s == 0, matching their
            # n_t_eff == 0 no-op status).
            t_slr_s, t_cfg_s, n_f_eff_s = survivor_batch_tables(
                batch.t_slr, batch.t_cfg, batch.n_f_eff, opts.resilience
            )
        if pad_b or pad_r:
            # Padded instances carry n_t_eff == 0 (all-feasible no-ops);
            # padded rows are garbage-swept and trimmed by the resolver.
            shares = np.pad(shares, ((0, pad_b), (0, pad_r), (0, 0)))
        iis = np.pad(batch.iis, ((0, pad_b), (0, 0))) if pad_b else batch.iis
        t_slr = np.pad(batch.t_slr, ((0, pad_b), (0, 0))) if pad_b else batch.t_slr
        t_cfg = np.pad(batch.t_cfg, ((0, pad_b), (0, 0))) if pad_b else batch.t_cfg
        n_t_eff = np.pad(batch.n_t_eff, (0, pad_b)) if pad_b else batch.n_t_eff
        n_f_eff = np.pad(batch.n_f_eff, (0, pad_b)) if pad_b else batch.n_f_eff

        n_shards = resolve_shard(shard, Bp)
        with enable_x64():
            if opts.resilience:
                if pad_b:
                    t_slr_s = np.pad(t_slr_s, ((0, pad_b), (0, 0)))
                    t_cfg_s = np.pad(t_cfg_s, ((0, pad_b), (0, 0)))
                    n_f_eff_s = np.pad(n_f_eff_s, (0, pad_b))
                outs = _jitted_batch_resilient_sweep(n_shards)(
                    shares,
                    iis,
                    t_slr,
                    t_cfg,
                    n_t_eff,
                    n_f_eff,
                    t_slr_s,
                    t_cfg_s,
                    n_f_eff_s,
                    np.float64(opts.resume_cost),
                    repay_init=opts.repay_init,
                )
            else:
                outs = _jitted_batch_sweep(n_shards)(
                    shares,
                    iis,
                    t_slr,
                    t_cfg,
                    n_t_eff,
                    n_f_eff,
                    np.float64(opts.resume_cost),
                    repay_init=opts.repay_init,
                )

        return lambda: tuple(np.asarray(a) for a in outs)

    def dispatch_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard: int | str | None = None,
    ):
        """Enqueue one vmapped sweep over all B instances' blocks.

        See the fleet-parallel batching contract in ``base.py``: the
        resolver returns one :class:`BatchPlacement` per instance,
        trimmed to its live rows, bit-identical to the numpy
        loop-over-instances reference.  ``shard`` lays the instance axis
        across a device mesh (clamped via :func:`resolve_shard`; a
        single-device host silently runs the plain vmap).
        """
        B = len(batch)
        if B == 0:
            return lambda: []
        raw = self.dispatch_blocks_raw(batch, opts, shard=shard)
        if raw is None:
            from .base import place_instance_blocks

            result = place_instance_blocks(
                self, batch, opts if opts is not None else PlacementOptions()
            )
            return lambda: result

        def resolve() -> list[BatchPlacement]:
            feas, placed, n_splits, devices_used = raw()
            out = []
            for i in range(B):
                r = int(batch.n_rows[i])
                out.append(
                    BatchPlacement(
                        feasible=feas[i, :r].astype(bool),
                        placed_tasks=placed[i, :r].astype(np.int64),
                        n_splits=n_splits[i, :r].astype(np.int64),
                        devices_used=devices_used[i, :r].astype(np.int64),
                    )
                )
            return out

        return resolve

    def place_blocks(
        self,
        batch: InstanceBatch,
        opts: PlacementOptions | None = None,
        *,
        shard: int | str | None = None,
    ) -> list[BatchPlacement]:
        return self.dispatch_blocks(batch, opts, shard=shard)()
