"""JAX block-placement backend — a jit'd ``lax.while_loop`` over (B,) state.

The numpy engine's vectorized carry/split step becomes one XLA program:
the whole per-row simulation state (device cursor, task cursor, remaining
capacity, carried share) is a tuple of ``(B,)`` arrays advanced inside a
``lax.while_loop`` with ``n_t`` / ``n_f`` static, so a TFS block of 10^6
rows sweeps in a single device call with no per-step host round-trip.

Bit-compatibility with the scalar oracle: the step arithmetic (defined
once in :func:`repro.kernels.ref.placement_sweep_ref`) replays the same
float64 add/sub chains in the same order — no multiply-add pairs, so XLA
cannot FMA-contract them — and runs under a scoped ``enable_x64`` so the
global jax float32 default (which the model/training substrate relies on)
is untouched.

Block shapes are padded to the next power of two, bounding recompilation
to O(log B) specializations per (n_t, n_f) topology; padded rows are
sliced off before the verdicts leave the backend.

``dispatch_block`` exposes jax's async dispatch to the scheduler walk:
the jit'd sweep is *enqueued* and a resolver returned; converting the
outputs to numpy (the only blocking step) happens when the walk calls
it, one block later — so enumeration of block k+1 overlaps the device
sweep of block k (double buffering, see ``base.py``).
"""

from __future__ import annotations

import functools

import numpy as np

from .base import (
    BatchPlacement,
    PlacementOptions,
    prepare_block,
    register_backend,
)

__all__ = ["JaxPlacementBackend"]

_MIN_PAD = 8


def _pad_rows(B: int) -> int:
    """Next power of two >= B (>= _MIN_PAD) — the static block height."""
    p = _MIN_PAD
    while p < B:
        p <<= 1
    return p


@functools.cache
def _jitted_sweep():
    """Build the jit'd sweep lazily so importing this module stays cheap."""
    import jax

    from repro.kernels.ref import placement_sweep_ref

    return jax.jit(placement_sweep_ref, static_argnames=("repay_init",))


@register_backend("jax")
class JaxPlacementBackend:
    """``lax.while_loop`` sweep, float64 via scoped ``enable_x64``."""

    name = "jax"

    @classmethod
    def available(cls) -> bool:
        try:
            import jax  # noqa: F401
        except ImportError:
            return False
        return True

    def dispatch_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ):
        """Enqueue the jit'd sweep; the returned resolver syncs verdicts.

        The outputs stay on-device until the resolver runs, so callers
        can overlap enumeration/dispatch of the next block with this
        one's execution (see the ``dispatch_block`` contract in
        ``base.py``).
        """
        shares, iis, t_slr_arr, t_cfg_arr, opts, early = prepare_block(
            shares, iis, t_slr, t_cfg, opts
        )
        if early is not None:
            return lambda: early
        from jax.experimental import enable_x64

        B = shares.shape[0]
        Bp = _pad_rows(B)
        if Bp != B:
            shares = np.pad(shares, ((0, Bp - B), (0, 0)))
        sweep = _jitted_sweep()
        with enable_x64():
            outs = sweep(
                shares,
                iis,
                t_slr_arr,
                t_cfg_arr,
                np.float64(opts.resume_cost),
                repay_init=opts.repay_init,
            )

        def resolve() -> BatchPlacement:
            out = [np.asarray(a)[:B] for a in outs]
            return BatchPlacement(
                feasible=out[0].astype(bool),
                placed_tasks=out[1].astype(np.int64),
                n_splits=out[2].astype(np.int64),
                devices_used=out[3].astype(np.int64),
            )

        return resolve

    def place_block(
        self,
        shares: np.ndarray,
        iis: np.ndarray,
        t_slr: np.ndarray,
        t_cfg: np.ndarray,
        opts: PlacementOptions | None = None,
    ) -> BatchPlacement:
        return self.dispatch_block(shares, iis, t_slr, t_cfg, opts)()
