"""Sharded checkpointing: async save, retention, auto-resume."""

from .checkpoint import CheckpointManager, load_pytree, save_pytree

__all__ = ["CheckpointManager", "load_pytree", "save_pytree"]
