"""Fault-tolerant checkpointing without external deps.

Layout:  <dir>/step_<N>/
            manifest.json      — tree structure, shapes, dtypes, meta
            arr_<i>.npy        — one file per leaf (host-local shard
                                 when the array is sharded; the full
                                 array on single-host runs)
         <dir>/LATEST          — atomic pointer (write tmp + rename)

Guarantees:
* Atomic publication — a crash mid-save never corrupts LATEST; a resume
  sees the last fully-written step (tested by killing a writer).
* Async save — leaves are snapshotted to host RAM synchronously
  (device->host copy), written by a background thread; training
  continues immediately.
* Retention — keep the newest K checkpoints, always keeping step 0
  multiples of ``keep_every`` if set.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]

_MANIFEST = "manifest.json"
_LATEST = "LATEST"


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_pytree(path: str, tree: Any, *, meta: dict | None = None) -> None:
    """Synchronous atomic save of a pytree of arrays."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _treedef = _flatten_with_paths(tree)
    entries = []
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i}.npy"
        dtype = str(arr.dtype)
        if dtype == "bfloat16":  # np.save can't serialise ml_dtypes natively
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"file": fname, "shape": list(arr.shape), "dtype": dtype})
    # Tree structure is NOT serialised: restore always goes through a
    # `like` tree (the live TrainState), which is both simpler and safe
    # across code refactors that keep leaf order.
    manifest = {
        "entries": entries,
        "meta": meta or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like: Any) -> tuple[Any, dict]:
    """Load into the structure of ``like`` (shardings applied by caller)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree.flatten(like)
    entries = manifest["entries"]
    if len(entries) != len(flat_like):
        raise ValueError(
            f"checkpoint {path} has {len(entries)} leaves, expected {len(flat_like)}"
        )
    leaves = []
    for e in entries:
        arr = np.load(os.path.join(path, e["file"]))
        if e["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), manifest["meta"]


@dataclasses.dataclass
class CheckpointManager:
    """Directory-of-steps manager with async save + retention."""

    directory: str
    keep: int = 3
    keep_every: int = 0  # additionally keep step % keep_every == 0

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: list[BaseException] = []

    # ---- paths ----
    def step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        """Resolve LATEST; fall back to directory scan (torn pointer)."""
        p = os.path.join(self.directory, _LATEST)
        if os.path.exists(p):
            try:
                with open(p) as f:
                    step = int(f.read().strip())
                if os.path.exists(os.path.join(self.step_dir(step), _MANIFEST)):
                    return step
            except (ValueError, OSError):
                pass
        steps = [s for s in self.all_steps()
                 if os.path.exists(os.path.join(self.step_dir(s), _MANIFEST))]
        return steps[-1] if steps else None

    # ---- save ----
    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def save(self, step: int, tree: Any, *, meta: dict | None = None, sync: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        # snapshot to host RAM now; write in background
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        meta = dict(meta or {}, step=step)

        def work():
            try:
                save_pytree(self.step_dir(step), host_tree, meta=meta)
                tmp = os.path.join(self.directory, _LATEST + ".tmp")
                with open(tmp, "w") as f:
                    f.write(str(step))
                os.replace(tmp, os.path.join(self.directory, _LATEST))
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error.append(e)

        if sync:
            work()
            if self._error:
                raise self._error.pop()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _gc(self) -> None:
        steps = self.all_steps()
        keepers = set(steps[-self.keep :]) if self.keep > 0 else set(steps)
        if self.keep_every:
            keepers |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in keepers:
                shutil.rmtree(self.step_dir(s), ignore_errors=True)

    # ---- restore ----
    def restore(self, like: Any, step: int | None = None) -> tuple[Any, dict] | None:
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        return load_pytree(self.step_dir(step), like)
