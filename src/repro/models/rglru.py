"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local
(sliding-window) attention blocks in a repeating pattern (rec, rec, attn).

Long-context decode is bounded: recurrent layers carry an O(W) state and
attention layers keep a ring-buffer KV cache of ``local_window`` slots —
this is the second arch that RUNS ``long_500k``.

Layer stacking: the repeating pattern is scanned as *super-blocks*
(one scan step = rec + rec + attn), with any pattern remainder applied
unscanned; HLO size stays O(pattern), not O(L).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.sharding.ctx import shard
from .layers import apply_rope, rms_norm, swiglu
from .params import ParamSpec
from .transformer import ExecConfig, attn_specs, mlp_specs

__all__ = [
    "hybrid_specs",
    "hybrid_forward",
    "hybrid_decode_step",
    "init_hybrid_state",
]

_N_DIAG_BLOCKS = 8  # Griffin's block-diagonal gate projections


def _pattern_split(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    pat = cfg.block_pattern
    n_super = cfg.n_layers // len(pat)
    rest = cfg.layer_kinds()[n_super * len(pat) :]
    return n_super, rest


def rec_block_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    D = cfg.d_model
    W = cfg.lru_width or D
    nb = _N_DIAG_BLOCKS
    wb = W // nb
    K = 4  # temporal conv width
    s = {
        "ln1": ParamSpec((L, D), ("layers", "embed"), init="zeros"),
        "w_gate_br": ParamSpec((L, D, W), ("layers", "embed", "state")),
        "w_rec_br": ParamSpec((L, D, W), ("layers", "embed", "state")),
        "conv_w": ParamSpec((L, K, W), ("layers", "conv", "state"), init="normal"),
        "conv_b": ParamSpec((L, W), ("layers", "state"), init="zeros"),
        # block-diagonal RG-LRU gate projections
        "wa": ParamSpec((L, nb, wb, wb), ("layers", None, "state", None)),
        "wx": ParamSpec((L, nb, wb, wb), ("layers", None, "state", None)),
        "ba": ParamSpec((L, W), ("layers", "state"), init="zeros"),
        "bx": ParamSpec((L, W), ("layers", "state"), init="zeros"),
        "log_lambda": ParamSpec((L, W), ("layers", "state"), init="recurrent"),
        "w_out": ParamSpec((L, W, D), ("layers", "state", "embed")),
        "ln2": ParamSpec((L, D), ("layers", "embed"), init="zeros"),
        "mlp": None,  # filled below
    }
    s["mlp"] = mlp_specs(cfg, L)
    return s


def attn_block_specs(cfg: ModelConfig, L: int) -> dict[str, Any]:
    return {
        "ln1": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros"),
        "attn": attn_specs(cfg, L),
        "ln2": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros"),
        "mlp": mlp_specs(cfg, L),
    }


def hybrid_specs(cfg: ModelConfig) -> dict[str, Any]:
    n_super, rest = _pattern_split(cfg)
    pat = cfg.block_pattern
    super_specs = {
        str(i): (
            rec_block_specs(cfg, n_super)
            if kind == "rec"
            else attn_block_specs(cfg, n_super)
        )
        for i, kind in enumerate(pat)
    }
    rest_specs = {
        str(i): (
            rec_block_specs(cfg, 1) if kind == "rec" else attn_block_specs(cfg, 1)
        )
        for i, kind in enumerate(rest)
    }
    s: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed"),
        "final_ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "super": super_specs,
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }
    if rest_specs:
        s["rest"] = rest_specs
    return s


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_diag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,S,W) @ block-diag w: (nb, wb, wb) + b."""
    B, S, W = x.shape
    nb, wb = w.shape[0], w.shape[1]
    xb = x.reshape(B, S, nb, wb)
    y = jnp.einsum("bsnw,nwv->bsnv", xb, w.astype(x.dtype))
    return y.reshape(B, S, W) + b.astype(x.dtype)


def _rec_block(cfg: ModelConfig, ex: ExecConfig, p: dict, h, *, state, return_state):
    """Griffin recurrent block.  state: {'conv': (B,3,W), 'h': (B,W)} or None."""
    dt = h.dtype
    W = cfg.lru_width or cfg.d_model
    h = shard(h, "batch", "act_seq", None)
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", hn, p["w_gate_br"].astype(dt)).astype(jnp.float32)
    ).astype(dt)
    gate = shard(gate, "batch", "seq", "state")
    xr = shard(jnp.einsum("bsd,dw->bsw", hn, p["w_rec_br"].astype(dt)), "batch", "seq", "state")

    new_state = {}
    if state is None:
        from .ssm import _causal_conv

        xc = _causal_conv(xr, p["conv_w"]) + p["conv_b"].astype(dt)
        if return_state:
            new_state["conv"] = xr[:, -(p["conv_w"].shape[0] - 1) :].astype(dt)
    else:
        from .ssm import _conv_step

        xc1, new_state["conv"] = _conv_step(state["conv"], xr[:, 0], p["conv_w"])
        xc = (xc1 + p["conv_b"].astype(dt))[:, None]

    r_gate = _block_diag(xc, p["wa"], p["ba"])
    i_gate = _block_diag(xc, p["wx"], p["bx"])

    if state is None:
        if ex.attn_impl == "pallas":
            from repro.kernels import ops

            out = ops.rglru_scan(
                xc, r_gate, i_gate, p["log_lambda"], return_state=return_state
            )
        else:
            out = kref.rglru_ref(
                xc, r_gate, i_gate, p["log_lambda"], return_state=return_state
            )
        if return_state:
            y, new_state["h"] = out
        else:
            y = out
    else:
        y1, new_state["h"] = kref.rglru_decode_step(
            state["h"], xc[:, 0], r_gate[:, 0], i_gate[:, 0], p["log_lambda"]
        )
        y = y1[:, None]

    y = y * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dt))
    h = shard(h + out, "batch", "act_seq", None)
    hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    h = h + swiglu(hn2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return shard(h, "batch", "act_seq", None), (
        new_state if (state is not None or return_state) else None
    )


def _ring_positions(idx: jax.Array, window: int) -> jax.Array:
    """Absolute position held by each ring slot after writing pos ``idx``.

    Slot s holds p_s = idx - ((idx - s) mod window); p_s < 0 => never written.
    """
    s = jnp.arange(window)
    return idx - jnp.mod(idx - s, window)


def _attn_block(cfg: ModelConfig, ex: ExecConfig, p: dict, h, *, state, idx, return_state):
    """Local-attention block with ring-buffer KV cache for decode."""
    from .transformer import _attn_dispatch

    dt = h.dtype
    Wwin = cfg.local_window
    h = shard(h, "batch", "act_seq", None)
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    a = p["attn"]
    q = shard(jnp.einsum("bsd,dhk->bshk", hn, a["wq"].astype(dt)), "batch", "seq", "heads", None)
    k = shard(jnp.einsum("bsd,dhk->bshk", hn, a["wk"].astype(dt)), "batch", "seq", "kv", None)
    v = shard(jnp.einsum("bsd,dhk->bshk", hn, a["wv"].astype(dt)), "batch", "seq", "kv", None)

    new_state = {}
    if state is None:
        B, S = hn.shape[0], hn.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        out = _attn_dispatch(
            ex, q, k, v, q_offset=0, kv_len=None, causal=True, window=Wwin
        )
        if return_state:
            # build the ring from the last `window` positions
            ring_pos = _ring_positions(jnp.asarray(S - 1), Wwin)  # (W,)
            safe = jnp.clip(ring_pos, 0, S - 1)
            new_state["ck"] = jnp.take(k, safe, axis=1).astype(dt)
            new_state["cv"] = jnp.take(v, safe, axis=1).astype(dt)
    else:
        B = hn.shape[0]
        pos = jnp.broadcast_to(idx[None, None], (B, 1))
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        slot = jnp.mod(idx, Wwin)
        ck = lax.dynamic_update_slice_in_dim(state["ck"], k.astype(dt), slot, axis=1)
        cv = lax.dynamic_update_slice_in_dim(state["cv"], v.astype(dt), slot, axis=1)
        new_state["ck"], new_state["cv"] = ck, cv
        # Ring semantics: slots hold exactly the last `window` positions
        # (<= idx); slots never written have ring_pos < 0 and are masked.
        ring_pos = _ring_positions(idx, Wwin)  # (W,)
        out = _ring_attention(q, ck, cv, ring_pos)

    o = jnp.einsum("bshk,hkd->bsd", out, a["wo"].astype(dt))
    h = shard(h + o, "batch", "act_seq", None)
    hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    h = h + swiglu(hn2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return shard(h, "batch", "act_seq", None), (
        new_state if (state is not None or return_state) else None
    )


def _ring_attention(q, ck, cv, ring_pos):
    """Decode attention over a ring cache with per-slot validity mask.

    q: (B,1,H,hd), ck/cv: (B,W,K,hd), ring_pos: (W,) — slots with
    ring_pos < 0 are masked out.
    """
    import math as _math

    B, S, H, hd = q.shape
    K = ck.shape[2]
    g = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, g, hd) / _math.sqrt(hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qf, ck.astype(jnp.float32))
    s = jnp.where(ring_pos[None, None, None, None, :] >= 0, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, cv.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Model assembly
# ---------------------------------------------------------------------------


def _apply_kind(cfg, ex, kind, p, h, *, state, idx, return_state):
    if kind == "rec":
        return _rec_block(cfg, ex, p, h, state=state, return_state=return_state)
    return _attn_block(cfg, ex, p, h, state=state, idx=idx, return_state=return_state)


def init_hybrid_state(cfg: ModelConfig, batch_size: int, dtype=None) -> dict:
    """Decode state: per pattern position, stacked over super-blocks."""
    dt = jnp.dtype(dtype or cfg.dtype)
    W = cfg.lru_width or cfg.d_model
    hd = cfg.resolved_head_dim
    n_super, rest = _pattern_split(cfg)
    pat = cfg.block_pattern

    def one(kind, L):
        if kind == "rec":
            return {
                "conv": jnp.zeros((L, batch_size, 3, W), dt),
                "h": jnp.zeros((L, batch_size, W), jnp.float32),
            }
        return {
            "ck": jnp.zeros((L, batch_size, cfg.local_window, cfg.n_kv_heads, hd), dt),
            "cv": jnp.zeros((L, batch_size, cfg.local_window, cfg.n_kv_heads, hd), dt),
        }

    st: dict[str, Any] = {"super": {str(i): one(k, n_super) for i, k in enumerate(pat)}}
    if rest:
        st["rest"] = {str(i): one(k, 1) for i, k in enumerate(rest)}
    return st


def hybrid_forward(
    cfg: ModelConfig,
    ex: ExecConfig,
    params: dict,
    batch: dict,
    *,
    return_state: bool = False,
):
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    pat = cfg.block_pattern
    n_super, rest = _pattern_split(cfg)

    def body(carry, xs):
        h = carry
        sts = {}
        for i, kind in enumerate(pat):
            h, st = _apply_kind(
                cfg, ex, kind, xs[str(i)], h, state=None, idx=None,
                return_state=return_state,
            )
            sts[str(i)] = st if st is not None else ()
        return h, sts

    body = ex.remat_wrap(body)
    if ex.scan_layers and n_super > 0:
        h, super_states = lax.scan(body, h, params["super"])
    else:
        sts_list = []
        for j in range(n_super):
            p_j = jax.tree.map(lambda a: a[j], params["super"])
            h, sts = body(h, p_j)
            sts_list.append(sts)
        super_states = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *sts_list) if return_state else {}
        )

    rest_states: dict = {}
    for i, kind in enumerate(rest):
        p_i = params["rest"][str(i)]
        p_i = jax.tree.map(lambda a: a[0], p_i)  # unstack L=1
        h, st = _apply_kind(
            cfg, ex, kind, p_i, h, state=None, idx=None, return_state=return_state
        )
        if return_state:
            rest_states[str(i)] = jax.tree.map(lambda a: a[None], st)

    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(dt))
    aux = jnp.zeros((), jnp.float32)
    if return_state:
        state = {"super": super_states}
        if rest_states:
            state["rest"] = rest_states
        return logits, aux, state
    return logits, aux


def hybrid_decode_step(cfg: ModelConfig, ex: ExecConfig, params: dict, state, tokens, idx):
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], tokens[:, None], axis=0).astype(dt)
    pat = cfg.block_pattern
    n_super, rest = _pattern_split(cfg)

    def body(carry, xs):
        h = carry
        p, st = xs
        new_sts = {}
        for i, kind in enumerate(pat):
            h, new_st = _apply_kind(
                cfg, ex, kind, p[str(i)], h, state=st[str(i)], idx=idx,
                return_state=False,
            )
            new_sts[str(i)] = new_st
        return h, new_sts

    if n_super > 0:
        h, new_super = lax.scan(body, h, (params["super"], state["super"]))
    else:
        new_super = {}

    new_rest: dict = {}
    for i, kind in enumerate(rest):
        p_i = jax.tree.map(lambda a: a[0], params["rest"][str(i)])
        st_i = jax.tree.map(lambda a: a[0], state["rest"][str(i)])
        h, new_st = _apply_kind(
            cfg, ex, kind, p_i, h, state=st_i, idx=idx, return_state=False
        )
        new_rest[str(i)] = jax.tree.map(lambda a: a[None], new_st)

    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(dt))[:, 0]
    new_state = {"super": new_super}
    if new_rest:
        new_state["rest"] = new_rest
    return logits, new_state
