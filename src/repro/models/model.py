"""Unified model facade over all assigned architecture families.

``Model`` dispatches on ``cfg.family`` to the family implementation and
exposes the four lowered entry points the launcher/dry-run consume:

* ``loss``         — training objective (next-token CE + MoE aux)
* ``forward``      — full-sequence logits (prefill without cache)
* ``prefill``      — full sequence -> (last_logits, decode state)
* ``decode_step``  — one token + state -> (logits, state)

plus abstract-input builders (``train_batch_specs`` etc.) so every
(arch x shape) cell lowers from ``ShapeDtypeStruct``s with zero
allocation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from . import encdec, rglru, ssm, transformer
from .params import abstract_params, init_params, logical_axes, param_count
from .transformer import ExecConfig

__all__ = [
    "Model",
    "ExecConfig",
    "cross_entropy",
    "train_batch_specs",
    "prefill_batch_specs",
    "decode_input_specs",
    "VLM_PATCHES",
]

VLM_PATCHES = 256  # vision-frontend stub: fixed patch-embedding prefix


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE.  logits: (B,S,V); labels: (B,S) (already aligned)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


class Model:
    def __init__(self, cfg: ModelConfig, ex: ExecConfig | None = None) -> None:
        self.cfg = cfg
        self.ex = ex or ExecConfig(remat=cfg.remat, scan_layers=cfg.scan_layers)

    # ---- parameters -----------------------------------------------------
    def specs(self) -> dict:
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.ssm_specs(cfg)
        if cfg.family == "hybrid":
            return rglru.hybrid_specs(cfg)
        if cfg.family == "encdec":
            return encdec.encdec_specs(cfg)
        return transformer.lm_specs(cfg)

    def init(self, key: jax.Array) -> dict:
        return init_params(self.specs(), key)

    def abstract_params(self, dtype: str | None = None) -> dict:
        tree = abstract_params(self.specs())
        if dtype is not None:
            dt = jnp.dtype(dtype)
            tree = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt), tree)
        return tree

    def param_axes(self) -> dict:
        return logical_axes(self.specs())

    def n_params(self) -> int:
        return param_count(self.specs())

    # ---- training / full forward ----------------------------------------
    def forward(self, params: dict, batch: dict) -> jax.Array:
        cfg, ex = self.cfg, self.ex
        if cfg.family == "ssm":
            logits, _ = ssm.ssm_forward(cfg, ex, params, batch)
        elif cfg.family == "hybrid":
            logits, _ = rglru.hybrid_forward(cfg, ex, params, batch)
        elif cfg.family == "encdec":
            logits, _ = encdec.encdec_forward(cfg, ex, params, batch)
        else:
            logits, _ = transformer.lm_forward(cfg, ex, params, batch)
        return logits

    def loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        cfg, ex = self.cfg, self.ex
        if cfg.family == "ssm":
            logits, aux = ssm.ssm_forward(cfg, ex, params, batch)
        elif cfg.family == "hybrid":
            logits, aux = rglru.hybrid_forward(cfg, ex, params, batch)
        elif cfg.family == "encdec":
            logits, aux = encdec.encdec_forward(cfg, ex, params, batch)
        else:
            logits, aux = transformer.lm_forward(cfg, ex, params, batch)
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        loss = ce + self.ex.moe_aux_coef * aux
        return loss, {"ce": ce, "aux": aux}

    # ---- serving ---------------------------------------------------------
    def prefill(self, params: dict, batch: dict):
        """Returns (last_token_logits, decode_state)."""
        cfg, ex = self.cfg, self.ex
        if cfg.family == "ssm":
            logits, _, state = ssm.ssm_forward(cfg, ex, params, batch, return_state=True)
        elif cfg.family == "hybrid":
            logits, _, state = rglru.hybrid_forward(cfg, ex, params, batch, return_state=True)
        elif cfg.family == "encdec":
            logits, _, state = encdec.encdec_forward(cfg, ex, params, batch, return_cache=True)
        else:
            logits, _, state = transformer.lm_forward(cfg, ex, params, batch, return_cache=True)
        return logits[:, -1], state

    def decode_step(self, params: dict, state, tokens: jax.Array, idx: jax.Array):
        cfg, ex = self.cfg, self.ex
        if cfg.family == "ssm":
            return ssm.ssm_decode_step(cfg, ex, params, state, tokens, idx)
        if cfg.family == "hybrid":
            return rglru.hybrid_decode_step(cfg, ex, params, state, tokens, idx)
        if cfg.family == "encdec":
            return encdec.encdec_decode_step(cfg, ex, params, state, tokens, idx)
        return transformer.lm_decode_step(cfg, ex, params, state, tokens, idx)

    def init_state(self, batch_size: int, max_len: int, enc_len: int | None = None):
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.init_ssm_state(cfg, batch_size)
        if cfg.family == "hybrid":
            return rglru.init_hybrid_state(cfg, batch_size)
        if cfg.family == "encdec":
            return encdec.init_encdec_cache(cfg, batch_size, max_len, enc_len or max_len)
        return transformer.init_cache(cfg, batch_size, max_len)

    def abstract_state(self, batch_size: int, max_len: int, enc_len: int | None = None):
        zeros = jax.eval_shape(
            lambda: self.init_state(batch_size, max_len, enc_len)
        )
        return zeros


# ---------------------------------------------------------------------------
# Abstract input builders (ShapeDtypeStruct — no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    emb_dt = cfg.dtype
    if cfg.family == "encdec":
        return {
            "enc_embeds": _sds((B, S, cfg.d_model), emb_dt),
            "tokens": _sds((B, S), "int32"),
            "labels": _sds((B, S), "int32"),
        }
    if cfg.family == "vlm":
        P = VLM_PATCHES
        return {
            "tokens": _sds((B, S - P), "int32"),
            "patch_embeds": _sds((B, P, cfg.d_model), emb_dt),
            "positions": _sds((B, S, 3), "int32"),
            "labels": _sds((B, S), "int32"),
        }
    return {
        "tokens": _sds((B, S), "int32"),
        "labels": _sds((B, S), "int32"),
    }


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    """Inputs for one serve_step: new token ids + fill index + state."""
    B, T = shape.global_batch, shape.seq_len
    model = Model(cfg)
    state = model.abstract_state(B, T, enc_len=min(T, 4096))
    return {
        "tokens": _sds((B,), "int32"),
        "idx": _sds((), "int32"),
        "state": state,
    }
