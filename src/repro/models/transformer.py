"""Decoder-only transformer family: dense GQA, MoE, and VLM (M-RoPE).

Layer-stacked parameters scanned with ``lax.scan`` (HLO size is O(1) in
depth — essential for the 95-layer deepseek-67b dry-run), configurable
remat policy, and a unified KV-cache decode path.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.ctx import shard
from .layers import (
    apply_mrope,
    apply_rope,
    chunked_attention,
    moe_aux_loss,
    moe_layer,
    rms_norm,
    swiglu,
)
from .params import ParamSpec

__all__ = ["ExecConfig", "block_specs", "lm_specs", "lm_forward", "lm_decode_step", "init_cache"]


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution knobs orthogonal to the architecture."""

    attn_impl: str = "xla"  # xla | pallas
    kv_chunk: int = 1024
    unroll_causal: bool = False  # skip dead causal chunks (see §Perf)
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True
    moe_aux_coef: float = 0.01
    # §Perf levers:
    # context-parallel attention — shard the QUERY sequence over 'model'
    # when the head count doesn't divide the axis (smollm: 9 heads on a
    # 16-wide axis otherwise replicates all attention compute 16x).
    cp_attention: str = "auto"  # auto | on | off
    # post-softmax probability dtype for the p @ v matmul (bf16 halves
    # the dominant score-tensor traffic; max/denominator stay f32)
    attn_p_dtype: str = "float32"
    # MoE dispatch implementation (see layers.moe_layer §Perf notes)
    moe_impl: str = "vmap"  # vmap | batched

    def remat_wrap(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots, prevent_cse=False
            )
        if self.remat == "full":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
            )
        raise ValueError(self.remat)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    D, H, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    s: dict[str, ParamSpec] = {
        "wq": ParamSpec((L, D, H, hd), ("layers", "embed", "heads", None)),
        "wk": ParamSpec((L, D, K, hd), ("layers", "embed", "kv", None)),
        "wv": ParamSpec((L, D, K, hd), ("layers", "embed", "kv", None)),
        "wo": ParamSpec((L, H, hd, D), ("layers", "heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((L, H, hd), ("layers", "heads", None), init="zeros")
        s["bk"] = ParamSpec((L, K, hd), ("layers", "kv", None), init="zeros")
        s["bv"] = ParamSpec((L, K, hd), ("layers", "kv", None), init="zeros")
    return s


def mlp_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((L, D, F), ("layers", "embed", "mlp")),
        "w_up": ParamSpec((L, D, F), ("layers", "embed", "mlp")),
        "w_down": ParamSpec((L, F, D), ("layers", "mlp", "embed")),
    }


def moe_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    assert cfg.moe is not None
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    return {
        "router": ParamSpec((L, D, E), ("layers", "embed", None)),
        "w_gate": ParamSpec((L, E, D, F), ("layers", "expert", "embed", None)),
        "w_up": ParamSpec((L, E, D, F), ("layers", "expert", "embed", None)),
        "w_down": ParamSpec((L, E, F, D), ("layers", "expert", None, "embed")),
    }


def block_specs(cfg: ModelConfig, L: int) -> dict[str, Any]:
    s: dict[str, Any] = {
        "ln1": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros"),
        "ln2": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros"),
        "attn": attn_specs(cfg, L),
    }
    s["moe" if cfg.family == "moe" else "mlp"] = (
        moe_specs(cfg, L) if cfg.family == "moe" else mlp_specs(cfg, L)
    )
    return s


def lm_specs(cfg: ModelConfig) -> dict[str, Any]:
    V, D = cfg.vocab, cfg.d_model
    s: dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), init="embed"),
        "final_ln": ParamSpec((D,), ("embed",), init="zeros"),
        "blocks": block_specs(cfg, cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    return s


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attention(cfg: ModelConfig, ex: ExecConfig, p: dict, hn, pos, *, cache, cache_idx):
    """Shared attention path.  Returns (attn_out, new_cache)."""
    dt = hn.dtype
    q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    # Context-parallel attention (§Perf): when heads don't fill the
    # 'model' axis, shard the query sequence over it instead — scores go
    # (B, K, g, S/model, T) per device rather than replicated.
    from repro.sharding.ctx import mesh_axis_size

    tp = mesh_axis_size("model")
    cp = ex.cp_attention == "on" or (
        ex.cp_attention == "auto"
        and tp is not None
        and cache is None  # full-sequence paths only
        and cfg.n_heads % tp != 0
    )
    q = shard(q, "batch", "act_seq" if cp else "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv", None)
    v = shard(v, "batch", "seq", "kv", None)

    if cfg.rope == "mrope":
        q = apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    if cache is None:
        out = _attn_dispatch(
            ex, q, k, v, q_offset=0, kv_len=None, causal=True, window=0
        )
        new_cache = (k, v)  # prefill fills the cache
    else:
        ck, cv = cache
        ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_idx, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_idx, axis=1)
        out = _attn_dispatch(
            ex,
            q,
            ck.astype(dt),
            cv.astype(dt),
            q_offset=cache_idx,
            kv_len=cache_idx + q.shape[1],
            causal=True,
            window=0,
        )
        new_cache = (ck, cv)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), new_cache


def _attn_dispatch(ex: ExecConfig, q, k, v, *, q_offset, kv_len, causal, window):
    if ex.attn_impl == "pallas":  # TPU path
        from repro.kernels import ops

        return ops.flash_attention(
            q, k, v, q_offset=q_offset, kv_len=kv_len, causal=causal, window=window
        )
    S, T = q.shape[1], k.shape[1]
    chunk = T if S == 1 else min(ex.kv_chunk, T)
    return chunked_attention(
        q,
        k,
        v,
        q_offset=q_offset,
        kv_len=kv_len,
        causal=causal,
        window=window,
        kv_chunk=chunk,
        unroll_causal=ex.unroll_causal and isinstance(q_offset, int),
        p_dtype=ex.attn_p_dtype,
    )


def _block_apply(cfg: ModelConfig, ex: ExecConfig, p: dict, h, aux, pos, *, cache, cache_idx):
    h = shard(h, "batch", "act_seq", None)
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    attn_out, new_cache = _attention(cfg, ex, p["attn"], hn, pos, cache=cache, cache_idx=cache_idx)
    h = h + attn_out
    h = shard(h, "batch", "act_seq", None)
    hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m = p["moe"]
        y, probs = moe_layer(
            hn2,
            m["router"],
            m["w_gate"],
            m["w_up"],
            m["w_down"],
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity,
            impl=ex.moe_impl,
        )
        aux = aux + moe_aux_loss(probs, cfg.moe.top_k)
    else:
        m = p["mlp"]
        y = swiglu(hn2, m["w_gate"], m["w_up"], m["w_down"])
    return shard(h + y, "batch", "act_seq", None), aux, new_cache


def _scan_blocks(
    cfg: ModelConfig,
    ex: ExecConfig,
    blocks: dict,
    h,
    pos,
    *,
    cache,
    cache_idx,
    collect_kv: bool = False,
):
    """Run all L blocks.  ``cache`` is the stacked (L, ...) kv cache or None.

    ``collect_kv`` gathers each layer's fresh K/V as scan outputs (prefill);
    training leaves it off so no (L, B, S, K, hd) buffer is materialised.
    """

    def body(carry, xs):
        h, aux = carry
        if cache is None:
            p = xs
            c = None
        else:
            p, ck, cv = xs
            c = (ck, cv)
        h, aux, new_c = _block_apply(cfg, ex, p, h, aux, pos, cache=c, cache_idx=cache_idx)
        keep = cache is not None or collect_kv
        ys = new_c if (new_c is not None and keep) else ()
        return (h, aux), ys

    body = ex.remat_wrap(body)
    aux0 = jnp.zeros((), jnp.float32)
    if ex.scan_layers:
        xs = blocks if cache is None else (blocks, cache[0], cache[1])
        (h, aux), ys = lax.scan(body, (h, aux0), xs)
        new_cache = ys if cache is not None or ys else None
    else:
        carry = (h, aux0)
        ks, vs = [], []
        L = cfg.n_layers
        for i in range(L):
            p_i = jax.tree.map(lambda a: a[i], blocks)
            xs = p_i if cache is None else (p_i, cache[0][i], cache[1][i])
            carry, ys = body(carry, xs)
            if ys:
                ks.append(ys[0])
                vs.append(ys[1])
        h, aux = carry
        new_cache = (jnp.stack(ks), jnp.stack(vs)) if ks else None
    return h, aux, new_cache


def _logits(cfg: ModelConfig, params: dict, h) -> jax.Array:
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    return shard(logits, "batch", "seq", "vocab")


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Token (+ modality prefix) embedding.  Returns (h, positions)."""
    dt = jnp.dtype(cfg.dtype)
    tok = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    if cfg.modality == "vision" and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(dt), tok], axis=1)
    else:
        h = tok
    h = shard(h, "batch", "act_seq", None)
    B, S = h.shape[0], h.shape[1]
    if cfg.rope == "mrope":
        pos = batch.get("positions")
        if pos is None:
            p1 = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 1))
            pos = jnp.broadcast_to(p1, (B, S, 3))
    else:
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return h, pos


def lm_forward(
    cfg: ModelConfig,
    ex: ExecConfig,
    params: dict,
    batch: dict,
    *,
    return_cache: bool = False,
):
    """Full-sequence forward (train / prefill).

    Returns (logits, aux_loss) or (logits, aux_loss, cache) — cache is the
    stacked (L, B, S, K, hd) K/V pair for decode continuation.
    """
    h, pos = _embed_inputs(cfg, params, batch)
    h, aux, kv = _scan_blocks(
        cfg,
        ex,
        params["blocks"],
        h,
        pos,
        cache=None,
        cache_idx=None,
        collect_kv=return_cache,
    )
    logits = _logits(cfg, params, h)
    if return_cache:
        return logits, aux, kv
    return logits, aux


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    """Zero KV cache, stacked over layers: (L, B, T, K, hd) x2."""
    dt = jnp.dtype(dtype or cfg.dtype)
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def abstract_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    sds = jax.ShapeDtypeStruct(shape, dt)
    return (sds, sds)


def lm_decode_step(
    cfg: ModelConfig,
    ex: ExecConfig,
    params: dict,
    cache,
    tokens: jax.Array,  # (B,) next-token ids
    idx: jax.Array,  # () int32 — current cache fill
):
    """One decode step: append token at ``idx``, return (logits, cache)."""
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], tokens[:, None], axis=0).astype(dt)  # (B,1,D)
    if cfg.rope == "mrope":
        p1 = jnp.broadcast_to(idx[None, None, None], (B, 1, 3))
        pos = p1
    else:
        pos = jnp.broadcast_to(idx[None, None], (B, 1))
    h, _aux, new_cache = _scan_blocks(
        cfg, ex, params["blocks"], h, pos, cache=cache, cache_idx=idx
    )
    logits = _logits(cfg, params, h)[:, 0]
    return logits, new_cache
