"""Pure-JAX model zoo for the assigned architectures.

Families: dense GQA transformer, MoE, Mamba-2 SSD, RG-LRU hybrid,
encoder-decoder, VLM (M-RoPE).  All layer-stacked + lax.scan'd, with
logical-axis parameter specs consumed by ``repro.sharding``.
"""

from .model import (
    ExecConfig,
    Model,
    cross_entropy,
    decode_input_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from .params import (
    ParamSpec,
    abstract_params,
    init_params,
    logical_axes,
    param_bytes,
    param_count,
)

__all__ = [
    "ExecConfig",
    "Model",
    "cross_entropy",
    "decode_input_specs",
    "prefill_batch_specs",
    "train_batch_specs",
    "ParamSpec",
    "abstract_params",
    "init_params",
    "logical_axes",
    "param_bytes",
    "param_count",
]
