"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), attention-free LM.

TPU-native formulation: the *chunked dual form* (intra-chunk quadratic
matmuls on the MXU + inter-chunk state recurrence) instead of the GPU
selective-scan.  Decode carries an O(1) per-layer state — this is the
arch that RUNS the ``long_500k`` shape.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.sharding.ctx import shard
from .layers import rms_norm
from .params import ParamSpec
from .transformer import ExecConfig

__all__ = [
    "ssm_specs",
    "ssm_forward",
    "ssm_decode_step",
    "init_ssm_state",
    "abstract_ssm_state",
]


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    ng = 1  # single B/C group (mamba2-130m)
    return di, nh, ng, cfg.ssm_state


def block_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    D = cfg.d_model
    di, nh, ng, ds = _dims(cfg)
    K = cfg.ssm_conv
    return {
        "ln": ParamSpec((L, D), ("layers", "embed"), init="zeros"),
        "w_z": ParamSpec((L, D, di), ("layers", "embed", "mlp")),
        "w_x": ParamSpec((L, D, di), ("layers", "embed", "mlp")),
        "w_B": ParamSpec((L, D, ng * ds), ("layers", "embed", "state")),
        "w_C": ParamSpec((L, D, ng * ds), ("layers", "embed", "state")),
        "w_dt": ParamSpec((L, D, nh), ("layers", "embed", None)),
        "dt_bias": ParamSpec((L, nh), ("layers", None), init="zeros"),
        "conv_x": ParamSpec((L, K, di), ("layers", "conv", "mlp"), init="normal"),
        "conv_B": ParamSpec((L, K, ng * ds), ("layers", "conv", "state"), init="normal"),
        "conv_C": ParamSpec((L, K, ng * ds), ("layers", "conv", "state"), init="normal"),
        "A_log": ParamSpec((L, nh), ("layers", None), init="zeros"),
        "Dskip": ParamSpec((L, nh), ("layers", None), init="ones"),
        "gn": ParamSpec((L, di), ("layers", "mlp"), init="zeros"),
        "w_out": ParamSpec((L, di, D), ("layers", "mlp", "embed")),
    }


def ssm_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed"),
        "final_ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "blocks": block_specs(cfg, cfg.n_layers),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + pad[:, k : k + S] * w[k].astype(x.dtype)
    return out


def _conv_step(state: jax.Array, x: jax.Array, w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token conv.  state: (B, K-1, C), x: (B, C).  -> (y, new_state)."""
    full = jnp.concatenate([state, x[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", full, w.astype(x.dtype))
    return y, full[:, 1:]


def _block(cfg: ModelConfig, ex: ExecConfig, p: dict, h, *, state, return_state):
    """One mamba2 block.  h: (B, S, D).  state: dict or None."""
    di, nh, ng, ds = _dims(cfg)
    hp = cfg.ssm_head_dim
    dt_ = h.dtype
    h = shard(h, "batch", "act_seq", None)
    hn = rms_norm(h, p["ln"], cfg.norm_eps)

    z = shard(jnp.einsum("bsd,de->bse", hn, p["w_z"].astype(dt_)), "batch", "seq", "mlp")
    x = shard(jnp.einsum("bsd,de->bse", hn, p["w_x"].astype(dt_)), "batch", "seq", "mlp")
    Bm = shard(jnp.einsum("bsd,de->bse", hn, p["w_B"].astype(dt_)), "batch", "seq", "state")
    Cm = shard(jnp.einsum("bsd,de->bse", hn, p["w_C"].astype(dt_)), "batch", "seq", "state")
    dt = shard(jnp.einsum("bsd,dh->bsh", hn, p["w_dt"].astype(dt_)), "batch", "seq", None)

    new_state = {}
    if state is None:
        xc = _causal_conv(x, p["conv_x"])
        Bc = _causal_conv(Bm, p["conv_B"])
        Cc = _causal_conv(Cm, p["conv_C"])
        if return_state:
            K = cfg.ssm_conv
            # conv tail: last K-1 *pre-conv* inputs
            new_state["conv_x"] = x[:, -(K - 1) :].astype(dt_)
            new_state["conv_B"] = Bm[:, -(K - 1) :].astype(dt_)
            new_state["conv_C"] = Cm[:, -(K - 1) :].astype(dt_)
    else:
        # decode: S == 1
        xc1, new_state["conv_x"] = _conv_step(state["conv_x"], x[:, 0], p["conv_x"])
        Bc1, new_state["conv_B"] = _conv_step(state["conv_B"], Bm[:, 0], p["conv_B"])
        Cc1, new_state["conv_C"] = _conv_step(state["conv_C"], Cm[:, 0], p["conv_C"])
        xc, Bc, Cc = xc1[:, None], Bc1[:, None], Cc1[:, None]

    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt_)
    Bc = jax.nn.silu(Bc.astype(jnp.float32)).astype(dt_)
    Cc = jax.nn.silu(Cc.astype(jnp.float32)).astype(dt_)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    B_, S_ = xc.shape[0], xc.shape[1]
    xh = xc.reshape(B_, S_, nh, hp)
    Bg = Bc.reshape(B_, S_, ng, ds)
    Cg = Cc.reshape(B_, S_, ng, ds)

    if state is None:
        if ex.attn_impl == "pallas":
            from repro.kernels import ops

            out = ops.ssd_scan(
                xh, dtp, A, Bg, Cg, p["Dskip"].astype(jnp.float32),
                chunk=cfg.ssm_chunk, return_state=return_state,
            )
        else:
            chunk = min(cfg.ssm_chunk, S_)
            while S_ % chunk:  # largest divisor of S not exceeding ssm_chunk
                chunk -= 1
            out = kref.ssd_chunked_ref(
                xh, dtp, A, Bg, Cg, p["Dskip"].astype(jnp.float32),
                chunk=chunk, return_state=return_state,
            )
        if return_state:
            y, new_state["ssm"] = out
        else:
            y = out
    else:
        y1, new_state["ssm"] = kref.ssd_decode_step(
            state["ssm"], xh[:, 0], dtp[:, 0], A, Bg[:, 0], Cg[:, 0],
            p["Dskip"].astype(jnp.float32),
        )
        y = y1[:, None]

    y = y.reshape(B_, S_, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    y = shard(rms_norm(y, p["gn"], cfg.norm_eps), "batch", "seq", "mlp")
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    return shard(h + out, "batch", "act_seq", None), (
        new_state if (state is not None or return_state) else None
    )


def init_ssm_state(cfg: ModelConfig, batch_size: int, dtype=None) -> dict:
    """Zero decode state, stacked over layers."""
    dt = jnp.dtype(dtype or cfg.dtype)
    di, nh, ng, ds = _dims(cfg)
    hp = cfg.ssm_head_dim
    L, K = cfg.n_layers, cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((L, batch_size, K - 1, di), dt),
        "conv_B": jnp.zeros((L, batch_size, K - 1, ng * ds), dt),
        "conv_C": jnp.zeros((L, batch_size, K - 1, ng * ds), dt),
        "ssm": jnp.zeros((L, batch_size, nh, ds, hp), jnp.float32),
    }


def abstract_ssm_state(cfg: ModelConfig, batch_size: int, dtype=None) -> dict:
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        init_ssm_state(cfg, batch_size, dtype),
        is_leaf=lambda x: isinstance(x, jax.Array),
    )


def ssm_forward(
    cfg: ModelConfig,
    ex: ExecConfig,
    params: dict,
    batch: dict,
    *,
    return_state: bool = False,
):
    """Full-sequence forward.  Returns (logits, aux) or (logits, aux, state)."""
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)

    def body(carry, p):
        h = carry
        h, st = _block(cfg, ex, p, h, state=None, return_state=return_state)
        return h, (st if st is not None else ())

    body = ex.remat_wrap(body)
    if ex.scan_layers:
        h, states = lax.scan(body, h, params["blocks"])
    else:
        sts = []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            h, st = body(h, p_i)
            sts.append(st)
        states = jax.tree.map(lambda *xs: jnp.stack(xs), *sts) if return_state else ()

    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(dt))
    aux = jnp.zeros((), jnp.float32)
    if return_state:
        return logits, aux, states
    return logits, aux


def ssm_decode_step(cfg: ModelConfig, ex: ExecConfig, params: dict, state: dict, tokens, idx):
    """One decode token.  tokens: (B,), idx unused (state is position-free)."""
    del idx
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], tokens[:, None], axis=0).astype(dt)

    def body(carry, xs):
        h = carry
        p, st = xs
        h, new_st = _block(cfg, ex, p, h, state=st, return_state=False)
        return h, new_st

    h, new_states = lax.scan(body, h, (params["blocks"], state))
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(dt))[:, 0]
    return logits, new_states
