"""Shared neural layers: norms, rotary embeddings, attention, MLP, MoE.

All functions are pure; parameters arrive as (sub)trees built from the
spec builders in the sibling model files.  Activations compute in
``cfg.dtype`` (bf16 on TPU) with f32 softmax/norm accumulators.

The attention entry point dispatches between the pure-XLA chunked
online-softmax implementation (used for CPU dry-runs and as the oracle)
and the Pallas TPU kernel (``repro.kernels``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "rms_norm",
    "make_rope_freqs",
    "apply_rope",
    "apply_mrope",
    "chunked_attention",
    "swiglu",
    "moe_layer",
    "moe_aux_loss",
]

_NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf / rms) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and Qwen2-VL's multimodal M-RoPE)
# ---------------------------------------------------------------------------


def make_rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    # x: (..., hd); cos/sin: broadcastable (..., hd//2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """Standard RoPE.  x: (B, S, H, hd); positions: (B, S) int."""
    freqs = make_rope_freqs(x.shape[-1], theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd//2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: tuple[int, ...],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  positions: (B, S, 3) = (t, h, w) ids.

    The ``head_dim//2`` frequency slots are partitioned into
    ``sections`` (e.g. 16/24/24); slot ``i`` rotates by the position
    stream its section is assigned to.  Text tokens carry t == h == w,
    reducing exactly to standard RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = make_rope_freqs(x.shape[-1], theta)  # (half,)
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )  # (half,) in {0,1,2}
    pos = positions.astype(jnp.float32)  # (B, S, 3)
    pos_per_slot = jnp.take(pos, sec_id, axis=-1)  # (B, S, half)
    ang = pos_per_slot * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


# ---------------------------------------------------------------------------
# Attention — chunked online-softmax (flash-attention algorithm in XLA)
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    causal: bool = True,
    window: int = 0,
    kv_chunk: int = 1024,
    unroll_causal: bool = False,
    p_dtype: str = "float32",
) -> jax.Array:
    """GQA attention with bounded memory: O(S * kv_chunk) score tiles.

    q: (B, S, H, hd);  k, v: (B, T, K, hd) with H = K * group.
    ``q_offset``: absolute position of q[0] (prefill continuation /
    decode).  ``kv_len``: valid prefix length of k/v (decode caches);
    None means all T positions are valid.  ``window`` > 0 enables
    sliding-window (local) masking:  qpos - kpos < window.

    ``unroll_causal`` unrolls the kv-chunk loop and *skips chunks that
    are entirely masked* for every query — the compute-roofline
    optimisation recorded in EXPERIMENTS.md §Perf (a lax.scan must
    execute every chunk; unrolling lets dead chunks disappear from the
    HLO).  Only valid when q_offset is a static int.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)

    qf = (q.astype(jnp.float32) * scale).reshape(B, S, K, g, hd)

    nc = -(-T // kv_chunk)
    Tp = nc * kv_chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    kc = jnp.moveaxis(k.reshape(B, nc, kv_chunk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, kv_chunk, K, hd), 1, 0)

    qpos = q_offset + jnp.arange(S)
    valid_len = T if kv_len is None else kv_len

    def chunk_scores(carry, kci, vci, c0):
        m, l, acc = carry
        s = jnp.einsum(
            "bskgd,bckd->bkgsc", qf, kci.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        kpos = c0 + jnp.arange(kv_chunk)
        mask = kpos[None, :] < valid_len
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window > 0:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        mc = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - mc[..., None])
        corr = jnp.exp(m - mc)
        l = l * corr + p.sum(axis=-1)
        # p @ v in p_dtype (bf16 halves the dominant score traffic; the
        # accumulator stays f32 via preferred_element_type)
        pdt = jnp.dtype(p_dtype)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p.astype(pdt), vci.astype(pdt),
            preferred_element_type=jnp.float32,
        )
        return mc, l, acc

    m0 = jnp.full((B, K, g, S), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, g, S), jnp.float32)
    a0 = jnp.zeros((B, K, g, S, hd), jnp.float32)

    if unroll_causal and isinstance(q_offset, int):
        carry = (m0, l0, a0)
        for c in range(nc):
            c0 = c * kv_chunk
            # Skip chunks fully beyond the causal horizon of ALL queries.
            if causal and c0 > q_offset + S - 1:
                continue
            # Skip chunks fully outside every query's window.
            if window > 0 and (q_offset - (c0 + kv_chunk - 1)) >= window:
                continue
            carry = chunk_scores(carry, kc[c], vc[c], c0)
        m, l, acc = carry
    else:
        def body(carry, xs):
            kci, vci, c0 = xs
            return chunk_scores(carry, kci, vci, c0), None

        starts = jnp.arange(nc) * kv_chunk
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, starts))

    out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, K, g, S, hd)
    out = jnp.moveaxis(out, 3, 1)  # (B, S, K, g, hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Feed-forward
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    from repro.sharding.ctx import shard

    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))


# ---------------------------------------------------------------------------
# Mixture-of-Experts — sort-based capacity dispatch (TPU-native:
# contiguous expert slabs -> dense batched matmuls on the MXU, instead
# of a GPU-style scatter of warp-sized groups).
# ---------------------------------------------------------------------------


def _route_group(
    xg: jax.Array,
    router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    top_k: int,
    capacity: int,
) -> tuple[jax.Array, jax.Array]:
    """Route one group of tokens (vmapped).  xg: (S, D)."""
    from repro.sharding.ctx import shard

    S, D = xg.shape
    E = router.shape[1]
    logits = xg.astype(jnp.float32) @ router.astype(jnp.float32)  # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, top_k)  # (S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    e_flat = idx.reshape(-1)  # token-major (S*k,)
    t_flat = jnp.repeat(jnp.arange(S), top_k)
    order = jnp.argsort(e_flat, stable=True)
    e_s, t_s = e_flat[order], t_flat[order]
    w_s = w.reshape(-1)[order]

    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(S * top_k) - starts[e_s]
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, capacity)  # overflow -> sacrificial slot

    buf = jnp.zeros((E, capacity + 1, D), xg.dtype)
    buf = buf.at[e_s, pos_c].set(xg[t_s] * keep[:, None].astype(xg.dtype))
    buf = buf[:, :capacity]
    # Expert parallelism: each device runs only its local experts; GSPMD
    # otherwise replicates the FFN and all-reduces outputs (4 TB/dev
    # measured on dbrx — §Perf).  The vmap batch dim stays unconstrained.
    buf = shard(buf, "expert", None, None)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(xg.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(xg.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    h = shard(h, "expert", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xg.dtype))  # (E, cap, D)
    y = shard(y, "expert", None, None)

    y_tok = y[e_s, pos_c] * (keep[:, None] * w_s[:, None]).astype(xg.dtype)
    out = jnp.zeros((S, D), xg.dtype).at[t_s].add(y_tok)
    return out, probs


def moe_layer(
    x: jax.Array,
    router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    top_k: int,
    capacity_factor: float,
    impl: str = "vmap",
) -> tuple[jax.Array, jax.Array]:
    """Top-k routed MoE over groups = batch rows.  x: (B, S, D).

    Returns (out, router_probs (B, S, E)) — probs feed the load-balance
    auxiliary loss.  Expert weights: (E, D, F) / (E, F, D); dispatch is
    per-group (sort-based, static capacity ceil(S*k/E*cf)).

    Two dispatch implementations (§Perf measured both on dbrx train_4k):
    * ``vmap`` (default): per-group routing under vmap with the expert
      dim constrained to 'model'.  GSPMD replicates the unconstrained
      vmap batch dim inside the expert FFN (compute 6x), but collectives
      stay sane — net best (MFU 0.067 vs 0.033 unconstrained).
    * ``batched``: explicit batch dim, fully constrainable buffer — but
      the 3-D data-dependent scatter forces GSPMD into a degenerate
      all-gather plan (collective 7 -> 173 s).  Kept as the measured
      refutation; the production fix is a shard_map'd all-to-all
      dispatch (future work).
    """
    from repro.sharding.ctx import shard

    B, S, D = x.shape
    E = router.shape[1]
    capacity = max(1, int(math.ceil(S * top_k / E * capacity_factor)))
    x = shard(x, "batch", "seq", None)

    if impl == "vmap":
        fn = lambda xg: _route_group(xg, router, w_gate, w_up, w_down, top_k, capacity)
        out, probs = jax.vmap(fn)(x)
        return shard(out, "batch", "seq", None), shard(probs, "batch", "seq", None)

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), router.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    w, idx = lax.top_k(probs, top_k)  # (B, S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    SK = S * top_k
    e_flat = idx.reshape(B, SK)  # token-major per row
    t_flat = jnp.broadcast_to(jnp.repeat(jnp.arange(S), top_k)[None], (B, SK))
    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_s = jnp.take_along_axis(e_flat, order, axis=1)
    t_s = jnp.take_along_axis(t_flat, order, axis=1)
    w_s = jnp.take_along_axis(w.reshape(B, SK), order, axis=1)

    counts = jax.nn.one_hot(e_flat, E, dtype=jnp.int32).sum(axis=1)  # (B, E)
    starts = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(SK)[None] - jnp.take_along_axis(starts, e_s, axis=1)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, capacity)  # overflow -> sacrificial slot

    b_idx = jnp.arange(B)[:, None]
    x_sorted = jnp.take_along_axis(x, t_s[..., None], axis=1)  # (B, SK, D)
    buf = jnp.zeros((B, E, capacity + 1, D), x.dtype)
    buf = buf.at[b_idx, e_s, pos_c].set(x_sorted * keep[..., None].astype(x.dtype))
    buf = buf[:, :, :capacity]
    # Expert parallelism: batch->data, expert->model — each device runs
    # only its local experts on its local groups.  Without the explicit
    # constraint GSPMD replicates the expert FFN and all-reduces outputs
    # (measured: 4 TB/dev of all-reduce on dbrx train_4k — see §Perf).
    buf = shard(buf, "batch", "expert", None, None)

    g = jnp.einsum("becd,edf->becf", buf, w_gate.astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", "expert", None, None)
    y = jnp.einsum("becf,efd->becd", h, w_down.astype(x.dtype))  # (B, E, cap, D)
    y = shard(y, "batch", "expert", None, None)

    y_tok = y[b_idx, e_s, pos_c] * (keep[..., None] * w_s[..., None]).astype(x.dtype)
    out = jnp.zeros((B, S, D), x.dtype).at[b_idx, t_s].add(y_tok)
    return shard(out, "batch", "seq", None), shard(probs, "batch", "seq", None)


def moe_aux_loss(probs: jax.Array, top_k: int) -> jax.Array:
    """Switch-style load-balance loss over all routed tokens.

    probs: (..., E) router softmax.  loss = E * mean(frac_tokens_e * mean_prob_e).
    """
    E = probs.shape[-1]
    flat = probs.reshape(-1, E)
    # differentiable proxy for assignment fraction: top-k hard mask
    _, idx = lax.top_k(flat, top_k)
    hard = jnp.zeros_like(flat).at[jnp.arange(flat.shape[0])[:, None], idx].set(1.0)
    frac = hard.mean(axis=0) / top_k
    mean_prob = flat.mean(axis=0)
    return E * jnp.sum(frac * mean_prob)
