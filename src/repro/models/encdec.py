"""Encoder-decoder backbone (seamless-m4t-large-v2 text/audio backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, D) straight into the encoder.
Decoder layers add cross-attention over the encoder output; decode keeps
a growing self-attention KV cache plus a fixed precomputed cross KV.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.ctx import shard
from .layers import apply_rope, rms_norm, swiglu
from .params import ParamSpec
from .transformer import ExecConfig, _attn_dispatch, attn_specs, mlp_specs

__all__ = [
    "encdec_specs",
    "encdec_forward",
    "encode",
    "encdec_decode_step",
    "init_encdec_cache",
]


def enc_block_specs(cfg: ModelConfig, L: int) -> dict[str, Any]:
    return {
        "ln1": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros"),
        "attn": attn_specs(cfg, L),
        "ln2": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros"),
        "mlp": mlp_specs(cfg, L),
    }


def dec_block_specs(cfg: ModelConfig, L: int) -> dict[str, Any]:
    s = enc_block_specs(cfg, L)
    s["ln_x"] = ParamSpec((L, cfg.d_model), ("layers", "embed"), init="zeros")
    s["xattn"] = attn_specs(cfg, L)
    return s


def encdec_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed"),
        "enc_blocks": enc_block_specs(cfg, cfg.enc_layers),
        "enc_ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "dec_blocks": dec_block_specs(cfg, cfg.n_layers),
        "final_ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def _proj_qkv(cfg, a, hn, pos=None):
    dt = hn.dtype
    q = jnp.einsum("bsd,dhk->bshk", hn, a["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", hn, a["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", hn, a["wv"].astype(dt))
    if pos is not None and cfg.rope == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv", None)
    v = shard(v, "batch", "seq", "kv", None)
    return q, k, v


def encode(cfg: ModelConfig, ex: ExecConfig, params: dict, enc_embeds: jax.Array):
    """Bidirectional encoder over precomputed frame embeddings."""
    dt = jnp.dtype(cfg.dtype)
    h = enc_embeds.astype(dt)
    B, S = h.shape[0], h.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, p):
        h = shard(carry, "batch", "act_seq", None)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(cfg, p["attn"], hn, pos)
        out = _attn_dispatch(ex, q, k, v, q_offset=0, kv_len=None, causal=False, window=0)
        h = shard(h + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(dt)), "batch", "act_seq", None)
        hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + swiglu(hn2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        return shard(h, "batch", "act_seq", None), ()

    body = ex.remat_wrap(body)
    h, _ = lax.scan(body, h, params["enc_blocks"])
    return rms_norm(h, params["enc_ln"], cfg.norm_eps)


def _dec_block(cfg, ex, p, h, enc_out, pos, *, self_cache, cache_idx, collect_kv):
    dt = h.dtype
    h = shard(h, "batch", "act_seq", None)
    # --- causal self-attention ---
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = _proj_qkv(cfg, p["attn"], hn, pos)
    new_self = None
    if self_cache is None:
        out = _attn_dispatch(ex, q, k, v, q_offset=0, kv_len=None, causal=True, window=0)
        if collect_kv:
            new_self = (k, v)
    else:
        ck, cv = self_cache
        ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_idx, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_idx, axis=1)
        out = _attn_dispatch(
            ex, q, ck.astype(dt), cv.astype(dt),
            q_offset=cache_idx, kv_len=cache_idx + q.shape[1], causal=True, window=0,
        )
        new_self = (ck, cv)
    h = h + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(dt))

    # --- cross-attention ---
    hn = rms_norm(h, p["ln_x"], cfg.norm_eps)
    xa = p["xattn"]
    qx = jnp.einsum("bsd,dhk->bshk", hn, xa["wq"].astype(dt))
    if isinstance(enc_out, tuple):  # precomputed cross K/V (decode)
        kx, vx = enc_out
        kx, vx = kx.astype(dt), vx.astype(dt)
    else:
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, xa["wk"].astype(dt))
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, xa["wv"].astype(dt))
    out = _attn_dispatch(ex, qx, kx, vx, q_offset=0, kv_len=None, causal=False, window=0)
    h = h + jnp.einsum("bshk,hkd->bsd", out, xa["wo"].astype(dt))

    # --- MLP ---
    hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    h = h + swiglu(hn2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    h = shard(h, "batch", "act_seq", None)
    return h, new_self, (None if isinstance(enc_out, tuple) else (kx, vx))


def encdec_forward(
    cfg: ModelConfig,
    ex: ExecConfig,
    params: dict,
    batch: dict,
    *,
    return_cache: bool = False,
):
    """Teacher-forced forward.  batch: enc_embeds (B,S_enc,D), tokens (B,S_dec)."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(cfg, ex, params, batch["enc_embeds"])
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    B, S = h.shape[0], h.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, p):
        h = carry
        h, new_self, new_cross = _dec_block(
            cfg, ex, p, h, enc_out, pos,
            self_cache=None, cache_idx=None, collect_kv=return_cache,
        )
        ys = ()
        if return_cache:
            ys = (new_self[0], new_self[1], new_cross[0], new_cross[1])
        return h, ys

    body = ex.remat_wrap(body)
    h, ys = lax.scan(body, h, params["dec_blocks"])
    logits = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", logits, params["lm_head"].astype(dt))
    aux = jnp.zeros((), jnp.float32)
    if return_cache:
        cache = {"self": (ys[0], ys[1]), "cross": (ys[2], ys[3])}
        return logits, aux, cache
    return logits, aux


def init_encdec_cache(cfg: ModelConfig, batch_size: int, max_len: int, enc_len: int, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    self_shape = (L, batch_size, max_len, K, hd)
    cross_shape = (L, batch_size, enc_len, K, hd)
    return {
        "self": (jnp.zeros(self_shape, dt), jnp.zeros(self_shape, dt)),
        "cross": (jnp.zeros(cross_shape, dt), jnp.zeros(cross_shape, dt)),
    }


def encdec_decode_step(cfg: ModelConfig, ex: ExecConfig, params: dict, cache, tokens, idx):
    """One decoder token with cached self + cross attention."""
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], tokens[:, None], axis=0).astype(dt)
    B = tokens.shape[0]
    pos = jnp.broadcast_to(idx[None, None], (B, 1))

    def body(carry, xs):
        h = carry
        p, sk, sv, xk, xv = xs
        h, new_self, _ = _dec_block(
            cfg, ex, p, h, (xk, xv), pos,
            self_cache=(sk, sv), cache_idx=idx, collect_kv=False,
        )
        return h, (new_self[0], new_self[1])

    sk, sv = cache["self"]
    xk, xv = cache["cross"]
    h, (nsk, nsv) = lax.scan(body, h, (params["dec_blocks"], sk, sv, xk, xv))
    logits = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", logits, params["lm_head"].astype(dt))[:, 0]
    new_cache = {"self": (nsk, nsv), "cross": (xk, xv)}
    return logits, new_cache
