"""Parameter specification trees with logical sharding axes.

Every model in the zoo describes its parameters as a nested dict of
:class:`ParamSpec` (shape, dtype, logical axes, initializer).  From one
spec tree we derive

* ``init_params``  — materialised arrays (PRNG-split deterministically
  by tree path),
* ``logical_axes`` — a matching tree of logical-axis tuples consumed by
  ``repro.sharding.rules`` to build ``NamedSharding``s,
* ``abstract_params`` — ``ShapeDtypeStruct``s for allocation-free
  lowering (the multi-pod dry-run).

Logical axis names used across the zoo:

``layers``  stacked-layer leading axis (scanned, never sharded)
``embed``   model width d_model            -> fsdp-style 'data' shard
``heads``   query heads x head_dim         -> 'model'
``kv``      kv heads x head_dim            -> 'model'
``mlp``     feed-forward hidden            -> 'model'
``vocab``   vocabulary                     -> 'model'
``expert``  MoE expert                     -> 'model' (expert parallel)
``state``   SSM/LRU recurrent state width  -> 'model'
``conv``    conv kernel taps               -> replicated
``None``    replicated dimension
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "init_params",
    "logical_axes",
    "abstract_params",
    "param_bytes",
    "param_count",
    "map_specs",
]

Initializer = str  # "normal" | "zeros" | "ones" | "embed" | "lecun" | "recurrent"


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: Initializer = "lecun"
    dtype: str = "float32"
    # fan-in override for stacked specs where the leading 'layers' axis
    # must not count toward the initializer's fan computation
    fan_in_dims: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def map_specs(fn: Callable[[tuple[str, ...], ParamSpec], Any], specs: Any) -> Any:
    """Map over a spec tree with path, preserving dict structure."""

    def rec(node: Any, path: tuple[str, ...]) -> Any:
        if _is_spec(node):
            return fn(path, node)
        if isinstance(node, dict):
            return {k: rec(v, path + (k,)) for k, v in node.items()}
        raise TypeError(f"unexpected node at {path}: {type(node)}")

    return rec(specs, ())


def _fan_in(spec: ParamSpec) -> int:
    dims = spec.fan_in_dims
    if dims is None:
        # default: all but the last dim (weights are [..., in, out] or [in, out])
        if len(spec.shape) <= 1:
            return max(1, int(np.prod(spec.shape)))
        dims = tuple(range(len(spec.shape) - 1))
        # skip a leading stacked-layer axis
        if spec.axes and spec.axes[0] == "layers" and len(spec.shape) > 2:
            dims = tuple(d for d in dims if d != 0)
    return max(1, int(np.prod([spec.shape[d] for d in dims])))


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        # GPT-2-style 0.02 std: keeps tied-embedding logits O(1) at init
        return (0.02 * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)
    if spec.init == "normal":
        return (0.02 * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)
    if spec.init == "lecun":
        scale = 1.0 / math.sqrt(_fan_in(spec))
        return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)
    if spec.init == "recurrent":
        # RG-LRU / SSM log-recurrence parameters: uniform in a stable range
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(u / (1.0 - u)).astype(dtype)  # logit of decay
    raise ValueError(f"unknown initializer {spec.init}")


def _path_seed(path: tuple[str, ...]) -> int:
    # Deterministic, order-independent folding of the tree path.
    h = 0
    for p in path:
        for ch in p:
            h = (h * 1000003 + ord(ch)) % (2**31 - 1)
        h = (h * 1000003 + 7) % (2**31 - 1)
    return h


def init_params(specs: Any, key: jax.Array) -> Any:
    """Materialise a parameter tree from a spec tree (path-keyed PRNG)."""

    def build(path: tuple[str, ...], spec: ParamSpec) -> jax.Array:
        return _init_one(jax.random.fold_in(key, _path_seed(path)), spec)

    return map_specs(build, specs)


def logical_axes(specs: Any) -> Any:
    return map_specs(lambda _p, s: s.axes, specs)


def abstract_params(specs: Any) -> Any:
    return map_specs(
        lambda _p, s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), specs
    )


def param_count(specs: Any) -> int:
    total = 0

    def add(_p: tuple[str, ...], s: ParamSpec) -> None:
        nonlocal total
        total += int(np.prod(s.shape))

    map_specs(add, specs)
    return total


def param_bytes(specs: Any) -> int:
    total = 0

    def add(_p: tuple[str, ...], s: ParamSpec) -> None:
        nonlocal total
        total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize

    map_specs(add, specs)
    return total
