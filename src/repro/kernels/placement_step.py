"""Pallas kernel: fused Alg-2 TFS-block placement sweep.

The scheduler's hot path advances a per-row simulation state (device
cursor ``j``, task cursor ``k``, remaining capacity ``c``, carried share
``tsd``) over a ``(B, n_t)`` shares block.  The jax backend expresses one
step as ~15 gather/where ops XLA schedules independently; here the whole
sweep is *one kernel*: a row tile of the block plus the (tiny) per-task
and per-device tables live in VMEM, and an in-kernel ``fori_loop`` runs
all ``n_t + n_f`` carry/split steps over that tile before it is written
back — no intermediate HBM traffic, so blocks of ~10^6 rows sweep per
call.

Gathers (``iis[k]``, ``t_cfg[j]``, ``shares[row, k]``) are one-hot
masked row reductions instead of dynamic-index loads: with the cursor
clipped into range exactly one column survives the mask, so the sum
reproduces the gathered float64 value bit-exactly while staying
TPU-lowerable (no scatter/gather lowering).

Validated in interpret mode against ``ref.placement_sweep_ref`` (which
is itself pinned bit-for-bit to the scalar Alg-2/Alg-3 oracle).  On
non-TPU hosts the kernel runs in interpret mode (see ``ops.py``); on TPU
float64 is unavailable, so bit-parity claims hold where the kernel is
lowerable at float64 (interpret mode) and to float32 accuracy otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _PLACE_EPS

__all__ = ["placement_sweep_pallas"]


def _onehot(cursor, width: int):
    """(bB, 1) int cursor -> (bB, width) one-hot bool mask."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (cursor.shape[0], width), 1)
    return cols == cursor


def _select(mask, table_row):
    """Masked row reduction: exact gather of one element per row.

    ``mask`` is (bB, width) with exactly one True per row; ``table_row``
    broadcasts (1, width) or (bB, width).  Summing a single surviving
    element over zeros is bit-exact in any float width.
    """
    return jnp.sum(jnp.where(mask, table_row, 0.0), axis=1, keepdims=True)


def _placement_sweep_kernel(
    shares_ref,  # (bB, n_t)
    iis_ref,  # (1, n_t)
    slr_ref,  # (1, n_f)
    cfg_ref,  # (1, n_f)
    resume_ref,  # (1, 1) — t_capture + t_store (traced, no recompiles)
    feas_ref,  # (bB, 1) int32 out
    placed_ref,  # (bB, 1) int32 out
    splits_ref,  # (bB, 1) int32 out
    devused_ref,  # (bB, 1) int32 out
    *,
    n_steps: int,
    repay_init: bool,
):
    shares = shares_ref[...]
    iis_row = iis_ref[...]  # (1, n_t)
    slr_row = slr_ref[...]  # (1, n_f)
    cfg_row = cfg_ref[...]
    resume_cost = resume_ref[0, 0]
    bB, n_t = shares.shape
    n_f = slr_row.shape[1]
    dt = shares.dtype

    c0 = jnp.full((bB, 1), slr_row[0, 0], dtype=dt)
    state = (
        jnp.zeros((bB, 1), jnp.int32),  # j
        jnp.zeros((bB, 1), jnp.int32),  # k
        c0,  # c
        jnp.zeros((bB, 1), dt),  # tsd
        jnp.zeros((bB, 1), jnp.bool_),  # dead
        jnp.zeros((bB, 1), jnp.int32),  # n_splits
        jnp.zeros((bB, 1), jnp.int32),  # devices_used
    )

    def step(_, state):
        j, k, c, tsd, dead, n_splits, devices_used = state
        live = ~dead & (k < n_t)
        kk = jnp.minimum(k, n_t - 1)
        jj = jnp.minimum(j, n_f - 1)
        oh_k = _onehot(kk, n_t)
        oh_j = _onehot(jj, n_f)
        ii = _select(oh_k, iis_row)
        tcfg = _select(oh_j, cfg_row)
        carried = tsd > _PLACE_EPS
        extra = jnp.where(carried, ii if repay_init else resume_cost, 0.0)
        rem = _select(oh_k, shares) - tsd
        avail = (c - tcfg) - extra
        can_start = (c > tcfg + ii + _PLACE_EPS) & (avail > _PLACE_EPS) & live
        split = can_start & (rem - avail > _PLACE_EPS)
        fits = can_start & ~split

        devices_used = jnp.where(
            can_start, jnp.maximum(devices_used, jj + 1), devices_used
        )
        tsd = jnp.where(split, tsd + avail, tsd)
        n_splits = n_splits + (split & ~carried)

        c_after = avail - rem
        closure = fits & (c_after <= tcfg + ii + _PLACE_EPS)
        c = jnp.where(fits, c_after, c)
        k = k + fits
        tsd = jnp.where(fits, 0.0, tsd)

        advance = (~can_start | split | closure) & live
        j_next = j + advance
        still_working = k < n_t
        overflow = advance & (j_next >= n_f) & still_working
        dead = dead | overflow
        refill = advance & (j_next < n_f)
        c = jnp.where(refill, _select(_onehot(jnp.minimum(j_next, n_f - 1), n_f), slr_row), c)
        return (j_next, k, c, tsd, dead, n_splits, devices_used)

    j, k, c, tsd, dead, n_splits, devices_used = jax.lax.fori_loop(
        0, n_steps, step, state
    )
    feas_ref[...] = ((k >= n_t) & ~dead).astype(jnp.int32)
    placed_ref[...] = k
    splits_ref[...] = n_splits
    devused_ref[...] = devices_used


def placement_sweep_pallas(
    shares: jax.Array,  # (B, n_t)
    iis: jax.Array,  # (n_t,)
    t_slr: jax.Array,  # (n_f,)
    t_cfg: jax.Array,  # (n_f,)
    *,
    resume_cost=0.0,  # traced scalar: t_capture + t_store
    repay_init: bool = True,
    block_rows: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused block placement sweep; same contract as
    ``ref.placement_sweep_ref``.

    Rows are tiled ``block_rows`` at a time through VMEM; the grid is the
    row-tile count, and each grid cell runs the entire ``n_t + n_f``-step
    simulation for its tile.  Degenerate ``n_t == 0`` / ``n_f == 0``
    blocks are the caller's job (see ``placement_backends.base``).

    Padding happens *outside* the jit boundary, to the next power of two
    (>= 8): distinct input heights B collapse onto O(log B) compiled
    specializations instead of one retrace per height.  Zero-share
    padding rows trivially "place" and are sliced off.
    """
    B = shares.shape[0]
    Bp = 8
    while Bp < B:
        Bp <<= 1
    if Bp != B:
        shares = jnp.pad(shares, ((0, Bp - B), (0, 0)))
    feas, placed, n_splits, devices_used = _placement_sweep_padded(
        shares, iis, t_slr, t_cfg, resume_cost,
        repay_init=repay_init, block_rows=block_rows, interpret=interpret,
    )
    return feas[:B], placed[:B], n_splits[:B], devices_used[:B]


@functools.partial(
    jax.jit,
    static_argnames=("repay_init", "block_rows", "interpret"),
)
def _placement_sweep_padded(
    shares: jax.Array,  # (Bp, n_t) — Bp a power of two >= 8
    iis: jax.Array,
    t_slr: jax.Array,
    t_cfg: jax.Array,
    resume_cost,
    *,
    repay_init: bool,
    block_rows: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    Bp, n_t = shares.shape
    n_f = t_slr.shape[0]
    dt = shares.dtype
    # Both Bp and the default block_rows are powers of two, so the tile
    # height always divides the padded height exactly.
    bB = min(block_rows, Bp)
    if Bp % bB:
        raise ValueError(f"block_rows={block_rows} must divide padded B={Bp}")

    kernel = functools.partial(
        _placement_sweep_kernel,
        n_steps=n_t + n_f,
        repay_init=repay_init,
    )
    out_shape = [jax.ShapeDtypeStruct((Bp, 1), jnp.int32)] * 4
    feas, placed, n_splits, devices_used = pl.pallas_call(
        kernel,
        grid=(Bp // bB,),
        in_specs=[
            pl.BlockSpec((bB, n_t), lambda i: (i, 0)),
            pl.BlockSpec((1, n_t), lambda i: (0, 0)),
            pl.BlockSpec((1, n_f), lambda i: (0, 0)),
            pl.BlockSpec((1, n_f), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bB, 1), lambda i: (i, 0))] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(shares, iis.reshape(1, n_t).astype(dt), t_slr.reshape(1, n_f).astype(dt),
      t_cfg.reshape(1, n_f).astype(dt),
      jnp.asarray(resume_cost, dtype=dt).reshape(1, 1))
    return (
        feas[:, 0].astype(bool),
        placed[:, 0],
        n_splits[:, 0],
        devices_used[:, 0],
    )
