"""Pallas kernel: fused Alg-2 TFS-block placement sweep.

The scheduler's hot path advances a per-row simulation state (device
cursor ``j``, task cursor ``k``, remaining capacity ``c``, carried share
``tsd``) over a ``(B, n_t)`` shares block.  The jax backend expresses one
step as ~15 gather/where ops XLA schedules independently; here the whole
sweep is *one kernel*: a row tile of the block plus the (tiny) per-task
and per-device tables live in VMEM, and an in-kernel ``fori_loop`` runs
all ``n_t + n_f`` carry/split steps over that tile before it is written
back — no intermediate HBM traffic, so blocks of ~10^6 rows sweep per
call.

Gathers (``iis[k]``, ``t_cfg[j]``, ``shares[row, k]``) are one-hot
masked row reductions instead of dynamic-index loads: with the cursor
clipped into range exactly one column survives the mask, so the sum
reproduces the gathered float64 value bit-exactly while staying
TPU-lowerable (no scatter/gather lowering).

Validated in interpret mode against ``ref.placement_sweep_ref`` (which
is itself pinned bit-for-bit to the scalar Alg-2/Alg-3 oracle).  On
non-TPU hosts the kernel runs in interpret mode (see ``ops.py``); on TPU
float64 is unavailable, so bit-parity claims hold where the kernel is
lowerable at float64 (interpret mode) and to float32 accuracy otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _PLACE_EPS

__all__ = ["placement_sweep_pallas", "placement_sweep_batch_pallas"]


def _onehot(cursor, width: int):
    """(bB, 1) int cursor -> (bB, width) one-hot bool mask."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (cursor.shape[0], width), 1)
    return cols == cursor


def _select(mask, table_row):
    """Masked row reduction: exact gather of one element per row.

    ``mask`` is (bB, width) with exactly one True per row; ``table_row``
    broadcasts (1, width) or (bB, width).  Summing a single surviving
    element over zeros is bit-exact in any float width.
    """
    return jnp.sum(jnp.where(mask, table_row, 0.0), axis=1, keepdims=True)


def _placement_sweep_kernel(
    shares_ref,  # (bB, n_t)
    iis_ref,  # (1, n_t)
    slr_ref,  # (1, n_f)
    cfg_ref,  # (1, n_f)
    resume_ref,  # (1, 1) — t_capture + t_store (traced, no recompiles)
    feas_ref,  # (bB, 1) int32 out
    placed_ref,  # (bB, 1) int32 out
    splits_ref,  # (bB, 1) int32 out
    devused_ref,  # (bB, 1) int32 out
    *,
    n_steps: int,
    repay_init: bool,
):
    shares = shares_ref[...]
    iis_row = iis_ref[...]  # (1, n_t)
    slr_row = slr_ref[...]  # (1, n_f)
    cfg_row = cfg_ref[...]
    resume_cost = resume_ref[0, 0]
    bB, n_t = shares.shape
    n_f = slr_row.shape[1]
    dt = shares.dtype

    c0 = jnp.full((bB, 1), slr_row[0, 0], dtype=dt)
    state = (
        jnp.zeros((bB, 1), jnp.int32),  # j
        jnp.zeros((bB, 1), jnp.int32),  # k
        c0,  # c
        jnp.zeros((bB, 1), dt),  # tsd
        jnp.zeros((bB, 1), jnp.bool_),  # dead
        jnp.zeros((bB, 1), jnp.int32),  # n_splits
        jnp.zeros((bB, 1), jnp.int32),  # devices_used
    )

    def step(_, state):
        j, k, c, tsd, dead, n_splits, devices_used = state
        live = ~dead & (k < n_t)
        kk = jnp.minimum(k, n_t - 1)
        jj = jnp.minimum(j, n_f - 1)
        oh_k = _onehot(kk, n_t)
        oh_j = _onehot(jj, n_f)
        ii = _select(oh_k, iis_row)
        tcfg = _select(oh_j, cfg_row)
        carried = tsd > _PLACE_EPS
        extra = jnp.where(carried, ii if repay_init else resume_cost, 0.0)
        rem = _select(oh_k, shares) - tsd
        avail = (c - tcfg) - extra
        can_start = (c > tcfg + ii + _PLACE_EPS) & (avail > _PLACE_EPS) & live
        split = can_start & (rem - avail > _PLACE_EPS)
        fits = can_start & ~split

        devices_used = jnp.where(
            can_start, jnp.maximum(devices_used, jj + 1), devices_used
        )
        tsd = jnp.where(split, tsd + avail, tsd)
        n_splits = n_splits + (split & ~carried)

        c_after = avail - rem
        closure = fits & (c_after <= tcfg + ii + _PLACE_EPS)
        c = jnp.where(fits, c_after, c)
        k = k + fits
        tsd = jnp.where(fits, 0.0, tsd)

        advance = (~can_start | split | closure) & live
        j_next = j + advance
        still_working = k < n_t
        overflow = advance & (j_next >= n_f) & still_working
        dead = dead | overflow
        refill = advance & (j_next < n_f)
        c = jnp.where(refill, _select(_onehot(jnp.minimum(j_next, n_f - 1), n_f), slr_row), c)
        return (j_next, k, c, tsd, dead, n_splits, devices_used)

    j, k, c, tsd, dead, n_splits, devices_used = jax.lax.fori_loop(
        0, n_steps, step, state
    )
    feas_ref[...] = ((k >= n_t) & ~dead).astype(jnp.int32)
    placed_ref[...] = k
    splits_ref[...] = n_splits
    devused_ref[...] = devices_used


def placement_sweep_pallas(
    shares: jax.Array,  # (B, n_t)
    iis: jax.Array,  # (n_t,)
    t_slr: jax.Array,  # (n_f,)
    t_cfg: jax.Array,  # (n_f,)
    *,
    resume_cost=0.0,  # traced scalar: t_capture + t_store
    repay_init: bool = True,
    block_rows: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused block placement sweep; same contract as
    ``ref.placement_sweep_ref``.

    Rows are tiled ``block_rows`` at a time through VMEM; the grid is the
    row-tile count, and each grid cell runs the entire ``n_t + n_f``-step
    simulation for its tile.  Degenerate ``n_t == 0`` / ``n_f == 0``
    blocks are the caller's job (see ``placement_backends.base``).

    Padding happens *outside* the jit boundary, to the next power of two
    (>= 8): distinct input heights B collapse onto O(log B) compiled
    specializations instead of one retrace per height.  Zero-share
    padding rows trivially "place" and are sliced off.
    """
    B = shares.shape[0]
    Bp = 8
    while Bp < B:
        Bp <<= 1
    if Bp != B:
        shares = jnp.pad(shares, ((0, Bp - B), (0, 0)))
    feas, placed, n_splits, devices_used = _placement_sweep_padded(
        shares, iis, t_slr, t_cfg, resume_cost,
        repay_init=repay_init, block_rows=block_rows, interpret=interpret,
    )
    return feas[:B], placed[:B], n_splits[:B], devices_used[:B]


def _placement_sweep_batch_kernel(
    shares_ref,  # (1, bR, n_t) — one instance's row tile
    iis_ref,  # (1, n_t) — this instance's task table
    slr_ref,  # (1, n_f) — this instance's device capacities
    cfg_ref,  # (1, n_f)
    eff_ref,  # (1, 2) int32 — [n_t_eff, n_f_eff] for this instance
    resume_ref,  # (1, 1)
    feas_ref,  # (1, bR, 1) int32 out
    placed_ref,  # (1, bR, 1) int32 out
    splits_ref,  # (1, bR, 1) int32 out
    devused_ref,  # (1, bR, 1) int32 out
    *,
    n_steps: int,
    repay_init: bool,
):
    """Instance-axis twin of ``_placement_sweep_kernel``.

    The grid is ``(B, Rp // bR)``: axis 0 walks instances (each grid cell
    sees its own ``iis``/``t_slr``/``t_cfg`` tables and effective counts),
    axis 1 walks row tiles within the instance's block.  The step
    arithmetic is the single-instance kernel's with the static
    ``n_t``/``n_f`` widths replaced by the *traced* effective counts —
    padded columns/slots are never read, so live rows replay the exact
    float64 chain and verdicts stay bit-identical per instance.
    """
    shares = shares_ref[0]  # (bR, n_t)
    iis_row = iis_ref[...]  # (1, n_t)
    slr_row = slr_ref[...]  # (1, n_f)
    cfg_row = cfg_ref[...]
    n_t_eff = eff_ref[0, 0]
    n_f_eff = eff_ref[0, 1]
    resume_cost = resume_ref[0, 0]
    bB, n_t = shares.shape
    n_f = slr_row.shape[1]
    dt = shares.dtype

    c0 = jnp.full((bB, 1), slr_row[0, 0], dtype=dt)
    state = (
        jnp.zeros((bB, 1), jnp.int32),  # j
        jnp.zeros((bB, 1), jnp.int32),  # k
        c0,  # c
        jnp.zeros((bB, 1), dt),  # tsd
        jnp.zeros((bB, 1), jnp.bool_),  # dead
        jnp.zeros((bB, 1), jnp.int32),  # n_splits
        jnp.zeros((bB, 1), jnp.int32),  # devices_used
    )

    def step(_, state):
        j, k, c, tsd, dead, n_splits, devices_used = state
        live = ~dead & (k < n_t_eff)
        kk = jnp.minimum(k, n_t - 1)
        jj = jnp.minimum(j, n_f - 1)
        oh_k = _onehot(kk, n_t)
        oh_j = _onehot(jj, n_f)
        ii = _select(oh_k, iis_row)
        tcfg = _select(oh_j, cfg_row)
        carried = tsd > _PLACE_EPS
        extra = jnp.where(carried, ii if repay_init else resume_cost, 0.0)
        rem = _select(oh_k, shares) - tsd
        avail = (c - tcfg) - extra
        can_start = (c > tcfg + ii + _PLACE_EPS) & (avail > _PLACE_EPS) & live
        split = can_start & (rem - avail > _PLACE_EPS)
        fits = can_start & ~split

        devices_used = jnp.where(
            can_start, jnp.maximum(devices_used, jj + 1), devices_used
        )
        tsd = jnp.where(split, tsd + avail, tsd)
        n_splits = n_splits + (split & ~carried)

        c_after = avail - rem
        closure = fits & (c_after <= tcfg + ii + _PLACE_EPS)
        c = jnp.where(fits, c_after, c)
        k = k + fits
        tsd = jnp.where(fits, 0.0, tsd)

        advance = (~can_start | split | closure) & live
        j_next = j + advance
        still_working = k < n_t_eff
        overflow = advance & (j_next >= n_f_eff) & still_working
        dead = dead | overflow
        refill = advance & (j_next < n_f_eff)
        c = jnp.where(refill, _select(_onehot(jnp.minimum(j_next, n_f - 1), n_f), slr_row), c)
        return (j_next, k, c, tsd, dead, n_splits, devices_used)

    j, k, c, tsd, dead, n_splits, devices_used = jax.lax.fori_loop(
        0, n_steps, step, state
    )
    feas_ref[0] = ((k >= n_t_eff) & ~dead).astype(jnp.int32)
    placed_ref[0] = k
    splits_ref[0] = n_splits
    devused_ref[0] = devices_used


def placement_sweep_batch_pallas(
    shares: jax.Array,  # (B, R, n_t) — stacked, padded instance blocks
    iis: jax.Array,  # (B, n_t)
    t_slr: jax.Array,  # (B, n_f)
    t_cfg: jax.Array,  # (B, n_f)
    n_t_eff: jax.Array,  # (B,) int
    n_f_eff: jax.Array,  # (B,) int
    *,
    resume_cost=0.0,
    repay_init: bool = True,
    block_rows: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fleet-parallel fused sweep; same contract as
    ``ref.placement_sweep_batch_ref``.

    One ``pallas_call`` sweeps every instance's block: the grid gains a
    leading instance axis, each cell streaming one ``(block_rows, n_t)``
    row tile of one instance through VMEM together with that instance's
    per-task/per-device tables.  Rows are padded to the next power of two
    (>= 8) outside the jit boundary — distinct (B, R) batch shapes
    collapse onto O(log R) compiled specializations per (B, n_t, n_f)
    topology.  Padded rows and all-padding instances (``n_t_eff == 0``)
    trivially "place" and are the caller's to slice off.
    """
    B, R, n_t = shares.shape
    Rp = 8
    while Rp < R:
        Rp <<= 1
    if Rp != R:
        shares = jnp.pad(shares, ((0, 0), (0, Rp - R), (0, 0)))
    eff = jnp.stack(
        [jnp.asarray(n_t_eff, jnp.int32), jnp.asarray(n_f_eff, jnp.int32)], axis=1
    )  # (B, 2)
    feas, placed, n_splits, devices_used = _placement_sweep_batch_padded(
        shares, iis, t_slr, t_cfg, eff, resume_cost,
        repay_init=repay_init, block_rows=block_rows, interpret=interpret,
    )
    return (
        feas[:, :R],
        placed[:, :R],
        n_splits[:, :R],
        devices_used[:, :R],
    )


@functools.partial(
    jax.jit,
    static_argnames=("repay_init", "block_rows", "interpret"),
)
def _placement_sweep_batch_padded(
    shares: jax.Array,  # (B, Rp, n_t) — Rp a power of two >= 8
    iis: jax.Array,
    t_slr: jax.Array,
    t_cfg: jax.Array,
    eff: jax.Array,  # (B, 2) int32
    resume_cost,
    *,
    repay_init: bool,
    block_rows: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    B, Rp, n_t = shares.shape
    n_f = t_slr.shape[1]
    dt = shares.dtype
    bR = min(block_rows, Rp)
    if Rp % bR:
        raise ValueError(f"block_rows={block_rows} must divide padded R={Rp}")

    kernel = functools.partial(
        _placement_sweep_batch_kernel,
        n_steps=n_t + n_f,
        repay_init=repay_init,
    )
    out_shape = [jax.ShapeDtypeStruct((B, Rp, 1), jnp.int32)] * 4
    feas, placed, n_splits, devices_used = pl.pallas_call(
        kernel,
        grid=(B, Rp // bR),
        in_specs=[
            pl.BlockSpec((1, bR, n_t), lambda b, r: (b, r, 0)),
            pl.BlockSpec((1, n_t), lambda b, r: (b, 0)),
            pl.BlockSpec((1, n_f), lambda b, r: (b, 0)),
            pl.BlockSpec((1, n_f), lambda b, r: (b, 0)),
            pl.BlockSpec((1, 2), lambda b, r: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, r: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bR, 1), lambda b, r: (b, r, 0))] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(
        shares,
        iis.astype(dt),
        t_slr.astype(dt),
        t_cfg.astype(dt),
        eff,
        jnp.asarray(resume_cost, dtype=dt).reshape(1, 1),
    )
    return (
        feas[..., 0].astype(bool),
        placed[..., 0],
        n_splits[..., 0],
        devices_used[..., 0],
    )


@functools.partial(
    jax.jit,
    static_argnames=("repay_init", "block_rows", "interpret"),
)
def _placement_sweep_padded(
    shares: jax.Array,  # (Bp, n_t) — Bp a power of two >= 8
    iis: jax.Array,
    t_slr: jax.Array,
    t_cfg: jax.Array,
    resume_cost,
    *,
    repay_init: bool,
    block_rows: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    Bp, n_t = shares.shape
    n_f = t_slr.shape[0]
    dt = shares.dtype
    # Both Bp and the default block_rows are powers of two, so the tile
    # height always divides the padded height exactly.
    bB = min(block_rows, Bp)
    if Bp % bB:
        raise ValueError(f"block_rows={block_rows} must divide padded B={Bp}")

    kernel = functools.partial(
        _placement_sweep_kernel,
        n_steps=n_t + n_f,
        repay_init=repay_init,
    )
    out_shape = [jax.ShapeDtypeStruct((Bp, 1), jnp.int32)] * 4
    feas, placed, n_splits, devices_used = pl.pallas_call(
        kernel,
        grid=(Bp // bB,),
        in_specs=[
            pl.BlockSpec((bB, n_t), lambda i: (i, 0)),
            pl.BlockSpec((1, n_t), lambda i: (0, 0)),
            pl.BlockSpec((1, n_f), lambda i: (0, 0)),
            pl.BlockSpec((1, n_f), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bB, 1), lambda i: (i, 0))] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(shares, iis.reshape(1, n_t).astype(dt), t_slr.reshape(1, n_f).astype(dt),
      t_cfg.reshape(1, n_f).astype(dt),
      jnp.asarray(resume_cost, dtype=dt).reshape(1, 1))
    return (
        feas[:, 0].astype(bool),
        placed[:, 0],
        n_splits[:, 0],
        devices_used[:, 0],
    )
