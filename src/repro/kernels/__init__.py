# Pallas TPU kernels for the perf-critical compute hot-spots, each with a
# pure-jnp oracle in ref.py and a jit'd dispatch wrapper in ops.py:
#   flash_attention  — tiled online-softmax attention (causal/GQA/window)
#   ssd_scan         — Mamba-2 SSD chunked dual form
#   rglru_scan       — RG-LRU gated linear recurrence
from . import ref

__all__ = ["ref"]
