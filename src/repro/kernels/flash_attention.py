"""Pallas TPU flash attention: tiled online-softmax, causal/GQA/window.

TPU-native design (not a CUDA port):

* Grid ``(B * H, n_q_blocks, n_kv_blocks)`` — the kv axis is innermost,
  so the f32 running-max / denominator / accumulator live in VMEM
  scratch across kv steps (TPU grid steps execute sequentially on a
  core; scratch persists between them).
* GQA without materialised repeat: the K/V BlockSpec ``index_map``
  folds the query head onto its kv head (``h // group``), so each q
  head streams its kv head's tiles straight from HBM.
* Block shapes default to (512, head_dim) q-tiles x (512, head_dim)
  kv-tiles — 128-aligned in the lane dimension for the MXU, and sized
  so q/k/v tiles + f32 accumulator fit comfortably in ~16 MB VMEM.
* Causal masking is block-aware: fully-masked kv tiles are skipped with
  ``pl.when`` (no FLOPs, no VMEM traffic beyond the prefetch).

Validated against ``ref.attention_ref`` in interpret mode (tests sweep
shapes/dtypes); on CPU the public wrapper in ``ops.py`` always selects
interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scratch,
    l_scratch,
    acc_scratch,
    *,
    scale: float,
    causal: bool,
    window: int,
    q_offset: int,
    block_q: int,
    block_kv: int,
    seq_q: int,
    seq_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = qi * block_q + q_offset
    k_start = ki * block_kv

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_kv)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kpos < seq_kv
        if causal:
            mask = mask & (qpos >= kpos)
        if window > 0:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scratch[...]  # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scratch[...] = l_scratch[...] * corr + p.sum(axis=1, keepdims=True)
        m_scratch[...] = m_new
        acc_scratch[...] = acc_scratch[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    if causal:
        # static grid — use pl.when for the runtime block skip
        @pl.when(q_start + block_q - 1 >= k_start)
        def _run():
            _compute()
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0] = (acc_scratch[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "q_offset",
        "block_q",
        "block_kv",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, T, K, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    n_q = pl.cdiv(S, block_q)
    n_kv = pl.cdiv(T, block_kv)

    # Pad to block multiples: Pallas blocks are clipped dynamic-slice
    # style at array edges, which would misalign partial tiles.  The
    # kv mask (kpos < seq_kv) hides the padding; padded q rows are
    # sliced off below.
    Sp, Tp = n_q * block_q, n_kv * block_kv
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))

    # (B*H, Sp, hd) query-head-major; k/v stay (B*K, Tp, hd)
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, Sp, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * K, Tp, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * K, Tp, hd)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * K + h // g, ki, 0)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        seq_q=S,
        seq_kv=T,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), kv_index),
            pl.BlockSpec((1, block_kv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        # m, l, acc — f32 VMEM scratch persisting across kv grid steps
        scratch_shapes=_scratches(block_q, hd),
        interpret=interpret,
    )(qr, kr, vr)

    return jnp.moveaxis(out.reshape(B, H, Sp, hd), 1, 2)[:, :S]


def _scratches(block_q: int, hd: int):
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, hd), jnp.float32),
    ]
