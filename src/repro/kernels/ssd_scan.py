"""Pallas TPU kernel: Mamba-2 SSD, chunked dual form.

TPU-native rethink of the GPU selective-scan: instead of a warp-level
sequential scan, the sequence is blocked into chunks where

* the *intra-chunk* term is a (chunk x chunk) masked matmul — MXU work,
* the *inter-chunk* term is a (ds, hp) state carried in VMEM scratch
  across the innermost (sequential) grid axis.

Grid: ``(B, nh, n_chunks)`` — chunks innermost so the state scratch
persists between steps of the same (batch, head).

Validated in interpret mode against ``ref.ssd_chunked_ref`` /
``ref.ssd_ref`` over shape/dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_scan_pallas"]


def _ssd_kernel(
    x_ref,  # (1, 1, chunk, hp)
    dt_ref,  # (1, 1, chunk)
    a_ref,  # (1,)
    b_ref,  # (1, 1, chunk, ds)
    c_ref,  # (1, 1, chunk, ds)
    d_ref,  # (1,)
    y_ref,  # (1, 1, chunk, hp)
    st_ref,  # (1, 1, ds, hp) — final state output
    state,  # VMEM scratch (ds, hp) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0].astype(jnp.float32)  # (chunk, hp)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (chunk,)
    A = a_ref[0].astype(jnp.float32)  # ()
    Bc = b_ref[0, 0].astype(jnp.float32)  # (chunk, ds)
    Cc = c_ref[0, 0].astype(jnp.float32)
    D = d_ref[0].astype(jnp.float32)

    logdec = dt * A  # (chunk,)
    cum = jnp.cumsum(logdec)  # (chunk,)
    total = cum[-1]

    # intra-chunk quadratic form
    diff = cum[:, None] - cum[None, :]  # (t, s)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    G = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (t, s)
    xdt = x * dt[:, None]  # (chunk, hp)
    y = jax.lax.dot_general(
        G * Lmat, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: contribution of the carried state
    y = y + jax.lax.dot_general(
        Cc * jnp.exp(cum)[:, None],
        state[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # state update: state' = exp(total) * state + B^T (x dt decay_in)
    dec_in = jnp.exp(total - cum)  # (chunk,)
    contrib = jax.lax.dot_general(
        Bc,
        xdt * dec_in[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (ds, hp)
    new_state = jnp.exp(total) * state[...] + contrib
    state[...] = new_state

    y = y + x * D
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_c - 1)
    def _emit_state():
        st_ref[0, 0] = new_state.astype(st_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "return_state", "interpret")
)
def ssd_scan_pallas(
    x: jax.Array,  # (B, S, nh, hp)
    dt: jax.Array,  # (B, S, nh)
    A: jax.Array,  # (nh,)
    Bm: jax.Array,  # (B, S, ng, ds)
    Cm: jax.Array,  # (B, S, ng, ds)
    D: jax.Array,  # (nh,)
    *,
    chunk: int = 128,
    return_state: bool = False,
    interpret: bool = False,
):
    Bb, S, nh, hp = x.shape
    ng, ds = Bm.shape[2], Bm.shape[3]
    rep = nh // ng
    assert S % chunk == 0, (S, chunk)
    n_c = S // chunk

    xt = jnp.moveaxis(x, 1, 2)  # (B, nh, S, hp)
    dtt = jnp.moveaxis(dt, 1, 2)  # (B, nh, S)
    Bt = jnp.moveaxis(Bm, 1, 2)  # (B, ng, S, ds)
    Ct = jnp.moveaxis(Cm, 1, 2)

    grid = (Bb, nh, n_c)
    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, ds), lambda b, h, c, _r=rep: (b, h // _r, c, 0)),
            pl.BlockSpec((1, 1, chunk, ds), lambda b, h, c, _r=rep: (b, h // _r, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ds, hp), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, nh, S, hp), x.dtype),
            jax.ShapeDtypeStruct((Bb, nh, ds, hp), jnp.float32),
        ],
        scratch_shapes=[_vmem((ds, hp))],
        interpret=interpret,
    )(xt, dtt, A, Bt, Ct, D)

    y = jnp.moveaxis(y, 1, 2)  # (B, S, nh, hp)
    if return_state:
        return y, st
    return y


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
