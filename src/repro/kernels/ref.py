"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(`tests/test_kernels.py` sweeps shapes/dtypes with assert_allclose) and
the implementations the models fall back to on non-TPU backends (the
multi-pod dry-run lowers these; the Pallas path is selected with
``impl='pallas'`` on TPU).

* ``attention_ref``    — exact softmax attention (GQA, causal, window).
* ``ssd_ref``          — Mamba-2 SSD, naive O(S^2) materialised form.
* ``ssd_chunked_ref``  — SSD chunked dual form (the TPU-native
  reformulation: intra-chunk quadratic matmuls + inter-chunk state
  recurrence).  Mathematically identical to ``ssd_ref``.
* ``rglru_ref``        — RG-LRU gated linear recurrence (Griffin).
* ``placement_sweep_ref`` — the scheduler's Alg-2 TFS-block placement
  sweep: a ``lax.while_loop`` advancing the (B,) carry/split state, the
  oracle for ``placement_step.placement_sweep_pallas`` and the program
  the jax placement backend jits.
* ``placement_sweep_eff_ref`` / ``placement_sweep_batch_ref`` — the
  fleet-parallel generalisation: the same sweep with *traced* effective
  task/device counts (so padded instances compose under jit), vmapped
  over a leading instance axis — one XLA program sweeps every
  instance's TFS block at once.
* ``placement_sweep_resilient_ref`` / ``placement_sweep_batch_resilient_ref``
  — the k-fault-tolerance composition: the primary sweep AND a second
  sweep on the worst-case survivor fleet, fused into one program so the
  resilience mode costs one dispatch, not two.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "attention_ref",
    "ssd_ref",
    "ssd_chunked_ref",
    "ssd_decode_step",
    "rglru_ref",
    "rglru_decode_step",
    "placement_step_ref",
    "placement_sweep_ref",
    "placement_sweep_eff_ref",
    "placement_sweep_batch_ref",
    "placement_sweep_resilient_ref",
    "placement_sweep_batch_resilient_ref",
]


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Exact attention oracle.  q: (B,S,H,hd), k/v: (B,T,K,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, g, hd) / math.sqrt(hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window > 0:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality, arXiv:2405.21060)
# ---------------------------------------------------------------------------


def ssd_ref(
    x: jax.Array,  # (B, S, nh, hp)
    dt: jax.Array,  # (B, S, nh)       — softplus already applied
    A: jax.Array,  # (nh,)             — negative decay rates
    Bm: jax.Array,  # (B, S, ng, ds)
    Cm: jax.Array,  # (B, S, ng, ds)
    D: jax.Array,  # (nh,)             — skip connection
) -> jax.Array:
    """Naive SSD: y_t = sum_{s<=t} C_t^T (prod_{r=s+1..t} a_r) B_s x_s dt_s.

    Materialises the (S, S) semiseparable matrix per head — O(S^2) memory;
    oracle only.  Heads are grouped onto B/C groups: ng divides nh.
    """
    Bb, S, nh, hp = x.shape
    ng = Bm.shape[2]
    rep = nh // ng
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B,S,nh,ds)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    logdecay = dtf * Af[None, None, :]  # (B,S,nh) log a_t
    cum = jnp.cumsum(logdecay, axis=1)  # (B,S,nh)
    # L[t, s] = exp(cum[t] - cum[s]) for s <= t else 0
    diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B, t, s, nh)
    Lmask = jnp.tril(jnp.ones((S, S), bool))
    Lmat = jnp.where(Lmask[None, :, :, None], jnp.exp(diff), 0.0)
    # scores G[t,s] = C_t . B_s
    G = jnp.einsum("bthd,bshd->btsh", Cf, Bf)  # (B,t,s,nh)
    M = G * Lmat
    y = jnp.einsum("btsh,bshp,bsh->bthp", M, xf, dtf)
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype)


def ssd_chunked_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    *,
    chunk: int = 64,
    initial_state: jax.Array | None = None,
    return_state: bool = False,
):
    """Chunked dual form: O(S * chunk) memory, MXU-friendly matmuls.

    Splits the sequence into chunks; within a chunk the quadratic form of
    ``ssd_ref`` applies; across chunks a (nh, hp, ds) state is carried:

        state_{c+1} = decay_chunk * state_c + B_c^T (x_c dt_c decay_in)
        y_c         = intra(x_c) + C_c (decay_out * state_c)
    """
    Bb, S, nh, hp = x.shape
    ng, ds = Bm.shape[2], Bm.shape[3]
    rep = nh // ng
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    xf = x.astype(jnp.float32).reshape(Bb, nc, chunk, nh, hp)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, chunk, nh)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, chunk, nh, ds)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, chunk, nh, ds)

    logdec = dtf * Af[None, None, None, :]  # (B,nc,C,nh)
    cum = jnp.cumsum(logdec, axis=2)  # within-chunk cumulative
    total = cum[:, :, -1, :]  # (B,nc,nh) — full-chunk log decay

    # --- intra-chunk (quadratic, per chunk) ---
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,t,s,nh)
    Lmask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(Lmask[None, None, :, :, None], jnp.exp(diff), 0.0)
    G = jnp.einsum("bcthd,bcshd->bctsh", Cf, Bf)
    y_intra = jnp.einsum("bctsh,bcshp,bcsh->bcthp", G * Lmat, xf, dtf)

    # --- chunk states ---
    # decay from position s to end of chunk: exp(total - cum_s)
    dec_in = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,C,nh)
    states = jnp.einsum("bcshd,bcsh,bcshp->bchdp", Bf, dtf * dec_in, xf)
    # (B, nc, nh, ds, hp) — per-chunk outgoing state contribution

    # --- inter-chunk recurrence over chunks ---
    dec_chunk = jnp.exp(total)  # (B,nc,nh)

    def scan_body(carry, inp):
        st_in, dc = inp  # (B,nh,ds,hp), (B,nh)
        new = carry * dc[:, :, None, None] + st_in
        return new, carry  # emit state *entering* the chunk

    init = (
        jnp.zeros((Bb, nh, ds, hp), jnp.float32)  # repro-lint: ignore[P203]  # ssd-scan reference accumulates at f32 by design (ML kernel, not the placement precision chain)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    final_state, state_in = lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(dec_chunk, 1, 0)),
    )
    state_in = jnp.moveaxis(state_in, 0, 1)  # (B,nc,nh,ds,hp)

    # --- inter-chunk output: y += C_t * decay(0..t) * state_in ---
    dec_out = jnp.exp(cum)  # (B,nc,C,nh) decay from chunk start to t (inclusive)
    y_inter = jnp.einsum("bcthd,bcth,bchdp->bcthp", Cf, dec_out, state_in)

    y = (y_intra + y_inter).reshape(Bb, S, nh, hp)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(
    state: jax.Array,  # (B, nh, ds, hp) f32
    x: jax.Array,  # (B, nh, hp)
    dt: jax.Array,  # (B, nh)
    A: jax.Array,  # (nh,)
    Bm: jax.Array,  # (B, ng, ds)
    Cm: jax.Array,  # (B, ng, ds)
    D: jax.Array,  # (nh,)
) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence (O(1) decode).  Returns (y, new_state)."""
    nh = x.shape[1]
    ng = Bm.shape[1]
    rep = nh // ng
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)  # (B,nh,ds)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    a = jnp.exp(dtf * A.astype(jnp.float32)[None, :])  # (B,nh)
    upd = jnp.einsum("bhd,bhp->bhdp", Bf, xf * dtf[..., None])
    new_state = state * a[:, :, None, None] + upd
    y = jnp.einsum("bhd,bhdp->bhp", Cf, new_state)
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma, arXiv:2402.19427)
# ---------------------------------------------------------------------------


def rglru_ref(
    x: jax.Array,  # (B, S, W)
    r_gate: jax.Array,  # (B, S, W) — recurrence gate pre-sigmoid
    i_gate: jax.Array,  # (B, S, W) — input gate pre-sigmoid
    log_lambda: jax.Array,  # (W,)  — learnable decay logits
    *,
    c: float = 8.0,
    initial_state: jax.Array | None = None,
    return_state: bool = False,
):
    """RG-LRU:  a_t = exp(-c * softplus(Λ) * sigmoid(r_t)),
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(i_t) * x_t).

    Associative-scan formulation (parallel over S).
    """
    xf = x.astype(jnp.float32)
    rf = jax.nn.sigmoid(r_gate.astype(jnp.float32))
    i_f = jax.nn.sigmoid(i_gate.astype(jnp.float32))
    lam = jax.nn.softplus(log_lambda.astype(jnp.float32))[None, None, :]
    log_a = -c * lam * rf  # (B,S,W), log of decay in (0,1)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_f * xf)

    if initial_state is not None:
        gated = gated.at[:, 0].add(a[:, 0] * initial_state.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    a_sc, h = lax.associative_scan(combine, (a, gated), axis=1)
    h = h.astype(x.dtype)
    if return_state:
        return h, h[:, -1].astype(jnp.float32)
    return h


def rglru_decode_step(
    state: jax.Array,  # (B, W) f32
    x: jax.Array,  # (B, W)
    r_gate: jax.Array,
    i_gate: jax.Array,
    log_lambda: jax.Array,
    *,
    c: float = 8.0,
) -> tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    rf = jax.nn.sigmoid(r_gate.astype(jnp.float32))
    i_f = jax.nn.sigmoid(i_gate.astype(jnp.float32))
    lam = jax.nn.softplus(log_lambda.astype(jnp.float32))[None, :]
    a = jnp.exp(-c * lam * rf)
    h = a * state + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_f * xf)
    return h.astype(x.dtype), h


# ---------------------------------------------------------------------------
# PADPS-FR Alg-2 placement sweep (the scheduler's TFS hot path)
# ---------------------------------------------------------------------------

_PLACE_EPS = 1e-9  # == repro.core.placement._EPS (kept literal: no core import)


def placement_step_ref(
    state: tuple,
    shares: jax.Array,  # (B, n_t)
    iis: jax.Array,  # (n_t,)
    t_slr: jax.Array,  # (n_f,)
    t_cfg: jax.Array,  # (n_f,)
    resume_cost: jax.Array,  # scalar
    *,
    repay_init: bool = True,
) -> tuple:
    """One fused carry/split step over the whole (B,) placement state.

    Mirrors the numpy engine
    (:mod:`repro.core.placement_backends.numpy_backend`) exactly: every
    live row either advances its task cursor (the current task fits) or
    its device cursor (no-start, split carry, or closure).  The float64
    operations are the scalar oracle's, in the same order — pure add/sub
    chains, so XLA cannot FMA-contract them and the verdicts stay
    bit-identical.
    """
    j, k, c, tsd, dead, n_splits, devices_used = state
    n_t = shares.shape[1]
    n_f = t_slr.shape[0]

    live = ~dead & (k < n_t)
    kk = jnp.minimum(k, n_t - 1)  # safe gather index once k == n_t
    jj = jnp.minimum(j, n_f - 1)  # safe gather index once j == n_f
    ii = iis[kk]
    tcfg = t_cfg[jj]
    carried = tsd > _PLACE_EPS
    extra = jnp.where(carried, ii if repay_init else resume_cost, 0.0)
    rem = jnp.take_along_axis(shares, kk[:, None], axis=1)[:, 0] - tsd
    avail = (c - tcfg) - extra
    can_start = (c > tcfg + ii + _PLACE_EPS) & (avail > _PLACE_EPS) & live
    split = can_start & (rem - avail > _PLACE_EPS)
    fits = can_start & ~split

    # Any placement (split or full) occupies the current device.
    devices_used = jnp.where(
        can_start, jnp.maximum(devices_used, jj + 1), devices_used
    )

    # Split: run `avail` here, carry the remainder to the next device.
    tsd = jnp.where(split, tsd + avail, tsd)
    n_splits = n_splits + (split & ~carried)

    # Fits: consume cfg + extra + remaining share, advance the task.
    c_after = avail - rem
    closure = fits & (c_after <= tcfg + ii + _PLACE_EPS)
    c = jnp.where(fits, c_after, c)
    k = k + fits
    tsd = jnp.where(fits, 0.0, tsd)

    # Device advance: no-start, split carry, or closure after a fit.
    advance = (~can_start | split | closure) & live
    j_next = j + advance
    still_working = k < n_t
    overflow = advance & (j_next >= n_f) & still_working
    dead = dead | overflow
    refill = advance & (j_next < n_f)
    c = jnp.where(refill, t_slr[jnp.minimum(j_next, n_f - 1)], c)
    return (j_next, k, c, tsd, dead, n_splits, devices_used)


def placement_sweep_ref(
    shares: jax.Array,  # (B, n_t) float64
    iis: jax.Array,  # (n_t,)
    t_slr: jax.Array,  # (n_f,)
    t_cfg: jax.Array,  # (n_f,)
    resume_cost: jax.Array = 0.0,  # scalar: t_capture + t_store
    *,
    repay_init: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Full Alg-2 block placement sweep as one ``lax.while_loop`` program.

    Returns ``(feasible, placed_tasks, n_splits, devices_used)`` — (B,)
    arrays matching :class:`repro.core.placement_backends.BatchPlacement`.
    ``n_t`` and ``n_f`` are static (from the input shapes); callers handle
    the degenerate ``n_t == 0`` / ``n_f == 0`` blocks on the host.  Each
    step advances every live row's task or device cursor, so the loop runs
    at most ``n_t + n_f`` iterations regardless of B.
    """
    B, n_t = shares.shape
    dt = shares.dtype
    state = (
        jnp.zeros(B, dtype=jnp.int32),  # j — device cursor
        jnp.zeros(B, dtype=jnp.int32),  # k — task cursor (paper's sti)
        jnp.full(B, t_slr[0], dtype=dt),  # c — remaining capacity
        jnp.zeros(B, dtype=dt),  # tsd — carried share of task k
        jnp.zeros(B, dtype=bool),  # dead
        jnp.zeros(B, dtype=jnp.int32),  # n_splits
        jnp.zeros(B, dtype=jnp.int32),  # devices_used
    )

    def cond(state):
        j, k, c, tsd, dead, n_splits, devices_used = state
        return jnp.any(~dead & (k < n_t))

    def body(state):
        return placement_step_ref(
            state, shares, iis, t_slr, t_cfg, resume_cost, repay_init=repay_init
        )

    j, k, c, tsd, dead, n_splits, devices_used = lax.while_loop(cond, body, state)
    return (k >= n_t) & ~dead, k, n_splits, devices_used


def placement_sweep_eff_ref(
    shares: jax.Array,  # (R, n_t) — n_t is the *padded* task width
    iis: jax.Array,  # (n_t,)
    t_slr: jax.Array,  # (n_f,) — n_f is the *padded* device width
    t_cfg: jax.Array,  # (n_f,)
    n_t_eff: jax.Array,  # scalar int — live task count (<= n_t)
    n_f_eff: jax.Array,  # scalar int — live device count (<= n_f)
    resume_cost: jax.Array = 0.0,
    *,
    repay_init: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """:func:`placement_sweep_ref` with *traced* effective counts.

    Padded task columns / device slots beyond ``n_t_eff`` / ``n_f_eff``
    are never read: the task cursor stops at ``n_t_eff`` and device
    overflow triggers at ``n_f_eff``, so the float64 add/sub chain for a
    live row is *exactly* the unpadded sweep's — bit-identical verdicts
    regardless of how much padding an :class:`InstanceBatch` carries.
    ``n_t_eff == 0`` rows come out all-feasible and ``n_f_eff == 0``
    (with live tasks) all-infeasible, matching the degenerate-block
    contract in ``placement_backends.base.prepare_block``.
    """
    R, n_t = shares.shape
    n_f = t_slr.shape[0]
    dt = shares.dtype
    state = (
        jnp.zeros(R, dtype=jnp.int32),  # j — device cursor
        jnp.zeros(R, dtype=jnp.int32),  # k — task cursor
        jnp.full(R, t_slr[0], dtype=dt),  # c — remaining capacity
        jnp.zeros(R, dtype=dt),  # tsd — carried share of task k
        jnp.zeros(R, dtype=bool),  # dead
        jnp.zeros(R, dtype=jnp.int32),  # n_splits
        jnp.zeros(R, dtype=jnp.int32),  # devices_used
    )

    def cond(state):
        j, k, c, tsd, dead, n_splits, devices_used = state
        return jnp.any(~dead & (k < n_t_eff))

    def body(state):
        j, k, c, tsd, dead, n_splits, devices_used = state
        live = ~dead & (k < n_t_eff)
        kk = jnp.minimum(k, n_t - 1)  # safe gather index at the pad edge
        jj = jnp.minimum(j, n_f - 1)
        ii = iis[kk]
        tcfg = t_cfg[jj]
        carried = tsd > _PLACE_EPS
        extra = jnp.where(carried, ii if repay_init else resume_cost, 0.0)
        rem = jnp.take_along_axis(shares, kk[:, None], axis=1)[:, 0] - tsd
        avail = (c - tcfg) - extra
        can_start = (c > tcfg + ii + _PLACE_EPS) & (avail > _PLACE_EPS) & live
        split = can_start & (rem - avail > _PLACE_EPS)
        fits = can_start & ~split

        devices_used = jnp.where(
            can_start, jnp.maximum(devices_used, jj + 1), devices_used
        )
        tsd = jnp.where(split, tsd + avail, tsd)
        n_splits = n_splits + (split & ~carried)

        c_after = avail - rem
        closure = fits & (c_after <= tcfg + ii + _PLACE_EPS)
        c = jnp.where(fits, c_after, c)
        k = k + fits
        tsd = jnp.where(fits, 0.0, tsd)

        advance = (~can_start | split | closure) & live
        j_next = j + advance
        still_working = k < n_t_eff
        overflow = advance & (j_next >= n_f_eff) & still_working
        dead = dead | overflow
        refill = advance & (j_next < n_f_eff)
        c = jnp.where(refill, t_slr[jnp.minimum(j_next, n_f - 1)], c)
        return (j_next, k, c, tsd, dead, n_splits, devices_used)

    j, k, c, tsd, dead, n_splits, devices_used = lax.while_loop(cond, body, state)
    return (k >= n_t_eff) & ~dead, k, n_splits, devices_used


def placement_sweep_batch_ref(
    shares: jax.Array,  # (B, R, n_t) — stacked instance blocks, padded
    iis: jax.Array,  # (B, n_t)
    t_slr: jax.Array,  # (B, n_f)
    t_cfg: jax.Array,  # (B, n_f)
    n_t_eff: jax.Array,  # (B,) int
    n_f_eff: jax.Array,  # (B,) int
    resume_cost: jax.Array = 0.0,
    *,
    repay_init: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fleet-parallel Alg-2 sweep: B instances' TFS blocks in one program.

    ``vmap`` of :func:`placement_sweep_eff_ref` over the leading instance
    axis — per-instance fleets (``t_slr``/``t_cfg`` rows), task tables
    (``iis``) and effective counts all batch; ``resume_cost`` and
    ``repay_init`` are global (the walk's :class:`PlacementOptions` apply
    to the whole batch).  Returns ``(feasible, placed, n_splits,
    devices_used)`` as (B, R) arrays.  Elementwise float64 arithmetic is
    unchanged by the batching, so every instance's verdict row is
    bit-identical to its own single-instance sweep.
    """
    return jax.vmap(
        lambda s, i, sl, cf, nt, nf: placement_sweep_eff_ref(
            s, i, sl, cf, nt, nf, resume_cost, repay_init=repay_init
        )
    )(shares, iis, t_slr, t_cfg, n_t_eff, n_f_eff)


def placement_sweep_resilient_ref(
    shares: jax.Array,  # (B, n_t)
    iis: jax.Array,  # (n_t,)
    t_slr: jax.Array,  # (n_f,) — the full fleet
    t_cfg: jax.Array,  # (n_f,)
    t_slr_s: jax.Array,  # (n_f - k,) — worst-case survivor fleet
    t_cfg_s: jax.Array,  # (n_f - k,)
    resume_cost: jax.Array = 0.0,
    *,
    repay_init: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Resilience-mode sweep: primary AND worst-case-survivor verdicts.

    The second, constrained pass of ``opts.resilience = k`` fused with
    the primary sweep into one jit program (one dispatch per block, not
    two).  ``feasible`` is the AND of the two sweeps; ``placed_tasks`` /
    ``n_splits`` / ``devices_used`` describe the primary sweep, matching
    the backend contract in ``placement_backends.base``.  Survivor tables
    arrive pre-trimmed (``base.survivor_tables``) so each sweep is
    bit-identical to a solo sweep on its own fleet.
    """
    feasible, k, n_splits, devices_used = placement_sweep_ref(
        shares, iis, t_slr, t_cfg, resume_cost, repay_init=repay_init
    )
    feasible_s, _, _, _ = placement_sweep_ref(
        shares, iis, t_slr_s, t_cfg_s, resume_cost, repay_init=repay_init
    )
    return feasible & feasible_s, k, n_splits, devices_used


def placement_sweep_batch_resilient_ref(
    shares: jax.Array,  # (B, R, n_t)
    iis: jax.Array,  # (B, n_t)
    t_slr: jax.Array,  # (B, n_f)
    t_cfg: jax.Array,  # (B, n_f)
    n_t_eff: jax.Array,  # (B,) int
    n_f_eff: jax.Array,  # (B,) int
    t_slr_s: jax.Array,  # (B, n_f) — survivors left-packed, zero-padded
    t_cfg_s: jax.Array,  # (B, n_f)
    n_f_eff_s: jax.Array,  # (B,) int — live survivor count (n_f_eff - k)
    resume_cost: jax.Array = 0.0,
    *,
    repay_init: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fleet-parallel resilience sweep (``placement_sweep_batch_ref`` x2).

    Survivor tables come from ``base.survivor_batch_tables``: per-instance
    survivors left-packed into the same padded width with ``n_f_eff_s``
    live slots, so the survivor pass reuses the traced-effective-count
    machinery unchanged (``n_f_eff_s == 0`` instances are all-infeasible
    for live tasks — a fleet that cannot survive k failures).
    """
    feasible, k, n_splits, devices_used = placement_sweep_batch_ref(
        shares, iis, t_slr, t_cfg, n_t_eff, n_f_eff, resume_cost,
        repay_init=repay_init,
    )
    feasible_s, _, _, _ = placement_sweep_batch_ref(
        shares, iis, t_slr_s, t_cfg_s, n_t_eff, n_f_eff_s, resume_cost,
        repay_init=repay_init,
    )
    return feasible & feasible_s, k, n_splits, devices_used
