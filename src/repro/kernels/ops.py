"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas path compiles natively; everywhere else (this CPU
container, the dry-run) the wrappers run the kernels in ``interpret``
mode — or, for the model forward paths, the models call the jnp
references directly (``repro.models`` uses ``ExecConfig.attn_impl``).
"""

from __future__ import annotations

import functools

import jax

from . import ref
from .flash_attention import flash_attention_pallas
from .placement_step import placement_sweep_batch_pallas, placement_sweep_pallas
from .rglru_scan import rglru_scan_pallas
from .ssd_scan import ssd_scan_pallas

__all__ = [
    "flash_attention",
    "ssd_scan",
    "rglru_scan",
    "placement_sweep",
    "placement_sweep_batch",
    "on_tpu",
]


@functools.cache
def on_tpu() -> bool:
    # Cached: the placement walk probes this once per dispatched block and
    # the default backend cannot change within a process.
    return jax.default_backend() == "tpu"


def flash_attention(
    q,
    k,
    v,
    *,
    q_offset=0,
    kv_len=None,
    causal=True,
    window=0,
    block_q=512,
    block_kv=512,
):
    """Flash attention with automatic fallback.

    The Pallas kernel covers the static full-sequence cases (train /
    prefill).  Decode (S == 1 with a runtime ``kv_len``) and traced
    ``q_offset`` fall back to the chunked-XLA path, which is
    memory-bound anyway and gains nothing from a custom kernel.
    """
    from repro.models.layers import chunked_attention

    S = q.shape[1]
    if S == 1 or kv_len is not None or not isinstance(q_offset, int):
        return chunked_attention(
            q, k, v, q_offset=q_offset, kv_len=kv_len, causal=causal,
            window=window, kv_chunk=min(1024, k.shape[1]),
        )
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        interpret=not on_tpu(),
    )


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128, return_state=False):
    """Mamba-2 SSD chunked scan (Pallas on TPU, interpret elsewhere)."""
    S = x.shape[1]
    if S % chunk != 0:
        while S % chunk:
            chunk -= 1
    return ssd_scan_pallas(
        x, dt, A, Bm, Cm, D, chunk=chunk, return_state=return_state,
        interpret=not on_tpu(),
    )


def rglru_scan(x, r_gate, i_gate, log_lambda, *, c=8.0, return_state=False):
    """RG-LRU blocked scan (Pallas on TPU, interpret elsewhere)."""
    return rglru_scan_pallas(
        x, r_gate, i_gate, log_lambda, c=c, return_state=return_state,
        interpret=not on_tpu(),
    )


def placement_sweep(
    shares, iis, t_slr, t_cfg, *, resume_cost=0.0, repay_init=True, block_rows=1024
):
    """Fused Alg-2 TFS-block placement sweep (Pallas on TPU, interpret
    elsewhere).  Oracle: ``ref.placement_sweep_ref``; the scheduler-facing
    entry is ``repro.core.placement_backends`` (engine="pallas").

    Returns device arrays without forcing a sync: like any jit'd call the
    kernel dispatches asynchronously, and only converting the outputs to
    numpy blocks — which is what the backend's ``dispatch_block`` resolver
    defers until the next block is already in flight."""
    return placement_sweep_pallas(
        shares, iis, t_slr, t_cfg,
        resume_cost=resume_cost, repay_init=repay_init, block_rows=block_rows,
        interpret=not on_tpu(),
    )


def placement_sweep_batch(
    shares,
    iis,
    t_slr,
    t_cfg,
    n_t_eff,
    n_f_eff,
    *,
    resume_cost=0.0,
    repay_init=True,
    block_rows=1024,
):
    """Fleet-parallel fused sweep over a ``(B, R, n_t)`` instance stack
    (Pallas on TPU, interpret elsewhere).  Oracle:
    ``ref.placement_sweep_batch_ref``; scheduler-facing entry is
    ``PADPSFRScheduler.schedule_many`` (engine="pallas").  Ragged
    instances arrive padded — ``n_t_eff``/``n_f_eff`` carry each
    instance's live extents so padded columns are never read."""
    return placement_sweep_batch_pallas(
        shares, iis, t_slr, t_cfg, n_t_eff, n_f_eff,
        resume_cost=resume_cost, repay_init=repay_init, block_rows=block_rows,
        interpret=not on_tpu(),
    )
