"""Pallas TPU kernel: RG-LRU gated linear recurrence (Griffin).

TPU-native blocked scan: time is split into blocks; *within* a block the
recurrence is computed as a masked (bt x bt) decay-matrix product
(cumulative log-decay trick — same MXU-friendly reformulation as the SSD
intra-chunk term), and the per-channel hidden state is carried across
time blocks in VMEM scratch.  Channels are tiled on the 128-lane axis.

Grid: ``(B, n_channel_blocks, n_time_blocks)`` — time innermost
(sequential), so the (bc,) state scratch persists per (b, cblock).

Validated in interpret mode against ``ref.rglru_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rglru_scan_pallas"]


def _rglru_kernel(
    x_ref,  # (1, bt, bc)
    r_ref,  # (1, bt, bc)
    i_ref,  # (1, bt, bc)
    lam_ref,  # (bc,)
    y_ref,  # (1, bt, bc)
    st_ref,  # (1, 1, bc) — final state output
    h_scratch,  # VMEM (1, bc) f32
    *,
    c: float,
    bt: int,
):
    ti = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)  # (bt, bc)
    r = jax.nn.sigmoid(r_ref[0].astype(jnp.float32))
    i = jax.nn.sigmoid(i_ref[0].astype(jnp.float32))
    lam = jax.nn.softplus(lam_ref[...].astype(jnp.float32))  # (bc,)

    log_a = -c * lam[None, :] * r  # (bt, bc)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)

    cum = jnp.cumsum(log_a, axis=0)  # (bt, bc)
    # h_t = sum_{s<=t} exp(cum_t - cum_s) g_s  +  exp(cum_t) * h_carry
    diff = cum[:, None, :] - cum[None, :, :]  # (t, s, bc)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1)
    )
    M = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    h = jnp.einsum("tsc,sc->tc", M, gated) + jnp.exp(cum) * h_scratch[0][None, :]

    h_scratch[0, :] = h[-1]
    y_ref[0] = h.astype(y_ref.dtype)

    @pl.when(ti == n_t - 1)
    def _emit():
        st_ref[0, 0] = h[-1].astype(st_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("c", "block_t", "block_c", "return_state", "interpret")
)
def rglru_scan_pallas(
    x: jax.Array,  # (B, S, W)
    r_gate: jax.Array,  # (B, S, W)
    i_gate: jax.Array,  # (B, S, W)
    log_lambda: jax.Array,  # (W,)
    *,
    c: float = 8.0,
    block_t: int = 64,
    block_c: int = 128,
    return_state: bool = False,
    interpret: bool = False,
):
    B, S, W = x.shape
    bt = min(block_t, S)
    bc = min(block_c, W)
    # pad to block multiples (see flash_attention.py: Pallas clips
    # partial blocks dynamic-slice style).  Time padding appends steps
    # whose gates decay from the valid state; outputs are sliced off.
    Sp = pl.cdiv(S, bt) * bt
    Wp = pl.cdiv(W, bc) * bc
    if Sp != S or Wp != W:
        pad = ((0, 0), (0, Sp - S), (0, Wp - W))
        x = jnp.pad(x, pad)
        r_gate = jnp.pad(r_gate, pad)
        i_gate = jnp.pad(i_gate, pad)
        log_lambda = jnp.pad(log_lambda, (0, Wp - W))

    grid = (B, Wp // bc, Sp // bt)
    y, st = pl.pallas_call(
        functools.partial(_rglru_kernel, c=c, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bc), lambda b, ci, ti: (b, ti, ci)),
            pl.BlockSpec((1, bt, bc), lambda b, ci, ti: (b, ti, ci)),
            pl.BlockSpec((1, bt, bc), lambda b, ci, ti: (b, ti, ci)),
            pl.BlockSpec((bc,), lambda b, ci, ti: (ci,)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bc), lambda b, ci, ti: (b, ti, ci)),
            pl.BlockSpec((1, 1, bc), lambda b, ci, ti: (b, 0, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Wp), x.dtype),
            jax.ShapeDtypeStruct((B, 1, Wp), jnp.float32),
        ],
        scratch_shapes=[_vmem((1, bc))],
        interpret=interpret,
    )(x, r_gate, i_gate, log_lambda)

    yv = y[:, :S, :W]
    if return_state:
        if Sp != S:
            # padded time steps decay the state (zero-padded gates are not
            # identity), so take the state at the last *valid* step — for
            # RG-LRU the hidden state IS the output.
            return yv, yv[:, -1].astype(jnp.float32)
        return yv, st[:, 0, :W]
    return yv


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
