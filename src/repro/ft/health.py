"""Fleet health tracking via heartbeats.

On real deployments each slice's host agent posts heartbeats; here the
controller is driven programmatically (tests inject failures).  A slice
that misses ``timeout`` seconds of heartbeats is declared DOWN, which
triggers the elastic re-plan.
"""

from __future__ import annotations

import dataclasses
import enum
import time

__all__ = ["SliceState", "FleetHealth"]


class SliceState(enum.Enum):
    UP = "up"
    SUSPECT = "suspect"
    DOWN = "down"


@dataclasses.dataclass
class _Slice:
    last_beat: float
    state: SliceState = SliceState.UP


class FleetHealth:
    """Heartbeat book-keeping for ``n_f`` slices."""

    def __init__(self, n_slices: int, *, timeout: float = 30.0, suspect: float = 10.0,
                 clock=time.monotonic) -> None:
        self.timeout = timeout
        self.suspect = suspect
        self._clock = clock
        now = clock()
        self._slices = {j: _Slice(last_beat=now) for j in range(n_slices)}

    def heartbeat(self, slice_id: int) -> None:
        s = self._slices[slice_id]
        s.last_beat = self._clock()
        if s.state != SliceState.DOWN:  # DOWN requires explicit revive
            s.state = SliceState.UP

    def mark_down(self, slice_id: int) -> None:
        self._slices[slice_id].state = SliceState.DOWN

    def revive(self, slice_id: int) -> None:
        s = self._slices[slice_id]
        s.state = SliceState.UP
        s.last_beat = self._clock()

    def poll(self) -> dict[int, SliceState]:
        """Advance state machine from heartbeat ages."""
        now = self._clock()
        for s in self._slices.values():
            if s.state == SliceState.DOWN:
                continue
            age = now - s.last_beat
            if age >= self.timeout:
                s.state = SliceState.DOWN
            elif age >= self.suspect:
                s.state = SliceState.SUSPECT
            else:
                s.state = SliceState.UP
        return {j: s.state for j, s in self._slices.items()}

    def up_slices(self) -> list[int]:
        return [j for j, s in self._slices.items() if s.state == SliceState.UP]

    @property
    def n_up(self) -> int:
        return len(self.up_slices())
