"""Fault tolerance: health tracking, elastic re-planning, stragglers."""

from .health import FleetHealth, SliceState
from .elastic import ElasticController, ReplanEvent
from .straggler import StragglerDetector

__all__ = [
    "FleetHealth",
    "SliceState",
    "ElasticController",
    "ReplanEvent",
    "StragglerDetector",
]
