"""Straggler mitigation: per-slice step-time EMA vs variant prediction.

Each job variant carries a predicted throughput (from the roofline
model or the paper's measured tables).  A slice whose observed step
time drifts ``threshold``x above prediction for ``patience``
consecutive windows is flagged; the controller's response is a re-plan
that avoids the slow slice (same PADPS-FR mechanism as failures —
a straggler is a slice whose *effective* throughput degraded, so its
task's variant table no longer holds there).
"""

from __future__ import annotations

import dataclasses

__all__ = ["StragglerDetector"]


@dataclasses.dataclass
class _Track:
    ema: float = 0.0
    n: int = 0
    strikes: int = 0


class StragglerDetector:
    def __init__(self, *, alpha: float = 0.2, threshold: float = 1.5,
                 patience: int = 3) -> None:
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self._tracks: dict[int, _Track] = {}

    def observe(self, slice_id: int, step_time: float, predicted: float) -> bool:
        """Record one step; returns True if the slice is now a straggler."""
        tr = self._tracks.setdefault(slice_id, _Track())
        tr.ema = step_time if tr.n == 0 else (1 - self.alpha) * tr.ema + self.alpha * step_time
        tr.n += 1
        if tr.n >= 3 and tr.ema > self.threshold * predicted:
            tr.strikes += 1
        else:
            tr.strikes = 0
        return tr.strikes >= self.patience

    def stragglers(self) -> list[int]:
        return [j for j, t in self._tracks.items() if t.strikes >= self.patience]

    def reset(self, slice_id: int) -> None:
        self._tracks.pop(slice_id, None)
