"""Elastic re-planning: node loss -> re-run PADPS-FR on the shrunk fleet.

The paper's scheduler is a pure function (fleet, tasks) -> plan, which
makes elasticity a re-plan: when health reports a slice DOWN, the
controller re-schedules the same task set on ``n_f - k`` slices; jobs
restart from their checkpoints (the framework's own mechanism — the
paper likewise re-writes a fresh bitstream + data split rather than
capturing context).  Growing the fleet is the same call with more
slices, typically unlocking lower-power variants.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.scheduler import PADPSFRScheduler, ScheduleResult
from repro.core.task import FleetSpec, Task

from .health import FleetHealth

__all__ = ["ReplanEvent", "ElasticController"]


@dataclasses.dataclass
class ReplanEvent:
    reason: str
    n_slices: int
    result: ScheduleResult
    dropped_tasks: list[str]


class ElasticController:
    """Owns the current placement plan; re-plans on fleet changes.

    If the full task set no longer fits, tasks are shed lowest-priority
    first (priority = list order) until the plan is feasible — degraded
    but live, never wedged.
    """

    def __init__(self, fleet: FleetSpec, tasks: Sequence[Task], *,
                 health: FleetHealth | None = None) -> None:
        self.base_fleet = fleet
        self.tasks = list(tasks)
        self.health = health or FleetHealth(fleet.n_f)
        self.events: list[ReplanEvent] = []
        self.current: ScheduleResult | None = None
        self.active_tasks: list[Task] = list(tasks)
        self._last_n_up = self.health.n_up
        self.replan("initial")

    def replan(self, reason: str) -> ReplanEvent:
        n_up = self.health.n_up
        self._last_n_up = n_up
        fleet = self.base_fleet.with_devices(max(n_up, 1))
        dropped: list[str] = []
        tasks = list(self.tasks)
        result = PADPSFRScheduler(fleet).schedule(tasks)
        while not result.feasible and len(tasks) > 1:
            shed = tasks.pop()  # lowest priority = last
            dropped.append(shed.name)
            result = PADPSFRScheduler(fleet).schedule(tasks)
        self.current = result
        self.active_tasks = tasks
        ev = ReplanEvent(reason=reason, n_slices=fleet.n_f, result=result,
                         dropped_tasks=dropped)
        self.events.append(ev)
        return ev

    # ---- fleet change entry points ----
    def on_slice_down(self, slice_id: int) -> ReplanEvent:
        self.health.mark_down(slice_id)
        return self.replan(f"slice {slice_id} down")

    def on_slice_up(self, slice_id: int) -> ReplanEvent:
        self.health.revive(slice_id)
        return self.replan(f"slice {slice_id} up")

    def poll(self) -> ReplanEvent | None:
        """Heartbeat-driven: re-plan if the up-count changed."""
        self.health.poll()
        if self.health.n_up != self._last_n_up:
            return self.replan("heartbeat change")
        return None
