"""Train-step factory: loss -> grads -> (optionally compressed) update.

The produced step is a pure function ``(state, batch) -> (state,
metrics)`` — jit it with shardings from ``repro.sharding`` (the dry-run
does) or run it eagerly on CPU for the smoke tests.

Features:
* microbatch gradient accumulation (``lax.scan`` over the split batch),
* optional int8 + error-feedback gradient compression on the DP
  all-reduce path (cross-pod traffic / 4),
* metrics: loss, CE, MoE aux, grad global-norm.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.compression import ErrorFeedback
from repro.optim.optimizers import Optimizer, global_norm

__all__ = ["TrainState", "make_train_step", "train_state_axes"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array
    ef_residual: Any = None  # error-feedback state (compression on)


def train_state_axes(model: Model, *, compress: bool = False) -> TrainState:
    """Logical-axes tree matching TrainState (for sharding resolution)."""
    p_axes = model.param_axes()
    # AdamW/SGD moments mirror params exactly
    opt_axes = {"m": p_axes, "v": p_axes}
    return TrainState(
        params=p_axes,
        opt_state=opt_axes,
        step=(),
        ef_residual=p_axes if compress else None,
    )


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    microbatch: int = 0,
    compress_grads: bool = False,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    loss_fn = lambda p, b: model.loss(p, b)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        """Microbatched grads: mean over `microbatch` slices of the batch."""
        nb = microbatch
        split = jax.tree.map(lambda x: x.reshape((nb, x.shape[0] // nb) + x.shape[1:]), batch)

        def body(carry, mb):
            loss_a, grads_a = carry
            loss, _m, grads = grads_of(params, mb)
            return (
                loss_a + loss / nb,
                jax.tree.map(lambda a, g: a + g / nb, grads_a, grads),
            ), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), split)
        return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}, grads

    def step_fn(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatch and microbatch > 1:
            loss, metrics, grads = accumulate(state.params, batch)
        else:
            loss, metrics, grads = grads_of(state.params, batch)

        ef = state.ef_residual
        if compress_grads:
            grads, ef = ErrorFeedback.apply(grads, ef)

        gnorm = global_norm(grads)
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params, state.step)
        new_state = TrainState(
            params=new_params,
            opt_state=new_opt,
            step=state.step + 1,
            ef_residual=ef,
        )
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, out

    return step_fn


def init_train_state(
    model: Model, optimizer: Optimizer, key: jax.Array, *, compress: bool = False
) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
        ef_residual=ErrorFeedback.init(params) if compress else None,
    )
