"""Training loop: checkpoint auto-resume, async saves, health hooks.

Deterministic end to end: data is a pure function of the step counter
(see ``repro.data``), so kill -9 at any point + restart reproduces the
exact same loss curve — asserted in tests/test_train_loop.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.ft.straggler import StragglerDetector

from .step import TrainState, init_train_state, make_train_step

__all__ = ["TrainLoopConfig", "TrainLoop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    ckpt_dir: str = ""
    keep: int = 3
    microbatch: int = 0
    compress_grads: bool = False
    predicted_step_time: float = 0.0  # straggler baseline (0 = off)


class TrainLoop:
    def __init__(
        self,
        model,
        optimizer,
        batch_fn: Callable[[int], dict],
        config: TrainLoopConfig,
        *,
        jit: bool = True,
        donate: bool = True,
    ) -> None:
        self.model = model
        self.optimizer = optimizer
        self.batch_fn = batch_fn
        self.config = config
        step = make_train_step(
            model,
            optimizer,
            microbatch=config.microbatch,
            compress_grads=config.compress_grads,
        )
        if jit:
            step = jax.jit(step, donate_argnums=(0,) if donate else ())
        self.step_fn = step
        self.ckpt = (
            CheckpointManager(config.ckpt_dir, keep=config.keep)
            if config.ckpt_dir
            else None
        )
        self.straggler = StragglerDetector()
        self.history: list[dict] = []

    def init_or_resume(self, key: jax.Array) -> TrainState:
        state = init_train_state(
            self.model, self.optimizer, key, compress=self.config.compress_grads
        )
        if self.ckpt is not None:
            restored = self.ckpt.restore(state)
            if restored is not None:
                tree, meta = restored
                state = jax.tree.map(jnp.asarray, tree)
                if not isinstance(state, TrainState):
                    state = TrainState(**state) if isinstance(state, dict) else tree
        return state

    def run(self, key: jax.Array, *, on_step=None) -> TrainState:
        cfg = self.config
        state = self.init_or_resume(key)
        start = int(state.step)
        for step in range(start, cfg.total_steps):
            batch = {k: jnp.asarray(v) for k, v in self.batch_fn(step).items()}
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics.update(step=step, step_time=dt)
            self.history.append(metrics)
            if cfg.predicted_step_time > 0:
                self.straggler.observe(0, dt, cfg.predicted_step_time)
            if on_step is not None:
                on_step(step, metrics)
            if cfg.log_every and step % cfg.log_every == 0:
                print(
                    f"step {step:6d}  loss {metrics['loss']:.4f}  "
                    f"gnorm {metrics['grad_norm']:.3f}  {dt*1e3:.1f} ms"
                )
            if self.ckpt is not None and cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
                self.ckpt.save(int(state.step), state)
        if self.ckpt is not None:
            self.ckpt.save(int(state.step), state, sync=True)
        return state
