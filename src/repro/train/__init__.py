"""Training: step factory (remat/microbatch/compression) + loop."""

from .step import TrainState, make_train_step, train_state_axes
from .loop import TrainLoop, TrainLoopConfig

__all__ = [
    "TrainState",
    "make_train_step",
    "train_state_axes",
    "TrainLoop",
    "TrainLoopConfig",
]
