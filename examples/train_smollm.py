"""End-to-end training driver: smollm-135m (~100M-class) for a few
hundred steps with checkpointing, auto-resume and loss tracking.

The synthetic stream has learnable structure (hash-chain tokens), so the
loss demonstrably falls from ~ln(V) toward the noise floor.

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 300]
(Use --full on a real fleet; the reduced config keeps CPU wall time sane.)
"""

import argparse

import jax

from repro.launch.train import build_loop


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_smollm_ckpt")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    loop, _ = build_loop(
        "smollm-135m",
        full=args.full,
        steps=args.steps,
        seq_len=args.seq_len,
        batch=args.batch,
        lr=1e-3,
        ckpt_dir=args.ckpt_dir,
        log_every=20,
    )
    state = loop.run(jax.random.PRNGKey(0))

    losses = [h["loss"] for h in loop.history]
    n = max(len(losses) // 10, 1)
    first, last = sum(losses[:n]) / n, sum(losses[-n:]) / n
    print(f"\nsteps run: {len(losses)} (resumed at {loop.history[0]['step']})")
    print(f"loss: {first:.4f} -> {last:.4f}  ({100 * (1 - last / first):.1f}% reduction)")
    print(f"checkpoints in {args.ckpt_dir}: re-run to resume from step {int(state.step)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
