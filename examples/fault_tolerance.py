"""k-fault-tolerant scheduling: pay watts now, survive failures later.

``PADPSFRScheduler.schedule(..., resilience=k)`` makes every accepted
combo prove — via a second Alg-2 placement sweep on the worst-case
survivor fleet — that it still meets all deadlines after *any* k device
failures.  This demo shows the whole story on one crafted instance:

1. the power-premium ladder: what k=0/1/2 resilience costs in watts;
2. the backup placement attached to a resilient plan (``plan.backup``);
3. the empirical check: seeded failure traces replayed through a live
   :class:`repro.service.SchedulerService` by the fault-injection
   simulator (``repro.service.faultsim``) — the k=1 plan records zero
   replan-window deadline misses under any single failure, while the
   k=0 plan misses every deadline on the same trace;
4. LIFO recovery: the failed device comes back and the service replans
   down to the resilient optimum again.

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""

from repro.core import FleetSpec, PADPSFRScheduler, Task, TaskVariant
from repro.service import power_premium, run_fault_injection


def _task(name):
    # Two realisations: cheap-but-wide (share 25 on the reference slice,
    # 2 W) vs fast-but-hot (share 10, 8 W).  Four wide tasks fill four
    # devices exactly, so surviving failures forces hot upgrades.
    return Task(
        name=name, period=10.0, data=20.0, init_interval=1.0,
        variants=(TaskVariant(cu=1, throughput=2.4, power=2.0),
                  TaskVariant(cu=2, throughput=6.0, power=8.0)),
    )


def main() -> int:
    fleet = FleetSpec(n_f=4, t_slr=30.0, t_cfg=1.0, name="pod-0")
    tasks = [_task(f"t{i}") for i in range(4)]

    print("== the power premium of k-fault tolerance ==")
    for k, point in power_premium(fleet, tasks, ks=(0, 1, 2)).items():
        premium = (
            f"+{point['premium_pct']:.0f}%" if point["premium_pct"] else "baseline"
        )
        print(f"  resilience={k}: power={point['power']:.1f} W ({premium})")

    print("\n== the resilient plan carries its own proof ==")
    res = PADPSFRScheduler(fleet).schedule(tasks, resilience=1)
    assert res.feasible and res.plan.backup is not None
    print(f"  primary : {len(res.plan.scripts)} device scripts on n_f={fleet.n_f}")
    print(f"  backup  : {len(res.plan.backup.scripts)} device scripts on the "
          f"{fleet.n_f - 1}-device worst-case survivor fleet "
          f"(feasible={res.plan.backup.feasible})")

    print("\n== failure injection: the guarantee, empirically ==")
    for k in (1, 0):
        for seed in range(3):
            r = run_fault_injection(
                fleet, tasks, resilience=k, n_failures=1, seed=seed
            )
            verdict = "survived" if r.survived else f"{r.total_misses} misses"
            print(f"  resilience={k} seed={seed}: {verdict}")
        if k == 1:
            print("  -- and without the guarantee:")

    print("\n== failure then recovery: back to the resilient optimum ==")
    r = run_fault_injection(
        fleet, tasks, resilience=1, n_failures=1, seed=0, recover=True
    )
    for rec in r.records:
        print(f"  {rec.event:<20} n_f={rec.n_f_after} misses={rec.misses} "
              f"power={rec.total_power:.1f}")
    assert r.survived and r.records[-1].total_power == r.initial_power
    print("\nOK: zero replan-window misses at k=1; k=0 missed on the same trace")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
