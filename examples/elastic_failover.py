"""Elastic failover: slice loss -> PADPS-FR re-plan -> resume from ckpt.

Simulates the full fault-tolerance loop on CPU:

1. Plan 3 training jobs on a 4-slice fleet; start the highest-priority
   one (reduced smollm) with checkpointing.
2. Kill a slice mid-run (heartbeat silence): the controller re-plans on
   3 slices — possibly shedding the lowest-priority job.
3. Resume training from the last checkpoint; verify the loss curve
   continues exactly where it left off.
4. Slice returns: re-plan back to the 4-slice (lower-power) placement.

Run:  PYTHONPATH=src python examples/elastic_failover.py
"""

import jax

from repro.configs import get_arch
from repro.configs.shapes import get_shape
from repro.core import FleetSpec
from repro.core.variants import JobSpec, make_task
from repro.ft import ElasticController, FleetHealth
from repro.launch.train import build_loop


def main() -> int:
    jobs = [
        JobSpec(cfg=get_arch("smollm-135m"), shape=get_shape("train_4k"),
                period_s=3600, steps_per_period=1000),
        JobSpec(cfg=get_arch("mamba2-130m"), shape=get_shape("train_4k"),
                period_s=3600, steps_per_period=800),
        JobSpec(cfg=get_arch("qwen2-vl-2b"), shape=get_shape("train_4k"),
                period_s=3600, steps_per_period=400),
    ]
    tasks = [make_task(j, chip_options=(16, 32, 64)) for j in jobs]
    fleet = FleetSpec(n_f=4, t_slr=3600.0, t_cfg=45.0)
    health = FleetHealth(4)
    ctl = ElasticController(fleet, tasks, health=health)
    print(f"initial plan ({ctl.current.plan and len(ctl.current.plan.scripts)} slices): "
          f"{ctl.current.summary(tasks)}")

    # --- training under the plan, phase 1 ---
    ckpt = "/tmp/repro_failover_ckpt"
    import shutil

    shutil.rmtree(ckpt, ignore_errors=True)
    loop, _ = build_loop("smollm-135m", steps=30, seq_len=64, batch=4,
                         ckpt_dir=ckpt, log_every=0)
    loop.config.total_steps = 15  # "crash" mid-run
    loop.config.ckpt_every = 5
    loop.run(jax.random.PRNGKey(0))
    print(f"phase 1: trained to step {loop.history[-1]['step']}, "
          f"loss {loop.history[-1]['loss']:.3f}")

    # --- slice failure ---
    ev = ctl.on_slice_down(3)
    print(f"\nslice 3 DOWN -> re-plan on {ev.n_slices} slices: "
          f"feasible={ev.result.feasible} dropped={ev.dropped_tasks} "
          f"power={ev.result.total_power/1e3:.1f} kW")

    # --- resume from checkpoint on the surviving fleet ---
    loop2, _ = build_loop("smollm-135m", steps=30, seq_len=64, batch=4,
                          ckpt_dir=ckpt, log_every=0)
    loop2.run(jax.random.PRNGKey(0))
    assert loop2.history[0]["step"] == 15, "must resume, not restart"
    print(f"phase 2: resumed at step {loop2.history[0]['step']}, "
          f"finished at {loop2.history[-1]['step']}, "
          f"loss {loop2.history[-1]['loss']:.3f}")

    # --- slice recovery ---
    ev = ctl.on_slice_up(3)
    print(f"\nslice 3 UP -> re-plan on {ev.n_slices} slices: "
          f"power={ev.result.total_power/1e3:.1f} kW (back to optimum)")
    print(f"\nevents: {[e.reason for e in ctl.events]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
