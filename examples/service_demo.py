"""Scheduler-as-a-service: a day in the life of a fleet under churn.

A data center doesn't call ``schedule()`` once — it sees a continuous
stream of task arrivals, exits, and device failures.  This demo drives
:class:`repro.service.SchedulerService` through such a trace and prints
the per-event telemetry: which latency tier handled each event
(``admission`` filter / plan ``cache`` / ``warm`` arrival replan /
``warm_exit`` and ``warm_failure`` projections / ``general`` re-solve),
how long it took, and what the live plan looks like afterwards, plus a
closing per-path breakdown with the state re-record count.

The service records exhaustive replan state on each solve, so a task
arrival warm-starts the Alg-1 walk from the previous plan (surviving
branch-and-bound frontier + previous winner as incumbent bound) instead
of re-enumerating — see ``docs/architecture.md`` ("the replan
lifecycle") and ``benchmarks/scheduler_scale.py`` for the cold-vs-warm
numbers.

Run:  PYTHONPATH=src python examples/service_demo.py
"""

from repro.core import FleetSpec, Task, TaskVariant
from repro.service import DeviceFailure, SchedulerService, TaskArrival, TaskExit


def _task(name, period, data, ii, *variants):
    return Task(
        name=name, period=period, data=data, init_interval=ii,
        variants=tuple(TaskVariant(cu=1, throughput=t, power=p)
                       for t, p in variants),
    )


def main() -> int:
    fleet = FleetSpec(n_f=3, t_slr=30.0, t_cfg=1.0, name="pod-0")
    svc = SchedulerService(fleet, engine="numpy")

    trace = [
        TaskArrival(_task("cam0", 10.0, 20.0, 1.0, (2.0, 5.0), (4.0, 8.0))),
        TaskArrival(_task("fft", 10.0, 40.0, 1.0, (4.0, 4.0), (8.0, 6.0))),
        TaskArrival(_task("crypt", 10.0, 30.0, 1.0, (6.0, 3.0), (12.0, 9.0))),
        # hopeless demand: rejected by the closed-form eq-7 admission filter
        TaskArrival(_task("giant", 10.0, 9000.0, 1.0, (2.0, 1.0))),
        TaskExit("crypt"),
        # same task set as two events ago -> plan-cache hit
        TaskArrival(_task("crypt", 10.0, 30.0, 1.0, (6.0, 3.0), (12.0, 9.0))),
        DeviceFailure(),
        TaskExit("cam0"),
    ]

    print(f"fleet: {fleet.n_f} devices, t_slr={fleet.t_slr}, t_cfg={fleet.t_cfg}")
    print()
    hdr = f"{'event':<22} {'tier':<10} {'ok':<4} {'ms':>8}  outcome"
    print(hdr)
    print("-" * len(hdr))
    for ev in trace:
        tel = svc.replay([ev])[0]
        if tel.admitted and tel.feasible:
            outcome = (f"power={tel.total_power:.1f} rank={tel.chosen_rank} "
                       f"({tel.n_tasks} tasks)")
        elif tel.admitted:
            outcome = "accepted, no feasible plan"
        else:
            outcome = f"rejected: {tel.reason}"
        print(f"{tel.event:<22} {tel.path:<10} {str(tel.admitted):<4} "
              f"{tel.latency_s * 1e3:>8.2f}  {outcome}")

    print()
    paths = [t.path for t in svc.telemetry]
    breakdown = ", ".join(f"{p}={paths.count(p)}" for p in sorted(set(paths)))
    print(f"path breakdown: {breakdown}; rerecords={svc.rerecord_count}")
    print(f"final fleet: {svc.fleet.n_f} device(s); "
          f"tasks: {[t.name for t in svc.tasks]}")
    if svc.plan is not None and svc.plan.feasible:
        print(svc.plan.summary(list(svc.tasks)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
