"""Quickstart: the paper's scheduler + the framework in five minutes.

1. Reproduce the paper's Example 1 (Table I / Fig 2) exactly.
2. Schedule REAL ML jobs (assigned architectures) on a TPU fleet with
   the same algorithm — variants generated from the roofline+power model.
3. Train a tiny model for a few steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_arch
from repro.configs.paper_examples import example1_fleet, example1_tasks
from repro.configs.shapes import get_shape
from repro.core import FleetSpec, PADPSFRScheduler, render_gantt
from repro.core.variants import JobSpec, make_task
from repro.launch.train import build_loop

# ---------------------------------------------------------------------------
print("=" * 72)
print("1. Paper Example 1 (Table I): 6 periodic hardware tasks, 4 FPGAs")
print("=" * 72)
tasks, fleet = example1_tasks(), example1_fleet()
result = PADPSFRScheduler(fleet).schedule(tasks, count_all_rejects=True)
print(result.summary(tasks))
print(render_gantt(result.plan, tasks, fleet))

# ---------------------------------------------------------------------------
print()
print("=" * 72)
print("2. Same algorithm, TPU fleet: power-aware placement of ML jobs")
print("=" * 72)
jobs = [
    JobSpec(cfg=get_arch("yi-34b"), shape=get_shape("train_4k"),
            period_s=3600, steps_per_period=500),
    JobSpec(cfg=get_arch("mamba2-130m"), shape=get_shape("train_4k"),
            period_s=1800, steps_per_period=2000),
    JobSpec(cfg=get_arch("smollm-135m"), shape=get_shape("decode_32k"),
            period_s=600, steps_per_period=4000),
]
tpu_fleet = FleetSpec(n_f=4, t_slr=3600.0, t_cfg=45.0, name="v5e-fleet")
tpu_tasks = [make_task(j, chip_options=(16, 32, 64)) for j in jobs]
for t in tpu_tasks:
    best = min(t.variants, key=lambda v: v.power)
    print(f"  {t.name}: {t.nv} variants; lowest-power {best.cu} chips "
          f"@ {best.throughput:.3g} steps/s, {best.power/1e3:.1f} kW")
res = PADPSFRScheduler(tpu_fleet).schedule(tpu_tasks)
print(res.summary(tpu_tasks))

# ---------------------------------------------------------------------------
print()
print("=" * 72)
print("3. Train a reduced smollm-135m for 20 steps on CPU")
print("=" * 72)
loop, _ = build_loop("smollm-135m", steps=20, seq_len=64, batch=4, lr=1e-3)
loop.run(jax.random.PRNGKey(0))
print(f"loss: {loop.history[0]['loss']:.3f} -> {loop.history[-1]['loss']:.3f}")
