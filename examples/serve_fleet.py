"""Power-aware scheduled serving: the paper's scheduler drives a real
serving engine.

A fleet receives three periodic inference jobs.  PADPS-FR picks the
lowest-power variant combination that meets every job's period; the
chosen slice sizes then configure actual ServeEngine instances (reduced
configs on CPU) which prefill + decode a batch to show the plan is
executable end-to-end, including a data split for a wrapped job.

Run:  PYTHONPATH=src python examples/serve_fleet.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.shapes import get_shape
from repro.core import FleetSpec, PADPSFRScheduler, render_gantt
from repro.core.variants import JobSpec, make_task
from repro.models import ExecConfig, Model
from repro.serve import ServeConfig, ServeEngine

JOBS = [
    ("smollm-135m", "decode_32k", 600.0, 2000),
    ("mamba2-130m", "decode_32k", 600.0, 3000),
    ("recurrentgemma-2b", "long_500k", 1200.0, 1500),
]


def main() -> int:
    # --- 1. plan the fleet ---
    jobs = [
        JobSpec(cfg=get_arch(a), shape=get_shape(s), period_s=p, steps_per_period=n)
        for a, s, p, n in JOBS
    ]
    fleet = FleetSpec(n_f=3, t_slr=600.0, t_cfg=30.0, name="serve-fleet")
    tasks = [make_task(j, chip_options=(8, 16, 32)) for j in jobs]
    result = PADPSFRScheduler(fleet).schedule(tasks)
    print(result.summary(tasks))
    if not result.feasible:
        return 1
    print(render_gantt(result.plan, tasks, fleet))

    # --- 2. execute the plan: one engine per job at its chosen variant ---
    for (arch, _s, _p, _n), task, j in zip(JOBS, tasks, result.combo.variant_idx, strict=True):
        variant = task.variants[j]
        cfg = get_arch(arch).reduced()
        model = Model(cfg, ExecConfig(remat="none"))
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, ServeConfig(max_len=48))
        batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
        out = engine.generate(batch, 8)
        print(
            f"  {task.name}: scheduled on {variant.cu}-chip slice "
            f"({variant.power/1e3:.1f} kW) -> generated {out.shape} tokens OK"
        )

    # --- 3. the split job's input stream divides by share ratio ---
    for sp in result.plan.splits:
        ratio = ":".join(f"{r:.2f}" for r in sp.ratio)
        print(f"  split: {tasks[sp.task].name} wraps across slices "
              f"{[d + 1 for d in sp.devices]} — request stream divided {ratio}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
