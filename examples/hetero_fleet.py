"""Heterogeneous-fleet scheduling: PADPS-FR on a mixed FPGA/GPU/CPU floor.

The source paper schedules a homogeneous FPGA fleet; real data-center
floors mix device classes with very different reconfiguration economics
(arXiv:2304.04488): an FPGA pays a bitstream load per placement, a GPU a
kernel launch, a CPU nothing — and effective capacities differ
(arXiv:1908.06519).  This example plans the same periodic task set on

  * an all-FPGA fleet,
  * a mixed fleet of equal device count,

and shows how the near-zero t_cfg of the GPU/CPU devices changes which
variant combination wins and where the DP-wrap split lands.  The Alg-2
block placement runs through the pluggable backend registry
(``engine="auto"`` here: the jit'd jax sweep when jax is installed, the
zero-dependency numpy engine otherwise — every backend is bit-identical).

Run:  PYTHONPATH=src python examples/hetero_fleet.py
"""

from repro.configs.paper_examples import example1_tasks
from repro.core import (
    FleetSpec,
    PADPSFRScheduler,
    available_backends,
    render_gantt,
)
from repro.core.variants import make_hetero_fleet


def main() -> int:
    tasks = example1_tasks()

    fpga_fleet = FleetSpec(n_f=4, t_slr=60.0, t_cfg=6.0, name="all-fpga")
    mixed_fleet = make_hetero_fleet(
        {"fpga": 2, "gpu": 1, "cpu": 1}, t_slr=60.0, name="fpga+gpu+cpu"
    )

    print(f"placement backends available here: {', '.join(available_backends())}")
    print()
    for fleet in (fpga_fleet, mixed_fleet):
        sched = PADPSFRScheduler(fleet, engine="auto")
        print(f"=== {fleet.name} "
              f"(capacity={fleet.capacity:g}, t_cfg range "
              f"[{fleet.t_cfg_min:g}, {fleet.t_cfg_max:g}]; "
              f"engine={sched.engine}) ===")
        result = sched.schedule(tasks, count_all_rejects=True)
        print(result.summary(tasks))
        if result.feasible:
            print(render_gantt(result.plan, tasks, fleet))
        print()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
