"""Roofline report: render experiments/dryrun.json as the §Roofline table."""

from __future__ import annotations

import json
import os

from .util import Row

__all__ = ["bench_roofline_report", "render_table", "load_results"]

_DEFAULT = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun.json")


def load_results(path: str = _DEFAULT) -> list[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return []


def render_table(rows: list[dict], mesh: str = "single") -> str:
    """Markdown roofline table for one mesh."""
    out = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "bottleneck | useful-FLOPs frac | roofline MFU | args/dev (GB) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "SKIP":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |"
            )
            continue
        if r.get("status") != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: {r.get('error','?')} |")
            continue
        out.append(
            "| {arch} | {shape} | {c:.2f} | {m:.2f} | {x:.2f} | {b} | "
            "{u:.2f} | {mfu:.4f} | {gb:.2f} |".format(
                arch=r["arch"],
                shape=r["shape"],
                c=r["compute_s"] * 1e3,
                m=r["memory_s"] * 1e3,
                x=r["collective_s"] * 1e3,
                b=r["bottleneck"],
                u=r["useful_flops_frac"],
                mfu=r["mfu"],
                gb=r["arg_bytes"] / 1e9,
            )
        )
    return "\n".join(out)


def bench_roofline_report() -> list[Row]:
    rows = load_results()
    ok = [r for r in rows if r.get("status") == "OK"]
    skip = [r for r in rows if r.get("status") == "SKIP"]
    if not ok:
        return [Row("roofline_report", 0.0, "no dryrun.json — run repro.launch.dryrun --all")]
    by_bottleneck: dict[str, int] = {}
    for r in ok:
        by_bottleneck[r["bottleneck"]] = by_bottleneck.get(r["bottleneck"], 0) + 1
    worst = min(
        (r for r in ok if r["shape"] == "train_4k" and r["mesh"] == "single"),
        key=lambda r: r["mfu"],
        default=None,
    )
    derived = (
        f"cells_ok={len(ok)};skips={len(skip)};bottlenecks={by_bottleneck};"
        + (f"worst_train_mfu={worst['arch']}:{worst['mfu']:.4f}" if worst else "")
    )
    return [Row("roofline_report", 0.0, derived)]
