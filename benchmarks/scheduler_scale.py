"""Beyond-paper scheduler engineering: scaling benchmarks.

* vectorised Alg-1 (numpy outer-sum) vs the paper's nested-loop
  enumeration, at growing |TSS|;
* branch-and-bound streaming search (no TSS materialisation) on
  instances where the exhaustive product would not fit in memory.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import FleetSpec, PADPSFRScheduler, Task, TaskVariant, search_feasible
from repro.core.feasibility import iter_feasible_pruned

from .util import Row, timeit

__all__ = ["bench_scheduler_scale"]


def _synth_tasks(n_t: int, nv: int, seed: int = 0) -> list[Task]:
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n_t):
        ths = np.sort(rng.uniform(0.5, 4.0, nv))
        pws = np.sort(rng.uniform(3.0, 9.0, nv))
        tasks.append(
            Task(
                name=f"S{i}",
                period=float(rng.uniform(50, 100)),
                data=float(rng.uniform(20, 60)),
                init_interval=float(rng.uniform(1, 5)),
                variants=tuple(
                    TaskVariant(cu=j + 1, throughput=float(t), power=float(p))
                    for j, (t, p) in enumerate(zip(ths, pws))
                ),
            )
        )
    return tasks


def _loop_enumeration(tasks, fleet) -> int:
    """The paper's Alg-1 as written: nested loops over the product."""
    shares = [t.shares(fleet.t_slr) for t in tasks]
    budget = fleet.workable_budget(len(tasks))
    n_fit = 0
    for combo in itertools.product(*[range(t.nv) for t in tasks]):
        s = sum(shares[i][j] for i, j in enumerate(combo))
        if s <= budget + 1e-9:
            n_fit += 1
    return n_fit


def bench_scheduler_scale() -> list[Row]:
    rows = []
    fleet = FleetSpec(n_f=8, t_slr=80.0, t_cfg=4.0)

    for n_t, nv in [(6, 4), (8, 4), (10, 4)]:  # |TSS| = 4k, 65k, 1M
        tasks = _synth_tasks(n_t, nv)
        us_vec = timeit(lambda: search_feasible(tasks, fleet), repeat=3)
        if nv**n_t <= 70_000:
            us_loop = timeit(lambda: _loop_enumeration(tasks, fleet), repeat=1)
            speedup = f"{us_loop / us_vec:.0f}x"
        else:
            us_loop, speedup = float("nan"), "loop-skipped"
        rows.append(
            Row(
                f"alg1_vectorized_tss{nv**n_t}", us_vec,
                f"paper_loop_us={us_loop:.0f};speedup={speedup}",
            )
        )

    # streaming engine on an instance with |TSS| = 8^12 ≈ 6.9e10 (cannot
    # materialise): time-to-first-feasible in power order
    big = _synth_tasks(12, 8, seed=1)
    big_fleet = FleetSpec(n_f=16, t_slr=120.0, t_cfg=3.0)

    def first_feasible():
        return next(iter(iter_feasible_pruned(big, big_fleet)))

    us = timeit(first_feasible, repeat=3)
    rows.append(
        Row("alg1_branch_and_bound_tss6.9e10", us,
            "streams lowest-power TFS without materialising TSS")
    )

    # end-to-end schedule at scale (streaming engine auto-selected)
    sched = PADPSFRScheduler(big_fleet, exhaustive=False)
    us = timeit(lambda: sched.schedule(big), repeat=3)
    res = sched.schedule(big)
    rows.append(
        Row("padpsfr_schedule_12tasks_8variants", us,
            f"feasible={res.feasible};power={res.total_power:.1f}")
    )
    return rows
