"""Beyond-paper scheduler engineering: scaling benchmarks.

* vectorised Alg-1 (numpy outer-sum) vs the paper's nested-loop
  enumeration, at growing |TSS|;
* batched Alg-2 placement (vectorized TFS blocks) vs the scalar
  one-combo-at-a-time walk, at growing |TFS|;
* heterogeneous-fleet scheduling (mixed FPGA/GPU/CPU device classes)
  at growing fleet sizes;
* branch-and-bound streaming search (no TSS materialisation) on
  instances where the exhaustive product would not fit in memory;
* placement-backend sweep (numpy vs jax vs pallas block engines) at
  growing |TFS| block sizes, reporting per-backend rows/s and the
  numpy<->jax crossover point into the BENCH JSON;
* enumeration-throughput sweep: the PR-2 Python-heap streamer
  (``iter_feasible_pruned``) vs the block-native enumerator
  (``iter_feasible_pruned_blocks``), rows/s each;
* deep-rank streaming schedule: an instance whose winner sits >= 1e5
  rows into the TFS, walked end-to-end by the PR-2 path
  (heap + per-row combos through ``select_lowest_power_batched``) and
  by the block-native pipeline — with the per-phase WalkStats
  breakdown (enumerate / place / sync / materialize) and the adaptive
  block-ramp sizes recorded in the JSON artifact;
* delta replanning (service steady state): a task arrives on the
  deep-rank instance after an exhaustively recorded solve — warm
  ``replan()`` (recorded verdicts + resumable frontier) vs cold
  ``schedule()`` of the extended set, bit-identity asserted, cold/warm
  microseconds and the speedup recorded as ``replan_cold_*`` /
  ``replan_warm_*`` rows plus a ``replan`` JSON section;
* k-fault-tolerant scheduling: the crafted premium-ladder instance at
  ``resilience=0,1,2``, with each level's power premium over the
  unconstrained baseline recorded as ``resilience_k*`` rows plus a
  ``resilience`` JSON section, and the guarantee verified by replaying
  seeded failure traces through the fault-injection simulator
  (``repro.service.faultsim``);
* churn (warm removals + live state): the deep-rank instance's warm
  task-exit and device-failure replans vs cold ``schedule()`` of the
  post-event instance (bit-identity asserted, >= 4x target), plus a
  200-event seeded arrival/exit/failure/recovery trace through a live
  ``SchedulerService`` with the staleness-bounded re-record policy —
  warm-hit rate, per-event-kind latency, and solved-events/s vs an
  all-cold baseline, recorded as ``churn_*`` rows plus a ``churn``
  JSON section.

CLI (the CI benchmark-smoke job):

    PYTHONPATH=src python -m benchmarks.scheduler_scale --quick \
        --json BENCH_scheduler_scale.json

    # backend sweep only, explicit engines:
    PYTHONPATH=src python -m benchmarks.scheduler_scale --quick \
        --backends numpy,jax --json BENCH_scheduler_scale.json
"""

from __future__ import annotations

import argparse
import itertools
import json
import sys

import numpy as np

from repro.core import (
    DeviceProfile,
    FleetSpec,
    PADPSFRScheduler,
    Task,
    TaskVariant,
    WalkStats,
    available_backends,
    get_backend,
    place_batch,
    place_combo,
    search_feasible,
)
from repro.core.feasibility import iter_feasible_pruned, iter_feasible_pruned_blocks
from repro.core.scheduler import select_lowest_power_batched
from repro.core.variants import make_hetero_fleet

from .util import Row, timeit

__all__ = [
    "bench_scheduler_scale",
    "bench_backend_sweep",
    "bench_enumeration_sweep",
    "bench_streaming_deep",
    "bench_replan",
    "bench_fleet_parallel",
    "bench_resilience",
    "bench_churn",
    "main",
]


def _synth_tasks(n_t: int, nv: int, seed: int = 0) -> list[Task]:
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n_t):
        ths = np.sort(rng.uniform(0.5, 4.0, nv))
        pws = np.sort(rng.uniform(3.0, 9.0, nv))
        tasks.append(
            Task(
                name=f"S{i}",
                period=float(rng.uniform(50, 100)),
                data=float(rng.uniform(20, 60)),
                init_interval=float(rng.uniform(1, 5)),
                variants=tuple(
                    TaskVariant(cu=j + 1, throughput=float(t), power=float(p))
                    for j, (t, p) in enumerate(zip(ths, pws, strict=True))
                ),
            )
        )
    return tasks


def _loop_enumeration(tasks, fleet) -> int:
    """The paper's Alg-1 as written: nested loops over the product."""
    shares = [t.shares(fleet.t_slr) for t in tasks]
    budget = fleet.workable_budget(len(tasks))
    n_fit = 0
    for combo in itertools.product(*[range(t.nv) for t in tasks]):
        s = sum(shares[i][j] for i, j in enumerate(combo))
        if s <= budget + 1e-9:
            n_fit += 1
    return n_fit


def bench_alg2_batched_vs_scalar(quick: bool = False) -> list[Row]:
    """Batched TFS placement sweeps vs the scalar one-row-at-a-time walk.

    The acceptance target: >= 10x over the scalar walk at |TFS| >= 1e4.
    """
    rows = []
    fleet = FleetSpec(n_f=8, t_slr=80.0, t_cfg=4.0)
    sizes = [(6, 4), (7, 4)] if quick else [(6, 4), (7, 4), (8, 4)]
    for n_t, nv in sizes:  # |TSS| = 4k, 16k, 65k
        tasks = _synth_tasks(n_t, nv)
        feas = search_feasible(tasks, fleet)
        order = feas.tfs_indices_by_power()
        iis = [t.init_interval for t in tasks]
        shares = feas.shares_matrix(order)

        def batched_walk():
            return place_batch(shares, iis, fleet).n_feasible

        def scalar_walk():
            n = 0
            for fi in order:
                if place_combo(feas.combo_at(int(fi)), tasks, fleet).feasible:
                    n += 1
            return n

        n_placed = batched_walk()
        us_batched = timeit(batched_walk, repeat=3)
        us_scalar = timeit(scalar_walk, repeat=1, warmup=0)
        rows.append(
            Row(
                f"alg2_batched_tfs{order.size}",
                us_batched,
                f"scalar_us={us_scalar:.0f};speedup={us_scalar / us_batched:.0f}x"
                f";placed={n_placed}",
            )
        )
    return rows


def bench_backend_sweep(
    quick: bool = False, backends: list[str] | None = None
) -> tuple[list[Row], dict]:
    """Per-backend block-placement throughput at growing |TFS| block sizes.

    One synthetic (B, n_t) shares block per size (mixed feasible /
    infeasible rows around the fleet's capacity), handed whole to each
    backend's ``place_block``.  Returns CSV rows plus a JSON-able summary
    with per-backend rows/s and the numpy<->jax crossover block size (the
    smallest B where the jit'd jax sweep beats the numpy loop — below it
    the numpy engine's lower fixed overhead wins).
    """
    n_t, n_f = 8, 8
    fleet = FleetSpec(n_f=n_f, t_slr=80.0, t_cfg=4.0)
    rng = np.random.default_rng(3)
    iis = rng.uniform(1.0, 5.0, n_t)
    sizes = [1_000, 10_000, 100_000] if quick else [1_000, 10_000, 100_000, 1_000_000]
    if backends is None:
        # scalar is O(B) Python round-trips — pointless past a few 1e3 rows.
        backends = [b for b in available_backends() if b != "scalar"]
    rows: list[Row] = []
    us: dict[str, dict[int, float]] = {b: {} for b in backends}
    for B in sizes:
        base = rng.uniform(0.5, 1.5, (B, n_t))
        scale = rng.uniform(0.4, 1.3, (B, 1)) * fleet.capacity / n_t
        shares = base * scale
        for name in backends:
            backend = get_backend(name)

            def run():
                return backend.place_block(
                    shares, iis, fleet.t_slr_arr, fleet.t_cfg_arr
                )

            n_feasible = run().n_feasible  # warms jit/pallas caches too
            t_us = timeit(run, repeat=3)
            us[name][B] = t_us
            rows.append(
                Row(
                    f"backend_{name}_rows{B}",
                    t_us,
                    f"rows_per_s={B / t_us * 1e6:.0f};feasible={n_feasible}",
                )
            )
    crossover = None
    if "numpy" in us and "jax" in us:
        for B in sizes:
            if us["jax"][B] < us["numpy"][B]:
                crossover = B
                break
    sweep = {
        "n_t": n_t,
        "n_f": n_f,
        "sizes": sizes,
        "us": {b: {str(B): v for B, v in d.items()} for b, d in us.items()},
        "rows_per_s": {
            b: {str(B): B / v * 1e6 for B, v in d.items()} for b, d in us.items()
        },
        # Smallest block size where the jax sweep overtakes the numpy loop
        # (None: jax never won, or one of the two engines was not swept).
        "numpy_jax_crossover_rows": crossover,
    }
    return rows, sweep


def _band_tasks(
    n_t: int,
    nv: int,
    seed: int = 7,
    base: float = 86.0,
    slope: float = 5.0,
    noise: float = 1.0,
    ii: tuple[float, float] = (8.0, 16.0),
) -> list[Task]:
    """Tasks whose shares decrease near-affinely with power.

    Low power => low throughput => high share (the paper's CU scaling),
    made near-deterministic: total share crosses the fleet capacity as
    total power rises, so the power-sorted TFS opens with a long band of
    rows that pass eq. 7 but fail placement (fragmentation: t_cfg=0
    fleets waste capacity on II repayments and leftovers).  The winner
    lands 1e5+ rows deep — the streaming-walk stress regime.
    """
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n_t):
        pws = np.sort(rng.uniform(3.0, 9.0, nv))
        shr = np.maximum(base - slope * pws + rng.uniform(0, noise, nv), 0.5)
        period, data, t_slr = 50.0, 1.0, 100.0
        ths = data * t_slr / (period * shr)
        tasks.append(
            Task(
                name=f"B{i}",
                period=period,
                data=data,
                init_interval=float(rng.uniform(*ii)),
                variants=tuple(
                    TaskVariant(cu=j + 1, throughput=float(t), power=float(p))
                    for j, (t, p) in enumerate(zip(ths, pws, strict=True))
                ),
            )
        )
    return tasks


def _deep_instance(quick: bool) -> tuple[list[Task], FleetSpec]:
    n_t = 9 if quick else 10
    tasks = _band_tasks(n_t, 4, base=86.0 if not quick else 78.0)
    fleet = FleetSpec(n_f=6 if not quick else 5, t_slr=100.0, t_cfg=0.0)
    return tasks, fleet


def bench_enumeration_sweep(quick: bool = False) -> tuple[list[Row], dict]:
    """TFS enumeration throughput: Python heap vs block-native arrays.

    Streams the first N power-ordered TFS rows of the deep-band instance
    through ``iter_feasible_pruned`` (one TaskSetCombo per row, PR-2) and
    ``iter_feasible_pruned_blocks`` (whole (B, n_t) array blocks), and
    reports rows/s for both plus the speedup — the Python-object churn
    the block-native walk removed from the scheduler's hot path.
    """
    tasks, fleet = _deep_instance(quick)
    target = 50_000 if quick else 200_000

    def heap_rows() -> int:
        n = 0
        for _ in iter_feasible_pruned(tasks, fleet):
            n += 1
            if n >= target:
                break
        return n

    def block_rows() -> int:
        n = 0
        for blk in iter_feasible_pruned_blocks(tasks, fleet, 65536):
            n += len(blk)
            if n >= target:
                break
        return n

    # Both engines warmed once by the row-count calls, then median-of-3
    # each — symmetric methodology so the speedup compares like with like.
    n_heap = heap_rows()
    n_block = block_rows()
    us_heap = timeit(heap_rows, repeat=3, warmup=0)
    us_block = timeit(block_rows, repeat=3, warmup=0)
    heap_rps = n_heap / us_heap * 1e6
    block_rps = n_block / us_block * 1e6
    rows = [
        Row(
            f"enum_python_heap_rows{target}",
            us_heap,
            f"rows_per_s={heap_rps:.0f}",
        ),
        Row(
            f"enum_block_native_rows{target}",
            us_block,
            f"rows_per_s={block_rps:.0f};speedup={us_heap / us_block:.1f}x",
        ),
    ]
    sweep = {
        "target_rows": target,
        "heap_us": us_heap,
        "block_us": us_block,
        "heap_rows_per_s": heap_rps,
        "block_rows_per_s": block_rps,
        "speedup": us_heap / us_block,
    }
    return rows, sweep


def bench_streaming_deep(quick: bool = False) -> tuple[list[Row], dict]:
    """End-to-end deep-rank streaming schedule: PR-2 path vs block-native.

    Both walks use the same numpy placement backend and produce the
    identical winner/rank (asserted); the PR-2 baseline pays the Python
    heap + per-row combo materialisation, the block-native path streams
    ComboBlock arrays on the adaptive ramp with pipelined dispatch.  The
    JSON gets the per-phase WalkStats breakdown and the ramp sizes.
    """
    tasks, fleet = _deep_instance(quick)
    sched = PADPSFRScheduler(fleet, exhaustive=False)

    stats = WalkStats()
    res = sched.schedule(tasks, walk_stats=stats)

    def block_native():
        return sched.schedule(tasks)

    def pr2_path():
        return select_lowest_power_batched(
            iter_feasible_pruned(tasks, fleet), tasks, fleet, block_size=4096
        )

    # The parity assertions above warm both walks once; both are then
    # median-of-3 so the published speedup is symmetrically measured.
    combo_old, _, rank_old, _ = pr2_path()
    assert res.feasible and rank_old == res.chosen_rank and combo_old == res.combo
    us_new = timeit(block_native, repeat=3, warmup=0)
    us_old = timeit(pr2_path, repeat=3, warmup=0)
    tag = f"{len(tasks)}t{tasks[0].nv}v_rank{res.chosen_rank}"
    rows = [
        Row(
            f"padpsfr_stream_pr2path_{tag}",
            us_old,
            f"rank={rank_old};python-heap + per-row combos",
        ),
        Row(
            f"padpsfr_stream_blocknative_{tag}",
            us_new,
            f"rank={res.chosen_rank};speedup={us_old / us_new:.1f}x",
        ),
    ]
    streaming = {
        "instance": tag,
        "chosen_rank": res.chosen_rank,
        "rows_walked": stats.rows,
        "pr2_us": us_old,
        "blocknative_us": us_new,
        "speedup": us_old / us_new,
        "phase_breakdown": stats.as_dict(),
    }
    return rows, streaming


def bench_replan(quick: bool = False) -> tuple[list[Row], dict]:
    """Service steady state: warm delta replan vs cold ``schedule()``.

    The deep-rank streaming instance is solved once with exhaustive
    recording (``record_state=True, record_exhaustive=True`` — the
    service layer's first solve, every TFS row gets a placement
    verdict), then a light task arrives.  The warm replan reuses the
    recorded verdicts (reject monotonicity skips dispatch for every
    recorded reject) and must produce a plan bit-identical to a cold
    ``schedule()`` of the extended set — asserted here, not just
    claimed.  Acceptance: warm ≥ 10x under cold on the full instance.
    """
    tasks, fleet = _deep_instance(quick)
    sched = PADPSFRScheduler(fleet, exhaustive=False)

    def record():
        return sched.schedule(tasks, record_state=True, record_exhaustive=True)

    rec = record()
    state = rec.plan_state
    arrival = Task(
        name="arrival",
        period=10.0,
        data=25.0,
        init_interval=0.5,
        variants=(
            TaskVariant(cu=1, throughput=5.0, power=1.0),
            TaskVariant(cu=2, throughput=10.0, power=2.5),
        ),
    )
    extended = list(tasks) + [arrival]

    warm_res = sched.replan(state, extended)
    cold_res = sched.schedule(extended)
    identical = (
        warm_res.feasible == cold_res.feasible
        and warm_res.chosen_rank == cold_res.chosen_rank
        and warm_res.n_placement_rejects == cold_res.n_placement_rejects
        and warm_res.total_power == cold_res.total_power
        and (
            not cold_res.feasible
            or (
                warm_res.combo.variant_idx == cold_res.combo.variant_idx
                and str(warm_res.plan) == str(cold_res.plan)
            )
        )
    )
    assert identical, "warm replan diverged from cold schedule"

    us_record = timeit(record, repeat=1, warmup=0)
    us_warm = timeit(lambda: sched.replan(state, extended), repeat=3, warmup=0)
    us_cold = timeit(lambda: sched.schedule(extended), repeat=3, warmup=0)
    tag = f"{len(extended)}t_arrival_rank{cold_res.chosen_rank}"
    speedup = us_cold / us_warm
    rows = [
        Row(
            f"replan_cold_{tag}",
            us_cold,
            f"rank={cold_res.chosen_rank};from-scratch schedule()",
        ),
        Row(
            f"replan_warm_{tag}",
            us_warm,
            f"rank={warm_res.chosen_rank};speedup={speedup:.1f}x"
            f";bit_identical={identical}",
        ),
    ]
    replan_summary = {
        "instance": tag,
        "chosen_rank": cold_res.chosen_rank,
        "record_us": us_record,
        "cold_us": us_cold,
        "warm_us": us_warm,
        "speedup": speedup,
        "bit_identical": identical,
        "recorded_rows": state.n_recorded,
    }
    return rows, replan_summary


def bench_fleet_parallel(
    quick: bool = False, backends: list[str] | None = None
) -> tuple[list[Row], dict]:
    """Fleet-parallel batch scheduling: ``schedule_many`` vs a schedule() loop.

    B independent deep-band instances (each winner ~1e3-1e4 rows into its
    power-ordered TFS) are solved two ways per backend: a Python loop of
    solo ``schedule()`` calls, and one ``schedule_many(instances)`` batched
    lockstep walk.  Per-instance results are asserted bit-identical
    (feasibility, winning rank, total power) before anything is timed, and
    both legs get one full untimed pass first so jit compilation for every
    block shape lands outside the measurement.

    Acceptance target: the vmapped jax backend >= 5x instances/s over the
    solo loop at B=64 identically-shaped instances.

    A third ``shard="auto"`` leg (jax backend, largest B) times the
    ``shard_map`` device layout when the host has >1 jax device; on a
    single-device host it degrades to the plain vmap, so the leg is
    recorded as skipped with a note instead of timing a duplicate.
    """
    from repro.core.scheduler import ScheduleInstance

    fleet = FleetSpec(n_f=4, t_slr=100.0, t_cfg=0.0)
    notes: dict[str, str] = {"scalar": "no batched dispatch surface; excluded"}
    if backends is None:
        backends = [b for b in available_backends() if b != "scalar"]
    else:
        backends = [b for b in backends if b != "scalar"]
    if "pallas" in backends:
        from repro.kernels.ops import on_tpu

        if not on_tpu():
            backends = [b for b in backends if b != "pallas"]
            notes["pallas"] = (
                "interpret mode off-TPU: parity-tested, not a throughput engine"
            )
    sizes = [64] if quick else [8, 64]
    points = [
        (name, B)
        for name in backends
        for B in sizes
        # numpy's solo loop at B=64 costs ~15 s in the smoke job; its
        # batched win is still visible at B=8 there.
        if not (quick and name == "numpy" and B > 8)
    ]
    if quick and "numpy" in backends:
        points = [("numpy", 8)] + points

    rows: list[Row] = []
    summary: dict = {
        "n_t": 7,
        "nv": 4,
        "fleet_n_f": fleet.n_f,
        "block_size": 16,
        "points": {},
        "notes": notes,
    }
    for name, B in points:
        insts = [
            ScheduleInstance(
                tasks=_band_tasks(
                    7, 4, seed=100 + s, base=84.0, slope=5.0, ii=(8.0, 16.0)
                )
            )
            for s in range(B)
        ]
        sched = PADPSFRScheduler(fleet, engine=name, block_size=16)

        def loop():
            return [sched.schedule(list(i.tasks)) for i in insts]

        def many():
            return sched.schedule_many(insts)

        # Full warmup pass of BOTH legs: compiles every block shape the
        # walks reach (including partial tails), and doubles as the
        # bit-identity reference.
        ref = loop()
        got = many()
        _assert_instancewise_identical(ref, got, f"{name} B={B}")
        us_loop = timeit(loop, repeat=1 if quick else 2, warmup=0)
        us_many = timeit(many, repeat=3, warmup=0)
        speedup = us_loop / us_many
        n_feas = sum(r.feasible for r in ref)
        rows.append(
            Row(
                f"fleet_parallel_{name}_B{B}_loop",
                us_loop,
                f"inst_per_s={B / us_loop * 1e6:.1f};solo schedule() x{B}",
            )
        )
        rows.append(
            Row(
                f"fleet_parallel_{name}_B{B}_many",
                us_many,
                f"inst_per_s={B / us_many * 1e6:.1f};speedup={speedup:.2f}x"
                f";feasible={n_feas};bit_identical=True",
            )
        )
        summary["points"][f"{name}_B{B}"] = {
            "backend": name,
            "B": B,
            "loop_us": us_loop,
            "many_us": us_many,
            "speedup": speedup,
            "inst_per_s_loop": B / us_loop * 1e6,
            "inst_per_s_many": B / us_many * 1e6,
            "n_feasible": n_feas,
            "bit_identical": True,
        }
        if name == "jax" and B == max(sizes):
            from repro.core.placement_backends.jax_backend import resolve_shard

            n_shards = resolve_shard("auto", B)
            if n_shards <= 1:
                summary["shard"] = {
                    "n_shards": 1,
                    "skipped": True,
                    "note": "single jax device: shard='auto' degrades to "
                    "the plain vmap, so the leg would duplicate _many",
                }
            else:

                def many_shard():
                    return sched.schedule_many(insts, shard="auto")

                got_shard = many_shard()
                _assert_instancewise_identical(
                    ref, got_shard, f"{name} B={B} shard=auto"
                )
                us_shard = timeit(many_shard, repeat=3, warmup=0)
                rows.append(
                    Row(
                        f"fleet_parallel_{name}_B{B}_shard{n_shards}",
                        us_shard,
                        f"inst_per_s={B / us_shard * 1e6:.1f}"
                        f";speedup={us_loop / us_shard:.2f}x"
                        f";devices={n_shards};bit_identical=True",
                    )
                )
                summary["shard"] = {
                    "n_shards": n_shards,
                    "skipped": False,
                    "us": us_shard,
                    "speedup": us_loop / us_shard,
                    "bit_identical": True,
                }
    return rows, summary


def bench_resilience(quick: bool = False) -> tuple[list[Row], dict]:
    """The power premium of k-fault tolerance, verified by fault injection.

    One crafted homogeneous instance is scheduled at ``resilience=0,1,2``;
    each level's winning power and its premium over the unconstrained
    baseline land as ``resilience_k*`` rows.  The guarantee is then
    checked empirically, not just claimed: ``run_fault_injection``
    replays seeded ``DeviceFailure`` traces through a live
    ``SchedulerService`` and asserts that the k=1 / k=2 plans record
    zero replan-window deadline misses under any k failures while the
    k=0 plan misses on the very same trace.
    """
    from repro.service.faultsim import run_fault_injection

    fleet = FleetSpec(n_f=4, t_slr=30.0, t_cfg=1.0)
    # Two variants per task: cheap-but-wide (share 25, 2 W) and
    # fast-but-hot (share 10, 8 W).  Four share-25 tasks fill four
    # devices exactly, so every survivor level forces hot upgrades —
    # the premium ladder is structural, not noise.
    tasks = [
        Task(
            name=f"R{i}",
            period=10.0,
            data=20.0,
            init_interval=1.0,
            variants=(
                TaskVariant(cu=1, throughput=2.4, power=2.0),
                TaskVariant(cu=2, throughput=6.0, power=8.0),
            ),
        )
        for i in range(4)
    ]
    sched = PADPSFRScheduler(fleet)
    tag = f"{len(tasks)}t{fleet.n_f}f"
    rows: list[Row] = []
    points: dict[str, dict] = {}
    base: float | None = None
    for k in (0, 1, 2):
        res = sched.schedule(tasks, resilience=k)
        us = timeit(lambda: sched.schedule(tasks, resilience=k), repeat=3)
        power = float(res.total_power) if res.feasible else None
        if k == 0:
            base = power
        premium = (
            (power - base) / base * 100.0
            if power is not None and base
            else None
        )
        premium_s = f"{premium:.0f}" if premium is not None else "n/a"
        rows.append(
            Row(
                f"resilience_k{k}_{tag}",
                us,
                f"feasible={res.feasible};power={power};"
                f"premium_pct={premium_s};rank={res.chosen_rank}",
            )
        )
        points[f"k{k}"] = {
            "feasible": bool(res.feasible),
            "power": power,
            "premium_pct": premium,
            "chosen_rank": int(res.chosen_rank),
            "us": us,
        }
    # Empirical verification: the analytic guarantee must hold on every
    # seeded trace, and must be non-vacuous (k=0 demonstrably misses).
    n_seeds = 3 if quick else 8
    k1_ok = all(
        run_fault_injection(
            fleet, tasks, resilience=1, n_failures=1, seed=s
        ).survived
        for s in range(n_seeds)
    )
    k2_ok = all(
        run_fault_injection(
            fleet, tasks, resilience=2, n_failures=2, seed=s
        ).survived
        for s in range(n_seeds)
    )
    k0 = run_fault_injection(fleet, tasks, resilience=0, n_failures=1, seed=0)
    assert k1_ok and k2_ok, "resilient plan missed a deadline under injection"
    assert not k0.survived, "k=0 baseline survived; premium would be vacuous"
    summary = {
        "instance": tag,
        "n_f": fleet.n_f,
        "n_t": len(tasks),
        "points": points,
        "faultsim": {
            "seeds": n_seeds,
            "k1_survives_all_seeds": k1_ok,
            "k2_survives_all_seeds": k2_ok,
            "k0_misses_on_failure": not k0.survived,
            "k0_misses": k0.total_misses,
        },
    }
    return rows, summary


def _churn_identical(a, b) -> bool:
    """Bit-identity between one warm and one cold schedule result."""
    return (
        a.feasible == b.feasible
        and a.chosen_rank == b.chosen_rank
        and a.n_placement_rejects == b.n_placement_rejects
        and a.total_power == b.total_power
        and (
            not b.feasible
            or (
                a.combo.variant_idx == b.combo.variant_idx
                and str(a.plan) == str(b.plan)
            )
        )
    )


def _churn_task(rng, name: str) -> Task:
    """A small random arrival for the churn trace.

    Shares fall near-affinely with power (the ``_band_tasks`` recipe,
    scaled to the trace fleet's t_slr=35): cheap variants are tempting
    but tight, so once several tasks are alive the cold walk rejects a
    band of low-power combos before its first placeable rank — exactly
    the regime where a warm re-rank of recorded rows pays off.
    """
    nv = int(rng.integers(2, 5))
    pws = np.sort(rng.uniform(3.0, 9.0, nv))
    shr = np.maximum(31.0 - 2.8 * pws + rng.uniform(0.0, 1.5, nv), 4.0)
    period = float(rng.uniform(20, 60))
    data = 1.0
    ths = data * 35.0 / (period * shr)
    return Task(
        name=name,
        period=period,
        data=data,
        init_interval=float(rng.uniform(2.0, 8.0)),
        variants=tuple(
            TaskVariant(cu=j + 1, throughput=float(t), power=float(p))
            for j, (t, p) in enumerate(zip(ths, pws, strict=True))
        ),
    )


def _eps_task(t_slr: float, name: str = "eps") -> Task:
    """One-variant task with negligible share and power.

    Appended *last* and exhaustively recorded, it makes every recorded
    reject die among the real tasks (primary-sweep depth < n-1), so a
    warm exit that drops it transfers every reject verdict and re-finds
    the deep-rank winner without dispatching a single placement.
    """
    period, share = 50.0, 1e-6
    th = t_slr / (period * share)
    return Task(
        name=name,
        period=period,
        data=1.0,
        init_interval=1.0,
        variants=(TaskVariant(cu=1, throughput=th, power=1e-6),),
    )


def _churn_deep_instance(quick: bool) -> tuple[list[Task], FleetSpec]:
    """The churn legs' deep-rank instance.

    Quick mode reuses :func:`_deep_instance`; full mode widens the band
    (``base=83``) so the winner lands ~58k rows deep — still inside the
    warm exit's phase-1 parent cap, so both removal legs measure the
    steady-state warm path rather than the full-band fallback.
    """
    if quick:
        return _deep_instance(True)
    return (
        _band_tasks(10, 4, base=83.0),
        FleetSpec(n_f=6, t_slr=100.0, t_cfg=0.0),
    )


def bench_churn(quick: bool = False) -> tuple[list[Row], dict]:
    """Warm removals + a long churn trace vs all-cold solving.

    Two measurements land in the ``churn`` JSON section:

    * **deep removals** — the deep-rank instance is exhaustively
      recorded once, then (a) an appended epsilon task exits, leaving
      exactly the deep instance, and (b) the last device of a fleet
      extended by one tiny device fails, leaving the deep fleet; each
      warm ``replan()`` is asserted bit-identical to a cold
      ``schedule()`` of the post-event instance and timed against it
      (acceptance: >= 4x).  Both legs transfer every recorded reject
      (prefix-death depths for the exit, survivor-prefix monotonicity
      for the failure), so the warm path is pure projection;
    * **churn trace** — a 200-event seeded arrival/exit/failure/recovery
      mix replayed through a live ``SchedulerService`` (numpy engine,
      staleness-bounded re-record policy on), reporting the warm-hit
      rate over solved events (acceptance: >= 0.80), mean latency per
      event kind, and solved-events/s against an all-cold baseline that
      re-solves every post-event task set from scratch.
    """
    from repro.service import SchedulerService

    rows: list[Row] = []

    # --- deep-instance warm removals -------------------------------------
    tasks, fleet = _churn_deep_instance(quick)
    sched = PADPSFRScheduler(fleet, exhaustive=False)

    # Exit leg: record tasks + eps exhaustively, then eps exits and the
    # survivors are the deep instance itself — the warm projection must
    # re-find its deep-rank winner from transferred verdicts alone.
    eps = _eps_task(fleet.t_slr)
    state = sched.schedule(
        [*tasks, eps], record_state=True, record_exhaustive=True
    ).plan_state
    warm_exit = sched.replan(state, tasks)
    cold_exit = sched.schedule(tasks)
    assert _churn_identical(warm_exit, cold_exit), "warm exit diverged"
    us_exit_warm = timeit(
        lambda: sched.replan(state, tasks), repeat=3, warmup=0
    )
    us_exit_cold = timeit(lambda: sched.schedule(tasks), repeat=3, warmup=0)

    # Failure leg: record on the deep fleet extended by one tiny device
    # (heterogeneous form, so the drop is a survivor-prefix: recorded
    # rejects transfer), then the tiny device dies and the survivor
    # fleet is the deep fleet — warm re-rank vs the deep cold walk.
    dev = DeviceProfile(t_slr=fleet.t_slr, t_cfg=fleet.t_cfg)
    tiny = DeviceProfile(t_slr=0.5, t_cfg=fleet.t_cfg)
    big_fleet = FleetSpec.heterogeneous(
        [dev] * fleet.n_f + [tiny], name="churn-het"
    )
    small_fleet = FleetSpec.heterogeneous([dev] * fleet.n_f, name="churn-het")
    big_sched = PADPSFRScheduler(big_fleet, exhaustive=False)
    small_sched = PADPSFRScheduler(small_fleet, exhaustive=False)
    big_state = big_sched.schedule(
        tasks, record_state=True, record_exhaustive=True
    ).plan_state
    warm_fail = big_sched.replan(big_state, tasks, fleet=small_fleet)
    cold_fail = small_sched.schedule(tasks)
    assert _churn_identical(warm_fail, cold_fail), "warm failure diverged"
    us_fail_warm = timeit(
        lambda: big_sched.replan(big_state, tasks, fleet=small_fleet),
        repeat=3, warmup=0,
    )
    us_fail_cold = timeit(
        lambda: small_sched.schedule(tasks), repeat=3, warmup=0
    )

    tag = f"{len(tasks)}t{fleet.n_f}f"
    rows.append(
        Row(
            f"churn_exit_cold_{tag}",
            us_exit_cold,
            f"rank={cold_exit.chosen_rank};from-scratch schedule()",
        )
    )
    rows.append(
        Row(
            f"churn_exit_warm_{tag}",
            us_exit_warm,
            f"rank={warm_exit.chosen_rank}"
            f";speedup={us_exit_cold / us_exit_warm:.1f}x;bit_identical=True",
        )
    )
    rows.append(
        Row(
            f"churn_failure_cold_{tag}",
            us_fail_cold,
            f"rank={cold_fail.chosen_rank};from-scratch schedule()",
        )
    )
    rows.append(
        Row(
            f"churn_failure_warm_{tag}",
            us_fail_warm,
            f"rank={warm_fail.chosen_rank}"
            f";speedup={us_fail_cold / us_fail_warm:.1f}x;bit_identical=True",
        )
    )

    # --- 200-event churn trace -------------------------------------------
    n_events = 200
    rng = np.random.default_rng(11)
    svc = SchedulerService(
        FleetSpec(n_f=4, t_slr=35.0, t_cfg=1.0), engine="numpy", max_stale=5
    )
    solved: list[tuple] = []  # (kind, tasks, fleet) per solved event
    kinds: list[str] = []
    counter = 0
    for _ in range(n_events):
        roll = float(rng.random())
        n_alive = len(svc.tasks)
        # Exits only fire at >= 2 alive tasks: draining the service to
        # empty would force a cold arrival-from-nothing on the next
        # submit, which measures restart cost rather than churn.
        if (roll < 0.55 and n_alive < 8) or n_alive < 2:
            kind = "arrival"
            counter += 1
            tel = svc.submit(_churn_task(rng, f"c{counter}"))
        elif roll < 0.80 and n_alive:
            kind = "exit"
            victim = svc.tasks[int(rng.integers(0, n_alive))]
            tel = svc.remove(victim.name)
        elif roll < 0.90 and svc.fleet.n_f > 1:
            kind = "failure"
            tel = svc.fail_device()
        else:
            kind = "recovery"
            tel = svc.recover_device()
        kinds.append(kind)
        if tel.path not in ("admission", "noop") and svc.tasks:
            solved.append((kind, svc.tasks, svc.fleet, tel))
    warm_hits = [
        tel
        for _, _, _, tel in solved
        if tel.path in ("cache", "warm", "warm_exit", "warm_failure")
    ]
    hit_rate = len(warm_hits) / max(1, len(solved))
    per_kind_us: dict[str, float] = {}
    per_kind_n: dict[str, int] = {}
    for kind, _, _, tel in solved:
        per_kind_us[kind] = per_kind_us.get(kind, 0.0) + tel.latency_s * 1e6
        per_kind_n[kind] = per_kind_n.get(kind, 0) + 1
    per_kind_us = {
        k: v / per_kind_n[k] for k, v in sorted(per_kind_us.items())
    }
    warm_total_us = sum(tel.latency_s for _, _, _, tel in solved) * 1e6

    # All-cold baseline: one from-scratch schedule() per solved event's
    # post-event instance (what the pre-warm service had to pay).
    cold_scheds: dict = {}
    def cold_loop() -> None:
        for _, ts, fl, _ in solved:
            if fl not in cold_scheds:
                cold_scheds[fl] = PADPSFRScheduler(fl, engine="numpy")
            cold_scheds[fl].schedule(ts)

    cold_total_us = timeit(cold_loop, repeat=1, warmup=1)
    rows.append(
        Row(
            f"churn_trace_{n_events}ev",
            warm_total_us,
            f"solved={len(solved)};warm_hit_rate={hit_rate:.2f}"
            f";rerecords={svc.rerecord_count}"
            f";cold_us={cold_total_us:.0f}"
            f";speedup={cold_total_us / warm_total_us:.1f}x",
        )
    )

    churn = {
        "deep_instance": tag,
        "exit": {
            "chosen_rank": int(cold_exit.chosen_rank),
            "cold_us": us_exit_cold,
            "warm_us": us_exit_warm,
            "speedup": us_exit_cold / us_exit_warm,
            "bit_identical": True,
        },
        "failure": {
            "chosen_rank": int(cold_fail.chosen_rank),
            "cold_us": us_fail_cold,
            "warm_us": us_fail_warm,
            "speedup": us_fail_cold / us_fail_warm,
            "bit_identical": True,
        },
        "trace": {
            "n_events": n_events,
            "n_solved": len(solved),
            "event_mix": {k: kinds.count(k) for k in sorted(set(kinds))},
            "warm_hit_rate": hit_rate,
            "rerecords": svc.rerecord_count,
            "per_kind_mean_us": per_kind_us,
            "warm_total_us": warm_total_us,
            "cold_total_us": cold_total_us,
            "events_per_s_warm": len(solved) / warm_total_us * 1e6,
            "events_per_s_cold": len(solved) / cold_total_us * 1e6,
            "speedup": cold_total_us / warm_total_us,
        },
    }
    return rows, churn


def _assert_instancewise_identical(ref, got, what: str) -> None:
    """Per-instance bit-identity between two lists of schedule results."""
    assert len(ref) == len(got), f"{what}: result count mismatch"
    for i, (a, b) in enumerate(zip(ref, got, strict=True)):
        same = (
            a.feasible == b.feasible
            and a.chosen_rank == b.chosen_rank
            and a.n_placement_rejects == b.n_placement_rejects
            and (not a.feasible or a.total_power == b.total_power)
        )
        assert same, f"{what}: instance {i} diverged from the solo loop"


def bench_hetero_fleet(quick: bool = False) -> list[Row]:
    """End-to-end PADPS-FR on mixed FPGA/GPU/CPU fleets at growing sizes."""
    rows = []
    tasks = _synth_tasks(8 if quick else 10, 4, seed=2)
    scales = [1, 2] if quick else [1, 2, 4]
    for scale in scales:
        fleet = make_hetero_fleet(
            {"fpga": 4 * scale, "gpu": 2 * scale, "cpu": 2 * scale},
            t_slr=80.0,
            name=f"mix-x{scale}",
        )
        sched = PADPSFRScheduler(fleet)
        res = sched.schedule(tasks)
        us = timeit(lambda: sched.schedule(tasks), repeat=3)
        rows.append(
            Row(
                f"padpsfr_hetero_{fleet.n_f}dev",
                us,
                f"feasible={res.feasible};power={res.total_power:.1f}"
                f";rank={res.chosen_rank}",
            )
        )
    return rows


def bench_scheduler_scale(quick: bool = False) -> list[Row]:
    rows = []
    fleet = FleetSpec(n_f=8, t_slr=80.0, t_cfg=4.0)

    sizes = [(6, 4), (8, 4)] if quick else [(6, 4), (8, 4), (10, 4)]
    for n_t, nv in sizes:  # |TSS| = 4k, 65k, 1M
        tasks = _synth_tasks(n_t, nv)
        us_vec = timeit(lambda: search_feasible(tasks, fleet), repeat=3)
        if nv**n_t <= 70_000 and not quick:
            us_loop = timeit(lambda: _loop_enumeration(tasks, fleet), repeat=1)
            speedup = f"{us_loop / us_vec:.0f}x"
        else:
            us_loop, speedup = float("nan"), "loop-skipped"
        rows.append(
            Row(
                f"alg1_vectorized_tss{nv**n_t}", us_vec,
                f"paper_loop_us={us_loop:.0f};speedup={speedup}",
            )
        )

    rows.extend(bench_alg2_batched_vs_scalar(quick))
    rows.extend(bench_hetero_fleet(quick))

    # streaming engine on an instance with |TSS| = 8^12 ≈ 6.9e10 (cannot
    # materialise): time-to-first-feasible in power order
    big = _synth_tasks(8 if quick else 12, 4 if quick else 8, seed=1)
    big_fleet = FleetSpec(n_f=16, t_slr=120.0, t_cfg=3.0)

    def first_feasible():
        return next(iter(iter_feasible_pruned(big, big_fleet)))

    us = timeit(first_feasible, repeat=3)
    rows.append(
        Row("alg1_branch_and_bound_streaming", us,
            "streams lowest-power TFS without materialising TSS")
    )

    # end-to-end schedule at scale (streaming engine, batched blocks)
    sched = PADPSFRScheduler(big_fleet, exhaustive=False)
    us = timeit(lambda: sched.schedule(big), repeat=3)
    res = sched.schedule(big)
    rows.append(
        Row(f"padpsfr_schedule_{len(big)}tasks_{big[0].nv}variants", us,
            f"feasible={res.feasible};power={res.total_power:.1f}")
    )
    return rows


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small |TSS| sizes for the CI smoke job")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON benchmark artifact")
    ap.add_argument("--backends", metavar="CSV", default=None,
                    help="comma-separated placement backends for the sweep "
                         "(default: every available backend except scalar)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="run only the placement-backend sweep")
    args = ap.parse_args(argv)
    backends = (
        [b.strip() for b in args.backends.split(",") if b.strip()]
        if args.backends
        else None
    )
    enum_sweep: dict = {}
    streaming: dict = {}
    replan_summary: dict = {}
    fleet_parallel: dict = {}
    resilience_summary: dict = {}
    churn_summary: dict = {}
    if args.sweep_only:
        rows = []
    else:
        rows = bench_scheduler_scale(quick=args.quick)
        enum_rows, enum_sweep = bench_enumeration_sweep(quick=args.quick)
        rows.extend(enum_rows)
        stream_rows, streaming = bench_streaming_deep(quick=args.quick)
        rows.extend(stream_rows)
        replan_rows, replan_summary = bench_replan(quick=args.quick)
        rows.extend(replan_rows)
        fleet_rows, fleet_parallel = bench_fleet_parallel(
            quick=args.quick, backends=backends
        )
        rows.extend(fleet_rows)
        res_rows, resilience_summary = bench_resilience(quick=args.quick)
        rows.extend(res_rows)
        churn_rows, churn_summary = bench_churn(quick=args.quick)
        rows.extend(churn_rows)
    sweep_rows, sweep = bench_backend_sweep(quick=args.quick, backends=backends)
    rows.extend(sweep_rows)
    for row in rows:
        print(row.csv())
    if args.json:
        payload = [
            {"name": r.name, "us": r.us, "derived": r.derived} for r in rows
        ]
        with open(args.json, "w") as fh:
            json.dump(
                {
                    "benchmark": "scheduler_scale",
                    "rows": payload,
                    "backend_sweep": sweep,
                    "enumeration_sweep": enum_sweep,
                    "streaming": streaming,
                    "replan": replan_summary,
                    "fleet_parallel": fleet_parallel,
                    "resilience": resilience_summary,
                    "churn": churn_summary,
                },
                fh,
                indent=2,
            )
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
