"""Kernel micro-benchmarks.

The container has no TPU, so Pallas timings here are *functional*
(interpret mode).  What IS meaningful on CPU: the XLA reference paths'
wall time (used by the serving/training examples) and the HLO-level
arithmetic-intensity each kernel achieves, derived from its shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models.layers import chunked_attention

from .util import Row, timeit

__all__ = ["bench_kernels"]


def _ai_attention(B, S, T, H, K, hd) -> float:
    flops = 2 * 2 * B * H * S * T * hd
    bytes_ = 2 * (B * S * H * hd + 2 * B * T * K * hd + B * S * H * hd)
    return flops / bytes_


def bench_kernels() -> list[Row]:
    key = jax.random.PRNGKey(0)
    rows = []

    # attention: XLA chunked path (the dry-run fallback)
    B, S, H, K, hd = 2, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd), jnp.bfloat16)
    fn = jax.jit(
        lambda q, k, v: chunked_attention(q, k, v, causal=True, kv_chunk=256)
    )
    us = timeit(lambda: jax.block_until_ready(fn(q, k, v)))
    rows.append(
        Row(
            "attn_xla_chunked_b2s1024h8kv2", us,
            f"arith_intensity={_ai_attention(B, S, S, H, K, hd):.0f}flop/B",
        )
    )

    # SSD chunked scan (jnp path)
    B, S, nh, hp, ng, ds = 2, 1024, 8, 64, 1, 64
    ks = [jax.random.fold_in(key, 10 + i) for i in range(6)]
    x = jax.random.normal(ks[0], (B, S, nh, hp), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, ng, ds), jnp.bfloat16) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, ng, ds), jnp.bfloat16) * 0.3
    D = jax.random.normal(ks[5], (nh,))
    fn = jax.jit(lambda *a: ref.ssd_chunked_ref(*a, chunk=128))
    us = timeit(lambda: jax.block_until_ready(fn(x, dt, A, Bm, Cm, D)))
    intra_flops = 2 * B * nh * S * 128 * (ds + hp)
    rows.append(
        Row("ssd_chunked_b2s1024nh8", us,
            f"intra_chunk_flops={intra_flops:.3g};chunk=128")
    )

    # RG-LRU associative scan (jnp path)
    B, S, W = 2, 1024, 512
    x = jax.random.normal(ks[0], (B, S, W))
    r = jax.random.normal(ks[1], (B, S, W))
    i = jax.random.normal(ks[2], (B, S, W))
    lam = jax.random.normal(ks[3], (W,))
    fn = jax.jit(lambda *a: ref.rglru_ref(*a))
    us = timeit(lambda: jax.block_until_ready(fn(x, r, i, lam)))
    rows.append(Row("rglru_assoc_scan_b2s1024w512", us, "log-depth scan"))

    # Pallas kernels, interpret mode: correctness-path cost only
    from repro.kernels.flash_attention import flash_attention_pallas

    q32 = q.astype(jnp.float32)[:1, :256]
    k32 = k.astype(jnp.float32)[:1, :256]
    v32 = v.astype(jnp.float32)[:1, :256]
    us = timeit(
        lambda: jax.block_until_ready(
            flash_attention_pallas(q32, k32, v32, causal=True, block_q=128,
                                   block_kv=128, interpret=True)
        ),
        repeat=2,
    )
    rows.append(
        Row("flash_attention_pallas_interpret_b1s256", us,
            "functional only (no TPU in container)")
    )
    return rows
