"""Benchmark trajectory: diff two or more ``BENCH_*.json`` artifacts.

CI's bench-smoke job uploads ``BENCH_scheduler_scale.json`` per run; this
tool turns a handful of those artifacts (downloaded from successive runs,
oldest first) into a throughput-trend table:

    PYTHONPATH=src python -m benchmarks.trend_report \
        run1/BENCH_scheduler_scale.json run2/BENCH_scheduler_scale.json

Per benchmark row it prints the us-per-call in every file and the percent
change from the first to the last (negative = got faster); the placement
backend sweep additionally gets a rows/s trend per (backend, block size).
``--json`` writes the same diff machine-readably for dashboards.
"""

from __future__ import annotations

import argparse
import json
import sys


def _load(path: str) -> dict:
    with open(path) as fh:
        data = json.load(fh)
    if "rows" not in data:
        raise ValueError(f"{path}: not a BENCH artifact (no 'rows' key)")
    return data


def _row_us(data: dict) -> dict[str, float]:
    return {r["name"]: float(r["us"]) for r in data["rows"]}


def _delta_pct(first: float, last: float) -> float:
    return (last - first) / first * 100.0


def trend(datas: list[dict], labels: list[str]) -> dict:
    """Build the trend structure: per-row us series + backend rows/s series."""
    per_file = [_row_us(d) for d in datas]
    names: list[str] = []
    for us in per_file:  # first-seen order, stable across files
        for name in us:
            if name not in names:
                names.append(name)
    rows = {}
    for name in names:
        series = [us.get(name) for us in per_file]
        present = [v for v in series if v is not None]
        rows[name] = {
            "us": series,
            "delta_pct": _delta_pct(present[0], present[-1])
            if len(present) >= 2
            else None,
        }
    sweep_series: dict[str, dict[str, list[float | None]]] = {}
    for d in datas:
        sweep = d.get("backend_sweep") or {}
        for backend, by_size in (sweep.get("rows_per_s") or {}).items():
            for size, rps in by_size.items():
                sweep_series.setdefault(backend, {}).setdefault(size, [])
    for d in datas:
        sweep = d.get("backend_sweep") or {}
        rps_map = sweep.get("rows_per_s") or {}
        for backend, by_size in sweep_series.items():
            for size in by_size:
                by_size[size].append((rps_map.get(backend) or {}).get(size))
    crossovers = [
        (d.get("backend_sweep") or {}).get("numpy_jax_crossover_rows")
        for d in datas
    ]
    # Cold-vs-warm replan rows arrived with the service layer; artifacts
    # from older runs simply don't have them — record None, never raise.
    replan = {
        key: [(d.get("replan") or {}).get(key) for d in datas]
        for key in ("cold_us", "warm_us", "speedup")
    }
    replan["missing_files"] = [
        lb for lb, d in zip(labels, datas, strict=True) if not d.get("replan")
    ]
    # Same deal for fleet-parallel batching: the section only exists in
    # artifacts recorded after schedule_many landed — older files get None
    # cells and a note, never an exception.
    fp_speedup: dict[str, list[float | None]] = {}
    for d in datas:
        for key in ((d.get("fleet_parallel") or {}).get("points") or {}):
            fp_speedup.setdefault(key, [])
    for d in datas:
        pts = (d.get("fleet_parallel") or {}).get("points") or {}
        for key, series in fp_speedup.items():
            p = pts.get(key)
            series.append(float(p["speedup"]) if p else None)
    fleet_parallel = {
        "speedup": fp_speedup,
        "missing_files": [
            lb for lb, d in zip(labels, datas, strict=True) if not d.get("fleet_parallel")
        ],
    }
    # And for k-fault tolerance: the resilience section only exists in
    # artifacts recorded after the resilience mode landed — older files
    # get None cells and a render-time note, never an exception.
    res_premium: dict[str, list[float | None]] = {}
    res_power: dict[str, list[float | None]] = {}
    for d in datas:
        for key in ((d.get("resilience") or {}).get("points") or {}):
            res_premium.setdefault(key, [])
            res_power.setdefault(key, [])
    for d in datas:
        pts = (d.get("resilience") or {}).get("points") or {}
        for key in res_premium:
            p = pts.get(key)
            res_premium[key].append(
                float(p["premium_pct"])
                if p and p.get("premium_pct") is not None
                else None
            )
            res_power[key].append(
                float(p["power"]) if p and p.get("power") is not None else None
            )
    resilience = {
        "premium_pct": res_premium,
        "power": res_power,
        "missing_files": [
            lb for lb, d in zip(labels, datas, strict=True) if not d.get("resilience")
        ],
    }
    # Service churn (warm exit/failure + trace): the section only exists
    # in artifacts recorded after the warm-removal paths landed — older
    # files get None cells and a render-time note, never an exception.
    churn_speedup: dict[str, list[float | None]] = {
        leg: [] for leg in ("exit", "failure", "trace")
    }
    churn_hit_rate: list[float | None] = []
    for d in datas:
        c = d.get("churn") or {}
        for leg, series in churn_speedup.items():
            sp = (c.get(leg) or {}).get("speedup")
            series.append(float(sp) if sp is not None else None)
        hr = (c.get("trace") or {}).get("warm_hit_rate")
        churn_hit_rate.append(float(hr) if hr is not None else None)
    churn = {
        "speedup": churn_speedup,
        "warm_hit_rate": churn_hit_rate,
        "missing_files": [
            lb for lb, d in zip(labels, datas, strict=True) if not d.get("churn")
        ],
    }
    return {
        "files": labels,
        "rows": rows,
        "backend_rows_per_s": sweep_series,
        "numpy_jax_crossover_rows": crossovers,
        "replan": replan,
        "fleet_parallel": fleet_parallel,
        "resilience": resilience,
        "churn": churn,
    }


def _fmt(v: float | None, unit: str = "") -> str:
    if v is None:
        return "-"
    return f"{v:,.1f}{unit}"


def render(t: dict) -> str:
    out = []
    labels = t["files"]
    width = max([len(n) for n in t["rows"]] + [24])
    header = f"{'benchmark':<{width}} " + " ".join(f"{lb:>14}" for lb in labels)
    out.append(header + f" {'Δ%':>8}")
    out.append("-" * len(header + "         "))
    for name, row in t["rows"].items():
        cells = " ".join(f"{_fmt(v):>14}" for v in row["us"])
        d = row["delta_pct"]
        out.append(
            f"{name:<{width}} {cells} {_fmt(d, '%') if d is not None else '-':>8}"
        )
    if t["backend_rows_per_s"]:
        out.append("")
        out.append("placement-backend throughput (rows/s):")
        for backend, by_size in sorted(t["backend_rows_per_s"].items()):
            for size, series in sorted(by_size.items(), key=lambda kv: int(kv[0])):
                cells = " ".join(f"{_fmt(v):>14}" for v in series)
                present = [v for v in series if v is not None]
                d = (
                    _fmt(_delta_pct(present[0], present[-1]), "%")
                    if len(present) >= 2
                    else "-"
                )
                out.append(
                    f"{backend + ' @ ' + size + ' rows':<{width}} {cells} {d:>8}"
                )
        xs = [x for x in t["numpy_jax_crossover_rows"] if x is not None]
        if xs:
            out.append(f"numpy<->jax crossover (rows): {t['numpy_jax_crossover_rows']}")
    replan = t.get("replan") or {}
    if any(v is not None for v in replan.get("speedup", [])):
        out.append("")
        out.append("delta replan (warm arrival vs cold schedule):")
        for key in ("cold_us", "warm_us"):
            cells = " ".join(f"{_fmt(v):>14}" for v in replan[key])
            out.append(f"{'replan ' + key:<24} {cells}")
        cells = " ".join(
            f"{_fmt(v, 'x'):>14}" if v is not None else f"{'-':>14}"
            for v in replan["speedup"]
        )
        out.append(f"{'replan speedup':<24} {cells}")
        if replan.get("missing_files"):
            out.append(
                "note: no replan rows in "
                + ", ".join(replan["missing_files"])
                + " (artifact predates the delta-replan benchmark; "
                "re-run benchmarks.scheduler_scale to record them)"
            )
    elif replan.get("missing_files"):
        out.append("")
        out.append(
            "delta replan: no artifact carries replan rows yet "
            "(all predate the delta-replan benchmark) — skipped"
        )
    fp = t.get("fleet_parallel") or {}
    if any(
        v is not None for series in fp.get("speedup", {}).values() for v in series
    ):
        out.append("")
        out.append("fleet-parallel batching (schedule_many vs solo loop, speedup):")
        for key, series in sorted(fp["speedup"].items()):
            cells = " ".join(
                f"{_fmt(v, 'x'):>14}" if v is not None else f"{'-':>14}"
                for v in series
            )
            out.append(f"{'fleet ' + key:<24} {cells}")
        if fp.get("missing_files"):
            out.append(
                "note: no fleet_parallel section in "
                + ", ".join(fp["missing_files"])
                + " (artifact predates batched scheduling; "
                "re-run benchmarks.scheduler_scale to record it)"
            )
    elif fp.get("missing_files"):
        out.append("")
        out.append(
            "fleet-parallel batching: no artifact carries fleet_parallel "
            "rows yet (all predate schedule_many) — skipped"
        )
    res = t.get("resilience") or {}
    if any(
        v is not None
        for series in res.get("premium_pct", {}).values()
        for v in series
    ):
        out.append("")
        out.append("k-fault tolerance (power premium over k=0, %):")
        for key, series in sorted(res["premium_pct"].items()):
            cells = " ".join(
                f"{_fmt(v, '%'):>14}" if v is not None else f"{'-':>14}"
                for v in series
            )
            out.append(f"{'resilience ' + key:<24} {cells}")
        if res.get("missing_files"):
            out.append(
                "note: no resilience section in "
                + ", ".join(res["missing_files"])
                + " (artifact predates the resilience benchmark; "
                "re-run benchmarks.scheduler_scale to record it)"
            )
    elif res.get("missing_files"):
        out.append("")
        out.append(
            "k-fault tolerance: no artifact carries resilience rows yet "
            "(all predate the resilience benchmark) — skipped"
        )
    ch = t.get("churn") or {}
    if any(
        v is not None for series in ch.get("speedup", {}).values() for v in series
    ):
        out.append("")
        out.append("service churn (warm removals vs cold, speedup):")
        for leg in ("exit", "failure", "trace"):
            series = ch["speedup"].get(leg) or []
            cells = " ".join(
                f"{_fmt(v, 'x'):>14}" if v is not None else f"{'-':>14}"
                for v in series
            )
            out.append(f"{'churn ' + leg:<24} {cells}")
        cells = " ".join(
            f"{_fmt(v * 100.0, '%'):>14}" if v is not None else f"{'-':>14}"
            for v in ch.get("warm_hit_rate", [])
        )
        out.append(f"{'churn warm-hit rate':<24} {cells}")
        if ch.get("missing_files"):
            out.append(
                "note: no churn section in "
                + ", ".join(ch["missing_files"])
                + " (artifact predates the churn benchmark; "
                "re-run benchmarks.scheduler_scale to record it)"
            )
    elif ch.get("missing_files"):
        out.append("")
        out.append(
            "service churn: no artifact carries churn rows yet "
            "(all predate the churn benchmark) — skipped"
        )
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="+", metavar="BENCH_JSON",
                    help="two or more BENCH_*.json artifacts, oldest first")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the diff as JSON")
    args = ap.parse_args(argv)
    if len(args.files) < 2:
        ap.error("need at least two BENCH_*.json files to diff")
    datas = [_load(p) for p in args.files]
    t = trend(datas, args.files)
    print(render(t))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(t, fh, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
