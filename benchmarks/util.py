"""Benchmark plumbing: timing + CSV rows."""

from __future__ import annotations

import time
from typing import Callable

__all__ = ["timeit", "Row", "emit"]


def timeit(fn: Callable, *, repeat: int = 5, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


class Row:
    def __init__(self, name: str, us: float, derived: str = "") -> None:
        self.name, self.us, self.derived = name, us, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def emit(rows) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
