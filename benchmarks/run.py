"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (Tables I/II, Figs 2-8), plus the
beyond-paper scheduler-scaling and kernel micro-benches and the roofline
report over the dry-run artifacts.  Output: ``name,us_per_call,derived``.
"""

from __future__ import annotations

import sys

from .kernel_bench import bench_kernels
from .paper_tables import (
    bench_example1,
    bench_example2,
    bench_example3,
    bench_fig5_trr,
    bench_fig6_workload,
    bench_fig7_avg_weight,
    bench_fig8_comparison,
)
from .roofline_report import bench_roofline_report
from .scheduler_scale import bench_scheduler_scale
from .util import emit

ALL = [
    bench_example1,
    bench_example2,
    bench_example3,
    bench_fig5_trr,
    bench_fig6_workload,
    bench_fig7_avg_weight,
    bench_fig8_comparison,
    bench_scheduler_scale,
    bench_kernels,
    bench_roofline_report,
]


def main() -> int:
    rows = []
    for fn in ALL:
        try:
            rows.extend(fn())
        except Exception as e:  # a failing bench must not hide the others
            from .util import Row

            rows.append(Row(fn.__name__, float("nan"), f"ERROR:{type(e).__name__}:{e}"))
    emit(rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
