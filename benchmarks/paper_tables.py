"""Paper tables/figures as benchmarks.

One function per published artefact; each returns CSV rows with the
reproduced statistic in `derived` and the scheduler wall time.
"""

from __future__ import annotations

from repro.configs.paper_examples import (
    example1_fleet,
    example1_tasks,
    example2_fleet,
    example2_tasks,
    example3_fleet,
    example3_tasks,
)
from repro.core import (
    PADPSFRScheduler,
    count_placeable,
    erfair_context_switches,
    place_shares,
    sweep_fleet,
)

from .util import Row, timeit

__all__ = [
    "bench_example1",
    "bench_example2",
    "bench_example3",
    "bench_fig5_trr",
    "bench_fig6_workload",
    "bench_fig7_avg_weight",
    "bench_fig8_comparison",
]


def bench_example1() -> list[Row]:
    """Table I + Fig 2: full schedule of Example 1."""
    tasks, fleet = example1_tasks(), example1_fleet()
    sched = PADPSFRScheduler(fleet)
    us = timeit(lambda: sched.schedule(tasks), repeat=5)
    res = sched.schedule(tasks, count_all_rejects=True)
    shares = "/".join(str(round(s)) for s in res.combo.shares)
    derived = (
        f"TSS={res.n_tss};TFS={res.n_tfs};TNFS={res.n_tnfs};"
        f"alg2_rejects={res.n_placement_rejects};rank={res.chosen_rank + 1};"
        f"shares={shares};power={res.total_power:g};"
        f"T3_split={':'.join(str(round(p)) for p in res.plan.splits[0].share_parts)}"
    )
    return [Row("example1_table1_fig2", us, derived)]


def bench_example2() -> list[Row]:
    """Fig 3: II(T3)=12 makes the Example-1 winner un-placeable."""
    tasks, fleet = example2_tasks(), example2_fleet()

    def probe():
        return place_shares([48, 36, 24, 32, 24, 24], [2, 4, 12, 4, 6, 6], fleet)

    us = timeit(probe)
    plan = probe()
    res = PADPSFRScheduler(fleet).schedule(tasks)
    derived = (
        f"paper_combo_feasible={plan.feasible};"
        f"fallback_shares={'/'.join(str(round(s)) for s in res.combo.shares)};"
        f"fallback_power={res.total_power:g}"
    )
    return [Row("example2_fig3", us, derived)]


def bench_example3() -> list[Row]:
    """Table II + Fig 4: Alveo-50 task set."""
    tasks, fleet = example3_tasks(), example3_fleet()
    sched = PADPSFRScheduler(fleet)
    us = timeit(lambda: sched.schedule(tasks), repeat=20)
    res = sched.schedule(tasks, count_all_rejects=True)
    derived = (
        f"TSS={res.n_tss};TFS={res.n_tfs};TNFS={res.n_tnfs};"
        f"accepted={res.n_tfs - res.n_placement_rejects};"
        f"shares={'/'.join(str(round(s)) for s in res.combo.shares)};"
        f"power={res.total_power:g}"
    )
    return [Row("example3_table2_fig4", us, derived)]


def _sweep_rows(metric: str, name: str) -> list[Row]:
    tasks = example1_tasks()
    base = example1_fleet()
    n_fs = [3, 4, 5, 6]
    t_cfgs = [2.0, 6.0, 10.0]

    def run():
        return sweep_fleet(tasks, base, n_fs, t_cfgs, with_placement=False)

    us = timeit(run, repeat=2)
    pts = run()
    rows = []
    for t_cfg in t_cfgs:
        vals = [
            f"{getattr(p, metric):.3g}"
            for p in pts
            if p.t_cfg == t_cfg
        ]
        rows.append(
            Row(f"{name}_tcfg{t_cfg:g}", us / len(t_cfgs),
                f"n_f={n_fs};{metric}={'/'.join(vals)}")
        )
    return rows


def bench_fig5_trr() -> list[Row]:
    """Fig 5: TRR(%) vs n_f for several t_cfg."""
    return _sweep_rows("trr_eq7", "fig5_trr")


def bench_fig6_workload() -> list[Row]:
    """Fig 6: system workload threshold (%) vs n_f."""
    return _sweep_rows("workload_threshold", "fig6_workload")


def bench_fig7_avg_weight() -> list[Row]:
    """Fig 7: average task weight threshold vs n_f."""
    return _sweep_rows("avg_weight_threshold", "fig7_avg_weight")


def bench_fig8_comparison() -> list[Row]:
    """Fig 8: TRR of PADPS-FR vs refs [9]/[10] with honest capture/store.

    Also reports the ER-fair uncontrolled context-switch count the paper
    argues against (§I / §IV-C).
    """
    tasks = example1_tasks()
    base = example1_fleet()
    rows = []
    for n_f in (4, 5, 6):
        fleet = base.with_devices(n_f)

        def ours():
            return count_placeable(tasks, fleet)

        us = timeit(ours, repeat=1, warmup=0)
        n, _tfs, ours_ok = ours()
        _, _, theirs_ok = count_placeable(
            tasks, fleet, t_capture=12.0, t_store=12.0, repay_init=False
        )
        trr_ours = 100 * (n - ours_ok) / n
        trr_theirs = 100 * (n - theirs_ok) / n
        er = erfair_context_switches(tasks, fleet, quantum=1.0)
        rows.append(
            Row(
                f"fig8_nf{n_f}", us,
                f"TRR_ours={trr_ours:.1f}%;TRR_refs9_10={trr_theirs:.1f}%;"
                f"erfair_switches={er}",
            )
        )
    return rows
