"""Repo-native developer tooling (`python -m tools.<tool>` from the repo root)."""
