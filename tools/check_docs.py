"""Docs health check: markdown links resolve, architecture snippets run.

Two stdlib-only checks, wired into CI's docs leg and tier-1
(``tests/test_docs.py``):

1. **Link check** — every relative markdown link ``[text](target)`` in the
   given files must point at an existing file or directory (anchors are
   stripped; ``http(s)``/``mailto`` targets are skipped — CI has no
   network guarantee).
2. **Snippet check** — every fenced ```` ```python ```` block in
   ``docs/architecture.md`` is executed (each in a fresh namespace) under
   the repo's ``src`` layout, so the documented API can never drift from
   the real one.
3. **Snippet lint** — the same blocks go through ``tools.repro_lint``
   (:func:`tools.repro_lint.lint_source`), so documentation can't model
   the anti-patterns the analyzer bans in ``src`` (bare-set iteration,
   float ``==``, unseeded global RNG, …).

Usage::

    PYTHONPATH=src python tools/check_docs.py [--no-snippets] [FILES...]

With no FILES the default set is ``docs/**/*.md``, ``ROADMAP.md``,
``CHANGES.md``, and ``README.md`` when present.  Exit 0 iff everything
passes.
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images is unnecessary (same resolution rule)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```(\w*)\s*$")
_EXTERNAL = ("http://", "https://", "mailto:")


def default_files() -> list[str]:
    files = sorted(glob.glob(os.path.join(_ROOT, "docs", "**", "*.md"),
                             recursive=True))
    for name in ("ROADMAP.md", "CHANGES.md", "README.md"):
        path = os.path.join(_ROOT, name)
        if os.path.exists(path):
            files.append(path)
    return files


def check_links(path: str) -> list[str]:
    """Return a list of human-readable problems for one markdown file."""
    problems = []
    with open(path) as fh:
        text = fh.read()
    # ignore link-looking text inside fenced code blocks
    lines, in_fence = [], False
    for line in text.splitlines():
        if _FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            lines.append(line)
    for target in _LINK_RE.findall("\n".join(lines)):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            problems.append(
                f"{os.path.relpath(path, _ROOT)}: broken link -> {target}"
            )
    return problems


def python_snippets(path: str) -> list[tuple[int, str]]:
    """Extract ``(start_line, source)`` for every ```python fence."""
    snippets, buf, start, lang = [], None, 0, None
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            m = _FENCE_RE.match(line)
            if m and buf is None:
                lang, start, buf = m.group(1), lineno + 1, []
            elif line.startswith("```") and buf is not None:
                if lang == "python":
                    snippets.append((start, "".join(buf)))
                buf = None
            elif buf is not None:
                buf.append(line)
    return snippets


def check_snippets(path: str) -> list[str]:
    problems = []
    for start, src in python_snippets(path):
        try:
            exec(compile(src, f"{path}:{start}", "exec"), {"__name__": "__snippet__"})
        except Exception as exc:  # report and keep checking the rest
            problems.append(
                f"{os.path.relpath(path, _ROOT)}:{start}: snippet failed: "
                f"{type(exc).__name__}: {exc}"
            )
    return problems


def lint_snippets(path: str) -> list[str]:
    """Run repro-lint over every python fence; doc code obeys repo rules."""
    if _ROOT not in sys.path:
        sys.path.insert(0, _ROOT)
    from tools.repro_lint import lint_source

    rel = os.path.relpath(path, _ROOT)
    problems = []
    for start, src in python_snippets(path):
        for f in lint_source(src, path=f"{rel}:{start}"):
            # snippet line numbers are fence-relative; report doc-absolute
            problems.append(
                f"{rel}:{start + f.line - 1}: snippet lint: {f.rule} {f.message}"
            )
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*", help="markdown files (default: docs set)")
    ap.add_argument("--no-snippets", action="store_true",
                    help="only check links, skip executing python fences")
    args = ap.parse_args(argv)
    files = [os.path.abspath(f) for f in args.files] or default_files()

    problems: list[str] = []
    for path in files:
        problems.extend(check_links(path))
    arch = os.path.join(_ROOT, "docs", "architecture.md")
    if not args.no_snippets and os.path.exists(arch):
        problems.extend(check_snippets(arch))
        problems.extend(lint_snippets(arch))

    n_snip = 0 if args.no_snippets else len(python_snippets(arch)) \
        if os.path.exists(arch) else 0
    if problems:
        print("\n".join(problems))
        print(f"FAIL: {len(problems)} problem(s) in {len(files)} file(s)")
        return 1
    print(f"OK: {len(files)} markdown file(s) link-checked, "
          f"{n_snip} snippet(s) executed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
