"""Command line for repro-lint.

Usage (from the repo root)::

    python -m tools.repro_lint src benchmarks tools          # human output
    python -m tools.repro_lint src --json                    # machine output
    python -m tools.repro_lint src --select P2 D4            # rule-id prefixes
    python -m tools.repro_lint --list-rules                  # print catalog

Exit status: 0 when no findings survive suppression, 1 when findings
remain, 2 on usage errors.  Suppressed findings are listed (with their
reasons) under ``--verbose`` and always included in ``--json`` output.
"""

from __future__ import annotations

import argparse
import sys

from .engine import all_rules, run_paths, to_json


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="AST-based invariant analyzer for this repository.",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the machine-readable JSON report")
    ap.add_argument("--select", nargs="+", metavar="PREFIX", default=None,
                    help="only report rules whose id starts with a prefix "
                         "(e.g. P2, D401)")
    ap.add_argument("--root", default=None,
                    help="directory paths are relative to (default: cwd)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also list suppressed findings with their reasons")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule_id, summary in sorted(all_rules().items()):
            print(f"{rule_id}  {summary}")
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (or use --list-rules)", file=sys.stderr)
        return 2

    result = run_paths(args.paths, root=args.root, select=args.select)

    if args.as_json:
        print(to_json(result))
        return 0 if result.ok else 1

    for f in result.findings:
        print(f.render())
    if args.verbose and result.suppressed:
        print()
        for f, reason in result.suppressed:
            print(f"{f.path}:{f.line}: suppressed {f.rule} — {reason}")
    n, s = len(result.findings), len(result.suppressed)
    print(
        f"repro-lint: {len(result.files)} files, {n} finding{'s' * (n != 1)}"
        + (f" ({s} suppressed)" if s else "")
    )
    return 0 if result.ok else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
