"""repro-lint: AST-based invariant analyzer for this repository.

The scheduler's core guarantees — four placement backends bit-identical to
the scalar oracle, exact power-tie determinism, survivor tables selected at
float64 before any f32 cast — are runtime-tested but easy to violate in a
way no existing test exercises.  This package rejects whole defect classes
statically, at CI time, before any test runs:

* **B1xx — backend contract** (:mod:`tools.repro_lint.rules.backend_contract`):
  every registered placement backend defines the full
  ``place_block`` / ``dispatch_block`` / ``place_blocks`` /
  ``dispatch_blocks`` / ``dispatch_blocks_raw`` surface with signatures
  structurally matching ``placement_backends/base.py``, and registry
  registrations are consistent.
* **P2xx — precision flow** (:mod:`tools.repro_lint.rules.precision`):
  float ``==``/``!=``, float32 casts flowing into threshold comparisons or
  survivor-table selection, implicit dtype narrowing in precision-critical
  modules.
* **T3xx — jax tracer hygiene** (:mod:`tools.repro_lint.rules.tracer`):
  Python control flow / host synchronisation on traced values inside
  ``jit`` / ``shard_map`` / pallas bodies, jit closures over mutable state.
* **D4xx — determinism** (:mod:`tools.repro_lint.rules.determinism`):
  iteration over bare sets, unsorted filesystem enumeration, global-state
  RNG, wall-clock reads in scheduling paths.

Run it as ``python -m tools.repro_lint <paths> [--json]``; suppress a
finding with a justified per-line comment::

    x = risky()  # repro-lint: ignore[P201]  # exact tie-break by contract

A suppression without a reason is itself a finding (``S001``).  See
``docs/architecture.md`` §"Static guarantees" for the full catalog.
"""

from __future__ import annotations

from .engine import (  # noqa: F401
    Finding,
    LintResult,
    all_rules,
    lint_source,
    run_paths,
)

__version__ = "1.0"

__all__ = [
    "Finding",
    "LintResult",
    "all_rules",
    "lint_source",
    "run_paths",
    "__version__",
]
