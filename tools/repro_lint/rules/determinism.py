"""D4xx — determinism.

All enumeration and placement engines must emit identical results across
runs, hosts, and hash seeds — determinism is a tested invariant of this
repo (power ties break on exact ``(total_power, flat_index)`` tuples).
These rules reject the usual ways ordering nondeterminism sneaks in:

* **D401** — iterating a bare ``set`` (literal, ``set()``/``frozenset()``
  call, set comprehension, set algebra, or a local name bound to one).
  Set iteration order depends on ``PYTHONHASHSEED`` for str keys; wrap in
  ``sorted(...)`` when the order can reach any output.
* **D402** — filesystem enumeration (``os.listdir`` / ``os.scandir`` /
  ``os.walk`` / ``glob.glob`` / ``iglob`` / ``Path.iterdir`` / ``.glob`` /
  ``.rglob``) not wrapped in ``sorted(...)`` at the call site: directory
  order is filesystem-dependent.
* **D403** — global-state RNG: ``np.random.<sampler>`` (the legacy global
  generator) or stdlib ``random.<sampler>`` module calls.  Use an explicit
  seeded generator (``np.random.default_rng(seed)`` /
  ``random.Random(seed)``) so call order elsewhere can't change draws.
* **D404** — wall-clock reads (``time.time`` / ``datetime.now`` / …) in
  scheduling paths (``repro/core`` or ``repro/service`` modules): plans
  must be functions of their inputs.  ``time.perf_counter`` /
  ``time.monotonic`` telemetry is exempt (not wall-clock, never ordering).
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from ..engine import Finding, ModuleContext
from . import call_name

RULES = {
    "D401": "iteration over a bare set (hash-seed-dependent order)",
    "D402": "unsorted filesystem enumeration (directory-order-dependent)",
    "D403": "global-state RNG call (np.random.* / random.*)",
    "D404": "wall-clock read in a scheduling path",
}

_SCHED_PATH_RE = re.compile(r"(/|^)(core|service)(/|$)")

# Dotted names that are definitely filesystem enumeration, plus method
# names that are Path-API enumeration on any receiver.  `walk`/`listdir`/
# `scandir` require the `os.` qualifier so e.g. `ast.walk` stays clean.
_FS_ENUM_QUALIFIED = {
    "os.listdir", "os.scandir", "os.walk", "glob.glob", "glob.iglob",
    "os.path.walk",
}
_FS_ENUM_METHODS = {"iterdir", "rglob", "glob"}

# Order-insensitive consumers: passing a set here is fine.
_ORDER_FREE_CALLS = {
    "sorted", "len", "min", "max", "sum", "any", "all", "set", "frozenset",
    "bool", "isinstance",
}

_NP_RANDOM_ALLOWED = {
    "default_rng", "Generator", "SeedSequence", "RandomState", "PCG64",
    "Philox", "MT19937", "SFC64", "BitGenerator",
}
_PY_RANDOM_SAMPLERS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle", "sample",
    "uniform", "gauss", "normalvariate", "expovariate", "betavariate",
    "triangular", "getrandbits", "seed", "vonmisesvariate", "paretovariate",
    "lognormvariate", "weibullvariate", "randbytes",
}
_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.localtime", "time.gmtime",
    "time.ctime", "datetime.now", "datetime.utcnow", "datetime.today",
    "date.today", "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}


def _is_set_expr(node: ast.AST, local_sets: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return call_name(node) in ("set", "frozenset")
    if isinstance(node, ast.Name):
        return node.id in local_sets
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitAnd, ast.BitOr, ast.BitXor, ast.Sub)
    ):
        # set algebra: tainted if either side is a set expression
        return _is_set_expr(node.left, local_sets) or _is_set_expr(
            node.right, local_sets
        )
    return False


def _local_set_names(tree: ast.AST) -> set[str]:
    """Names bound (anywhere) to an obvious set expression, minus reuses."""
    bound: set[str] = set()
    rebound_other: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            is_set = _is_set_expr(node.value, set())
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    (bound if is_set else rebound_other).add(tgt.id)
    return bound - rebound_other


def _check_d401(ctx: ModuleContext) -> Iterator[Finding]:
    local_sets = _local_set_names(ctx.tree)

    def flag(node: ast.AST, how: str) -> Finding:
        return Finding(
            "D401", ctx.path, node.lineno, node.col_offset + 1,
            f"iteration over a bare set ({how}) — order depends on the hash "
            f"seed; wrap in sorted(...)",
        )

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            if _is_set_expr(node.iter, local_sets):
                yield flag(node, "for loop")
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                               ast.DictComp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter, local_sets):
                    yield flag(node, "comprehension")
        elif isinstance(node, ast.Call):
            fname = call_name(node)
            leaf = fname.split(".")[-1] if fname else None
            if fname in _ORDER_FREE_CALLS:
                continue
            if leaf in ("list", "tuple", "enumerate", "iter", "reversed",
                        "join") or fname in ("map", "filter"):
                for arg in node.args:
                    if _is_set_expr(arg, local_sets):
                        yield flag(node, f"{leaf}() materialisation")
                        break
        elif isinstance(node, ast.Starred):
            if _is_set_expr(node.value, local_sets):
                yield flag(node, "star-unpack")


def _check_d402(ctx: ModuleContext) -> Iterator[Finding]:
    sorted_args: set[int] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and call_name(node) == "sorted":
            for arg in node.args:
                for sub in ast.walk(arg):
                    sorted_args.add(id(sub))
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = call_name(node)
        leaf = fname.split(".")[-1] if fname else (
            node.func.attr if isinstance(node.func, ast.Attribute) else None
        )
        hit = (fname in _FS_ENUM_QUALIFIED) or (
            leaf in _FS_ENUM_METHODS
            and isinstance(node.func, ast.Attribute)
            and fname != "glob.glob"  # already covered; avoid double report
        ) or (leaf in ("glob", "iglob") and fname in ("glob.glob", "glob.iglob"))
        if hit and id(node) not in sorted_args:
            yield Finding(
                "D402", ctx.path, node.lineno, node.col_offset + 1,
                f"{leaf}() order is filesystem-dependent — wrap the call in "
                f"sorted(...) (or suppress where order provably cannot "
                f"reach an output)",
            )


def _rng_import_names(tree: ast.Module) -> set[str]:
    """Names imported *from* random/numpy.random that are global-state samplers."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module in (
            "random", "numpy.random"
        ):
            for alias in node.names:
                if alias.name not in _NP_RANDOM_ALLOWED:
                    out.add(alias.asname or alias.name)
    return out


def _check_d403(ctx: ModuleContext) -> Iterator[Finding]:
    from_imports = _rng_import_names(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = call_name(node)
        if fname is None:
            continue
        parts = fname.split(".")
        # np.random.X(...) / numpy.random.X(...)
        if len(parts) >= 3 and parts[-3] in ("np", "numpy") and parts[-2] == "random":
            if parts[-1] not in _NP_RANDOM_ALLOWED:
                yield Finding(
                    "D403", ctx.path, node.lineno, node.col_offset + 1,
                    f"global-state RNG {fname}() — use a seeded "
                    f"np.random.default_rng(seed) generator",
                )
        # random.X(...) — stdlib module calls (jax.random is key-based: fine)
        elif len(parts) == 2 and parts[0] == "random" and (
            parts[1] in _PY_RANDOM_SAMPLERS
        ):
            yield Finding(
                "D403", ctx.path, node.lineno, node.col_offset + 1,
                f"global-state RNG {fname}() — use a random.Random(seed) "
                f"instance",
            )
        elif len(parts) == 1 and parts[0] in from_imports:
            yield Finding(
                "D403", ctx.path, node.lineno, node.col_offset + 1,
                f"global-state RNG {fname}() (imported from a random module) "
                f"— use an explicit seeded generator",
            )


def _check_d404(ctx: ModuleContext) -> Iterator[Finding]:
    if not _SCHED_PATH_RE.search(ctx.path):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = call_name(node)
        if fname in _WALL_CLOCK:
            yield Finding(
                "D404", ctx.path, node.lineno, node.col_offset + 1,
                f"wall-clock read {fname}() in a scheduling path — plans "
                f"must be functions of their inputs (perf_counter/monotonic "
                f"telemetry is exempt)",
            )


def check(ctx: ModuleContext) -> Iterator[Finding]:
    yield from _check_d401(ctx)
    yield from _check_d402(ctx)
    yield from _check_d403(ctx)
    yield from _check_d404(ctx)
